//! Dense row-major f64 matrices for the BCM round-matrix analysis.
//!
//! Networks in the paper are n <= 128, so dense O(n^2) storage and O(n^3)
//! products are perfectly adequate for the *analysis* path (the protocol
//! itself never materializes matrices).

use std::ops::{Index, IndexMut};

#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    n: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(n: usize) -> Self {
        Self {
            n,
            data: vec![0.0; n * n],
        }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Self::zeros(n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    /// `self * other` (row-major ikj loop).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.n, other.n);
        let n = self.n;
        let mut out = Matrix::zeros(n);
        for i in 0..n {
            for k in 0..n {
                let a = self.data[i * n + k];
                if a == 0.0 {
                    continue;
                }
                let row_k = &other.data[k * n..(k + 1) * n];
                let row_o = &mut out.data[i * n..(i + 1) * n];
                for j in 0..n {
                    row_o[j] += a * row_k[j];
                }
            }
        }
        out
    }

    /// `x * self` for a row vector x (the load-vector evolution
    /// xi^(t) = xi^(t-1) M, paper Appendix A Eq. 7).
    pub fn apply_left(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(x.len(), self.n);
        let n = self.n;
        let mut out = vec![0.0; n];
        for i in 0..n {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            let row = &self.data[i * n..(i + 1) * n];
            for j in 0..n {
                out[j] += xi * row[j];
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let n = self.n;
        let mut out = Matrix::zeros(n);
        for i in 0..n {
            for j in 0..n {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    pub fn is_doubly_stochastic(&self, tol: f64) -> bool {
        let n = self.n;
        for i in 0..n {
            let rs: f64 = (0..n).map(|j| self[(i, j)]).sum();
            let cs: f64 = (0..n).map(|j| self[(j, i)]).sum();
            if (rs - 1.0).abs() > tol || (cs - 1.0).abs() > tol {
                return false;
            }
        }
        self.data.iter().all(|&x| x >= -tol)
    }

    pub fn is_symmetric(&self, tol: f64) -> bool {
        let n = self.n;
        for i in 0..n {
            for j in (i + 1)..n {
                if (self[(i, j)] - self[(j, i)]).abs() > tol {
                    return false;
                }
            }
        }
        true
    }

    pub fn frobenius_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }
}

impl Index<(usize, usize)> for Matrix {
    type Output = f64;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        &self.data[i * self.n + j]
    }
}

impl IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        &mut self.data[i * self.n + j]
    }
}

/// Matching matrix M^(t) (paper §2): identity except each matched pair
/// (u, v) has the 2x2 averaging block [[1/2, 1/2], [1/2, 1/2]].
pub fn matching_matrix(n: usize, pairs: &[(u32, u32)]) -> Matrix {
    let mut m = Matrix::identity(n);
    let mut matched = vec![false; n];
    for &(u, v) in pairs {
        let (u, v) = (u as usize, v as usize);
        assert!(u != v && u < n && v < n, "bad pair ({u},{v})");
        assert!(!matched[u] && !matched[v], "vertex reused in matching");
        matched[u] = true;
        matched[v] = true;
        m[(u, u)] = 0.5;
        m[(v, v)] = 0.5;
        m[(u, v)] = 0.5;
        m[(v, u)] = 0.5;
    }
    m
}

/// Round matrix M = prod_s M^(s) (paper §2.1).
pub fn round_matrix(n: usize, matchings: &[Vec<(u32, u32)>]) -> Matrix {
    let mut m = Matrix::identity(n);
    for pairs in matchings {
        // x^(t) = x^(t-1) M^(t): accumulate on the right.
        m = m.matmul(&matching_matrix(n, pairs));
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn identity_matmul() {
        let i = Matrix::identity(4);
        let m = matching_matrix(4, &[(0, 2)]);
        assert_eq!(i.matmul(&m), m);
        assert_eq!(m.matmul(&i), m);
    }

    #[test]
    fn matching_matrix_structure() {
        let m = matching_matrix(3, &[(0, 1)]);
        assert_eq!(m[(0, 0)], 0.5);
        assert_eq!(m[(0, 1)], 0.5);
        assert_eq!(m[(1, 0)], 0.5);
        assert_eq!(m[(2, 2)], 1.0);
        assert_eq!(m[(2, 0)], 0.0);
        assert!(m.is_symmetric(0.0));
        assert!(m.is_doubly_stochastic(1e-12));
    }

    #[test]
    #[should_panic(expected = "vertex reused")]
    fn matching_matrix_rejects_overlap() {
        matching_matrix(4, &[(0, 1), (1, 2)]);
    }

    #[test]
    fn apply_left_averages_pair() {
        let m = matching_matrix(4, &[(1, 3)]);
        let x = vec![1.0, 10.0, 2.0, 0.0];
        let y = m.apply_left(&x);
        assert_eq!(y, vec![1.0, 5.0, 2.0, 5.0]);
    }

    #[test]
    fn round_matrix_is_doubly_stochastic() {
        let m = round_matrix(4, &[vec![(0, 1), (2, 3)], vec![(1, 2)], vec![(0, 3)]]);
        assert!(m.is_doubly_stochastic(1e-12));
        // products of symmetric matrices need not be symmetric
    }

    #[test]
    fn round_matrix_order_matters() {
        let a = round_matrix(3, &[vec![(0, 1)], vec![(1, 2)]]);
        let b = round_matrix(3, &[vec![(1, 2)], vec![(0, 1)]]);
        assert_ne!(a, b);
    }

    #[test]
    fn apply_left_equals_matmul_row() {
        let m = round_matrix(4, &[vec![(0, 1)], vec![(2, 3)], vec![(1, 2)]]);
        let x = vec![4.0, 3.0, 2.0, 1.0];
        let y = m.apply_left(&x);
        // compare against explicit row-vector multiply
        let mut want = vec![0.0; 4];
        for j in 0..4 {
            for i in 0..4 {
                want[j] += x[i] * m[(i, j)];
            }
        }
        for (a, b) in y.iter().zip(&want) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn transpose_involution() {
        let m = round_matrix(4, &[vec![(0, 1)], vec![(1, 2)]]);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn mass_conservation() {
        let m = round_matrix(5, &[vec![(0, 4), (1, 3)], vec![(2, 3)]]);
        let x = vec![5.0, 1.0, 7.0, 2.0, 9.0];
        let y = m.apply_left(&x);
        let sx: f64 = x.iter().sum();
        let sy: f64 = y.iter().sum();
        assert!((sx - sy).abs() < 1e-12);
    }
}
