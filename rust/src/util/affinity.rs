//! Best-effort CPU pinning for intra-host shard workers.
//!
//! The two-tier coordinator runs several shard workers as threads of one
//! `cluster-worker` process; pinning each to its own core keeps the
//! per-worker caches (edge scratch, arena segments) hot and stops the
//! scheduler from stacking workers on one core while others idle.  The
//! crate is dependency-free and links no libc, so the Linux
//! implementation issues the raw `sched_setaffinity` syscall via inline
//! assembly (x86_64 and aarch64); every other platform is a documented
//! no-op.
//!
//! Pinning is purely a performance hint: results are bit-identical
//! pinned or not (the determinism contract keys randomness on values,
//! never thread placement), so every failure path — out-of-range CPU,
//! cgroup cpuset refusal, unsupported platform — returns `false` and the
//! caller simply proceeds unpinned.

/// Largest CPU index the fixed-size syscall mask can express.
const MASK_WORDS: usize = 16; // 16 x 64 = 1024 CPUs

/// Pin the calling thread to `cpu` (best effort).
///
/// Returns `true` if the kernel accepted the single-CPU mask, `false`
/// on any failure or on platforms without an implementation.  Never
/// panics and never blocks.
pub fn pin_current_thread(cpu: usize) -> bool {
    if cpu >= MASK_WORDS * 64 {
        return false;
    }
    pin_impl(cpu)
}

#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
fn pin_impl(cpu: usize) -> bool {
    let mut mask = [0u64; MASK_WORDS];
    mask[cpu / 64] = 1u64 << (cpu % 64);
    let ret: isize;
    #[cfg(target_arch = "x86_64")]
    // SAFETY: sched_setaffinity(0, len, mask) reads `len` bytes from
    // `mask`, which outlives the call; pid 0 targets only the calling
    // thread, and the syscall clobbers exactly rcx/r11 as declared.
    unsafe {
        std::arch::asm!(
            "syscall",
            inlateout("rax") 203isize => ret, // __NR_sched_setaffinity
            in("rdi") 0usize,                 // pid 0 = calling thread
            in("rsi") MASK_WORDS * 8,         // mask length in bytes
            in("rdx") mask.as_ptr(),
            lateout("rcx") _,
            lateout("r11") _,
            options(nostack),
        );
    }
    #[cfg(target_arch = "aarch64")]
    // SAFETY: as above; the aarch64 svc convention clobbers only the
    // declared registers.
    unsafe {
        std::arch::asm!(
            "svc #0",
            in("x8") 122isize,                // __NR_sched_setaffinity
            inlateout("x0") 0isize => ret,    // pid 0 = calling thread
            in("x1") MASK_WORDS * 8,          // mask length in bytes
            in("x2") mask.as_ptr(),
            options(nostack),
        );
    }
    ret == 0
}

#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
fn pin_impl(_cpu: usize) -> bool {
    false
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn out_of_range_cpu_is_refused_not_ub() {
        assert!(!pin_current_thread(usize::MAX));
        assert!(!pin_current_thread(MASK_WORDS * 64));
    }

    #[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
    #[test]
    fn some_low_cpu_accepts_a_pin() {
        // scan the low indices on a scratch thread (a cgroup cpuset may
        // exclude cpu 0, so any accepted pin in 0..64 counts) and leave
        // the test runner's own affinity untouched
        let ok = std::thread::spawn(|| (0..64).any(pin_current_thread))
            .join()
            .unwrap();
        assert!(ok, "no CPU in 0..64 accepted a pin");
    }

    #[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
    #[test]
    fn unsupported_platform_is_a_clean_noop() {
        assert!(!pin_current_thread(0));
    }
}
