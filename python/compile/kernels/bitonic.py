"""Layer-1 Pallas kernel: batched descending bitonic sort with permutation.

SortedGreedy (paper §4.1) first sorts the balls by descending weight.  The
paper uses MATLAB quicksort and discusses O(m) distribution sorts
(bucketsort / Proxmap / flashsort); on a TPU-shaped target the natural
analogue is a *sorting network*: branch-free, oblivious to the data
distribution, O(log^2 M) compare-exchange sweeps, each sweep a fully
vectorized VPU op over all (B, M) lanes.

Inputs
------
weights : f32[B, M]  unordered non-negative ball weights, zero-padded; M
                     must be a power of two (padding guarantees this).

Outputs
-------
sorted_w : f32[B, M]  weights per row in descending order (padding zeros
                      sink to the right since weights are non-negative).
perm     : i32[B, M]  original index of each sorted element, so the
                      coordinator can map bin assignments back to load ids.

The network is the standard XOR-partner bitonic sort with every comparator
direction flipped to produce a descending order.  Ties keep both elements
in place, so ``perm`` is always a valid permutation.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _bitonic_kernel(w_ref, out_w_ref, out_idx_ref, *, m: int):
    w = w_ref[...]  # [Bb, M]
    pos = jax.lax.broadcasted_iota(jnp.int32, w.shape, dimension=1)
    idx = pos

    # The (k, j) stage schedule — k = 2,4,..,M with j = k/2,..,1 inside —
    # is expressed as a single while_loop whose body is traced ONCE
    # (log2(M)^2/2 iterations at run time).  Unrolling the stages instead
    # multiplies the HLO size by the stage count and blows XLA compile
    # time up ~40x for M=512 (see EXPERIMENTS.md §Perf experiment D).
    def cond(carry):
        k, _j, _w, _idx = carry
        return k <= m

    def body(carry):
        k, j, w, idx = carry
        partner = pos ^ j
        pw = jnp.take_along_axis(w, partner, axis=1)
        pidx = jnp.take_along_axis(idx, partner, axis=1)
        # Ascending network: take_max = ((pos & k) != 0) ^ (pos > partner).
        # Flipping the block-direction term reverses every comparator,
        # yielding a descending sort.
        take_max = ((pos & k) == 0) ^ (pos > partner)
        pick_partner = jnp.where(take_max, pw > w, pw < w)
        w = jnp.where(pick_partner, pw, w)
        idx = jnp.where(pick_partner, pidx, idx)
        j_next = j // 2
        done_k = j_next < 1
        k_next = jnp.where(done_k, k * 2, k)
        j_next = jnp.where(done_k, k_next // 2, j_next)
        return k_next, j_next, w, idx

    if m >= 2:
        _, _, w, idx = jax.lax.while_loop(
            cond, body, (jnp.int32(2), jnp.int32(1), w, idx)
        )
    out_w_ref[...] = w
    out_idx_ref[...] = idx


def bitonic_sort_desc(weights, *, block_b: int | None = None):
    """Sort each row of ``weights`` in descending order.

    Returns ``(sorted_w[B, M], perm[B, M])``; M must be a power of two.
    """
    b, m = weights.shape
    if m & (m - 1) != 0 or m == 0:
        raise ValueError(f"M must be a power of two, got {m}")
    if block_b is None:
        block_b = min(b, 8)
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")

    kernel = functools.partial(_bitonic_kernel, m=m)
    grid = (b // block_b,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((block_b, m), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), weights.dtype),
            jax.ShapeDtypeStruct((b, m), jnp.int32),
        ],
        interpret=True,
    )(weights)
