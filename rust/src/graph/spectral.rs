//! Spectral analysis of the round matrix.
//!
//! The BCM convergence time depends on the spectral gap 1 − λ(M) where
//! λ(M) = max{|λ₂(M)|, |λ_n(M)|} (paper §2.1, §3).  Individual matching
//! matrices are symmetric, but their product M is generally not, so we
//! report the *contraction factor* σ₂(M): the largest singular value of M
//! restricted to the subspace orthogonal to the all-ones vector.  For
//! symmetric M, σ₂ = λ(M) exactly; in general σ₂ ≥ |λ₂| and the bound
//! τ_cont computed from σ₂ is conservative (an upper bound on rounds).
//!
//! Implementation: power iteration on A = M Mᵀ with the 1-direction
//! deflated each step, plus a full cyclic-Jacobi eigensolver for symmetric
//! matrices (used to validate the power iteration and to analyze single
//! matchings / diffusion matrices).

use super::matrix::Matrix;
use crate::util::rng::Pcg64;

/// Largest singular value of M on the subspace orthogonal to 1.
///
/// This is the per-round contraction factor of the continuous-case load
/// evolution and the quantity driving the τ_cont bound.
pub fn contraction_factor(m: &Matrix, iters: usize, seed: u64) -> f64 {
    let n = m.n();
    assert!(n >= 2);
    let mt = m.transpose();
    let mut rng = Pcg64::new(seed);
    let mut v: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    deflate_ones(&mut v);
    normalize(&mut v);
    let mut sigma2 = 0.0;
    for _ in 0..iters {
        // w = (M Mᵀ) v, computed as row-vector products:
        // v * M * Mᵀ = apply_left twice.
        let w1 = m.apply_left(&v);
        let mut w = mt.apply_left(&w1);
        deflate_ones(&mut w);
        let norm = normalize(&mut w);
        sigma2 = norm; // Rayleigh estimate of λ_max(MMᵀ|⊥1) = σ₂²
        v = w;
    }
    sigma2.max(0.0).sqrt()
}

fn deflate_ones(v: &mut [f64]) {
    let mean = v.iter().sum::<f64>() / v.len() as f64;
    for x in v.iter_mut() {
        *x -= mean;
    }
}

fn normalize(v: &mut [f64]) -> f64 {
    let norm = v.iter().map(|x| x * x).sum::<f64>().sqrt();
    if norm > 0.0 {
        for x in v.iter_mut() {
            *x /= norm;
        }
    }
    norm
}

/// All eigenvalues of a *symmetric* matrix by cyclic Jacobi rotations,
/// sorted descending.
pub fn jacobi_eigenvalues(m: &Matrix, tol: f64, max_sweeps: usize) -> Vec<f64> {
    assert!(m.is_symmetric(1e-9), "jacobi requires a symmetric matrix");
    let n = m.n();
    let mut a = m.clone();
    for _ in 0..max_sweeps {
        let mut off = 0.0;
        for i in 0..n {
            for j in (i + 1)..n {
                off += a[(i, j)] * a[(i, j)];
            }
        }
        if off.sqrt() < tol {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = a[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = a[(p, p)];
                let aqq = a[(q, q)];
                let theta = 0.5 * (aqq - app).atan2(2.0 * apq) * -1.0;
                // Standard Jacobi rotation that zeroes a[(p,q)].
                let tau = (aqq - app) / (2.0 * apq);
                let t = if tau >= 0.0 {
                    1.0 / (tau + (1.0 + tau * tau).sqrt())
                } else {
                    -1.0 / (-tau + (1.0 + tau * tau).sqrt())
                };
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = t * c;
                let _ = theta;
                for k in 0..n {
                    let akp = a[(k, p)];
                    let akq = a[(k, q)];
                    a[(k, p)] = c * akp - s * akq;
                    a[(k, q)] = s * akp + c * akq;
                }
                for k in 0..n {
                    let apk = a[(p, k)];
                    let aqk = a[(q, k)];
                    a[(p, k)] = c * apk - s * aqk;
                    a[(q, k)] = s * apk + c * aqk;
                }
            }
        }
    }
    let mut eig: Vec<f64> = (0..n).map(|i| a[(i, i)]).collect();
    eig.sort_by(|x, y| y.partial_cmp(x).unwrap());
    eig
}

/// λ(M) := max{|λ₂|, |λ_n|} for a symmetric round matrix (paper §2.1).
pub fn lambda_symmetric(m: &Matrix) -> f64 {
    let eig = jacobi_eigenvalues(m, 1e-12, 100);
    // eig[0] should be 1 (doubly stochastic); λ = max(|eig[1]|, |eig[n-1]|)
    let n = eig.len();
    eig[1].abs().max(eig[n - 1].abs())
}

/// Ergodicity check: the Markov chain with transition matrix M must have
/// contraction factor < 1 on ⊥1 (paper §2.1 requires λ(M) < 1).
pub fn is_ergodic(m: &Matrix, seed: u64) -> bool {
    contraction_factor(m, 200, seed) < 1.0 - 1e-9
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::coloring::EdgeColoring;
    use crate::graph::matrix::{matching_matrix, round_matrix};
    use crate::graph::topology::Graph;

    #[test]
    fn jacobi_diagonal() {
        let mut m = Matrix::zeros(3);
        m[(0, 0)] = 3.0;
        m[(1, 1)] = 1.0;
        m[(2, 2)] = 2.0;
        let eig = jacobi_eigenvalues(&m, 1e-12, 50);
        assert!((eig[0] - 3.0).abs() < 1e-10);
        assert!((eig[1] - 2.0).abs() < 1e-10);
        assert!((eig[2] - 1.0).abs() < 1e-10);
    }

    #[test]
    fn jacobi_known_2x2() {
        // [[2, 1], [1, 2]] -> eigenvalues 3 and 1
        let mut m = Matrix::zeros(2);
        m[(0, 0)] = 2.0;
        m[(0, 1)] = 1.0;
        m[(1, 0)] = 1.0;
        m[(1, 1)] = 2.0;
        let eig = jacobi_eigenvalues(&m, 1e-12, 50);
        assert!((eig[0] - 3.0).abs() < 1e-10, "{eig:?}");
        assert!((eig[1] - 1.0).abs() < 1e-10, "{eig:?}");
    }

    #[test]
    fn matching_matrix_eigenvalues() {
        // Single matching on (0,1) in n=2: eigenvalues {1, 0}.
        let m = matching_matrix(2, &[(0, 1)]);
        let eig = jacobi_eigenvalues(&m, 1e-12, 50);
        assert!((eig[0] - 1.0).abs() < 1e-10);
        assert!(eig[1].abs() < 1e-10);
    }

    #[test]
    fn contraction_matches_jacobi_for_symmetric() {
        // A single matching matrix is symmetric: σ₂ == λ(M).
        let m = matching_matrix(4, &[(0, 1)]);
        let sigma = contraction_factor(&m, 300, 7);
        let lambda = lambda_symmetric(&m);
        assert!(
            (sigma - lambda).abs() < 1e-6,
            "sigma={sigma} lambda={lambda}"
        );
    }

    #[test]
    fn round_matrix_of_ring_is_ergodic() {
        let g = Graph::ring(8);
        let coloring = EdgeColoring::greedy(&g);
        let m = round_matrix(g.n(), coloring.classes());
        assert!(is_ergodic(&m, 3));
        let sigma = contraction_factor(&m, 400, 3);
        assert!(sigma > 0.0 && sigma < 1.0, "sigma={sigma}");
    }

    #[test]
    fn complete_graph_contracts_fast() {
        let g = Graph::complete(8);
        let coloring = EdgeColoring::greedy(&g);
        let m = round_matrix(g.n(), coloring.classes());
        let sigma_complete = contraction_factor(&m, 400, 5);
        let g2 = Graph::ring(8);
        let c2 = EdgeColoring::greedy(&g2);
        let m2 = round_matrix(g2.n(), c2.classes());
        let sigma_ring = contraction_factor(&m2, 400, 5);
        assert!(
            sigma_complete < sigma_ring,
            "complete {sigma_complete} vs ring {sigma_ring}"
        );
    }

    #[test]
    fn disconnected_round_matrix_not_ergodic() {
        // Two disjoint pairs balanced forever never mix across components.
        let m = round_matrix(4, &[vec![(0, 1), (2, 3)]]);
        assert!(!is_ergodic(&m, 11));
    }

    #[test]
    fn contraction_in_unit_interval_random_graphs() {
        let mut rng = crate::util::rng::Pcg64::new(31);
        for n in [4, 16, 32] {
            let g = Graph::random_connected(n, &mut rng);
            let coloring = EdgeColoring::greedy(&g);
            let m = round_matrix(n, coloring.classes());
            let sigma = contraction_factor(&m, 300, 13);
            assert!(sigma < 1.0 && sigma >= 0.0, "n={n} sigma={sigma}");
        }
    }
}
