//! Sharded-coordinator scaling: sequential reference vs the cluster at a
//! ladder of shard counts crossed with a ladder of round-batch sizes
//! (the coordinator counterpart of `hotpath_parallel`).
//!
//! Every cluster run is checked bit-identical against the sequential
//! engine before its time is reported, so this bench doubles as a
//! determinism smoke test for the coordinator — including the pipelined
//! batched protocol (`--batch-rounds`), whose leader-message
//! amortization shows up in the `ldr_msgs_per_round` column.
//!
//! `cargo bench --bench cluster_sharded` runs the n=4096 scenarios;
//! `-- --smoke` (or `BCM_DLB_SMOKE=1` / `BCM_DLB_QUICK=1`) derates to
//! n=256, 1 sweep, so CI can exercise the sharded protocol in seconds.
//! `-- --batch-rounds B` pins the batch ladder to the single value B
//! (default ladder: 1 and 4 rounds per leader Ctl message).
//!
//! Smoke runs additionally enforce the perf-regression floor checked
//! into `bench_floor.toml` (section `[cluster_sharded.smoke]`): if the
//! best cluster throughput drops below `min_edges_per_s`, the bench
//! exits nonzero and CI fails.  `-- --no-floor` skips the gate (for
//! hosts known to be slower than the floor assumes); a host with fewer
//! cores than the recorded `pinned_cores` skips the throughput floor
//! automatically, with a notice.
//!
//! Every run also executes the E15 scenario (EXPERIMENTS.md §Tiered): a
//! two-tier 2x2 spawn on a torus3d, bit-verified against Sequential,
//! reporting inter-host bytes/round.  Smoke runs gate the measured cut
//! reduction — the fraction of cross-shard messages the cut-aware
//! partition kept off the wire — against `min_cut_reduction` (a
//! structural floor, enforced regardless of host size).

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::shard::resolve_shards;
use bcm_dlb::coordinator::{Cluster, TierLayout};
use bcm_dlb::experiments::scaling::{run_scaling, scaling_table};
use bcm_dlb::graph::{Graph, Topology};
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::table::f;
use std::path::Path;

/// Read `key` from `[section]` of the checked-in floor file (a tiny
/// hand-rolled parser for the toml subset the file uses: section
/// headers, `key = value`, `#` comments).
fn read_floor(path: &Path, section: &str, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut in_section = false;
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            in_section = name.trim() == section;
        } else if in_section {
            if let Some((k, v)) = line.split_once('=') {
                if k.trim() == key {
                    return v.trim().parse().ok();
                }
            }
        }
    }
    None
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || env_flag("BCM_DLB_SMOKE")
        || env_flag("BCM_DLB_QUICK");
    let batch_ladder: Vec<usize> = match args.iter().position(|a| a == "--batch-rounds") {
        Some(i) => {
            let v = args
                .get(i + 1)
                .and_then(|s| s.parse().ok())
                .expect("--batch-rounds expects an integer");
            vec![v]
        }
        None => vec![1, 4],
    };
    let shard_ladder = [1usize, 2, 4, 0]; // 0 = auto (one worker per core)
    let cores = resolve_shards(0);
    let scenarios: Vec<(&str, Topology)> = vec![
        ("ring", Topology::Ring),
        ("torus2d", Topology::Torus2d),
    ];
    let (n, loads, sweeps) = if smoke { (256, 10, 1) } else { (4096, 20, 2) };
    eprintln!(
        "cluster_sharded: {} scenarios at n={n}, {cores} cores, batch ladder {batch_ladder:?}{}",
        scenarios.len(),
        if smoke { " (smoke)" } else { "" }
    );

    let start = std::time::Instant::now();
    let mut diverged = false;
    let mut best_overall: f64 = 0.0;
    let mut best_cluster_eps: f64 = 0.0;
    for (name, topology) in scenarios {
        let report = match run_scaling(
            &topology,
            n,
            loads,
            sweeps,
            2013,
            &[],
            &shard_ladder,
            &batch_ladder,
        ) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("cluster_sharded: {name} failed: {e}");
                std::process::exit(1);
            }
        };
        let t = scaling_table(&report);
        println!("{}", t.render());
        t.write_csv(Path::new(&format!("results/cluster_sharded_{name}.csv")))
            .ok();
        if !report.all_identical() {
            eprintln!("DIVERGENCE: {name} sharded cluster != sequential");
            diverged = true;
        }
        // batching must never increase leader messages per round at a
        // fixed shard count (the amortization claim of the batched
        // protocol, also asserted unit-side)
        for pair in report.cluster_rows.windows(2) {
            if pair[0].shards == pair[1].shards
                && pair[1].batch > pair[0].batch
                && pair[1].leader_msgs_per_round > pair[0].leader_msgs_per_round
            {
                eprintln!(
                    "REGRESSION: {name} batch {} sends more leader messages/round than batch {}",
                    pair[1].batch, pair[0].batch
                );
                diverged = true;
            }
        }
        best_overall = best_overall.max(report.best_speedup());
        for row in &report.cluster_rows {
            let eps = report.edges_balanced as f64 / row.secs.max(1e-12);
            best_cluster_eps = best_cluster_eps.max(eps);
        }
    }
    // E15: two-tier inter-host traffic on a torus3d.  The egress pump
    // frames ONLY edges whose endpoints live on different hosts; the
    // rest of the cross-shard cut rides shared-memory channels.  The
    // run is verified bit-identical to Sequential like every other
    // scenario, and the measured cut reduction — the fraction of
    // cross-shard messages that stayed off the wire — is gated below.
    let (ta, tb, tc) = if smoke { (4usize, 8, 8) } else { (16usize, 16, 16) };
    let g = Graph::torus3d(ta, tb, tc);
    let tn = ta * tb * tc;
    let tiered_schedule = Schedule::from_graph(&g);
    let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
    let mut rng = Pcg64::new(2013);
    let state0 = LoadState::init_uniform_counts(
        tn,
        loads,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let mut seq_state = state0.clone();
    let seq_trace = Sequential.run(
        &mut seq_state,
        &tiered_schedule,
        algo,
        StopRule::sweeps(sweeps),
        2013,
    );
    let layout = TierLayout::new(2, 2);
    let (mut tiered, traffic) = Cluster::spawn_tiered(state0, algo, layout, g.edges());
    let mut cut_reduction = 0.0f64;
    match tiered
        .run_seeded(&tiered_schedule, sweeps, 2013)
        .and_then(|trace| tiered.shutdown().map(|fin| (trace, fin)))
    {
        Ok((trace, fin)) => {
            if trace != seq_trace || fin != seq_state {
                eprintln!("DIVERGENCE: torus3d tiered cluster != sequential");
                diverged = true;
            }
            let (bytes, inter, intra) = traffic.snapshot();
            let rounds = (sweeps * tiered_schedule.period()) as u64;
            cut_reduction = intra as f64 / (inter + intra).max(1) as f64;
            eprintln!(
                "E15 torus3d({ta}x{tb}x{tc}) {}x{} tiered: {} inter-host bytes/round \
                 ({inter} framed msgs, {intra} intra-host msgs stayed off the wire, \
                 cut reduction {})",
                layout.hosts,
                layout.shards_per_host,
                f(bytes as f64 / rounds.max(1) as f64, 0),
                f(cut_reduction, 3)
            );
        }
        Err(e) => {
            eprintln!("cluster_sharded: torus3d tiered run failed: {e}");
            diverged = true;
        }
    }

    eprintln!(
        "cluster_sharded completed in {:.1}s; best speedup {}x, best cluster {} edges/s",
        start.elapsed().as_secs_f64(),
        f(best_overall, 2),
        f(best_cluster_eps, 0)
    );
    // Perf-regression gate (smoke/CI runs only): the best cluster
    // throughput must clear the floor recorded next to the E11 baseline.
    if smoke && !args.iter().any(|a| a == "--no-floor") {
        let floor_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_floor.toml");
        // The throughput floor was pinned on a `pinned_cores`-vCPU
        // container; a smaller host cannot hold it, so skip with a
        // notice rather than fail (the structural gates below still
        // run — they do not depend on host speed).
        let host_cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        let pinned = read_floor(&floor_path, "cluster_sharded.smoke", "pinned_cores");
        let undersized = match pinned {
            Some(p) => (host_cores as f64) < p,
            None => false,
        };
        if undersized {
            eprintln!(
                "cluster_sharded: throughput floor SKIPPED — this host has {host_cores} \
                 core(s), fewer than the bench_floor.toml pinned_cores the floor was \
                 pinned on"
            );
        } else {
            match read_floor(&floor_path, "cluster_sharded.smoke", "min_edges_per_s") {
                Some(floor) if best_cluster_eps < floor => {
                    eprintln!(
                        "REGRESSION: best cluster throughput {} edges/s is below the \
                         bench_floor.toml floor of {} edges/s",
                        f(best_cluster_eps, 0),
                        f(floor, 0)
                    );
                    diverged = true;
                }
                Some(floor) => {
                    eprintln!(
                        "perf floor ok: {} edges/s >= {} edges/s floor",
                        f(best_cluster_eps, 0),
                        f(floor, 0)
                    );
                }
                None => {
                    // the floor file is checked in: a missing/unparsable
                    // value means the gate was broken, not that it should
                    // silently stop gating
                    eprintln!(
                        "REGRESSION GATE BROKEN: no parsable [cluster_sharded.smoke] \
                         min_edges_per_s in {} (use --no-floor to bypass deliberately)",
                        floor_path.display()
                    );
                    diverged = true;
                }
            }
        }
        // E15 gate: the cut reduction is a structural property of the
        // partitioner + tier classification, independent of host speed —
        // never skipped for an undersized host
        match read_floor(&floor_path, "cluster_sharded.smoke", "min_cut_reduction") {
            Some(floor) if cut_reduction < floor => {
                eprintln!(
                    "REGRESSION: tiered cut reduction {} is below the bench_floor.toml \
                     floor of {} (partitioner placing host blocks cut-oblivious, or the \
                     tier classifier framing intra-host edges)",
                    f(cut_reduction, 3),
                    f(floor, 3)
                );
                diverged = true;
            }
            Some(floor) => {
                eprintln!(
                    "cut-reduction floor ok: {} >= {} floor",
                    f(cut_reduction, 3),
                    f(floor, 3)
                );
            }
            None => {
                eprintln!(
                    "REGRESSION GATE BROKEN: no parsable [cluster_sharded.smoke] \
                     min_cut_reduction in {} (use --no-floor to bypass deliberately)",
                    floor_path.display()
                );
                diverged = true;
            }
        }
    }
    if diverged {
        std::process::exit(1);
    }
}
