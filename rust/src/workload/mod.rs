//! Realistic workload generators exercising the DLB protocol end-to-end.

pub mod particle_mesh;
pub mod service_traffic;

pub use particle_mesh::{run_driver, DlbPolicy, DriverResult, ParticleSim};
pub use service_traffic::{
    apply_ops, apply_ops_nodes, id_high_water, ops_for_round, run_dynamic_cluster,
    run_dynamic_cluster_tiered, run_dynamic_engine, sustained_stats, ChurnOp, SustainedStats,
    TrafficConfig,
};
