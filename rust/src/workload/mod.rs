//! Realistic workload generators exercising the DLB protocol end-to-end.

pub mod particle_mesh;

pub use particle_mesh::{run_driver, DlbPolicy, DriverResult, ParticleSim};
