//! The discrepancy / convergence bounds of paper §3 and Appendix A.

/// Continuous-case round bound: a BCM reaches discrepancy eps from initial
/// discrepancy K on an n-node graph within
/// `4 d / (1 − λ(M)) · log(K n / eps)` rounds (paper §3; Rabani et al.
/// Thm 1, Sauerwald & Sun Thm 2.2).
///
/// `lambda` is the round-matrix contraction factor (see
/// `graph::spectral::contraction_factor`).
pub fn tau_cont(k: f64, eps: f64, n: usize, d: usize, lambda: f64) -> f64 {
    assert!(k > 0.0 && eps > 0.0 && lambda < 1.0);
    4.0 * d as f64 / (1.0 - lambda) * ((k * n as f64) / eps).ln().max(0.0)
}

/// The discrete-case discrepancy target: `sqrt(12 log n) + 1` (paper §3,
/// S&S Thm 2.14), in units of the maximum single load l_max.
///
/// For unit tokens l_max = 1 and this is the paper's literal bound; for
/// indivisible real-valued loads, Appendix A scales the edge-error range
/// to ±l_max/2, so the guaranteed discrepancy is this value times l_max.
pub fn discrete_discrepancy_bound(n: usize, l_max: f64) -> f64 {
    assert!(n >= 2);
    ((12.0 * (n as f64).ln()).sqrt() + 1.0) * l_max
}

/// Theorem-1 tail: Pr[max_w |x_w − xi_w| >= sqrt(4 δ log n) · l_max]
/// <= 2 n^{1−δ}, returned as (deviation_bound, probability).
pub fn theorem1_tail(n: usize, delta: f64, l_max: f64) -> (f64, f64) {
    assert!(n >= 2 && delta >= 1.0);
    let dev = (4.0 * delta * (n as f64).ln()).sqrt() * l_max;
    let prob = 2.0 * (n as f64).powf(1.0 - delta);
    (dev, prob)
}

/// Lemma 5: the maximum deviation of the SortedGreedy two-bin result from
/// the continuous split is |d_max| <= l_1 / 2 where l_1 is the heaviest
/// local load.
pub fn lemma5_max_error(l1: f64) -> f64 {
    l1 / 2.0
}

/// Hoeffding-style concentration from Lemma 1 (S&S Lemma 2.12) with
/// per-edge error ranges g (here |e| <= l_max/2 per edge): probability
/// that |Z| >= delta given the sum of squared ranges.
pub fn lemma1_tail(delta: f64, sum_sq_ranges: f64) -> f64 {
    if sum_sq_ranges <= 0.0 {
        return 0.0;
    }
    (2.0 * (-delta * delta / (2.0 * sum_sq_ranges)).exp()).min(1.0)
}

/// Eq. 3/4 of §4.1: for m uniform balls on [0,1], the smallest ball is
/// below 1/m w.h.p., so the last-step discrepancy change obeys
/// ΔG_m <= W_m <= 1/m.
pub fn sorted_greedy_last_step_bound(m: usize) -> f64 {
    assert!(m >= 1);
    1.0 / m as f64
}

/// Sustained-discrepancy plateau under churn, after Berenbrink et al.
/// (arXiv 2302.12201): an averaging protocol whose schedule sweep
/// contracts the continuous discrepancy by `lambda < 1` while the
/// workload injects at most `churn_per_sweep` total imbalance per sweep
/// settles at the fixed point of `D <= lambda · D + C`, i.e.
/// `D_inf <= churn_per_sweep / (1 − lambda)`.  Indivisibility adds the
/// static discrete floor on top, so the predicted plateau is
///
/// `churn_per_sweep / (1 − lambda) + discrete_discrepancy_bound(n, l_max)`.
///
/// With zero churn this degenerates to the static discrete bound — the
/// dynamic regime strictly generalizes §3.
pub fn sustained_discrepancy_bound(
    churn_per_sweep: f64,
    lambda: f64,
    n: usize,
    l_max: f64,
) -> f64 {
    assert!(churn_per_sweep >= 0.0 && (0.0..1.0).contains(&lambda));
    churn_per_sweep / (1.0 - lambda) + discrete_discrepancy_bound(n, l_max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tau_cont_monotonic() {
        // More rounds needed for: bigger K, smaller eps, bigger n, bigger
        // d, lambda closer to 1.
        let base = tau_cont(100.0, 1.0, 16, 3, 0.5);
        assert!(tau_cont(1000.0, 1.0, 16, 3, 0.5) > base);
        assert!(tau_cont(100.0, 0.1, 16, 3, 0.5) > base);
        assert!(tau_cont(100.0, 1.0, 64, 3, 0.5) > base);
        assert!(tau_cont(100.0, 1.0, 16, 6, 0.5) > base);
        assert!(tau_cont(100.0, 1.0, 16, 3, 0.9) > base);
    }

    #[test]
    fn tau_cont_nonnegative_even_when_target_exceeds_k() {
        assert_eq!(tau_cont(1.0, 1000.0, 4, 2, 0.5), 0.0);
    }

    #[test]
    fn discrete_bound_values() {
        // n = 128: sqrt(12 ln 128) + 1 ≈ 8.63
        let b = discrete_discrepancy_bound(128, 1.0);
        assert!((b - 8.63).abs() < 0.05, "{b}");
        // scales linearly with l_max
        assert!((discrete_discrepancy_bound(128, 100.0) - 100.0 * b).abs() < 1e-9);
    }

    #[test]
    fn theorem1_tail_shrinks_with_delta() {
        let (d1, p1) = theorem1_tail(64, 1.0, 1.0);
        let (d3, p3) = theorem1_tail(64, 3.0, 1.0);
        assert!(d3 > d1);
        assert!(p3 < p1);
        assert!((p1 - 2.0).abs() < 1e-12); // δ=1 -> trivial probability 2
    }

    #[test]
    fn lemma5() {
        assert_eq!(lemma5_max_error(100.0), 50.0);
    }

    #[test]
    fn lemma1_tail_behaviour() {
        assert_eq!(lemma1_tail(1.0, 0.0), 0.0);
        let loose = lemma1_tail(1.0, 100.0);
        let tight = lemma1_tail(10.0, 1.0);
        assert!(tight < loose);
        assert!(loose <= 1.0);
    }

    #[test]
    fn last_step_bound() {
        assert_eq!(sorted_greedy_last_step_bound(100), 0.01);
    }

    #[test]
    fn sustained_bound_behaviour() {
        // zero churn degenerates to the static discrete floor
        assert_eq!(
            sustained_discrepancy_bound(0.0, 0.5, 128, 1.0),
            discrete_discrepancy_bound(128, 1.0)
        );
        // monotone in injected churn and in lambda -> 1
        let base = sustained_discrepancy_bound(10.0, 0.5, 128, 1.0);
        assert!(sustained_discrepancy_bound(20.0, 0.5, 128, 1.0) > base);
        assert!(sustained_discrepancy_bound(10.0, 0.9, 128, 1.0) > base);
        // a slack sweep (lambda -> 0) still pays one sweep of churn
        let tight = sustained_discrepancy_bound(10.0, 0.0, 128, 1.0);
        assert!((tight - 10.0 - discrete_discrepancy_bound(128, 1.0)).abs() < 1e-12);
    }
}
