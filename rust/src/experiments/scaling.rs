//! E11: engine and coordinator scaling on large topologies.
//!
//! Runs the same `(seed, schedule, state)` through the sequential
//! reference engine, the deterministic parallel engine at a ladder of
//! thread counts, and the sharded cluster coordinator at a ladder of
//! shard counts crossed with a ladder of round-batch sizes — verifying
//! bit-identical traces/states for every row and reporting wall-clock
//! speedup, throughput (edges balanced per second, the roofline axis),
//! and leader messages per round (the quantity round batching
//! amortizes).  The `scale` CLI command and the `hotpath_parallel` /
//! `cluster_sharded` benches all drive this module.

use crate::balancer::{PairAlgorithm, SortAlgo};
use crate::bcm::{Engine, Parallel, Schedule, Sequential, StopRule};
use crate::coordinator::{Cluster, WorkerAlgo};
use crate::graph::Topology;
use crate::load::{LoadState, Mobility, WeightDistribution};
use crate::util::error::Result;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};
use std::time::Instant;

/// One large-topology scenario for the parallel-engine sweeps.
#[derive(Clone, Debug)]
pub struct ScalingScenario {
    pub name: &'static str,
    pub topology: Topology,
    pub n: usize,
    pub loads_per_node: usize,
}

/// The n >= 4096 scenario set (torus / hypercube / random-regular), the
/// scale at which the acceptance criterion's >= 2x speedup is measured.
pub fn large_scenarios() -> Vec<ScalingScenario> {
    vec![
        ScalingScenario {
            name: "torus2d-4096",
            topology: Topology::Torus2d,
            n: 4096,
            loads_per_node: 20,
        },
        ScalingScenario {
            name: "torus3d-4096",
            topology: Topology::Torus3d,
            n: 4096,
            loads_per_node: 20,
        },
        ScalingScenario {
            name: "hypercube-4096",
            topology: Topology::Hypercube,
            n: 4096,
            loads_per_node: 20,
        },
        ScalingScenario {
            name: "regular8-4096",
            topology: Topology::RandomRegular { d: 8 },
            n: 4096,
            loads_per_node: 20,
        },
    ]
}

/// One parallel-engine measurement within a [`ScalingReport`].
#[derive(Clone, Debug)]
pub struct ThreadMeasurement {
    pub threads: usize,
    pub secs: f64,
    /// Sequential wall time / parallel wall time.
    pub speedup: f64,
    /// Trace AND final state bit-identical to the sequential run.
    pub identical: bool,
}

/// One sharded-cluster measurement within a [`ScalingReport`].
#[derive(Clone, Debug)]
pub struct ShardMeasurement {
    pub shards: usize,
    /// Rounds dispatched per leader Ctl message (resolved, >= 1).
    pub batch: usize,
    pub secs: f64,
    pub speedup: f64,
    pub identical: bool,
    /// Leader messages (ctl + reports) per round — the quantity round
    /// batching amortizes.
    pub leader_msgs_per_round: f64,
}

/// Result of one scenario's sequential-vs-parallel-vs-cluster comparison.
#[derive(Clone, Debug)]
pub struct ScalingReport {
    pub scenario: String,
    pub n: usize,
    pub edges: usize,
    pub colors: usize,
    pub seq_secs: f64,
    pub final_discrepancy: f64,
    /// Total edges balanced over the run (identical for every row by the
    /// determinism contract) — the numerator of the edges/s column.
    pub edges_balanced: usize,
    pub rows: Vec<ThreadMeasurement>,
    pub cluster_rows: Vec<ShardMeasurement>,
}

impl ScalingReport {
    pub fn all_identical(&self) -> bool {
        self.rows.iter().all(|r| r.identical)
            && self.cluster_rows.iter().all(|r| r.identical)
    }

    /// Best observed speedup across the thread and shard ladders.
    pub fn best_speedup(&self) -> f64 {
        self.rows
            .iter()
            .map(|r| r.speedup)
            .chain(self.cluster_rows.iter().map(|r| r.speedup))
            .fold(0.0, f64::max)
    }
}

/// Run one scenario: a sequential reference run, then one parallel run
/// per entry of `thread_counts` and one sharded-cluster run per
/// (`shard_counts` x `batch_counts`) combination (0 = auto for both
/// knobs; an empty `batch_counts` means batch 1), each checked for
/// bit-identity against the reference.  Cluster worker failures surface
/// as errors.
#[allow(clippy::too_many_arguments)]
pub fn run_scaling(
    topology: &Topology,
    n: usize,
    loads_per_node: usize,
    sweeps: usize,
    seed: u64,
    thread_counts: &[usize],
    shard_counts: &[usize],
    batch_counts: &[usize],
) -> Result<ScalingReport> {
    let mut rng = Pcg64::new(seed);
    let g = topology.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state0 = LoadState::init_uniform_counts(
        n,
        loads_per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
    let stop = StopRule::sweeps(sweeps);

    let mut seq_state = state0.clone();
    let t0 = Instant::now();
    let seq_trace = Sequential.run(&mut seq_state, &schedule, algo, stop, seed);
    let seq_secs = t0.elapsed().as_secs_f64();

    let mut rows = Vec::with_capacity(thread_counts.len());
    for &threads in thread_counts {
        let engine = Parallel::new(threads);
        let mut st = state0.clone();
        let t0 = Instant::now();
        let trace = engine.run(&mut st, &schedule, algo, stop, seed);
        let secs = t0.elapsed().as_secs_f64();
        rows.push(ThreadMeasurement {
            threads: engine.thread_count(),
            secs,
            speedup: seq_secs / secs.max(1e-12),
            identical: trace == seq_trace && st == seq_state,
        });
    }

    let batches: &[usize] = if batch_counts.is_empty() {
        &[1]
    } else {
        batch_counts
    };
    let mut cluster_rows = Vec::with_capacity(shard_counts.len() * batches.len());
    for &shards in shard_counts {
        for &batch in batches {
            // WorkerAlgo::SortedGreedy maps to the same PairAlgorithm as
            // the reference run, so the bit-identity check is meaningful.
            let mut cluster =
                Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
            cluster.set_batch_rounds(batch);
            let resolved = cluster.shards();
            let resolved_batch = cluster.batch_rounds();
            let t0 = Instant::now();
            let trace = cluster.run_seeded(&schedule, sweeps, seed)?;
            let stats = cluster.message_stats();
            let st = cluster.shutdown()?;
            let secs = t0.elapsed().as_secs_f64();
            cluster_rows.push(ShardMeasurement {
                shards: resolved,
                batch: resolved_batch,
                secs,
                speedup: seq_secs / secs.max(1e-12),
                identical: trace == seq_trace && st == seq_state,
                leader_msgs_per_round: (stats.ctl_sent + stats.reports_received) as f64
                    / stats.rounds.max(1) as f64,
            });
        }
    }

    Ok(ScalingReport {
        scenario: topology.name(),
        n,
        edges: g.num_edges(),
        colors: schedule.period(),
        seq_secs,
        final_discrepancy: seq_trace.final_discrepancy(),
        edges_balanced: seq_trace.total_edges_balanced(),
        rows,
        cluster_rows,
    })
}

/// One L/n point of a roofline sweep: the per-node load count and the
/// full scaling report measured at it.
#[derive(Clone, Debug)]
pub struct RooflinePoint {
    /// Loads per node (the L/n axis of the roofline).
    pub loads_per_node: usize,
    /// The (sequential, thread ladder, shard x batch ladder) report at
    /// this L/n.
    pub report: ScalingReport,
}

/// Run the full scaling ladder at every L/n of `loads_ladder` — the E11
/// roofline sweep, one command for the whole (workers x L/n) surface.
/// Every point is held to the usual bit-identity bar.
#[allow(clippy::too_many_arguments)]
pub fn run_roofline(
    topology: &Topology,
    n: usize,
    loads_ladder: &[usize],
    sweeps: usize,
    seed: u64,
    thread_counts: &[usize],
    shard_counts: &[usize],
    batch_counts: &[usize],
) -> Result<Vec<RooflinePoint>> {
    loads_ladder
        .iter()
        .map(|&loads_per_node| {
            Ok(RooflinePoint {
                loads_per_node,
                report: run_scaling(
                    topology,
                    n,
                    loads_per_node,
                    sweeps,
                    seed,
                    thread_counts,
                    shard_counts,
                    batch_counts,
                )?,
            })
        })
        .collect()
}

/// Render a roofline sweep as one combined table: a row per
/// engine/worker/batch configuration, an `eps@L<loads>` throughput
/// (edges/s) column per L/n point.  All points share the same ladders,
/// so rows line up across columns by construction.
pub fn roofline_table(points: &[RooflinePoint]) -> Table {
    assert!(!points.is_empty(), "roofline needs at least one L/n point");
    let first = &points[0].report;
    let mut headers: Vec<String> =
        vec!["engine".to_string(), "workers".to_string(), "batch".to_string()];
    for p in points {
        headers.push(format!("eps@L{}", p.loads_per_node));
    }
    let header_refs: Vec<&str> = headers.iter().map(|h| h.as_str()).collect();
    let mut t = Table::new(
        &format!(
            "E11 roofline: {} n={} — edges/s across workers x L/n ({} points)",
            first.scenario, first.n, points.len()
        ),
        &header_refs,
    );
    let eps = |r: &ScalingReport, secs: f64| f(r.edges_balanced as f64 / secs.max(1e-12), 0);
    let mut row = vec!["sequential".to_string(), "1".to_string(), "-".to_string()];
    for p in points {
        row.push(eps(&p.report, p.report.seq_secs));
    }
    t.row(row);
    for (i, m) in first.rows.iter().enumerate() {
        let mut row = vec!["parallel".to_string(), m.threads.to_string(), "-".to_string()];
        for p in points {
            row.push(eps(&p.report, p.report.rows[i].secs));
        }
        t.row(row);
    }
    for (i, m) in first.cluster_rows.iter().enumerate() {
        let mut row = vec![
            "cluster".to_string(),
            m.shards.to_string(),
            m.batch.to_string(),
        ];
        for p in points {
            row.push(eps(&p.report, p.report.cluster_rows[i].secs));
        }
        t.row(row);
    }
    t
}

/// Render a report in the shared table format (and for CSV export): one
/// row per engine/worker-count point, with throughput (edges/s) as the
/// roofline axis.
pub fn scaling_table(r: &ScalingReport) -> Table {
    let mut t = Table::new(
        &format!(
            "E11 scaling: {} n={} ({} edges, d={} colors, final disc {:.3})",
            r.scenario, r.n, r.edges, r.colors, r.final_discrepancy
        ),
        &[
            "engine",
            "workers",
            "batch",
            "wall_s",
            "speedup",
            "edges_per_s",
            "ldr_msgs_per_round",
            "identical",
        ],
    );
    let eps = |secs: f64| f(r.edges_balanced as f64 / secs.max(1e-12), 0);
    t.row(vec![
        "sequential".into(),
        "1".into(),
        "-".into(),
        f(r.seq_secs, 3),
        "1.00".into(),
        eps(r.seq_secs),
        "-".into(),
        "-".into(),
    ]);
    for m in &r.rows {
        t.row(vec![
            "parallel".into(),
            m.threads.to_string(),
            "-".into(),
            f(m.secs, 3),
            f(m.speedup, 2),
            eps(m.secs),
            "-".into(),
            m.identical.to_string(),
        ]);
    }
    for m in &r.cluster_rows {
        t.row(vec![
            "cluster".into(),
            m.shards.to_string(),
            m.batch.to_string(),
            f(m.secs, 3),
            f(m.speedup, 2),
            eps(m.secs),
            f(m.leader_msgs_per_round, 2),
            m.identical.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_scaling_run_is_identical_across_threads_and_shards() {
        let r =
            run_scaling(&Topology::Torus2d, 64, 10, 2, 42, &[2, 4], &[2, 4], &[1, 3]).unwrap();
        assert_eq!(r.n, 64);
        assert_eq!(r.rows.len(), 2);
        assert_eq!(r.cluster_rows.len(), 4); // shards x batches
        assert!(r.all_identical(), "a row diverged: {r:?}");
        assert!(r.final_discrepancy.is_finite());
        assert!(r.edges_balanced > 0);
        // the batch ladder amortizes leader messaging at every shard count
        for pair in r.cluster_rows.chunks(2) {
            assert_eq!(pair[0].shards, pair[1].shards);
            assert_eq!(pair[0].batch, 1);
            assert_eq!(pair[1].batch, 3);
            assert!(
                pair[1].leader_msgs_per_round < pair[0].leader_msgs_per_round,
                "batching did not reduce leader messages: {:?}",
                r.cluster_rows
            );
        }
    }

    #[test]
    fn scenario_set_covers_large_topologies() {
        let scenarios = large_scenarios();
        assert!(scenarios.len() >= 3);
        assert!(scenarios.iter().all(|s| s.n >= 4096));
        let names: Vec<&str> = scenarios.iter().map(|s| s.name).collect();
        assert!(names.contains(&"hypercube-4096"));
        assert!(names.contains(&"regular8-4096"));
    }

    #[test]
    fn roofline_sweep_combines_ln_points() {
        let points =
            run_roofline(&Topology::Ring, 16, &[4, 8], 1, 3, &[2], &[2], &[1]).unwrap();
        assert_eq!(points.len(), 2);
        assert_eq!(points[0].loads_per_node, 4);
        assert_eq!(points[1].loads_per_node, 8);
        assert!(points.iter().all(|p| p.report.all_identical()));
        let t = roofline_table(&points);
        assert_eq!(t.rows.len(), 3); // sequential + 1 thread + 1 (shard, batch)
        let s = t.render();
        assert!(s.contains("eps@L4"));
        assert!(s.contains("eps@L8"));
        assert!(s.contains("roofline"));
    }

    #[test]
    fn table_renders_engine_and_cluster_rows() {
        let r = run_scaling(&Topology::Ring, 16, 5, 1, 1, &[2], &[2], &[]).unwrap();
        assert_eq!(r.cluster_rows.len(), 1); // empty batch ladder = batch 1
        assert_eq!(r.cluster_rows[0].batch, 1);
        let s = scaling_table(&r).render();
        assert!(s.contains("speedup"));
        assert!(s.contains("edges_per_s"));
        assert!(s.contains("batch"));
        assert!(s.contains("ldr_msgs_per_round"));
        assert!(s.contains("sequential"));
        assert!(s.contains("parallel"));
        assert!(s.contains("cluster"));
    }
}
