//! Distributed BCM runtime: a leader thread orchestrating one worker
//! thread per processor, communicating over channels in the matching
//! model (one-to-one per round).

pub mod cluster;
pub mod messages;
pub mod worker;

pub use cluster::Cluster;
pub use worker::{Worker, WorkerAlgo};
