//! E9 — the end-to-end validation driver (see DESIGN.md §4).
//!
//! ```bash
//! cargo run --release --example particle_mesh_dlb
//! ```
//!
//! A PPM-style particle-mesh simulation (the paper's motivating
//! application, §1/§8): 200k particles advect through a time-dependent
//! swirl on a unit torus decomposed into 32x32 = 1024 fixed subdomains
//! spread over 32 processors.  Subdomain costs drift as particles move;
//! every 10 steps the BCM protocol rebalances the (indivisible,
//! real-valued) subdomain costs.  We compare no-DLB, Greedy-BCM and
//! SortedGreedy-BCM on total simulated makespan, and log the loss-curve
//! analogue (per-step makespan) to results/e9_makespan_curve.csv.

use bcm_dlb::bcm::Schedule;
use bcm_dlb::graph::Topology;
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::table::{f, Table};
use bcm_dlb::workload::{run_driver, DlbPolicy, ParticleSim};
use std::path::Path;

fn main() {
    let procs = 32;
    let sub_side = 32; // 1024 subdomains
    let particles = 200_000;
    let steps = 300;
    let dlb_interval = 10;
    let sweeps = 8;
    let seed = 42u64;

    let mut rng = Pcg64::new(seed);
    let g = Topology::RandomConnected.build(procs, &mut rng);
    let schedule = Schedule::from_graph(&g);
    println!(
        "E9: {procs} procs, {}x{} subdomains, {particles} particles, {steps} steps, DLB every {dlb_interval} steps\n",
        sub_side, sub_side
    );

    let mut table = Table::new(
        "E9 results",
        &["policy", "total_makespan", "efficiency", "migrations", "speedup_vs_no_dlb"],
    );
    let mut curves: Vec<(String, Vec<f64>)> = Vec::new();
    let mut base = None;
    for policy in [DlbPolicy::None, DlbPolicy::Greedy, DlbPolicy::SortedGreedy] {
        let start = std::time::Instant::now();
        let mut sim_rng = Pcg64::new(seed ^ 0xFACE);
        let mut sim = ParticleSim::new(sub_side, particles, &mut sim_rng);
        let mut prng = Pcg64::new(seed ^ 0xBEEF);
        let r = run_driver(
            policy,
            &mut sim,
            &schedule,
            procs,
            steps,
            dlb_interval,
            sweeps,
            &mut prng,
        );
        let wall = start.elapsed().as_secs_f64();
        let speedup = base.map(|b: f64| b / r.total_makespan).unwrap_or(1.0);
        if base.is_none() {
            base = Some(r.total_makespan);
        }
        println!(
            "{:<18} makespan {:>9.0}  efficiency {:.3}  migrations {:>7}  ({wall:.1}s wall)",
            policy.label(),
            r.total_makespan,
            r.efficiency(),
            r.migrations
        );
        table.row(vec![
            policy.label().into(),
            f(r.total_makespan, 0),
            f(r.efficiency(), 3),
            r.migrations.to_string(),
            format!("{}x", f(speedup, 2)),
        ]);
        curves.push((policy.label().to_string(), r.makespans));
    }
    println!("\n{}", table.render());
    table.write_csv(Path::new("results/e9_particle_mesh.csv")).ok();

    // makespan-vs-step curve (the training-loss-curve analogue)
    let mut curve = Table::new(
        "per-step makespan",
        &["step", "no_dlb", "greedy_bcm", "sorted_greedy_bcm"],
    );
    for i in 0..steps {
        curve.row(vec![
            i.to_string(),
            f(curves[0].1[i], 1),
            f(curves[1].1[i], 1),
            f(curves[2].1[i], 1),
        ]);
    }
    curve
        .write_csv(Path::new("results/e9_makespan_curve.csv"))
        .ok();
    println!("per-step curve written to results/e9_makespan_curve.csv");
}
