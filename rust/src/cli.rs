//! Minimal CLI argument parsing (clap is not vendored offline).
//!
//! Grammar: `bcm-dlb <command> [--flag value]... [--switch]...`

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(cmd) = it.next() {
            if cmd.starts_with("--") {
                return Err(format!("expected a command, got flag '{cmd}'"));
            }
            args.command = cmd.clone();
        }
        while let Some(tok) = it.next() {
            if let Some(name) = tok.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        args.flags.insert(name.to_string(), it.next().unwrap().clone());
                    }
                    _ => args.switches.push(name.to_string()),
                }
            } else {
                return Err(format!("unexpected positional argument '{tok}'"));
            }
        }
        Ok(args)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name} expects an integer, got '{v}'")),
        }
    }

    /// A float-valued flag; `None` when absent (callers that need a
    /// default overlay it themselves).
    pub fn get_f64(&self, name: &str) -> Result<Option<f64>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name} expects a number, got '{v}'")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

pub const USAGE: &str = "\
bcm-dlb — balancing indivisible real-valued loads in arbitrary networks
(Demirel & Sbalzarini 2013, three-layer Rust+JAX+Pallas reproduction)

USAGE: bcm-dlb <command> [flags]

COMMANDS
  run            run one BCM experiment
                 --config FILE | --n N --loads L --algo A --mobility M
                 --topology T --sweeps S --seed X [--device] [--cluster]
                 [--threads K]  deterministic parallel engine (0 = auto,
                                1 = sequential; identical results)
                 [--shards K]   sharded coordinator workers on the
                                --cluster path (0 = one per core;
                                identical results at any count)
                 [--batch-rounds B]  rounds per leader Ctl message on the
                                --cluster path (0 = auto, max(1, n/16384);
                                identical results at any batch size)
                 [--transport local|tcp]  cluster backend: in-process
                                channels (default) or real sockets with
                                cluster-worker processes
                 [--hosts H]    two-tier hierarchical coordinator: H
                                hosts x --shards-per-host in-process
                                shard workers, shards partitioned
                                cut-aware so cross-host traffic scales
                                with the inter-host cut (0 = flat, the
                                default; identical results at any H)
                 [--shards-per-host K]  shard workers inside each host
                                on the --hosts path (0 = one per core)
                 [--listen ADDR]  tcp leader bind address (workers dial
                                in with cluster-worker --connect ADDR)
                 [--peers A,B,...]  tcp leader dials these listening
                                workers instead (cluster-worker --listen)
                 [--checkpoint-every R]  leader keeps load-state
                                checkpoints at batch boundaries every R
                                rounds and recovers from worker loss by
                                rejoin or shard reassignment (0 = off,
                                classic fail-stop; see OPERATIONS.md)
                 [--rejoin-wait MS]  how long recovery waits for a
                                restarted worker before reassigning its
                                shard to the survivors (def. 5000; 0 =
                                reassign immediately)
                 [--workload service-traffic]  dynamic mode: churn the
                                load set between rounds (arrivals with
                                Pareto costs, departures, cost drift) and
                                report sustained discrepancy over a
                                trailing window plus cumulative migration
                                traffic (E14; results/e14_*.csv); runs
                                sweeps x period rounds
                 [--arrival-rate R]  mean arrivals/node/round (def. 1.0;
                                requires --workload)
                 [--pareto-alpha A]  arrival-cost tail index, > 1
                                (def. 2.5; requires --workload)
                 [--hotspot-every H] rounds between hotspot bursts (0 =
                                off; def. 32; requires --workload)
                 [--verify]     rerun Sequential and assert the cluster
                                trace/state are bit-identical
                 [--trace-out FILE.csv]  per-round time series (rep 0)
  cluster-worker one shard worker process of a TCP cluster; exits after
                 the leader shuts the cluster down
                 --connect HOST:PORT  dial the leader
                 --listen HOST:PORT   await the leader's dial-in
                 [--retry N]    connect attempts, 250 ms apart (def. 40)
                 [--fault-exit ROUND]  kill this process (exit 3) at the
                                start of round ROUND — simulates a crash
                                for recovery drills and tests
                 [--no-pin]     skip the best-effort per-shard core
                                pinning a two-tier host worker applies
                 the worker auto-detects its role from the leader's
                 init frame: a flat leader makes it one shard, a
                 two-tier leader (run --hosts) makes it a whole host of
                 in-process shards behind one egress socket
                 a relaunched worker rejoins a checkpointed leader's
                 recovery window automatically (OPERATIONS.md §rejoin)
  launch         print the per-host command lines of a two-tier cluster
                 --hosts A,B,C        host addresses, one worker each
                 [--shards-per-host K] in-process shards per host (def. 1)
                 [--port P]           worker listen port (def. 7411)
                 [--no-pin]           forwarded to every worker line
  serve          multi-tenant balancer service: accepts JSON job specs
                 over a socket, runs them concurrently on one shared
                 shard pool, streams per-round reports back as JSON lines
                 [--listen ADDR]    bind address (def. 127.0.0.1:7412)
                 [--max-jobs J]     concurrent job slots (def. 4)
                 [--shards K]       pool workers (0 = one per core)
                 [--max-conns C]    queued + active connections (def. 64)
  submit         send one job spec to a serve instance and stream its
                 per-round reports to stdout; exits nonzero on job error
                 --config FILE | --n N --loads L --algo A ... (run flags)
                 [--connect ADDR]   service address (def. 127.0.0.1:7412)
                 [--verify]     service reruns Sequential and asserts the
                                streamed trace is bit-identical
                 [--stats]      stream a service-side throughput snapshot
                                ({\"event\":\"stats\",...}) before done
                 [--shutdown]   ask the service to drain and exit instead
                                of submitting a job
  scale          sequential vs parallel engine vs sharded cluster
                 [--n N] [--topology T] [--loads L[,L2,...]] [--sweeps S]
                 [--threads K] [--shards K] [--batch-rounds B] [--seed X]
                 (default: n=4096 torus2d, thread ladder 2/4/auto, shard
                 ladder 2/auto, batch ladder 1/4/16; verifies trace
                 identity, reports edges/s; a multi-value --loads ladder
                 additionally emits the combined workers x L/n roofline
                 table)
  sweep          the paper's full §6 sweep (Figs. 1-3 data)
                 [--quick]
  fig1..fig5     regenerate one figure's table(s)   [--quick]
  timings        §11.3 timing table                 [--reps R]
  particle-mesh  E9 end-to-end PPM-style driver
                 [--procs P] [--steps S] [--particles N]
  spectral       round-matrix analysis + theory bounds
                 --topology T --n N [--seed X]
  validate       E8: measured rounds/discrepancy vs theory bounds
                 [--n N] [--topology T]
  artifacts      check + compile every AOT artifact through PJRT
  help           this message

FLAGS (run)
  --algo     greedy | sorted | sorted:SORT | random     (SORT: quick/merge/flash/std)
  --mobility full | partial
  --topology random | ring | path | complete | star | grid2d | torus2d |
             torus3d | hypercube | er:P | regular:D | scalefree:M
  --device   execute matchings through the PJRT artifacts
  --cluster  run on the sharded leader/worker coordinator (one worker
             per core owning a contiguous node shard; see --shards)
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(&s.iter().map(|x| x.to_string()).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn basic() {
        let a = parse(&["run", "--n", "32", "--device", "--algo", "sorted"]);
        assert_eq!(a.command, "run");
        assert_eq!(a.get("n"), Some("32"));
        assert_eq!(a.get("algo"), Some("sorted"));
        assert!(a.has("device"));
        assert!(!a.has("cluster"));
    }

    #[test]
    fn typed_getters() {
        let a = parse(&["run", "--n", "32"]);
        assert_eq!(a.get_usize("n", 8).unwrap(), 32);
        assert_eq!(a.get_usize("missing", 8).unwrap(), 8);
        let bad = parse(&["run", "--n", "abc"]);
        assert!(bad.get_usize("n", 8).is_err());
    }

    #[test]
    fn float_getter() {
        let a = parse(&["run", "--arrival-rate", "2.5"]);
        assert_eq!(a.get_f64("arrival-rate").unwrap(), Some(2.5));
        assert_eq!(a.get_f64("missing").unwrap(), None);
        let bad = parse(&["run", "--arrival-rate", "lots"]);
        assert!(bad.get_f64("arrival-rate").is_err());
    }

    #[test]
    fn trailing_switch() {
        let a = parse(&["sweep", "--quick"]);
        assert!(a.has("quick"));
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&["--oops".to_string()]).is_err());
        assert!(Args::parse(&["run".to_string(), "stray".to_string()]).is_err());
    }
}
