//! PJRT runtime: load AOT HLO-text artifacts (built once by
//! `make artifacts`) and execute the batched per-round rebalance.
//! Python never runs on this path.

pub mod client;
pub mod executor;
pub mod fallback;
pub mod manifest;

pub use client::{Executable, OutputBuffer, Runtime};
pub use executor::{solve_batch, DeviceAlgo, EdgeProblem, EdgeSolution, ExecPath};
pub use manifest::{ArtifactSpec, Manifest, TensorSpec};

/// Default artifacts directory: `$BCM_DLB_ARTIFACTS` or `./artifacts`.
pub fn default_artifacts_dir() -> std::path::PathBuf {
    std::env::var_os("BCM_DLB_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|| std::path::PathBuf::from("artifacts"))
}
