"""bitonic_sort_desc Pallas kernel vs numpy sort oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

from compile.kernels.bitonic import bitonic_sort_desc
from compile.kernels import ref


def check(w, block_b=None):
    sw, perm = bitonic_sort_desc(jnp.asarray(w), block_b=block_b)
    sw, perm = np.asarray(sw), np.asarray(perm)
    rsw, _ = ref.ref_sort_desc(w)
    np.testing.assert_allclose(sw, rsw)
    # perm is a valid permutation and explains the sorted output
    for r in range(w.shape[0]):
        assert sorted(perm[r].tolist()) == list(range(w.shape[1]))
    np.testing.assert_allclose(np.take_along_axis(w, perm, axis=1), sw)
    return sw, perm


def test_basic():
    check(np.array([[3.0, 1.0, 4.0, 1.5]], np.float32))


def test_already_sorted():
    check(np.array([[4.0, 3.0, 2.0, 1.0]], np.float32))


def test_reverse_sorted():
    check(np.array([[1.0, 2.0, 3.0, 4.0]], np.float32))


def test_all_equal_keeps_valid_permutation():
    check(np.full((2, 8), 5.0, np.float32))


def test_zero_padding_sinks_right():
    w = np.array([[0.0, 2.0, 0.0, 1.0]], np.float32)
    sw, _ = check(w)
    np.testing.assert_allclose(sw[0], [2.0, 1.0, 0.0, 0.0])


def test_single_element():
    check(np.array([[7.0]], np.float32))


def test_rejects_non_power_of_two():
    with pytest.raises(ValueError):
        bitonic_sort_desc(jnp.zeros((2, 6)))


def test_batch_rows_independent():
    rng = np.random.default_rng(0)
    w = rng.uniform(0, 1, (8, 32)).astype(np.float32)
    sw_all, _ = bitonic_sort_desc(jnp.asarray(w))
    for r in range(8):
        sw_row, _ = bitonic_sort_desc(jnp.asarray(w[r : r + 1]))
        np.testing.assert_allclose(np.asarray(sw_all)[r], np.asarray(sw_row)[0])


@settings(max_examples=30, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    logm=st.integers(0, 8),
    seed=st.integers(0, 2**31 - 1),
    dist=st.sampled_from(["uniform", "exp", "discrete"]),
)
def test_hypothesis_sorts(b, logm, seed, dist):
    m = 1 << logm
    rng = np.random.default_rng(seed)
    if dist == "uniform":
        w = rng.uniform(0, 100, (b, m))
    elif dist == "exp":
        w = rng.exponential(1.0, (b, m))
    else:
        w = rng.integers(0, 4, (b, m)).astype(float)  # many ties
    check(w.astype(np.float32), block_b=1)


def test_bfloat16_sorts():
    """DESIGN §Hardware-Adaptation: the MXU story is bf16 — the sorting
    network must be dtype-polymorphic (compare-exchange only)."""
    rng = np.random.default_rng(3)
    w = rng.uniform(0, 100, (2, 64)).astype(jnp.bfloat16)
    sw, perm = bitonic_sort_desc(jnp.asarray(w))
    sw = np.asarray(sw.astype(jnp.float32))
    assert (np.diff(sw, axis=1) <= 0).all()
    # permutation validity
    perm = np.asarray(perm)
    for r in range(2):
        assert sorted(perm[r].tolist()) == list(range(64))


def test_float64_disabled_or_works():
    """f64 requires jax_enable_x64; under default config jax silently
    downcasts — either way the kernel must not crash and must sort."""
    w = np.array([[3.0, 1.0, 2.0, 4.0]])
    sw, _ = bitonic_sort_desc(jnp.asarray(w))
    np.testing.assert_allclose(np.asarray(sw)[0], [4.0, 3.0, 2.0, 1.0])
