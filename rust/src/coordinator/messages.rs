//! Message types of the sharded distributed BCM protocol.
//!
//! The communication structure mirrors the matching model the paper
//! assumes (§1, §2) at shard granularity: per round, only the edges that
//! cross a shard boundary exchange payloads (one `Offer` from the slave
//! shard, one `Settle` back from the master), while intra-shard edges are
//! solved with no messaging at all.  The leader is pure control plane —
//! it broadcasts one `Round` per shard and collects one aggregated
//! report per shard, so leader traffic is O(shards) and worker-to-worker
//! traffic is O(cross-shard edges) per round.

use super::shard::RoundPlan;
use crate::load::Load;
use std::sync::Arc;

/// Leader -> worker control messages.
#[derive(Debug)]
pub enum Ctl {
    /// Execute round `round`.  `seed` keys the counter-based per-edge RNG
    /// streams (`Pcg64::for_edge(seed, round, edge)`), replacing the
    /// leader-drawn coin flips of the historical cluster — the source of
    /// the sharded runtime's bit-identity with `bcm::Sequential`.
    Round {
        round: usize,
        seed: u64,
        plan: Arc<RoundPlan>,
    },
    /// Report the shard's per-node weights to the leader.
    PollWeights,
    /// Terminate and return the shard's final load lists.
    Shutdown,
}

/// Worker -> worker payloads, tagged with the edge's index within the
/// round's matching (which also keys its RNG stream).
#[derive(Debug)]
pub enum ShardMsg {
    /// Slave -> master: `v`'s mobile loads (in node order) and its pinned
    /// weight sum.
    Offer {
        edge: usize,
        loads: Vec<Load>,
        pinned: f64,
    },
    /// Master -> slave: `v`'s new mobile loads.
    Settle { edge: usize, loads: Vec<Load> },
}

/// Worker -> leader reports.
#[derive(Debug)]
pub enum Report {
    /// Round finished on this shard: movement count of the edges this
    /// shard mastered plus the shard's node-weight extremes (the leader
    /// folds these into the global discrepancy) and the number of peer
    /// messages sent.
    Round {
        shard: usize,
        movements: usize,
        min_weight: f64,
        max_weight: f64,
        peer_msgs: usize,
    },
    /// Per-node weights of the shard (in response to `Ctl::PollWeights`).
    Weights { shard: usize, weights: Vec<f64> },
    /// Final load lists of the shard's nodes (in response to
    /// `Ctl::Shutdown`).
    Final { shard: usize, nodes: Vec<Vec<Load>> },
    /// Fatal protocol violation on the worker; the leader surfaces it as
    /// a `util::error` instead of wedging.
    Error { shard: usize, message: String },
}
