//! Swap refinement — an extension beyond the paper.
//!
//! After a greedy placement, a bounded hill-climbing pass tries (a) moving
//! a single ball from the heaviest to the lightest bin and (b) swapping a
//! pair of balls between them, keeping any change that reduces the
//! discrepancy.  The paper's future-work section asks how far the greedy
//! family is from optimal; this gives a cheap upper-bound improvement the
//! ablation bench quantifies.

use super::offline::Placement;

/// Refine `p` in place for up to `max_iters` improving steps.
/// Returns the number of improving steps applied.
pub fn swap_refine(weights: &[f64], p: &mut Placement, max_iters: usize) -> usize {
    let nbins = p.sums.len();
    if nbins < 2 || weights.is_empty() {
        return 0;
    }
    // bin -> ball indices
    let mut members: Vec<Vec<usize>> = vec![Vec::new(); nbins];
    for (i, &k) in p.assignment.iter().enumerate() {
        members[k].push(i);
    }
    let mut steps = 0usize;
    for _ in 0..max_iters {
        let (hi, lo) = extremes(&p.sums);
        let gap = p.sums[hi] - p.sums[lo];
        if gap <= 0.0 {
            break;
        }
        let mut best_delta = 0.0f64;
        let mut best_action: Option<(usize, Option<usize>)> = None;
        // (a) single-ball move hi -> lo: new gap contribution changes by
        // moving w: improvement if 0 < w < gap.
        for &i in &members[hi] {
            let w = weights[i];
            if w <= 0.0 || w >= gap {
                continue;
            }
            // post-move spread between these two bins
            let delta = gap - (gap - 2.0 * w).abs();
            if delta > best_delta + 1e-15 {
                best_delta = delta;
                best_action = Some((i, None));
            }
        }
        // (b) pair swap i (hi) <-> j (lo): net transfer w_i - w_j.
        for &i in &members[hi] {
            for &j in &members[lo] {
                let t = weights[i] - weights[j];
                if t <= 0.0 || t >= gap {
                    continue;
                }
                let delta = gap - (gap - 2.0 * t).abs();
                if delta > best_delta + 1e-15 {
                    best_delta = delta;
                    best_action = Some((i, Some(j)));
                }
            }
        }
        match best_action {
            None => break,
            Some((i, None)) => {
                members[hi].retain(|&x| x != i);
                members[lo].push(i);
                p.assignment[i] = lo;
                p.sums[hi] -= weights[i];
                p.sums[lo] += weights[i];
                steps += 1;
            }
            Some((i, Some(j))) => {
                members[hi].retain(|&x| x != i);
                members[lo].retain(|&x| x != j);
                members[hi].push(j);
                members[lo].push(i);
                p.assignment[i] = lo;
                p.assignment[j] = hi;
                let t = weights[i] - weights[j];
                p.sums[hi] -= t;
                p.sums[lo] += t;
                steps += 1;
            }
        }
    }
    steps
}

fn extremes(sums: &[f64]) -> (usize, usize) {
    let mut hi = 0;
    let mut lo = 0;
    for (k, &v) in sums.iter().enumerate() {
        if v > sums[hi] {
            hi = k;
        }
        if v < sums[lo] {
            lo = k;
        }
    }
    (hi, lo)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::offline::{greedy, sorted_greedy};
    use crate::balancer::sorting::SortAlgo;
    use crate::util::rng::Pcg64;

    #[test]
    fn refine_never_worsens() {
        for seed in 0..20 {
            let mut rng = Pcg64::new(seed);
            let w: Vec<f64> = (0..100).map(|_| rng.next_f64()).collect();
            let mut p = greedy(&w, 4);
            let before = p.discrepancy();
            swap_refine(&w, &mut p, 100);
            assert!(p.discrepancy() <= before + 1e-12);
        }
    }

    #[test]
    fn refine_preserves_mass_and_assignment_consistency() {
        let mut rng = Pcg64::new(3);
        let w: Vec<f64> = (0..200).map(|_| rng.uniform(0.0, 10.0)).collect();
        let mut p = greedy(&w, 8);
        swap_refine(&w, &mut p, 500);
        let mut sums = vec![0.0; 8];
        for (i, &k) in p.assignment.iter().enumerate() {
            sums[k] += w[i];
        }
        for (a, b) in sums.iter().zip(&p.sums) {
            assert!((a - b).abs() < 1e-9);
        }
        assert!((p.sums.iter().sum::<f64>() - w.iter().sum::<f64>()).abs() < 1e-9);
    }

    #[test]
    fn refine_improves_bad_greedy() {
        // adversarial: big balls last wrecks Greedy; refine recovers a lot
        let mut w: Vec<f64> = vec![0.1; 50];
        w.push(5.0);
        let mut p = greedy(&w, 2);
        let before = p.discrepancy();
        let steps = swap_refine(&w, &mut p, 200);
        assert!(steps > 0);
        assert!(p.discrepancy() < before / 2.0);
    }

    #[test]
    fn refine_on_sorted_greedy_rarely_helps_much() {
        // SortedGreedy is already near-optimal: refinement gain is small.
        let mut rng = Pcg64::new(7);
        let w: Vec<f64> = (0..500).map(|_| rng.next_f64()).collect();
        let mut p = sorted_greedy(&w, 2, SortAlgo::Quick);
        let before = p.discrepancy();
        swap_refine(&w, &mut p, 200);
        assert!(p.discrepancy() <= before);
    }

    #[test]
    fn degenerate_inputs() {
        let mut p = greedy(&[], 2);
        assert_eq!(swap_refine(&[], &mut p, 10), 0);
        let w = [1.0];
        let mut p1 = greedy(&w, 1);
        assert_eq!(swap_refine(&w, &mut p1, 10), 0);
    }
}
