//! Quickstart: balance indivisible real-valued loads on a random network.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Builds the paper's §6 setting at small scale — a random connected
//! 16-node network with 50 loads per node, weights U[0, 100) — and runs
//! the BCM protocol with both local algorithms, printing the discrepancy
//! trajectory.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{run, Schedule, StopRule};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;

fn main() {
    let n = 16;
    let loads_per_node = 50;
    let mut rng = Pcg64::new(42);

    // 1. The network: random edges drawn until connected (paper §6).
    let graph = Graph::random_connected(n, &mut rng);
    println!(
        "network: n={n}, |E|={}, max degree {}",
        graph.num_edges(),
        graph.max_degree()
    );

    // 2. The matching schedule: approximate minimum edge coloring (§5).
    let schedule = Schedule::from_graph(&graph);
    println!("schedule: d={} matchings per sweep", schedule.period());

    // 3. Initial loads: 50 per node, weights U[0, 100), all mobile.
    let state0 = LoadState::init_uniform_counts(
        n,
        loads_per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    println!(
        "loads: {} total, initial discrepancy {:.1}\n",
        state0.total_loads(),
        state0.discrepancy()
    );

    // 4. Run the BCM protocol with each local algorithm.
    for (name, algo) in [
        ("Greedy", PairAlgorithm::Greedy),
        ("SortedGreedy", PairAlgorithm::SortedGreedy(SortAlgo::Quick)),
    ] {
        let mut state = state0.clone();
        let mut run_rng = Pcg64::new(7);
        let trace = run(&mut state, &schedule, algo, StopRule::sweeps(12), &mut run_rng);
        println!("{name}:");
        for s in trace.rounds.iter().step_by(schedule.period() * 2) {
            println!("  round {:>3}  discrepancy {:>10.3}", s.round, s.discrepancy);
        }
        println!(
            "  final: {:.3} ({}x reduction), {} loads moved, {:.2} moves/edge\n",
            trace.final_discrepancy(),
            trace.discrepancy_reduction() as u64,
            trace.total_movements(),
            trace.movements_per_edge()
        );
    }
}
