//! Computable forms of the paper's §3 bounds (following Sauerwald & Sun).
//!
//! These are used by `bcm-dlb validate`, the E8 bench, and the
//! theory-bound integration tests to check that measured behaviour stays
//! inside the proved envelopes.

pub mod bounds;

pub use bounds::*;
