//! Property-based tests over the protocol's invariants.
//!
//! proptest is not vendored in this offline image, so the harness is a
//! seed-sweep: each property runs over many deterministic random cases
//! and reports the failing seed, which reproduces the case exactly.

use bcm_dlb::balancer::refine::swap_refine;
use bcm_dlb::balancer::{
    balance_pair, greedy, sorted_greedy, PairAlgorithm, SortAlgo,
};
use bcm_dlb::bcm::{run, Engine, Parallel, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::{resolve_shards, Cluster, WorkerAlgo};
use bcm_dlb::graph::{round_matrix, EdgeColoring, Graph, Topology};
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::{fallback, DeviceAlgo, EdgeProblem};
use bcm_dlb::util::rng::Pcg64;

/// Run `prop` over `cases` seeds; panic with the seed on failure.
fn forall(name: &str, cases: u64, prop: impl Fn(&mut Pcg64)) {
    for seed in 0..cases {
        let mut rng = Pcg64::new(0xFEED_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng)
        }));
        if let Err(e) = result {
            panic!("property '{name}' failed at seed {seed}: {e:?}");
        }
    }
}

fn random_dist(rng: &mut Pcg64) -> WeightDistribution {
    match rng.below(4) {
        0 => WeightDistribution::Uniform { lo: 0.0, hi: 100.0 },
        1 => WeightDistribution::Exponential { mean: 10.0 },
        2 => WeightDistribution::Normal { mean: 20.0, std: 8.0 },
        _ => WeightDistribution::Pareto { scale: 1.0, alpha: 2.5 },
    }
}

fn random_loads(rng: &mut Pcg64, max: usize, id0: u64) -> Vec<Load> {
    let dist = random_dist(rng);
    let m = rng.below(max + 1);
    (0..m)
        .map(|i| {
            let mut l = Load::new(id0 + i as u64, dist.sample(rng));
            l.mobile = rng.next_f64() < 0.8;
            l
        })
        .collect()
}

#[test]
fn prop_pair_balance_conserves_everything() {
    forall("pair conservation", 200, |rng| {
        let u = random_loads(rng, 40, 0);
        let v = random_loads(rng, 40, 1000);
        let algo = match rng.below(4) {
            0 => PairAlgorithm::Greedy,
            1 => PairAlgorithm::GreedyIncremental,
            2 => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            _ => PairAlgorithm::Random,
        };
        let out = balance_pair(&u, &v, algo, rng);
        // every mobile load accounted for exactly once
        let mut got: Vec<u64> = out.to_u.iter().chain(&out.to_v).map(|l| l.id).collect();
        got.sort_unstable();
        let mut want: Vec<u64> = u
            .iter()
            .chain(&v)
            .filter(|l| l.mobile)
            .map(|l| l.id)
            .collect();
        want.sort_unstable();
        assert_eq!(got, want);
        // mass conservation (mobile part)
        let total_in: f64 = u
            .iter()
            .chain(&v)
            .filter(|l| l.mobile)
            .map(|l| l.weight)
            .sum();
        let total_out: f64 = out.to_u.iter().chain(&out.to_v).map(|l| l.weight).sum();
        assert!((total_in - total_out).abs() < 1e-9);
        // movements never exceed the pool size
        assert!(out.movements <= got.len());
    });
}

#[test]
fn prop_sorted_beats_greedy_locally_on_average() {
    // LPT does NOT dominate arrival-order greedy on every instance (a
    // lucky arrival order can beat it), but it wins decisively on
    // average, and its local discrepancy is always <= the largest ball.
    let mut sum_sorted = 0.0;
    let mut sum_greedy = 0.0;
    forall("sorted <= greedy on average", 200, |rng| {
        let dist = random_dist(rng);
        let m = 2 + rng.below(100);
        let u: Vec<Load> = (0..m)
            .map(|i| Load::new(i as u64, dist.sample(rng)))
            .collect();
        let lmax = u.iter().map(|l| l.weight).fold(0.0, f64::max);
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(1);
        let g = balance_pair(&u, &[], PairAlgorithm::Greedy, &mut r1);
        let s = balance_pair(&u, &[], PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut r2);
        assert!(s.local_discrepancy <= lmax + 1e-9);
        // can't use captured state inside forall's Fn; recompute outside
        let _ = (g, s);
    });
    // average comparison over an explicit seed sweep
    for seed in 0..200u64 {
        let mut rng = Pcg64::new(0xFEED_0000 + seed);
        let dist = random_dist(&mut rng);
        let m = 2 + rng.below(100);
        let u: Vec<Load> = (0..m)
            .map(|i| Load::new(i as u64, dist.sample(&mut rng)))
            .collect();
        let mut r1 = Pcg64::new(1);
        let mut r2 = Pcg64::new(1);
        sum_greedy += balance_pair(&u, &[], PairAlgorithm::Greedy, &mut r1).local_discrepancy;
        sum_sorted += balance_pair(&u, &[], PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut r2)
            .local_discrepancy;
    }
    assert!(
        sum_sorted < sum_greedy / 2.0,
        "sorted {sum_sorted} not clearly below greedy {sum_greedy}"
    );
}

#[test]
fn prop_two_bin_discrepancy_bounded_by_largest_ball() {
    // Lemma 5 consequence: with equal bases, the two-bin greedy-on-sorted
    // placement ends within l_max of perfect balance.
    forall("lemma5 bound", 300, |rng| {
        let dist = random_dist(rng);
        let m = 1 + rng.below(200);
        let weights: Vec<f64> = (0..m).map(|_| dist.sample(rng)).collect();
        let lmax = weights.iter().cloned().fold(0.0, f64::max);
        let p = sorted_greedy(&weights, 2, SortAlgo::Quick);
        assert!(
            p.discrepancy() <= lmax + 1e-9,
            "disc {} > lmax {lmax}",
            p.discrepancy()
        );
    });
}

#[test]
fn prop_greedy_nbin_discrepancy_bounded_by_largest_ball() {
    // Graham-style bound: greedy keeps max-min <= l_max for any number of
    // bins (each placement goes to the current minimum).
    forall("nbin greedy bound", 200, |rng| {
        let nbins = 2 + rng.below(15);
        let m = nbins + rng.below(300);
        let dist = random_dist(rng);
        let weights: Vec<f64> = (0..m).map(|_| dist.sample(rng)).collect();
        let lmax = weights.iter().cloned().fold(0.0, f64::max);
        let p = sorted_greedy(&weights, nbins, SortAlgo::Quick);
        assert!(p.discrepancy() <= lmax + 1e-9);
        let g = greedy(&weights, nbins);
        assert!(g.discrepancy() <= lmax + 1e-9);
    });
}

#[test]
fn prop_protocol_run_invariants() {
    forall("protocol invariants", 25, |rng| {
        let n = 4 + rng.below(20);
        let g = Graph::random_connected(n, rng);
        let schedule = Schedule::from_graph(&g);
        let per_node = 1 + rng.below(30);
        let mobility = if rng.coin() { Mobility::Full } else { Mobility::Partial };
        let dist = random_dist(rng);
        let mut state = LoadState::init_uniform_counts(n, per_node, &dist, mobility, rng);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init = state.discrepancy();
        let algo = match rng.below(3) {
            0 => PairAlgorithm::Greedy,
            1 => PairAlgorithm::GreedyIncremental,
            _ => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        };
        // pinned loads' hosts before
        let pinned_before: Vec<(u64, usize)> = (0..n)
            .flat_map(|v| {
                state
                    .node(v)
                    .iter()
                    .filter(|l| !l.mobile)
                    .map(move |l| (l.id, v))
                    .collect::<Vec<_>>()
            })
            .collect();
        let trace = run(&mut state, &schedule, algo, StopRule::sweeps(5), rng);
        // conservation
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6 * mass.max(1.0));
        // no discrepancy increase overall (monotone in expectation; allow
        // the single-load quantum slack)
        let lmax = state.max_load_weight();
        assert!(trace.final_discrepancy() <= init + 2.0 * lmax + 1e-9);
        // pinned loads never moved
        for (id, host) in pinned_before {
            assert!(
                state.node(host).iter().any(|l| l.id == id),
                "pinned load {id} left node {host}"
            );
        }
        // per-round metrics are self-consistent
        for r in &trace.rounds {
            assert!(r.discrepancy >= 0.0);
            assert!(r.movements <= state.total_loads());
        }
    });
}

#[test]
fn prop_parallel_engine_bit_identical_to_sequential() {
    // The tentpole invariant: for any topology, algorithm, mobility, seed
    // and thread count, the parallel engine's trace (per-round
    // discrepancy, movements, edge counts) and final per-node state are
    // bit-identical to the sequential engine's.
    forall("parallel == sequential", 10, |rng| {
        let (topology, n) = match rng.below(5) {
            0 => (Topology::Ring, 9 + rng.below(24)),
            1 => (Topology::Torus2d, 36),
            2 => (Topology::Torus3d, 64),
            3 => (Topology::Hypercube, 32),
            _ => (Topology::RandomConnected, 5 + rng.below(30)),
        };
        let g = topology.build(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mobility = if rng.coin() { Mobility::Full } else { Mobility::Partial };
        let dist = random_dist(rng);
        let state0 =
            LoadState::init_uniform_counts(n, 1 + rng.below(25), &dist, mobility, rng);
        let algo = match rng.below(4) {
            0 => PairAlgorithm::Greedy,
            1 => PairAlgorithm::GreedyIncremental,
            2 => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            _ => PairAlgorithm::Random,
        };
        // include the plateau stop rule so early-exit decisions are also
        // compared across engines
        let stop = if rng.coin() {
            StopRule::sweeps(1 + rng.below(4))
        } else {
            StopRule {
                max_sweeps: 30,
                rel_tol: 1e-3,
            }
        };
        let seed = rng.next_u64();

        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(&mut seq_state, &schedule, algo, stop, seed);

        for threads in [1usize, 2, 3, 4, 8] {
            let mut par_state = state0.clone();
            let par_trace =
                Parallel::new(threads).run(&mut par_state, &schedule, algo, stop, seed);
            assert_eq!(
                par_trace, seq_trace,
                "trace diverged: {topology:?} n={n} algo={algo:?} threads={threads}"
            );
            assert_eq!(
                par_state, seq_state,
                "state diverged: {topology:?} n={n} algo={algo:?} threads={threads}"
            );
            assert_eq!(par_state.load_vector(), seq_state.load_vector());
        }
        // auto thread count must agree too
        let mut auto_state = state0.clone();
        let auto_trace = Parallel::auto().run(&mut auto_state, &schedule, algo, stop, seed);
        assert_eq!(auto_trace, seq_trace);
        assert_eq!(auto_state, seq_state);
    });
}

#[test]
fn prop_sharded_cluster_bit_identical_to_sequential() {
    // The coordinator extension of the tentpole invariant: for any
    // topology, mobility and seed, the sharded cluster's trace and final
    // state are bit-identical to the sequential engine's at shard counts
    // 1, 2 and one-per-core (the counter-based per-edge streams replace
    // the old leader-drawn coin flips).
    let cores = resolve_shards(0);
    forall("cluster == sequential", 6, |rng| {
        let (topology, n) = match rng.below(4) {
            0 => (Topology::Ring, 8 + rng.below(17)),
            1 => (Topology::Torus2d, 16),
            2 => (Topology::Hypercube, 16),
            _ => (Topology::RandomConnected, 5 + rng.below(20)),
        };
        let g = topology.build(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mobility = if rng.coin() { Mobility::Full } else { Mobility::Partial };
        let dist = random_dist(rng);
        let state0 = LoadState::init_uniform_counts(n, 1 + rng.below(20), &dist, mobility, rng);
        let (walgo, algo) = if rng.coin() {
            (WorkerAlgo::Greedy, PairAlgorithm::Greedy)
        } else {
            (WorkerAlgo::SortedGreedy, PairAlgorithm::SortedGreedy(SortAlgo::Quick))
        };
        let sweeps = 1 + rng.below(3);
        let seed = rng.next_u64();

        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            algo,
            StopRule::sweeps(sweeps),
            seed,
        );
        for shards in [1usize, 2, cores] {
            let mut cluster = Cluster::spawn_sharded(state0.clone(), walgo, shards);
            let trace = cluster.run_seeded(&schedule, sweeps, seed).unwrap();
            let fin = cluster.shutdown().unwrap();
            assert_eq!(
                trace, seq_trace,
                "trace diverged: {topology:?} n={n} algo={algo:?} shards={shards}"
            );
            assert_eq!(
                fin, seq_state,
                "state diverged: {topology:?} n={n} algo={algo:?} shards={shards}"
            );
        }
    });
}

#[test]
fn prop_batched_cluster_bit_identical_to_sequential() {
    // The batching extension of the cluster invariant: dispatching B
    // rounds per leader control message (with workers pipelining through
    // the post-offers / solve-local / collect-settles state machine, and
    // fast shards running rounds ahead of slow ones) must be invisible
    // in the results.  Covers B = 1 (lock-step), B = 3 (partial batches,
    // since total rounds need not divide by 3) and B = total rounds (the
    // whole run in one dispatch), each at shard counts 1, 2 and
    // one-per-core.
    let cores = resolve_shards(0);
    forall("batched cluster == sequential", 4, |rng| {
        let (topology, n) = match rng.below(3) {
            0 => (Topology::Ring, 8 + rng.below(13)),
            1 => (Topology::Torus2d, 16),
            _ => (Topology::RandomConnected, 6 + rng.below(15)),
        };
        let g = topology.build(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mobility = if rng.coin() { Mobility::Full } else { Mobility::Partial };
        let dist = random_dist(rng);
        let state0 = LoadState::init_uniform_counts(n, 1 + rng.below(15), &dist, mobility, rng);
        let sweeps = 2 + rng.below(2);
        let total_rounds = sweeps * schedule.period();
        let seed = rng.next_u64();

        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(sweeps),
            seed,
        );
        for shards in [1usize, 2, cores] {
            for batch in [1usize, 3, total_rounds] {
                let mut cluster =
                    Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
                cluster.set_batch_rounds(batch);
                let trace = cluster.run_seeded(&schedule, sweeps, seed).unwrap();
                let fin = cluster.shutdown().unwrap();
                assert_eq!(
                    trace, seq_trace,
                    "trace diverged: {topology:?} n={n} shards={shards} batch={batch}"
                );
                assert_eq!(
                    fin, seq_state,
                    "state diverged: {topology:?} n={n} shards={shards} batch={batch}"
                );
            }
        }
    });
}

#[test]
fn prop_parallel_engine_keeps_protocol_invariants() {
    // Conservation and pinning through the threaded path specifically.
    forall("parallel invariants", 15, |rng| {
        let n = 6 + rng.below(24);
        let g = Graph::random_connected(n, rng);
        let schedule = Schedule::from_graph(&g);
        let dist = random_dist(rng);
        let mut state =
            LoadState::init_uniform_counts(n, 2 + rng.below(20), &dist, Mobility::Partial, rng);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let pinned_w: Vec<f64> = (0..n).map(|v| state.pinned_weight(v)).collect();
        let threads = 2 + rng.below(6);
        Parallel::new(threads).run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(4),
            rng.next_u64(),
        );
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6 * mass.max(1.0));
        for v in 0..n {
            assert!((state.pinned_weight(v) - pinned_w[v]).abs() < 1e-9);
        }
    });
}

#[test]
fn prop_fallback_assignment_explains_sums() {
    forall("fallback consistency", 300, |rng| {
        let m = rng.below(150);
        let dist = random_dist(rng);
        let p = EdgeProblem {
            weights: (0..m).map(|_| dist.sample(rng)).collect(),
            hosts: (0..m).map(|_| rng.below(2) as u8).collect(),
            base: [rng.uniform(0.0, 100.0), rng.uniform(0.0, 100.0)],
        };
        for algo in [DeviceAlgo::Greedy, DeviceAlgo::SortedGreedy] {
            let s = fallback::solve(&p, algo);
            let mut sums = p.base;
            for (i, &a) in s.assign.iter().enumerate() {
                sums[a as usize] += p.weights[i];
            }
            assert!((sums[0] - s.sums[0]).abs() < 1e-9);
            assert!((sums[1] - s.sums[1]).abs() < 1e-9);
            let moves = s
                .assign
                .iter()
                .zip(&p.hosts)
                .filter(|(a, h)| a != h)
                .count();
            assert_eq!(moves, s.movements);
        }
    });
}

#[test]
fn prop_edge_coloring_always_valid() {
    forall("coloring validity", 60, |rng| {
        let n = 2 + rng.below(60);
        let g = Graph::random_connected(n.max(2), rng);
        let c = EdgeColoring::greedy(&g);
        c.validate(&g).unwrap();
        assert!(c.num_colors() <= 2 * g.max_degree());
        // the round matrix of any coloring is doubly stochastic
        let m = round_matrix(g.n(), c.classes());
        assert!(m.is_doubly_stochastic(1e-9));
    });
}

#[test]
fn prop_swap_refine_monotone_and_consistent() {
    forall("swap refine", 150, |rng| {
        let m = rng.below(120);
        let nbins = 1 + rng.below(8);
        let dist = random_dist(rng);
        let weights: Vec<f64> = (0..m).map(|_| dist.sample(rng)).collect();
        let mut p = greedy(&weights, nbins);
        let before = p.discrepancy();
        swap_refine(&weights, &mut p, 60);
        assert!(p.discrepancy() <= before + 1e-9);
        let mut sums = vec![0.0; nbins];
        for (i, &k) in p.assignment.iter().enumerate() {
            sums[k] += weights[i];
        }
        for (a, b) in sums.iter().zip(&p.sums) {
            assert!((a - b).abs() < 1e-6);
        }
    });
}

#[test]
fn prop_mobility_partial_keeps_pinned_weight_per_node() {
    forall("partial pinning stable", 50, |rng| {
        let n = 2 + rng.below(12);
        let g = Graph::random_connected(n, rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            n,
            2 + rng.below(20),
            &WeightDistribution::paper_section6(),
            Mobility::Partial,
            rng,
        );
        let pinned_w: Vec<f64> = (0..n).map(|v| state.pinned_weight(v)).collect();
        run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(4),
            rng,
        );
        for v in 0..n {
            assert!((state.pinned_weight(v) - pinned_w[v]).abs() < 1e-9);
        }
    });
}
