"""AOT lowering: JAX entry points -> HLO *text* artifacts + manifest.

Run once by ``make artifacts``.  Python never executes on the Rust request
path; this script is the entire compile-time bridge.

Interchange format is HLO TEXT, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the published ``xla`` 0.1.6 crate) rejects
(``proto.id() <= INT_MAX``).  The text parser reassigns ids and round-trips
cleanly — see /opt/xla-example/README.md.

Each artifact is one (entry point, shape bucket) pair.  Buckets are chosen
to cover the paper's sweeps (n in 4..128 processors, L/n in {10, 50, 100}):
a round of a BCM on n nodes has at most n/2 concurrent matchings (batch B)
and each matching rebalances at most ~2·(L/n)·mobility balls (padded to the
next power of two, axis M).  The Rust runtime picks the smallest bucket
that fits and zero-pads.

Output layout::

    artifacts/
      manifest.json                  # entry -> file, shapes, dtypes
      balance_two_bin_b64_m256.hlo.txt
      ...
"""

from __future__ import annotations

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

# (entry_name, fn, [(arg_name, shape, dtype), ...]) buckets.
F32 = "f32"
I32 = "i32"

# Shape buckets for the BCM hot path.  B = max concurrent matchings per
# round (power of two), M = padded ball count per matching.
TWO_BIN_BUCKETS = [
    (8, 64),
    (8, 256),
    (16, 256),
    (32, 256),
    (64, 64),
    (64, 256),
    (64, 512),
]
NBIN_BUCKETS = [
    # (B, M, N): offline Appendix-C experiments (Figs. 4-5).
    (8, 1024, 2),
    (8, 1024, 8),
    (8, 4096, 2),
]
CONTINUOUS_BUCKETS = [
    # (B, N): batch of load vectors x network size.
    (8, 128),
]


def _dt(s: str):
    return {"f32": jnp.float32, "i32": jnp.int32}[s]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def specs_of(args):
    return [jax.ShapeDtypeStruct(shape, _dt(dt)) for (_, shape, dt) in args]


def build_catalog():
    """The full artifact catalog: name -> (fn, arg specs, output specs)."""
    catalog = []
    for b, m in TWO_BIN_BUCKETS:
        catalog.append(
            dict(
                name=f"balance_two_bin_b{b}_m{m}",
                entry="balance_two_bin",
                fn=model.balance_two_bin,
                args=[("weights", (b, m), F32), ("base", (b, 2), F32)],
                outputs=[
                    ("sorted_w", (b, m), F32),
                    ("perm", (b, m), I32),
                    ("assign", (b, m), F32),
                    ("sums", (b, 2), F32),
                ],
            )
        )
        catalog.append(
            dict(
                name=f"greedy_two_bin_b{b}_m{m}",
                entry="greedy_two_bin",
                fn=model.greedy_two_bin,
                args=[("weights", (b, m), F32), ("base", (b, 2), F32)],
                outputs=[
                    ("assign", (b, m), F32),
                    ("sums", (b, 2), F32),
                ],
            )
        )
    for b, m, n in NBIN_BUCKETS:
        catalog.append(
            dict(
                name=f"offline_nbin_b{b}_m{m}_n{n}",
                entry="offline_nbin",
                fn=model.offline_nbin,
                args=[("weights", (b, m), F32), ("base", (b, n), F32)],
                outputs=[
                    ("sorted_w", (b, m), F32),
                    ("perm", (b, m), I32),
                    ("assign", (b, m), I32),
                    ("sums", (b, n), F32),
                ],
            )
        )
    for b, n in CONTINUOUS_BUCKETS:
        catalog.append(
            dict(
                name=f"continuous_round_b{b}_n{n}",
                entry="continuous_round",
                fn=model.continuous_round,
                args=[("x", (b, n), F32), ("m", (n, n), F32)],
                outputs=[("x_next", (b, n), F32)],
            )
        )
    return catalog


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--only",
        default=None,
        help="comma-separated artifact-name substrings to (re)build",
    )
    opts = ap.parse_args()

    os.makedirs(opts.out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "version": 1, "artifacts": []}

    for item in build_catalog():
        fname = f"{item['name']}.hlo.txt"
        manifest["artifacts"].append(
            dict(
                name=item["name"],
                entry=item["entry"],
                file=fname,
                inputs=[
                    dict(name=n, shape=list(s), dtype=dt)
                    for (n, s, dt) in item["args"]
                ],
                outputs=[
                    dict(name=n, shape=list(s), dtype=dt)
                    for (n, s, dt) in item["outputs"]
                ],
            )
        )
        if opts.only and not any(
            key in item["name"] for key in opts.only.split(",")
        ):
            continue
        lowered = jax.jit(item["fn"]).lower(*specs_of(item["args"]))
        text = to_hlo_text(lowered)
        path = os.path.join(opts.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)", file=sys.stderr)

    mpath = os.path.join(opts.out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath} ({len(manifest['artifacts'])} artifacts)", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
