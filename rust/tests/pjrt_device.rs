//! Device-vs-parallel differential test, compiled only with the `pjrt`
//! cargo feature (`cargo test --features pjrt`).
//!
//! With the vendored `vendor/xla` API stub, `Runtime::new` fails by
//! design and the device path runs through the bit-equivalent pure-Rust
//! fallback; with a real `xla` checkout in its place the same test
//! exercises actual PJRT execution.  Either way the device engine and
//! the deterministic parallel engine must agree on everything the
//! protocol conserves: load identity, total mass, per-round edge
//! counts, and the contraction of the discrepancy (the two engines use
//! different RNG models — shared stream vs counter-based — so the
//! comparison is structural/statistical, not bit-exact; bit-exactness
//! across *engines* is covered by `property_invariants.rs`).

#![cfg(feature = "pjrt")]

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{run_device, Engine, Parallel, Schedule, StopRule};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::{default_artifacts_dir, DeviceAlgo, Runtime};
use bcm_dlb::util::rng::Pcg64;

#[test]
fn device_vs_parallel_differential() {
    let n = 24;
    let sweeps = 8;
    let seed = 9u64;
    let mut rng = Pcg64::new(seed);
    let g = Graph::random_connected(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state0 = LoadState::init_uniform_counts(
        n,
        30,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let init_disc = state0.discrepancy();

    // Device path: a real PJRT runtime when one is available (real xla
    // vendored + artifacts built), else the bit-equivalent fallback.
    let mut rt = Runtime::new(&default_artifacts_dir()).ok();
    let mut dev_state = state0.clone();
    let mut dev_rng = Pcg64::new(seed ^ 0xD0D0);
    let dev_trace = run_device(
        &mut dev_state,
        &schedule,
        DeviceAlgo::SortedGreedy,
        sweeps,
        rt.as_mut(),
        &mut dev_rng,
    )
    .expect("device/fallback run failed");

    // Parallel engine on the same initial state.
    let mut par_state = state0.clone();
    let par_trace = Parallel::new(2).run(
        &mut par_state,
        &schedule,
        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        StopRule::sweeps(sweeps),
        seed,
    );

    // Conservation: identical load populations and total mass.
    assert_eq!(dev_state.all_ids(), par_state.all_ids());
    assert!((dev_state.total_weight() - par_state.total_weight()).abs() < 1e-6);

    // Structure: same rounds, same per-round matching sizes.
    assert_eq!(dev_trace.rounds.len(), par_trace.rounds.len());
    for (d, p) in dev_trace.rounds.iter().zip(&par_trace.rounds) {
        assert_eq!(d.edges, p.edges, "matching size diverged at round {}", d.round);
        assert_eq!(d.color, p.color, "schedule color diverged at round {}", d.round);
    }

    // Effectiveness: both engines contract the initial discrepancy by a
    // wide margin (SortedGreedy/full mobility reaches near-l_max), and
    // land within a small factor of each other.
    let (df, pf) = (dev_trace.final_discrepancy(), par_trace.final_discrepancy());
    assert!(df < init_disc / 4.0, "device engine barely balanced: {df} vs {init_disc}");
    assert!(pf < init_disc / 4.0, "parallel engine barely balanced: {pf} vs {init_disc}");
    let ratio = (df.max(1e-9)) / (pf.max(1e-9));
    assert!(
        (0.2..=5.0).contains(&ratio),
        "device ({df}) and parallel ({pf}) engines disagree beyond tolerance"
    );
}
