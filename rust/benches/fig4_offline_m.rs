//! E4 — regenerates paper Fig. 4 (Appendix C): offline balls-into-bins
//! discrepancy vs number of balls m, for n = 2 and n = 8 bins,
//! U[0,1) weights, 1000 repetitions (paper setting).
//!
//! Shape expectations: Greedy's mean discrepancy is ~constant in m
//! (≈ E[W] ≈ 0.5 for n=2); SortedGreedy's decays roughly exponentially,
//! reaching 10–60x (n=2) / ~73x (n=8) below Greedy for large m.

use bcm_dlb::experiments::figures;
use std::path::Path;

fn main() {
    let quick = std::env::var("BCM_DLB_QUICK").map(|v| v == "1").unwrap_or(false);
    let reps = if quick { 100 } else { 1000 };
    let start = std::time::Instant::now();
    for t in figures::fig4(reps, 2013, Path::new("results")) {
        println!("{}", t.render());
    }
    eprintln!("fig4 completed in {:.1}s", start.elapsed().as_secs_f64());
}
