//! Offline weighted balls-into-bins solvers with n >= 2 bins.
//!
//! These drive the Appendix-C experiments (paper Figs. 4 and 5): m balls
//! with i.i.d. weights are placed into n bins and the final discrepancy
//! max_k U_k − min_k U_k is measured.  `Greedy` places balls in arrival
//! order into the currently lightest bin (paper Alg. 4.2); `SortedGreedy`
//! sorts descending first (Alg. 4.1) — the classical LPT rule.

use super::sorting::SortAlgo;
use crate::util::rng::Pcg64;

/// Result of one offline placement.
#[derive(Clone, Debug)]
pub struct Placement {
    /// assignment[i] = bin of ball i (indices refer to the *input* order).
    pub assignment: Vec<usize>,
    /// Final bin sums.
    pub sums: Vec<f64>,
}

impl Placement {
    pub fn discrepancy(&self) -> f64 {
        let max = self.sums.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = self.sums.iter().cloned().fold(f64::INFINITY, f64::min);
        max - min
    }
}

/// Greedy: each ball (arrival order) into the lightest bin, ties to the
/// lowest index.
pub fn greedy(weights: &[f64], nbins: usize) -> Placement {
    place_in_order(weights, (0..weights.len()).collect(), nbins)
}

/// SortedGreedy: sort descending (with `sort`), then Greedy.
pub fn sorted_greedy(weights: &[f64], nbins: usize, sort: SortAlgo) -> Placement {
    let mut order: Vec<usize> = (0..weights.len()).collect();
    // sort indices descending by weight, reusing the configured algorithm
    #[derive(Clone)]
    struct K(f64, usize);
    impl super::sorting::Keyed for K {
        fn key(&self) -> f64 {
            self.0
        }
    }
    let mut keyed: Vec<K> = order.iter().map(|&i| K(weights[i], i)).collect();
    sort.sort_desc(&mut keyed);
    for (slot, k) in order.iter_mut().zip(&keyed) {
        *slot = k.1;
    }
    place_in_order(weights, order, nbins)
}

/// Random baseline: each ball to a uniformly random bin.
pub fn random_place(weights: &[f64], nbins: usize, rng: &mut Pcg64) -> Placement {
    assert!(nbins >= 1);
    let mut sums = vec![0.0; nbins];
    let mut assignment = vec![0usize; weights.len()];
    for (i, &w) in weights.iter().enumerate() {
        let k = rng.below(nbins);
        assignment[i] = k;
        sums[k] += w;
    }
    Placement { assignment, sums }
}

fn place_in_order(weights: &[f64], order: Vec<usize>, nbins: usize) -> Placement {
    assert!(nbins >= 1);
    let mut sums = vec![0.0; nbins];
    let mut assignment = vec![0usize; weights.len()];
    for &i in &order {
        let k = lightest_bin(&sums);
        assignment[i] = k;
        sums[k] += weights[i];
    }
    Placement { assignment, sums }
}

/// Index of the minimum bin sum; ties to the lowest index (the convention
/// shared with the Pallas kernel and its oracle).
#[inline]
pub fn lightest_bin(sums: &[f64]) -> usize {
    let mut best = 0usize;
    let mut best_v = sums[0];
    for (k, &v) in sums.iter().enumerate().skip(1) {
        if v < best_v {
            best = k;
            best_v = v;
        }
    }
    best
}

/// A perfectly divisible lower-bound oracle: the continuous-case
/// discrepancy is zero; the best *indivisible* bound is
/// max(0, max_i w_i − (total − max_i w_i)/(n−1)) — we simply report the
/// average-per-bin for reference plots.
pub fn average_per_bin(weights: &[f64], nbins: usize) -> f64 {
    weights.iter().sum::<f64>() / nbins as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_matches_paper_pseudocode() {
        // Alg 4.2: first ball to bin 1 (index 0), then lightest.
        let p = greedy(&[3.0, 2.0, 2.0], 2);
        assert_eq!(p.assignment, vec![0, 1, 1]);
        assert_eq!(p.sums, vec![3.0, 4.0]);
        assert!((p.discrepancy() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sorted_greedy_is_lpt() {
        // weights 1,5,3,4 -> sorted 5,4,3,1 -> bins: 5|4, then 3 to bin1
        // (4<5), then 1 to bin0? sums (5,7): 1 -> bin0 -> (6,7).
        let p = sorted_greedy(&[1.0, 5.0, 3.0, 4.0], 2, SortAlgo::Quick);
        assert_eq!(p.sums.iter().sum::<f64>(), 13.0);
        assert!((p.discrepancy() - 1.0).abs() < 1e-12);
        // assignment refers to input order
        assert_eq!(p.assignment[1], 0); // the 5 went first into bin 0
    }

    #[test]
    fn assignment_consistent_with_sums() {
        let mut rng = Pcg64::new(1);
        let weights: Vec<f64> = (0..200).map(|_| rng.uniform(0.0, 1.0)).collect();
        for p in [
            greedy(&weights, 8),
            sorted_greedy(&weights, 8, SortAlgo::Quick),
            random_place(&weights, 8, &mut rng),
        ] {
            let mut sums = vec![0.0; 8];
            for (i, &k) in p.assignment.iter().enumerate() {
                sums[k] += weights[i];
            }
            for (a, b) in sums.iter().zip(&p.sums) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn sorted_discrepancy_much_smaller_fig4_shape() {
        // Fig. 4(a): n=2, m >= 32 -> SortedGreedy ~10-60x below Greedy on
        // average over repetitions.
        let reps = 200;
        let m = 512;
        let mut dg = 0.0;
        let mut ds = 0.0;
        for rep in 0..reps {
            let mut rng = Pcg64::new(42 + rep);
            let w: Vec<f64> = (0..m).map(|_| rng.next_f64()).collect();
            dg += greedy(&w, 2).discrepancy();
            ds += sorted_greedy(&w, 2, SortAlgo::Quick).discrepancy();
        }
        assert!(ds * 10.0 < dg, "sorted {ds} vs greedy {dg}");
    }

    #[test]
    fn discrepancy_decreases_with_m_for_sorted() {
        // Fig. 4: SortedGreedy's discrepancy decays as m grows.
        let disc_at = |m: usize| -> f64 {
            (0..50)
                .map(|rep| {
                    let mut rng = Pcg64::new(900 + rep);
                    let w: Vec<f64> = (0..m).map(|_| rng.next_f64()).collect();
                    sorted_greedy(&w, 2, SortAlgo::Quick).discrepancy()
                })
                .sum::<f64>()
                / 50.0
        };
        let d32 = disc_at(32);
        let d1024 = disc_at(1024);
        assert!(d1024 < d32 / 4.0, "d32={d32} d1024={d1024}");
    }

    #[test]
    fn greedy_discrepancy_roughly_constant_in_m() {
        // Fig. 4: Greedy's mean discrepancy is ~constant with m.
        let disc_at = |m: usize| -> f64 {
            (0..200)
                .map(|rep| {
                    let mut rng = Pcg64::new(300 + rep);
                    let w: Vec<f64> = (0..m).map(|_| rng.next_f64()).collect();
                    greedy(&w, 2).discrepancy()
                })
                .sum::<f64>()
                / 200.0
        };
        let d64 = disc_at(64);
        let d2048 = disc_at(2048);
        assert!(d64 > 0.05 && d2048 > 0.05, "d64={d64} d2048={d2048}");
        assert!((d64 / d2048) < 4.0 && (d2048 / d64) < 4.0);
    }

    #[test]
    fn nbins_one_trivial() {
        let p = greedy(&[1.0, 2.0], 1);
        assert_eq!(p.discrepancy(), 0.0);
        assert_eq!(p.sums, vec![3.0]);
    }

    #[test]
    fn empty_weights() {
        let p = sorted_greedy(&[], 4, SortAlgo::Quick);
        assert_eq!(p.discrepancy(), 0.0);
        assert!(p.assignment.is_empty());
    }

    #[test]
    fn lightest_bin_tie_lowest_index() {
        assert_eq!(lightest_bin(&[1.0, 1.0, 0.5, 0.5]), 2);
        assert_eq!(lightest_bin(&[0.0, 0.0]), 0);
    }
}
