//! The zero-allocation contract of the hot path (DESIGN.md §9): after a
//! warm-up sweep, a steady-state BCM round performs **zero** heap
//! allocations — the arena rewrites segments in place, the edge scratch
//! is reused, and the trace/reduction read cached totals.
//!
//! A counting `#[global_allocator]` wraps `System` and counts every
//! allocation event (alloc / alloc_zeroed / realloc).  The whole
//! contract lives in a single `#[test]` so no concurrent test can
//! perturb the global counter.
//!
//! The workload is an equal-weight ring: every edge pools 16 unit
//! loads and splits them 8/8, so node sizes never leave their segment
//! caps — the steady state the slack is designed around.  (Random
//! weights migrate loads across cap boundaries, which legitimately
//! relocates segments; that path is exercised by the property tests,
//! not this budget.)

use bcm_dlb::balancer::{EdgeScratch, PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{balance_edge_with, parallel_round_ctx, RoundCtx, Schedule};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{Load, LoadState};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::workload::{apply_ops, ops_for_round, TrafficConfig};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOC_EVENTS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOC_EVENTS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOC_EVENTS.load(Ordering::SeqCst)
}

/// `per_node` unit-weight mobile loads on each of `n` nodes.
fn equal_state(n: usize, per_node: usize) -> LoadState {
    let mut s = LoadState::empty(n);
    let mut id = 0u64;
    for v in 0..n {
        for _ in 0..per_node {
            s.push(v, Load::new(id, 1.0));
            id += 1;
        }
    }
    s
}

fn seq_sweeps(
    state: &mut LoadState,
    schedule: &Schedule,
    algo: PairAlgorithm,
    rounds: std::ops::Range<usize>,
    seed: u64,
    scratch: &mut EdgeScratch,
) {
    for round in rounds {
        for (e, &(u, v)) in schedule.matching(round).iter().enumerate() {
            let mut rng = Pcg64::for_edge(seed, round, e);
            balance_edge_with(state, u as usize, v as usize, algo, &mut rng, scratch);
        }
    }
}

#[test]
fn steady_state_rounds_allocate_nothing() {
    let n = 64;
    let per_node = 8;
    let seed = 0xA110_C8;
    let g = Graph::ring(n);
    let schedule = Schedule::from_graph(&g);
    let d = schedule.period();
    // Merge/Flash sorts use scratch buffers by design; Quick is in-place.
    let algos = [
        PairAlgorithm::Greedy,
        PairAlgorithm::GreedyIncremental,
        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
    ];

    for algo in algos {
        // --- sequential engine loop ---
        let mut state = equal_state(n, per_node);
        let mut scratch = EdgeScratch::new();
        seq_sweeps(&mut state, &schedule, algo, 0..d, seed, &mut scratch);
        let before = allocs();
        seq_sweeps(&mut state, &schedule, algo, d..3 * d, seed, &mut scratch);
        assert_eq!(
            allocs() - before,
            0,
            "sequential steady-state rounds allocated ({algo:?})"
        );

        // --- parallel round, single worker (no thread spawns) ---
        let mut state = equal_state(n, per_node);
        let mut ctx = RoundCtx::new(1);
        for round in 0..d {
            let pairs = schedule.matching(round);
            parallel_round_ctx(&mut state, pairs, round, algo, seed, 1, &mut ctx);
        }
        let before = allocs();
        for round in d..3 * d {
            let pairs = schedule.matching(round);
            parallel_round_ctx(&mut state, pairs, round, algo, seed, 1, &mut ctx);
        }
        assert_eq!(
            allocs() - before,
            0,
            "1-worker parallel steady-state rounds allocated ({algo:?})"
        );

        // --- parallel round, two workers ---
        // Spawning OS threads inherently allocates (thread packets,
        // boxed closures), so the budget here is: no more events than a
        // scope of the same shape spawning *empty* closures — i.e. the
        // round work itself contributes zero.
        let mut state = equal_state(n, per_node);
        let mut ctx = RoundCtx::new(2);
        for round in 0..d {
            let pairs = schedule.matching(round);
            parallel_round_ctx(&mut state, pairs, round, algo, seed, 2, &mut ctx);
        }
        let spawn_shape = || {
            std::thread::scope(|scope| {
                for _ in 0..2 {
                    scope.spawn(|| {});
                }
            })
        };
        spawn_shape(); // warm any lazy thread-runtime state
        let before = allocs();
        for _ in 0..2 * d {
            spawn_shape();
        }
        let baseline = allocs() - before;
        let before = allocs();
        for round in d..3 * d {
            let pairs = schedule.matching(round);
            parallel_round_ctx(&mut state, pairs, round, algo, seed, 2, &mut ctx);
        }
        let spent = allocs() - before;
        assert!(
            spent <= baseline,
            "2-worker rounds allocated beyond the bare spawn overhead \
             ({algo:?}: {spent} events vs {baseline} baseline)"
        );
    }

    // --- churning steady state: an *amortized* budget ---
    // Churn legitimately allocates: each round builds one op vector
    // (O(log ops) doubling events) and arrivals can grow the arena or
    // relocate segments past their caps (amortized O(1) events per op).
    // What must NOT happen is a per-round cost proportional to n or to
    // the resident load count — that would mean the arena re-materializes
    // state instead of editing in place.  The budget below is generous
    // in the constant but linear only in rounds and ops.
    {
        let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
        let mut state = equal_state(n, per_node);
        let cfg = TrafficConfig::default();
        let wseed = 0xC4E2_17;
        let mut scratch = EdgeScratch::new();
        // warm-up: one full diurnal-free period of churn + sweeps
        for round in 0..d {
            let ops = ops_for_round(&cfg, wseed, round, n);
            apply_ops(&mut state, &ops);
            seq_sweeps(&mut state, &schedule, algo, round..round + 1, seed, &mut scratch);
        }
        let measured_rounds = 4 * d;
        let before = allocs();
        let mut total_ops = 0usize;
        for round in d..d + measured_rounds {
            let ops = ops_for_round(&cfg, wseed, round, n);
            total_ops += ops.len();
            apply_ops(&mut state, &ops);
            seq_sweeps(&mut state, &schedule, algo, round..round + 1, seed, &mut scratch);
        }
        let spent = allocs() - before;
        let budget = 16 * measured_rounds + 8 * total_ops;
        assert!(
            total_ops > 0,
            "churn workload generated no ops; the budget test is vacuous"
        );
        assert!(
            spent <= budget,
            "churning rounds allocated {spent} events for {total_ops} ops over \
             {measured_rounds} rounds (budget {budget}); churn cost must be \
             amortized O(ops), not O(state)"
        );
    }
}
