//! Network topologies (the *processor view* of the DLB problem).
//!
//! Vertices are processors, edges are direct communication links.  The
//! paper's experiments use random connected graphs ("edges are randomly
//! drawn until the graph is connected", §6); the named topologies are the
//! standard testbeds the theory section's bounds are usually evaluated on
//! and are used by the extension benches.

use crate::util::rng::Pcg64;

/// An undirected, simple, connected-by-construction graph.
///
/// Edges are stored canonically as `(u, v)` with `u < v` (paper notation
/// `[u:v]`).
#[derive(Clone, Debug)]
pub struct Graph {
    n: usize,
    edges: Vec<(u32, u32)>,
    adj: Vec<Vec<u32>>,
}

impl Graph {
    /// Build from an explicit edge list; dedups and canonicalizes.
    pub fn from_edges(n: usize, raw: &[(u32, u32)]) -> Self {
        let mut edges: Vec<(u32, u32)> = raw
            .iter()
            .filter(|(u, v)| u != v)
            .map(|&(u, v)| if u < v { (u, v) } else { (v, u) })
            .collect();
        edges.sort_unstable();
        edges.dedup();
        let mut adj = vec![Vec::new(); n];
        for &(u, v) in &edges {
            assert!((v as usize) < n, "edge ({u},{v}) out of range for n={n}");
            adj[u as usize].push(v);
            adj[v as usize].push(u);
        }
        Self { n, edges, adj }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn edges(&self) -> &[(u32, u32)] {
        &self.edges
    }

    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    pub fn neighbors(&self, v: u32) -> &[u32] {
        &self.adj[v as usize]
    }

    pub fn degree(&self, v: u32) -> usize {
        self.adj[v as usize].len()
    }

    pub fn max_degree(&self) -> usize {
        (0..self.n).map(|v| self.adj[v].len()).max().unwrap_or(0)
    }

    /// BFS connectivity check.
    pub fn is_connected(&self) -> bool {
        if self.n == 0 {
            return true;
        }
        let mut seen = vec![false; self.n];
        let mut stack = vec![0u32];
        seen[0] = true;
        let mut count = 1;
        while let Some(v) = stack.pop() {
            for &w in self.neighbors(v) {
                if !seen[w as usize] {
                    seen[w as usize] = true;
                    count += 1;
                    stack.push(w);
                }
            }
        }
        count == self.n
    }

    /// The paper's §6 network: draw uniform random edges until connected.
    pub fn random_connected(n: usize, rng: &mut Pcg64) -> Self {
        assert!(n >= 2);
        let mut uf = UnionFind::new(n);
        let mut edges: Vec<(u32, u32)> = Vec::new();
        let mut present = std::collections::HashSet::new();
        let mut components = n;
        while components > 1 {
            let u = rng.below(n) as u32;
            let v = rng.below(n) as u32;
            if u == v {
                continue;
            }
            let key = if u < v { (u, v) } else { (v, u) };
            if present.insert(key) {
                edges.push(key);
                if uf.union(u as usize, v as usize) {
                    components -= 1;
                }
            }
        }
        Self::from_edges(n, &edges)
    }

    /// Erdős–Rényi G(n, p), resampled until connected (bounded retries).
    pub fn erdos_renyi_connected(n: usize, p: f64, rng: &mut Pcg64) -> Self {
        assert!(n >= 2);
        for _ in 0..1000 {
            let mut edges = Vec::new();
            for u in 0..n as u32 {
                for v in (u + 1)..n as u32 {
                    if rng.next_f64() < p {
                        edges.push((u, v));
                    }
                }
            }
            let g = Self::from_edges(n, &edges);
            if g.is_connected() {
                return g;
            }
        }
        panic!("erdos_renyi_connected: p={p} too small for n={n}");
    }

    /// Cycle 0-1-2-…-(n-1)-0.
    pub fn ring(n: usize) -> Self {
        assert!(n >= 3);
        let edges: Vec<(u32, u32)> = (0..n as u32)
            .map(|i| (i, (i + 1) % n as u32))
            .collect();
        Self::from_edges(n, &edges)
    }

    /// Path 0-1-…-(n-1).
    pub fn path(n: usize) -> Self {
        assert!(n >= 2);
        let edges: Vec<(u32, u32)> = (0..n as u32 - 1).map(|i| (i, i + 1)).collect();
        Self::from_edges(n, &edges)
    }

    /// Complete graph K_n.
    pub fn complete(n: usize) -> Self {
        assert!(n >= 2);
        let mut edges = Vec::with_capacity(n * (n - 1) / 2);
        for u in 0..n as u32 {
            for v in (u + 1)..n as u32 {
                edges.push((u, v));
            }
        }
        Self::from_edges(n, &edges)
    }

    /// Star with center 0.
    pub fn star(n: usize) -> Self {
        assert!(n >= 2);
        let edges: Vec<(u32, u32)> = (1..n as u32).map(|v| (0, v)).collect();
        Self::from_edges(n, &edges)
    }

    /// `rows x cols` 2-D mesh (no wraparound).
    pub fn grid2d(rows: usize, cols: usize) -> Self {
        assert!(rows * cols >= 2);
        let id = |r: usize, c: usize| (r * cols + c) as u32;
        let mut edges = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if c + 1 < cols {
                    edges.push((id(r, c), id(r, c + 1)));
                }
                if r + 1 < rows {
                    edges.push((id(r, c), id(r + 1, c)));
                }
            }
        }
        Self::from_edges(rows * cols, &edges)
    }

    /// `rows x cols` 2-D torus (wraparound mesh).
    pub fn torus2d(rows: usize, cols: usize) -> Self {
        assert!(rows >= 2 && cols >= 2);
        let id = |r: usize, c: usize| (r * cols + c) as u32;
        let mut edges = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                edges.push((id(r, c), id(r, (c + 1) % cols)));
                edges.push((id(r, c), id((r + 1) % rows, c)));
            }
        }
        Self::from_edges(rows * cols, &edges)
    }

    /// `a x b x c` 3-D torus (wraparound mesh) — the interconnect shape of
    /// large particle-mesh clusters; the natural n >= 4096 testbed for the
    /// parallel engine (degree 6, diameter O(n^(1/3))).
    pub fn torus3d(a: usize, b: usize, c: usize) -> Self {
        assert!(a >= 2 && b >= 2 && c >= 2);
        let id = |x: usize, y: usize, z: usize| ((x * b + y) * c + z) as u32;
        let mut edges = Vec::with_capacity(3 * a * b * c);
        for x in 0..a {
            for y in 0..b {
                for z in 0..c {
                    edges.push((id(x, y, z), id((x + 1) % a, y, z)));
                    edges.push((id(x, y, z), id(x, (y + 1) % b, z)));
                    edges.push((id(x, y, z), id(x, y, (z + 1) % c)));
                }
            }
        }
        Self::from_edges(a * b * c, &edges)
    }

    /// `d`-dimensional hypercube (n = 2^d vertices).
    pub fn hypercube(d: usize) -> Self {
        assert!(d >= 1);
        let n = 1usize << d;
        let mut edges = Vec::new();
        for v in 0..n as u32 {
            for bit in 0..d {
                let w = v ^ (1 << bit);
                if v < w {
                    edges.push((v, w));
                }
            }
        }
        Self::from_edges(n, &edges)
    }

    /// Random `d`-regular-ish expander by superposing `d/2` random
    /// Hamiltonian cycles (permutation method); retried until connected.
    pub fn random_regular(n: usize, d: usize, rng: &mut Pcg64) -> Self {
        assert!(n >= 3 && d >= 2 && d % 2 == 0, "need even d >= 2, n >= 3");
        for _ in 0..100 {
            let mut edges = Vec::new();
            for _ in 0..d / 2 {
                let mut perm: Vec<u32> = (0..n as u32).collect();
                rng.shuffle(&mut perm);
                for i in 0..n {
                    edges.push((perm[i], perm[(i + 1) % n]));
                }
            }
            let g = Self::from_edges(n, &edges);
            if g.is_connected() {
                return g;
            }
        }
        panic!("random_regular: failed to build a connected graph");
    }

    /// Barabási–Albert preferential attachment with `m_attach` edges per
    /// new vertex — a scale-free network (hub-heavy degree distribution,
    /// the shape of real cluster interconnect overlays).
    pub fn barabasi_albert(n: usize, m_attach: usize, rng: &mut Pcg64) -> Self {
        assert!(m_attach >= 1 && n > m_attach);
        // seed: complete graph on m_attach + 1 vertices
        let mut edges: Vec<(u32, u32)> = Vec::new();
        let mut targets: Vec<u32> = Vec::new(); // degree-weighted pool
        for u in 0..=(m_attach as u32) {
            for v in (u + 1)..=(m_attach as u32) {
                edges.push((u, v));
                targets.push(u);
                targets.push(v);
            }
        }
        for v in (m_attach as u32 + 1)..(n as u32) {
            let mut chosen: Vec<u32> = Vec::with_capacity(m_attach);
            while chosen.len() < m_attach {
                let t = targets[rng.below(targets.len())];
                if t != v && !chosen.contains(&t) {
                    chosen.push(t);
                }
            }
            for &t in &chosen {
                edges.push((v.min(t), v.max(t)));
                targets.push(v);
                targets.push(t);
            }
        }
        Self::from_edges(n, &edges)
    }
}

/// Topology selector used by configs and the CLI.
#[derive(Clone, Debug, PartialEq)]
pub enum Topology {
    RandomConnected,
    ErdosRenyi { p: f64 },
    Ring,
    Path,
    Complete,
    Star,
    Grid2d,
    Torus2d,
    Torus3d,
    Hypercube,
    /// Random d-regular expander (d even).
    RandomRegular { d: usize },
    /// Barabási–Albert scale-free with m attachments per vertex.
    ScaleFree { m: usize },
}

impl Topology {
    /// Build an `n`-vertex instance (grids use the closest factorization;
    /// hypercube requires `n` to be a power of two).
    pub fn build(&self, n: usize, rng: &mut Pcg64) -> Graph {
        match self {
            Topology::RandomConnected => Graph::random_connected(n, rng),
            Topology::ErdosRenyi { p } => Graph::erdos_renyi_connected(n, *p, rng),
            Topology::Ring => Graph::ring(n),
            Topology::Path => Graph::path(n),
            Topology::Complete => Graph::complete(n),
            Topology::Star => Graph::star(n),
            Topology::Grid2d => {
                let rows = (n as f64).sqrt().floor() as usize;
                let rows = (1..=rows).rev().find(|r| n % r == 0).unwrap_or(1);
                Graph::grid2d(rows, n / rows)
            }
            Topology::Torus2d => {
                let rows = (n as f64).sqrt().floor() as usize;
                let rows = (2..=rows).rev().find(|r| n % r == 0).unwrap_or(2);
                assert!(n % rows == 0 && n / rows >= 2, "torus needs composite n");
                Graph::torus2d(rows, n / rows)
            }
            Topology::Torus3d => {
                // Nearest-to-cubic factorization a x b x c, backtracking
                // over a: the largest a <= cbrt(n) need not leave n/a
                // splittable (e.g. n=44: a=4 leaves prime 11, a=2 works).
                let cbrt = (n as f64).cbrt().round() as usize;
                let (a, b, c) = (2..=cbrt.max(2))
                    .rev()
                    .filter(|a| n % a == 0)
                    .find_map(|a| {
                        let rest = n / a;
                        let sqrt = (rest as f64).sqrt().floor() as usize;
                        (2..=sqrt.max(2))
                            .rev()
                            .find(|b| rest % b == 0 && rest / b >= 2)
                            .map(|b| (a, b, rest / b))
                    })
                    .expect("torus3d needs n = a*b*c with a,b,c >= 2");
                Graph::torus3d(a, b, c)
            }
            Topology::Hypercube => {
                assert!(n.is_power_of_two(), "hypercube needs n = 2^d");
                Graph::hypercube(n.trailing_zeros() as usize)
            }
            Topology::RandomRegular { d } => Graph::random_regular(n, *d, rng),
            Topology::ScaleFree { m } => Graph::barabasi_albert(n, *m, rng),
        }
    }

    pub fn parse(s: &str) -> Option<Topology> {
        match s {
            "random" | "random-connected" => Some(Topology::RandomConnected),
            "ring" => Some(Topology::Ring),
            "path" => Some(Topology::Path),
            "complete" => Some(Topology::Complete),
            "star" => Some(Topology::Star),
            "grid" | "grid2d" => Some(Topology::Grid2d),
            "torus" | "torus2d" => Some(Topology::Torus2d),
            "torus3d" => Some(Topology::Torus3d),
            "hypercube" => Some(Topology::Hypercube),
            s if s.starts_with("er:") => s[3..]
                .parse::<f64>()
                .ok()
                .map(|p| Topology::ErdosRenyi { p }),
            s if s.starts_with("regular:") => s[8..]
                .parse::<usize>()
                .ok()
                .map(|d| Topology::RandomRegular { d }),
            s if s.starts_with("scalefree:") => s[10..]
                .parse::<usize>()
                .ok()
                .map(|m| Topology::ScaleFree { m }),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            Topology::RandomConnected => "random".into(),
            Topology::ErdosRenyi { p } => format!("er:{p}"),
            Topology::Ring => "ring".into(),
            Topology::Path => "path".into(),
            Topology::Complete => "complete".into(),
            Topology::Star => "star".into(),
            Topology::Grid2d => "grid2d".into(),
            Topology::Torus2d => "torus2d".into(),
            Topology::Torus3d => "torus3d".into(),
            Topology::Hypercube => "hypercube".into(),
            Topology::RandomRegular { d } => format!("regular:{d}"),
            Topology::ScaleFree { m } => format!("scalefree:{m}"),
        }
    }
}

/// Union-find with path halving + union by size.
pub struct UnionFind {
    parent: Vec<usize>,
    size: Vec<usize>,
}

impl UnionFind {
    pub fn new(n: usize) -> Self {
        Self {
            parent: (0..n).collect(),
            size: vec![1; n],
        }
    }

    pub fn find(&mut self, mut x: usize) -> usize {
        while self.parent[x] != x {
            self.parent[x] = self.parent[self.parent[x]];
            x = self.parent[x];
        }
        x
    }

    /// Returns true if the two sets were merged (were previously disjoint).
    pub fn union(&mut self, a: usize, b: usize) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra] < self.size[rb] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb] = ra;
        self.size[ra] += self.size[rb];
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_structure() {
        let g = Graph::ring(5);
        assert_eq!(g.num_edges(), 5);
        assert!(g.is_connected());
        assert!(g.edges().iter().all(|&(u, v)| u < v));
        for v in 0..5 {
            assert_eq!(g.degree(v), 2);
        }
    }

    #[test]
    fn path_structure() {
        let g = Graph::path(4);
        assert_eq!(g.num_edges(), 3);
        assert_eq!(g.degree(0), 1);
        assert_eq!(g.degree(1), 2);
    }

    #[test]
    fn complete_structure() {
        let g = Graph::complete(6);
        assert_eq!(g.num_edges(), 15);
        assert_eq!(g.max_degree(), 5);
    }

    #[test]
    fn star_structure() {
        let g = Graph::star(7);
        assert_eq!(g.num_edges(), 6);
        assert_eq!(g.degree(0), 6);
        assert_eq!(g.degree(3), 1);
    }

    #[test]
    fn grid_structure() {
        let g = Graph::grid2d(3, 4);
        assert_eq!(g.n(), 12);
        assert_eq!(g.num_edges(), 3 * 3 + 2 * 4); // horizontal + vertical
        assert!(g.is_connected());
    }

    #[test]
    fn torus_structure() {
        let g = Graph::torus2d(3, 4);
        assert_eq!(g.num_edges(), 2 * 12);
        for v in 0..12 {
            assert_eq!(g.degree(v), 4);
        }
    }

    #[test]
    fn torus_2x2_no_duplicate_edges() {
        let g = Graph::torus2d(2, 2);
        // wraparound == direct neighbor for size 2: dedup leaves 4 edges
        assert_eq!(g.num_edges(), 4);
    }

    #[test]
    fn torus3d_structure() {
        let g = Graph::torus3d(2, 3, 4);
        assert_eq!(g.n(), 24);
        assert!(g.is_connected());
        // dimension of size 2 collapses its wrap edge: degree 5 not 6
        for v in 0..24 {
            assert_eq!(g.degree(v), 5);
        }
        let g = Graph::torus3d(3, 3, 3);
        assert_eq!(g.num_edges(), 3 * 27);
        for v in 0..27 {
            assert_eq!(g.degree(v), 6);
        }
    }

    #[test]
    fn torus3d_build_factorizes() {
        let mut rng = Pcg64::new(7);
        // 44 = 2x2x11 and 76 = 2x2x19 need the backtracking step: the
        // largest factor below cbrt(n) leaves a prime remainder.
        for n in [16, 44, 64, 76, 4096] {
            let g = Topology::Torus3d.build(n, &mut rng);
            assert_eq!(g.n(), n);
            assert!(g.is_connected(), "n={n}");
        }
    }

    #[test]
    fn hypercube_structure() {
        let g = Graph::hypercube(4);
        assert_eq!(g.n(), 16);
        assert_eq!(g.num_edges(), 16 * 4 / 2);
        for v in 0..16 {
            assert_eq!(g.degree(v), 4);
        }
        assert!(g.is_connected());
    }

    #[test]
    fn random_connected_is_connected() {
        let mut rng = Pcg64::new(5);
        for n in [2, 4, 16, 64, 128] {
            let g = Graph::random_connected(n, &mut rng);
            assert!(g.is_connected(), "n={n}");
            assert_eq!(g.n(), n);
        }
    }

    #[test]
    fn random_connected_no_self_loops_or_dups() {
        let mut rng = Pcg64::new(9);
        let g = Graph::random_connected(32, &mut rng);
        let mut seen = std::collections::HashSet::new();
        for &(u, v) in g.edges() {
            assert!(u < v);
            assert!(seen.insert((u, v)));
        }
    }

    #[test]
    fn erdos_renyi_connected_works() {
        let mut rng = Pcg64::new(17);
        let g = Graph::erdos_renyi_connected(32, 0.3, &mut rng);
        assert!(g.is_connected());
    }

    #[test]
    fn disconnected_detected() {
        let g = Graph::from_edges(4, &[(0, 1), (2, 3)]);
        assert!(!g.is_connected());
    }

    #[test]
    fn from_edges_canonicalizes() {
        let g = Graph::from_edges(3, &[(1, 0), (0, 1), (2, 2), (1, 2)]);
        assert_eq!(g.edges(), &[(0, 1), (1, 2)]);
    }

    #[test]
    fn topology_parse_roundtrip() {
        for name in [
            "random",
            "ring",
            "path",
            "complete",
            "star",
            "grid2d",
            "torus2d",
            "torus3d",
            "hypercube",
        ] {
            let t = Topology::parse(name).unwrap();
            assert_eq!(Topology::parse(&t.name()).unwrap(), t);
        }
        assert_eq!(
            Topology::parse("er:0.25"),
            Some(Topology::ErdosRenyi { p: 0.25 })
        );
        assert_eq!(Topology::parse("nope"), None);
    }

    #[test]
    fn topology_build_all() {
        let mut rng = Pcg64::new(3);
        for t in [
            Topology::RandomConnected,
            Topology::Ring,
            Topology::Path,
            Topology::Complete,
            Topology::Star,
            Topology::Grid2d,
            Topology::Torus2d,
            Topology::Torus3d,
            Topology::Hypercube,
        ] {
            let g = t.build(16, &mut rng);
            assert_eq!(g.n(), 16);
            assert!(g.is_connected(), "{t:?}");
        }
    }

    #[test]
    fn random_regular_structure() {
        let mut rng = Pcg64::new(41);
        let g = Graph::random_regular(20, 4, &mut rng);
        assert!(g.is_connected());
        // superposed cycles may collide on an edge, so degree <= 4
        for v in 0..20 {
            assert!(g.degree(v) >= 2 && g.degree(v) <= 4, "deg {}", g.degree(v));
        }
    }

    #[test]
    fn barabasi_albert_structure() {
        let mut rng = Pcg64::new(43);
        let g = Graph::barabasi_albert(64, 2, &mut rng);
        assert!(g.is_connected());
        assert_eq!(g.n(), 64);
        // scale-free: max degree well above the attachment count
        assert!(g.max_degree() >= 6, "max degree {}", g.max_degree());
        // every late vertex has degree >= m
        for v in 3..64 {
            assert!(g.degree(v) >= 2);
        }
    }

    #[test]
    fn extended_topology_parse_roundtrip() {
        for s in ["regular:4", "scalefree:2"] {
            let t = Topology::parse(s).unwrap();
            assert_eq!(t.name(), s);
            let mut rng = Pcg64::new(1);
            let g = t.build(16, &mut rng);
            assert!(g.is_connected());
        }
    }

    #[test]
    fn union_find() {
        let mut uf = UnionFind::new(5);
        assert!(uf.union(0, 1));
        assert!(!uf.union(1, 0));
        assert!(uf.union(2, 3));
        assert!(uf.union(0, 3));
        assert_eq!(uf.find(2), uf.find(1));
        assert_ne!(uf.find(4), uf.find(0));
    }
}
