//! The in-process transport: the coordinator's original
//! `std::sync::mpsc` channels, packaged as [`LeaderTransport`] /
//! [`WorkerTransport`] implementations.
//!
//! This is the PR-4 wiring verbatim — one control channel per worker,
//! one shared report channel, one inbound peer channel per worker that
//! every other worker holds a sender for — so the behavior of every
//! existing bit-identity and fail-stop test is unchanged: the channels
//! are unbounded (sends never block), FIFO per sender/receiver pair, and
//! messages move by pointer (a `Ctl::RunBatch`'s plan table crosses as a
//! zero-copy `Arc` clone, never serialized).

use super::{LeaderTransport, TransportError, WorkerTransport};
use crate::coordinator::messages::{Ctl, Report, ShardMsg};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::time::Duration;

/// Leader half of an in-process cluster: control senders plus the
/// shared report receiver.
pub struct LocalLeader {
    ctl_tx: Vec<Sender<Ctl>>,
    report_rx: Receiver<Report>,
}

/// Worker half of an in-process cluster: the four channel endpoints of
/// one shard.
pub struct LocalWorker {
    shard: usize,
    ctl_rx: Receiver<Ctl>,
    report_tx: Sender<Report>,
    peer_rx: Receiver<ShardMsg>,
    peer_tx: Vec<Sender<ShardMsg>>,
}

/// Wire up a `shards`-worker in-process cluster: one [`LocalLeader`]
/// and one [`LocalWorker`] per shard, fully cross-connected.
pub fn pair(shards: usize) -> (LocalLeader, Vec<LocalWorker>) {
    assert!(shards > 0, "local transport needs at least one worker");
    let (report_tx, report_rx) = channel::<Report>();
    let mut ctl_tx = Vec::with_capacity(shards);
    let mut ctl_rx = Vec::with_capacity(shards);
    let mut peer_tx = Vec::with_capacity(shards);
    let mut peer_rx = Vec::with_capacity(shards);
    for _ in 0..shards {
        let (ct, cr) = channel::<Ctl>();
        ctl_tx.push(ct);
        ctl_rx.push(cr);
        let (pt, pr) = channel::<ShardMsg>();
        peer_tx.push(pt);
        peer_rx.push(pr);
    }
    let mut workers = Vec::with_capacity(shards);
    // each worker takes ownership of its own receivers and shares
    // clones of every peer sender (its own included, by symmetry)
    for (shard, (cr, pr)) in ctl_rx.into_iter().zip(peer_rx).enumerate() {
        workers.push(LocalWorker {
            shard,
            ctl_rx: cr,
            report_tx: report_tx.clone(),
            peer_rx: pr,
            peer_tx: peer_tx.clone(),
        });
    }
    // the leader holds no report sender: when every worker is gone the
    // channel disconnects, exactly like the pre-transport wiring
    drop(report_tx);
    (LocalLeader { ctl_tx, report_rx }, workers)
}

impl LeaderTransport for LocalLeader {
    fn shards(&self) -> usize {
        self.ctl_tx.len()
    }

    fn send_ctl(&mut self, shard: usize, msg: Ctl) -> Result<(), TransportError> {
        self.ctl_tx[shard]
            .send(msg)
            .map_err(|_| TransportError::Closed(format!("worker {shard} control channel closed")))
    }

    fn recv_report(&mut self, wait: Duration) -> Result<Report, TransportError> {
        match self.report_rx.recv_timeout(wait) {
            Ok(r) => Ok(r),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed(
                "all cluster workers terminated".to_string(),
            )),
        }
    }
}

impl WorkerTransport for LocalWorker {
    fn shard(&self) -> usize {
        self.shard
    }

    fn shards(&self) -> usize {
        self.peer_tx.len()
    }

    fn recv_ctl(&mut self) -> Result<Ctl, TransportError> {
        self.ctl_rx
            .recv()
            .map_err(|_| TransportError::Closed("leader control channel closed".to_string()))
    }

    fn send_report(&mut self, msg: Report) -> Result<(), TransportError> {
        self.report_tx
            .send(msg)
            .map_err(|_| TransportError::Closed("leader report channel closed".to_string()))
    }

    fn send_peer(&mut self, peer: usize, msg: ShardMsg) -> Result<(), TransportError> {
        self.peer_tx[peer]
            .send(msg)
            .map_err(|_| TransportError::Closed(format!("peer shard {peer} channel closed")))
    }

    fn recv_peer(&mut self, wait: Duration) -> Result<ShardMsg, TransportError> {
        match self.peer_rx.recv_timeout(wait) {
            Ok(m) => Ok(m),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed(
                "peer channels closed".to_string(),
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_cross_connects_leader_and_workers() {
        let (mut leader, mut workers) = pair(3);
        assert_eq!(leader.shards(), 3);
        assert_eq!(workers.len(), 3);
        for (i, w) in workers.iter().enumerate() {
            assert_eq!(w.shard(), i);
            assert_eq!(WorkerTransport::shards(w), 3);
        }
        // leader -> worker control
        leader.send_ctl(1, Ctl::PollWeights { job: 0 }).unwrap();
        assert!(matches!(
            workers[1].recv_ctl().unwrap(),
            Ctl::PollWeights { job: 0 }
        ));
        // worker -> worker peer plane
        workers[0]
            .send_peer(
                2,
                ShardMsg::Settle {
                    job: 0,
                    round: 0,
                    edge: 0,
                    loads: vec![],
                },
            )
            .unwrap();
        let got = workers[2].recv_peer(Duration::from_secs(1)).unwrap();
        assert!(matches!(got, ShardMsg::Settle { .. }));
        // worker -> leader reports
        workers[2]
            .send_report(Report::Weights {
                job: 0,
                shard: 2,
                weights: vec![1.0],
            })
            .unwrap();
        assert!(matches!(
            leader.recv_report(Duration::from_secs(1)).unwrap(),
            Report::Weights { shard: 2, .. }
        ));
    }

    #[test]
    fn dropped_workers_disconnect_the_report_channel() {
        let (mut leader, workers) = pair(2);
        drop(workers);
        match leader.recv_report(Duration::from_millis(10)) {
            Err(TransportError::Closed(_)) => {}
            other => panic!("expected Closed, got {other:?}"),
        }
        assert!(leader.send_ctl(0, Ctl::Shutdown).is_err());
    }

    #[test]
    fn empty_queue_times_out() {
        let (mut leader, mut workers) = pair(1);
        assert!(matches!(
            leader.recv_report(Duration::from_millis(5)),
            Err(TransportError::Timeout)
        ));
        assert!(matches!(
            workers[0].recv_peer(Duration::from_millis(5)),
            Err(TransportError::Timeout)
        ));
    }
}
