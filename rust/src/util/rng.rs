//! Deterministic pseudo-random number generation (no external crates).
//!
//! The offline build vendors only the `xla` dependency tree, so the
//! simulator carries its own RNG: [`SplitMix64`] for seeding and
//! [`Pcg64`] (PCG-XSL-RR 128/64) as the workhorse generator.  Every
//! experiment takes an explicit `u64` seed, making all paper figures
//! bit-reproducible.

/// SplitMix64 — used to expand a single `u64` seed into stream state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
///
/// Period 2^128, passes BigCrush, and is fast enough that RNG never shows
/// up in the simulator profile.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Create a generator from a seed; distinct seeds give distinct streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let s0 = sm.next_u64() as u128;
        let s1 = sm.next_u64() as u128;
        let i0 = sm.next_u64() as u128;
        let i1 = sm.next_u64() as u128;
        let mut rng = Self {
            state: (s0 << 64) | s1,
            inc: ((i0 << 64) | i1) | 1,
        };
        rng.next_u64();
        rng
    }

    /// Derive an independent child stream (for per-thread workers).
    pub fn split(&mut self) -> Pcg64 {
        Pcg64::new(self.next_u64())
    }

    /// Derive a stream from a tuple key, hashing the parts through
    /// SplitMix64.  The stream depends only on the key values, never on
    /// call order — the building block for counter-based determinism.
    pub fn keyed(parts: &[u64]) -> Pcg64 {
        let mut h = 0x243F_6A88_85A3_08D3u64; // pi fraction, arbitrary
        for &p in parts {
            let mut sm = SplitMix64::new(h ^ p);
            h = sm.next_u64();
        }
        Pcg64::new(h)
    }

    /// The per-edge stream of round `round`'s matching, edge index `edge`.
    ///
    /// Both BCM engines draw every edge's randomness from this stream, so
    /// a run is a pure function of `(seed, schedule, state)` no matter how
    /// edges are ordered or distributed over threads — the contract behind
    /// `bcm::parallel`'s bit-identical-to-sequential guarantee.
    pub fn for_edge(seed: u64, round: usize, edge: usize) -> Pcg64 {
        Pcg64::keyed(&[seed, round as u64, edge as u64])
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MULT)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's rejection method).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Uniform integer in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_inclusive(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(hi >= lo);
        lo + self.below(hi - lo + 1)
    }

    /// Fair coin.
    #[inline]
    pub fn coin(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    /// Exponential with the given mean (inverse-CDF).
    pub fn exponential(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.next_f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        let u1 = 1.0 - self.next_f64();
        let u2 = self.next_f64();
        let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        mean + std * z
    }

    /// Pareto with scale `x_m > 0` and shape `alpha > 0`.
    pub fn pareto(&mut self, scale: f64, alpha: f64) -> f64 {
        let u = 1.0 - self.next_f64();
        scale / u.powf(1.0 / alpha)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        debug_assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_streams() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_close() {
        let mut rng = Pcg64::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.uniform(0.0, 100.0)).sum::<f64>() / n as f64;
        assert!((mean - 50.0).abs() < 0.5, "mean={mean}");
    }

    #[test]
    fn below_is_unbiased_enough() {
        let mut rng = Pcg64::new(13);
        let mut counts = [0usize; 7];
        for _ in 0..70_000 {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 500.0, "{counts:?}");
        }
    }

    #[test]
    fn range_inclusive_hits_ends() {
        let mut rng = Pcg64::new(17);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..1000 {
            match rng.range_inclusive(3, 5) {
                3 => lo_seen = true,
                5 => hi_seen = true,
                4 => {}
                other => panic!("out of range: {other}"),
            }
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn exponential_mean() {
        let mut rng = Pcg64::new(19);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| rng.exponential(2.5)).sum::<f64>() / n as f64;
        assert!((mean - 2.5).abs() < 0.05, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(23);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal(10.0, 3.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean={mean}");
        assert!((var - 9.0).abs() < 0.3, "var={var}");
    }

    #[test]
    fn pareto_above_scale() {
        let mut rng = Pcg64::new(29);
        for _ in 0..10_000 {
            assert!(rng.pareto(1.5, 2.0) >= 1.5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(31);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg64::new(37);
        let s = rng.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn keyed_streams_deterministic_and_distinct() {
        let mut a = Pcg64::for_edge(1, 2, 3);
        let mut b = Pcg64::for_edge(1, 2, 3);
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // neighboring keys decorrelate
        for (s, r, e) in [(1, 2, 4), (1, 3, 3), (2, 2, 3), (0, 0, 0)] {
            let mut a = Pcg64::for_edge(1, 2, 3);
            let mut c = Pcg64::for_edge(s, r, e);
            let same = (0..64).filter(|_| a.next_u64() == c.next_u64()).count();
            assert!(same < 2, "key ({s},{r},{e}) collides");
        }
    }

    #[test]
    fn split_streams_independent() {
        let mut parent = Pcg64::new(41);
        let mut c1 = parent.split();
        let mut c2 = parent.split();
        let same = (0..64).filter(|_| c1.next_u64() == c2.next_u64()).count();
        assert!(same < 2);
    }
}
