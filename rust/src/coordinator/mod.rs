//! Distributed BCM runtime: a leader orchestrating shard workers over a
//! pluggable [`transport`] — in-process channels (one worker thread per
//! core) or TCP sockets (one worker OS process per shard).
//!
//! # Architecture
//!
//! The node range is carved into contiguous shards ([`ShardMap`]), one
//! worker per shard.  Per round, a matching is classified once into a
//! [`RoundPlan`]: intra-shard edges are solved locally with zero
//! messaging, and only cross-shard edges exchange (offer -> placement ->
//! settle) payloads between the two shards the edge spans.  The leader
//! is pure control plane: it dispatches rounds in **batches** of `B`
//! rounds per [`messages::Ctl::RunBatch`] and receives one coalesced
//! [`messages::Report::Batch`] per shard, so leader traffic amortizes to
//! O(shards / B) messages per round while worker-to-worker traffic stays
//! O(cut edges).  Within a batch, workers pipeline: each round runs
//! through a post-offers / solve-local / collect-settles state machine,
//! overlapping cross-shard communication with intra-shard computation,
//! and a shard may run rounds ahead of a slower peer (early messages are
//! stashed by round tag).
//!
//! # Determinism
//!
//! Every edge draws from the counter-based `Pcg64::for_edge(seed,
//! round, edge)` streams, so no RNG state ever crosses a message and
//! cluster runs are **bit-identical** to the in-process engines for any
//! shard count and any batch size ([`Cluster::run_seeded`],
//! [`Cluster::set_batch_rounds`]).
//!
//! # Failure model
//!
//! By default fail-stop: a worker failure (dead peer, protocol
//! violation, or a caught panic) is reported to the leader with the
//! round it occurred in, poisons the cluster against further rounds,
//! and re-surfaces from [`Cluster::shutdown`].  With a checkpoint
//! cadence set ([`Cluster::set_checkpoint_every`], flag
//! `--checkpoint-every`), workers stream load-state checkpoints to the
//! leader at batch boundaries and a failure triggers recovery instead:
//! the leader aborts the current wire job, waits
//! [`Cluster::set_rejoin_wait`] for a restarted worker to reclaim the
//! dead shard, otherwise reassigns its node range onto the survivors
//! ([`ShardMap::reassign`]), then replays from the last checkpoint.
//! Replay is bit-identical to an undisturbed run because every edge
//! draws from counter-based RNG streams keyed only on `(seed, round,
//! edge)` — no RNG state lives in the lost worker.  The full recovery
//! contract is specified in `DESIGN.md` §8 and the operational
//! procedures in `OPERATIONS.md`.
//!
//! # Transports
//!
//! All coordinator I/O flows through the [`transport`] traits.  The
//! [`transport::local`] backend keeps the historical in-process
//! channels; the [`transport::tcp`] backend frames the same messages
//! with the [`transport::codec`] wire format over real sockets, so
//! `bcm-dlb run --cluster --transport tcp` plus `bcm-dlb
//! cluster-worker` processes form a genuine multi-process cluster —
//! still bit-identical to `bcm::Sequential`.  Socket I/O runs entirely
//! on the calling thread through a readiness [`transport::poll`]er —
//! nonblocking sockets, incremental frame reassembly, buffered writes —
//! so neither endpoint spawns per-connection helper threads.  The
//! [`transport::tiered`] backend composes the two into a hierarchy: one
//! `cluster-worker` process per *host* runs several in-process shard
//! workers ([`TierLayout`]), a per-process egress pump multiplexes all
//! cross-host traffic onto the TCP host mesh, and
//! [`ShardMap::partition_tiered`] places the shards to minimize the
//! inter-host cut — so wire traffic scales with the slow-tier cut, not
//! the global cut ([`Cluster::spawn_tiered`],
//! [`Cluster::spawn_tcp_tiered`], DESIGN.md §10).
//!
//! # Multi-tenancy
//!
//! Every data-plane message carries a job id, so one worker set can
//! serve several independent runs at once: [`ShardPool`] is the
//! event-driven leader that multiplexes jobs ([`JobSpec`]) over a
//! shared worker pool and surfaces progress as [`JobEvent`]s — the
//! engine behind `bcm-dlb serve`.  The classic [`Cluster`] API is the
//! single-job special case (job id 0).
//!
//! The message-by-message wire protocol, ordering guarantees, the
//! on-the-wire frame format, and the determinism argument are specified
//! in `DESIGN.md` §"Cluster wire protocol".

#![deny(missing_docs)]

pub mod cluster;
pub mod messages;
pub mod shard;
pub mod transport;
pub mod worker;

pub use cluster::{resolve_batch_rounds, Cluster, JobEvent, JobSpec, MessageStats, ShardPool};
pub use shard::{resolve_shards, RoundPlan, ShardMap, ShardPlan, TierLayout};
pub use transport::tiered::TierTraffic;
pub use transport::{LeaderTransport, TransportError, TransportKind, WorkerTransport};
pub use worker::{ShardWorker, WorkerAlgo};
