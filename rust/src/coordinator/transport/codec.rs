//! The cluster wire codec: hand-rolled, dependency-free binary ser/de
//! for every message that crosses a TCP link, framed with a versioned
//! header and a CRC-32 payload checksum.
//!
//! The crate vendors no external crates, so the format is defined here
//! from first principles and `DESIGN.md` §6 ("Wire frame format") is its
//! normative specification.  In short:
//!
//! ```text
//! offset  size  field
//! 0       4     magic     the bytes "BCMW" (LE u32 0x574D4342)
//! 4       2     version   WIRE_VERSION, little-endian
//! 6       1     kind      message discriminant (see `kind` consts)
//! 7       1     reserved  must be 0
//! 8       4     length    payload byte count, little-endian
//! 12      4     checksum  CRC-32 (IEEE, poly 0xEDB88320) of the payload
//! 16      len   payload   fields in declaration order, little-endian
//! ```
//!
//! Integers are fixed-width little-endian (`usize` travels as `u64`),
//! `f64` travels as its IEEE-754 bit pattern (`to_bits`/`from_bits`, so
//! load weights round-trip *bit-exactly* — the determinism contract
//! survives the wire), `bool` is one byte (0/1), strings and vectors are
//! length-prefixed with a `u64` count.  Decoders reject truncated
//! frames, bad magic, version skew, checksum mismatches, unknown kinds,
//! trailing payload bytes, and length fields that overrun the frame —
//! each with a distinct [`CodecError`] so failure modes are testable.

use crate::coordinator::messages::{Ctl, Report, RoundReport, ShardMsg};
use crate::coordinator::shard::{RoundPlan, ShardPlan};
use crate::load::Load;
use crate::workload::service_traffic::ChurnOp;
use std::fmt;
use std::io::{Read, Write};
use std::sync::Arc;

/// Frame magic: the bytes `B C M W` read as a little-endian `u32`.
pub const FRAME_MAGIC: u32 = 0x574D_4342;

/// Current wire protocol version; bumped on any incompatible change.
/// Version 2 added the job id carried by every data-plane message plus
/// the `OpenJob`/`CloseJob` control frames of the multi-tenant service.
/// The elastic extension (checkpoint / rejoin / remesh frames, kinds
/// 15–17, and the widened `Hello`/`Init` handshake) stays within v2:
/// the new frames and fields only ever travel between endpoints that
/// both already speak them.  The churn frame (`ApplyChurn`, kind 18)
/// follows the same rule: only a leader driving a dynamic workload
/// emits it.  So does the two-tier extension (`Mux`, kind 19, and
/// `HostInit`, kind 20): those frames travel only on super-shard links
/// between a tiered leader and `cluster-worker --local-shards`
/// processes — endpoints that both already speak them.
pub const WIRE_VERSION: u16 = 2;

/// Frame header size in bytes (magic + version + kind + reserved +
/// length + checksum).
pub const HEADER_LEN: usize = 16;

/// Upper bound on a frame's payload size (256 MiB): a corrupted length
/// field must not translate into an unbounded allocation.
pub const MAX_PAYLOAD: usize = 256 << 20;

/// Message discriminants (the header's `kind` byte).
mod kind {
    pub const CTL_RUN_BATCH: u8 = 1;
    pub const CTL_POLL_WEIGHTS: u8 = 2;
    pub const CTL_SHUTDOWN: u8 = 3;
    pub const PEER_OFFER: u8 = 4;
    pub const PEER_SETTLE: u8 = 5;
    pub const REPORT_BATCH: u8 = 6;
    pub const REPORT_WEIGHTS: u8 = 7;
    pub const REPORT_FINAL: u8 = 8;
    pub const REPORT_ERROR: u8 = 9;
    pub const HELLO: u8 = 10;
    pub const INIT: u8 = 11;
    pub const PEER_HELLO: u8 = 12;
    pub const CTL_OPEN_JOB: u8 = 13;
    pub const CTL_CLOSE_JOB: u8 = 14;
    pub const REPORT_CHECKPOINT: u8 = 15;
    pub const CTL_ABORT_JOB: u8 = 16;
    pub const CTL_REMESH: u8 = 17;
    pub const CTL_APPLY_CHURN: u8 = 18;
    pub const MUX: u8 = 19;
    pub const HOST_INIT: u8 = 20;
}

/// Per-op tag bytes inside a [`kind::CTL_APPLY_CHURN`] payload.
mod churn_tag {
    pub const ARRIVE: u8 = 0;
    pub const DEPART: u8 = 1;
    pub const DRIFT: u8 = 2;
}

/// Everything that can travel over a cluster TCP link: the three
/// protocol message families plus the connection-setup handshake.
#[derive(Debug, PartialEq)]
pub enum WireMsg {
    /// Leader -> worker control message.
    Ctl(Ctl),
    /// Worker -> worker data-plane message.
    Peer(ShardMsg),
    /// Worker -> leader report.
    Report(Report),
    /// Worker -> leader, first frame after connecting: announces the
    /// address of the worker's peer-mesh listener.
    Hello {
        /// `host:port` the worker accepts peer connections on.
        peer_addr: String,
        /// Rejoin token: `None` for a fresh (or restarted) worker,
        /// `Some(t)` when reclaiming a shard with a token previously
        /// issued by the leader's `Init`.  A restarted process has no
        /// memory of its token and sends `None`; the leader only
        /// accepts the claim while it is waiting out a dead shard's
        /// rejoin window (`DESIGN.md` §8).
        rejoin: Option<u64>,
    },
    /// Leader -> worker, the reply to [`WireMsg::Hello`] once every
    /// worker has connected: the worker's identity and initial state.
    Init(Init),
    /// Worker -> worker, first frame on a freshly dialed peer
    /// connection: identifies the dialing shard.
    PeerHello {
        /// The dialing worker's shard index.
        shard: usize,
    },
    /// A shard-tagged envelope on a two-tier super-shard link: one host
    /// process multiplexes the control, report, and peer traffic of all
    /// of its in-process shard workers onto a single connection, so
    /// every frame names the global shard it belongs to.  On a
    /// leader -> host link `shard` is the destination worker; on a
    /// host -> leader link it is the reporting worker; on a
    /// host -> host link it is the destination of the peer message
    /// (whose `(job, round, edge)` tags travel inside the inner
    /// `ShardMsg` unchanged).  The inner message is encoded with its
    /// own kind byte but no nested header or checksum — the envelope's
    /// frame already covers both.  Nesting is one level deep by
    /// construction: a `Mux` (or `HostInit`) inside a `Mux` is rejected
    /// at decode as malformed.
    Mux {
        /// Global shard index the inner message is routed by.
        shard: usize,
        /// The enveloped protocol message.
        inner: Box<WireMsg>,
    },
    /// Leader -> host, the reply to [`WireMsg::Hello`] on a two-tier
    /// super-shard link: everything one `cluster-worker --local-shards`
    /// process needs to run its block of in-process shard workers.
    HostInit(HostInit),
}

/// The payload of [`WireMsg::Init`]: everything a worker process needs
/// to become shard `shard` of a cluster.
#[derive(Debug, PartialEq)]
pub struct Init {
    /// The shard index assigned to this worker.
    pub shard: usize,
    /// Total number of shards in the cluster.
    pub shards: usize,
    /// First node id the shard owns (`nodes[i]` holds node `lo + i`).
    pub lo: usize,
    /// The pair algorithm to run, as its canonical
    /// `PairAlgorithm::name()` spelling.
    pub algo: String,
    /// Initial per-node load lists, in node order.
    pub nodes: Vec<Vec<Load>>,
    /// Peer-mesh listener address of every worker, indexed by shard
    /// (entry `shard` is this worker's own address).
    pub peers: Vec<String>,
    /// True when this `Init` re-admits a worker into a running cluster:
    /// the worker accepts its `shards - 1` surviving peers (who are
    /// told to dial it via `Ctl::Remesh`) instead of dialing lower
    /// shards itself, and it skips the job-0 install — state arrives
    /// through `Ctl::OpenJob` carrying the checkpoint slice.
    pub rejoin: bool,
    /// First round the worker will be asked to execute (0 for a fresh
    /// cluster; the checkpoint round + 1 on rejoin).  Informational —
    /// every `RunBatch` names its rounds explicitly.
    pub resume_round: usize,
    /// Leader-issued identity token for this shard; a future `Hello`
    /// carrying it as `rejoin: Some(token)` reclaims the shard.
    pub token: u64,
}

/// The payload of [`WireMsg::HostInit`]: a host's identity and the
/// initial state of every in-process shard worker it runs.  The
/// two-tier analogue of [`Init`] — one frame per *host* instead of one
/// per shard, with the peer table listing host-mesh listeners instead
/// of per-shard ones (global shard `s` lives on host
/// `s / shards_per_host`, so the mesh needs no per-shard addressing).
#[derive(Debug, PartialEq)]
pub struct HostInit {
    /// The host index assigned to this process (its shards are
    /// `host * shards_per_host ..` the next block).
    pub host: usize,
    /// Total number of host processes.
    pub hosts: usize,
    /// In-process shard workers per host.
    pub shards_per_host: usize,
    /// The pair algorithm to run, as its canonical
    /// `PairAlgorithm::name()` spelling.
    pub algo: String,
    /// Per local shard, in global-shard order within the host's block:
    /// the shard's first node id and its initial per-node load lists.
    pub shards: Vec<(usize, Vec<Vec<Load>>)>,
    /// Host-mesh listener address of every host, indexed by host
    /// (entry `host` is this process's own address).
    pub host_peers: Vec<String>,
    /// Leader-issued identity token for this host.
    pub token: u64,
}

/// A decode failure; each frame defect maps to a distinct variant.
#[derive(Debug, PartialEq, Eq)]
pub enum CodecError {
    /// The buffer ends before the header or the declared payload does.
    Truncated,
    /// The first four bytes are not [`FRAME_MAGIC`].
    BadMagic,
    /// The frame's version field disagrees with [`WIRE_VERSION`].
    BadVersion(u16),
    /// The payload checksum does not match the header's CRC-32.
    BadChecksum,
    /// The header's `kind` byte names no known message.
    BadKind(u8),
    /// The payload decoded cleanly but left unconsumed bytes.
    Trailing,
    /// A field inside the payload is malformed (bad bool byte, a length
    /// prefix overrunning the frame, an oversized payload, ...).
    Malformed(&'static str),
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::Truncated => write!(f, "truncated frame"),
            CodecError::BadMagic => write!(f, "bad frame magic"),
            CodecError::BadVersion(v) => {
                write!(f, "wire version skew: got {v}, speak {WIRE_VERSION}")
            }
            CodecError::BadChecksum => write!(f, "frame checksum mismatch"),
            CodecError::BadKind(k) => write!(f, "unknown frame kind {k}"),
            CodecError::Trailing => write!(f, "trailing bytes after payload"),
            CodecError::Malformed(what) => write!(f, "malformed payload: {what}"),
        }
    }
}

/// CRC-32 (IEEE 802.3, reflected polynomial `0xEDB88320`), bitwise —
/// plenty fast for protocol frames and entirely self-contained.
pub fn crc32(data: &[u8]) -> u32 {
    let mut crc = 0xFFFF_FFFFu32;
    for &byte in data {
        crc ^= u32::from(byte);
        for _ in 0..8 {
            let mask = (crc & 1).wrapping_neg();
            crc = (crc >> 1) ^ (0xEDB8_8320 & mask);
        }
    }
    !crc
}

// ---------------------------------------------------------------- encode

fn put_u8(buf: &mut Vec<u8>, x: u8) {
    buf.push(x);
}

fn put_u16(buf: &mut Vec<u8>, x: u16) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u32(buf: &mut Vec<u8>, x: u32) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, x: u64) {
    buf.extend_from_slice(&x.to_le_bytes());
}

fn put_usize(buf: &mut Vec<u8>, x: usize) {
    put_u64(buf, x as u64);
}

fn put_f64(buf: &mut Vec<u8>, x: f64) {
    put_u64(buf, x.to_bits());
}

fn put_bool(buf: &mut Vec<u8>, x: bool) {
    put_u8(buf, u8::from(x));
}

fn put_str(buf: &mut Vec<u8>, s: &str) {
    put_usize(buf, s.len());
    buf.extend_from_slice(s.as_bytes());
}

fn put_load(buf: &mut Vec<u8>, l: &Load) {
    put_u64(buf, l.id);
    put_f64(buf, l.weight);
    put_bool(buf, l.mobile);
}

fn put_loads(buf: &mut Vec<u8>, loads: &[Load]) {
    put_usize(buf, loads.len());
    for l in loads {
        put_load(buf, l);
    }
}

fn put_shard_plan(buf: &mut Vec<u8>, p: &ShardPlan) {
    put_usize(buf, p.local.len());
    for &(e, u, v) in &p.local {
        put_usize(buf, e);
        put_u32(buf, u);
        put_u32(buf, v);
    }
    put_usize(buf, p.master.len());
    for &(e, u, v, slave) in &p.master {
        put_usize(buf, e);
        put_u32(buf, u);
        put_u32(buf, v);
        put_usize(buf, slave);
    }
    put_usize(buf, p.slave.len());
    for &(e, v, master) in &p.slave {
        put_usize(buf, e);
        put_u32(buf, v);
        put_usize(buf, master);
    }
}

fn put_round_plan(buf: &mut Vec<u8>, p: &RoundPlan) {
    put_usize(buf, p.cross_edges);
    put_usize(buf, p.edges);
    put_usize(buf, p.per_shard.len());
    for sp in &p.per_shard {
        put_shard_plan(buf, sp);
    }
}

/// Serialize a message's payload and return `(kind, payload)`.
fn encode_payload(msg: &WireMsg) -> (u8, Vec<u8>) {
    let mut b = Vec::new();
    let kind = match msg {
        WireMsg::Ctl(Ctl::OpenJob {
            job,
            lo,
            algo,
            nodes,
        }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *lo);
            put_str(&mut b, algo);
            put_usize(&mut b, nodes.len());
            for node in nodes {
                put_loads(&mut b, node);
            }
            kind::CTL_OPEN_JOB
        }
        WireMsg::Ctl(Ctl::CloseJob { job }) => {
            put_u32(&mut b, *job);
            kind::CTL_CLOSE_JOB
        }
        WireMsg::Ctl(Ctl::RunBatch {
            job,
            start_round,
            rounds,
            seed,
            plans,
            checkpoint,
        }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *start_round);
            put_usize(&mut b, *rounds);
            put_u64(&mut b, *seed);
            put_usize(&mut b, plans.len());
            for p in plans.iter() {
                put_round_plan(&mut b, p);
            }
            put_bool(&mut b, *checkpoint);
            kind::CTL_RUN_BATCH
        }
        WireMsg::Ctl(Ctl::PollWeights { job }) => {
            put_u32(&mut b, *job);
            kind::CTL_POLL_WEIGHTS
        }
        WireMsg::Ctl(Ctl::ApplyChurn { job, ops }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, ops.len());
            for op in ops {
                match *op {
                    ChurnOp::Arrive { node, id, weight } => {
                        put_u8(&mut b, churn_tag::ARRIVE);
                        put_u32(&mut b, node);
                        put_u64(&mut b, id);
                        put_f64(&mut b, weight);
                    }
                    ChurnOp::Depart { node, k } => {
                        put_u8(&mut b, churn_tag::DEPART);
                        put_u32(&mut b, node);
                        put_u64(&mut b, k);
                    }
                    ChurnOp::Drift { node, k, factor } => {
                        put_u8(&mut b, churn_tag::DRIFT);
                        put_u32(&mut b, node);
                        put_u64(&mut b, k);
                        put_f64(&mut b, factor);
                    }
                }
            }
            kind::CTL_APPLY_CHURN
        }
        WireMsg::Ctl(Ctl::AbortJob { job }) => {
            put_u32(&mut b, *job);
            kind::CTL_ABORT_JOB
        }
        WireMsg::Ctl(Ctl::Remesh { shard, addr }) => {
            put_usize(&mut b, *shard);
            put_str(&mut b, addr);
            kind::CTL_REMESH
        }
        WireMsg::Ctl(Ctl::Shutdown) => kind::CTL_SHUTDOWN,
        WireMsg::Peer(ShardMsg::Offer {
            job,
            round,
            edge,
            loads,
            pinned,
        }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *round);
            put_usize(&mut b, *edge);
            put_loads(&mut b, loads);
            put_f64(&mut b, *pinned);
            kind::PEER_OFFER
        }
        WireMsg::Peer(ShardMsg::Settle {
            job,
            round,
            edge,
            loads,
        }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *round);
            put_usize(&mut b, *edge);
            put_loads(&mut b, loads);
            kind::PEER_SETTLE
        }
        WireMsg::Report(Report::Batch { job, shard, rounds }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *shard);
            put_usize(&mut b, rounds.len());
            for r in rounds {
                put_usize(&mut b, r.round);
                put_usize(&mut b, r.movements);
                put_f64(&mut b, r.min_weight);
                put_f64(&mut b, r.max_weight);
                put_usize(&mut b, r.peer_msgs);
            }
            kind::REPORT_BATCH
        }
        WireMsg::Report(Report::Weights {
            job,
            shard,
            weights,
        }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *shard);
            put_usize(&mut b, weights.len());
            for &w in weights {
                put_f64(&mut b, w);
            }
            kind::REPORT_WEIGHTS
        }
        WireMsg::Report(Report::Checkpoint {
            job,
            shard,
            round,
            nodes,
        }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *shard);
            put_usize(&mut b, *round);
            // declared slice size: the total load count across all
            // nodes, cross-checked by the decoder against the loads
            // the payload actually carries
            let total: u64 = nodes.iter().map(|n| n.len() as u64).sum();
            put_u64(&mut b, total);
            put_usize(&mut b, nodes.len());
            for node in nodes {
                put_loads(&mut b, node);
            }
            kind::REPORT_CHECKPOINT
        }
        WireMsg::Report(Report::Final { job, shard, nodes }) => {
            put_u32(&mut b, *job);
            put_usize(&mut b, *shard);
            put_usize(&mut b, nodes.len());
            for node in nodes {
                put_loads(&mut b, node);
            }
            kind::REPORT_FINAL
        }
        WireMsg::Report(Report::Error {
            job,
            shard,
            round,
            message,
        }) => {
            match job {
                Some(j) => {
                    put_bool(&mut b, true);
                    put_u32(&mut b, *j);
                }
                None => put_bool(&mut b, false),
            }
            put_usize(&mut b, *shard);
            match round {
                Some(r) => {
                    put_bool(&mut b, true);
                    put_usize(&mut b, *r);
                }
                None => put_bool(&mut b, false),
            }
            put_str(&mut b, message);
            kind::REPORT_ERROR
        }
        WireMsg::Hello { peer_addr, rejoin } => {
            put_str(&mut b, peer_addr);
            match rejoin {
                Some(t) => {
                    put_bool(&mut b, true);
                    put_u64(&mut b, *t);
                }
                None => put_bool(&mut b, false),
            }
            kind::HELLO
        }
        WireMsg::Init(init) => {
            put_usize(&mut b, init.shard);
            put_usize(&mut b, init.shards);
            put_usize(&mut b, init.lo);
            put_str(&mut b, &init.algo);
            put_usize(&mut b, init.nodes.len());
            for node in &init.nodes {
                put_loads(&mut b, node);
            }
            put_usize(&mut b, init.peers.len());
            for p in &init.peers {
                put_str(&mut b, p);
            }
            put_bool(&mut b, init.rejoin);
            put_usize(&mut b, init.resume_round);
            put_u64(&mut b, init.token);
            kind::INIT
        }
        WireMsg::PeerHello { shard } => {
            put_usize(&mut b, *shard);
            kind::PEER_HELLO
        }
        WireMsg::Mux { shard, inner } => {
            let (ik, ip) = encode_payload(inner);
            // the envelope carries protocol messages, never another
            // envelope: one level of nesting, enforced on both ends
            assert!(
                ik != kind::MUX && ik != kind::HOST_INIT,
                "Mux frames carry protocol messages, never nested Mux/HostInit"
            );
            put_usize(&mut b, *shard);
            put_u8(&mut b, ik);
            b.extend_from_slice(&ip);
            kind::MUX
        }
        WireMsg::HostInit(hi) => {
            put_usize(&mut b, hi.host);
            put_usize(&mut b, hi.hosts);
            put_usize(&mut b, hi.shards_per_host);
            put_str(&mut b, &hi.algo);
            put_usize(&mut b, hi.shards.len());
            for (lo, nodes) in &hi.shards {
                put_usize(&mut b, *lo);
                put_usize(&mut b, nodes.len());
                for node in nodes {
                    put_loads(&mut b, node);
                }
            }
            put_usize(&mut b, hi.host_peers.len());
            for p in &hi.host_peers {
                put_str(&mut b, p);
            }
            put_u64(&mut b, hi.token);
            kind::HOST_INIT
        }
    };
    (kind, b)
}

/// Serialize `msg` into one self-contained frame (header + payload).
pub fn encode_frame(msg: &WireMsg) -> Vec<u8> {
    let (kind, payload) = encode_payload(msg);
    assert!(payload.len() <= MAX_PAYLOAD, "frame payload exceeds MAX_PAYLOAD");
    let mut frame = Vec::with_capacity(HEADER_LEN + payload.len());
    put_u32(&mut frame, FRAME_MAGIC);
    put_u16(&mut frame, WIRE_VERSION);
    put_u8(&mut frame, kind);
    put_u8(&mut frame, 0); // reserved
    put_u32(&mut frame, payload.len() as u32);
    put_u32(&mut frame, crc32(&payload));
    frame.extend_from_slice(&payload);
    frame
}

// ---------------------------------------------------------------- decode

/// A bounds-checked read cursor over a payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::Truncated);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    fn usize(&mut self) -> Result<usize, CodecError> {
        usize::try_from(self.u64()?).map_err(|_| CodecError::Malformed("usize overflow"))
    }

    fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn bool(&mut self) -> Result<bool, CodecError> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            _ => Err(CodecError::Malformed("bad bool byte")),
        }
    }

    /// Read a vector length prefix and sanity-check it against the bytes
    /// actually left in the frame (each element needs at least
    /// `min_elem` bytes), so a corrupted count cannot trigger an
    /// unbounded allocation.
    fn vec_len(&mut self, min_elem: usize) -> Result<usize, CodecError> {
        let n = self.usize()?;
        match n.checked_mul(min_elem.max(1)) {
            Some(need) if need <= self.remaining() => Ok(n),
            _ => Err(CodecError::Malformed("length prefix overruns frame")),
        }
    }

    fn str(&mut self) -> Result<String, CodecError> {
        let n = self.vec_len(1)?;
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::Malformed("non-utf8 string"))
    }

    fn load(&mut self) -> Result<Load, CodecError> {
        Ok(Load {
            id: self.u64()?,
            weight: self.f64()?,
            mobile: self.bool()?,
        })
    }

    fn loads(&mut self) -> Result<Vec<Load>, CodecError> {
        let n = self.vec_len(17)?; // id(8) + weight(8) + mobile(1)
        let mut v = Vec::with_capacity(n);
        for _ in 0..n {
            v.push(self.load()?);
        }
        Ok(v)
    }

    fn shard_plan(&mut self) -> Result<ShardPlan, CodecError> {
        let n_local = self.vec_len(16)?;
        let mut local = Vec::with_capacity(n_local);
        for _ in 0..n_local {
            local.push((self.usize()?, self.u32()?, self.u32()?));
        }
        let n_master = self.vec_len(24)?;
        let mut master = Vec::with_capacity(n_master);
        for _ in 0..n_master {
            master.push((self.usize()?, self.u32()?, self.u32()?, self.usize()?));
        }
        let n_slave = self.vec_len(20)?;
        let mut slave = Vec::with_capacity(n_slave);
        for _ in 0..n_slave {
            slave.push((self.usize()?, self.u32()?, self.usize()?));
        }
        Ok(ShardPlan {
            local,
            master,
            slave,
        })
    }

    fn round_plan(&mut self) -> Result<RoundPlan, CodecError> {
        let cross_edges = self.usize()?;
        let edges = self.usize()?;
        let n = self.vec_len(24)?; // three length prefixes minimum
        let mut per_shard = Vec::with_capacity(n);
        for _ in 0..n {
            per_shard.push(self.shard_plan()?);
        }
        Ok(RoundPlan {
            per_shard,
            cross_edges,
            edges,
        })
    }
}

/// Deserialize a payload of the given `kind`.
fn decode_payload(kind: u8, payload: &[u8]) -> Result<WireMsg, CodecError> {
    let mut c = Cursor::new(payload);
    let msg = match kind {
        kind::CTL_OPEN_JOB => {
            let job = c.u32()?;
            let lo = c.usize()?;
            let algo = c.str()?;
            let n = c.vec_len(8)?;
            let mut nodes = Vec::with_capacity(n);
            for _ in 0..n {
                nodes.push(c.loads()?);
            }
            WireMsg::Ctl(Ctl::OpenJob {
                job,
                lo,
                algo,
                nodes,
            })
        }
        kind::CTL_CLOSE_JOB => WireMsg::Ctl(Ctl::CloseJob { job: c.u32()? }),
        kind::CTL_RUN_BATCH => {
            let job = c.u32()?;
            let start_round = c.usize()?;
            let rounds = c.usize()?;
            let seed = c.u64()?;
            let n = c.vec_len(24)?;
            let mut plans = Vec::with_capacity(n);
            for _ in 0..n {
                plans.push(Arc::new(c.round_plan()?));
            }
            let checkpoint = c.bool()?;
            WireMsg::Ctl(Ctl::RunBatch {
                job,
                start_round,
                rounds,
                seed,
                plans: Arc::new(plans),
                checkpoint,
            })
        }
        kind::CTL_POLL_WEIGHTS => WireMsg::Ctl(Ctl::PollWeights { job: c.u32()? }),
        kind::CTL_APPLY_CHURN => {
            let job = c.u32()?;
            // smallest op = tag(1) + node(4) + k(8)
            let n = c.vec_len(13)?;
            let mut ops = Vec::with_capacity(n);
            for _ in 0..n {
                let op = match c.u8()? {
                    churn_tag::ARRIVE => ChurnOp::Arrive {
                        node: c.u32()?,
                        id: c.u64()?,
                        weight: c.f64()?,
                    },
                    churn_tag::DEPART => ChurnOp::Depart {
                        node: c.u32()?,
                        k: c.u64()?,
                    },
                    churn_tag::DRIFT => ChurnOp::Drift {
                        node: c.u32()?,
                        k: c.u64()?,
                        factor: c.f64()?,
                    },
                    _ => return Err(CodecError::Malformed("bad churn op tag")),
                };
                ops.push(op);
            }
            WireMsg::Ctl(Ctl::ApplyChurn { job, ops })
        }
        kind::CTL_ABORT_JOB => WireMsg::Ctl(Ctl::AbortJob { job: c.u32()? }),
        kind::CTL_REMESH => WireMsg::Ctl(Ctl::Remesh {
            shard: c.usize()?,
            addr: c.str()?,
        }),
        kind::CTL_SHUTDOWN => WireMsg::Ctl(Ctl::Shutdown),
        kind::PEER_OFFER => WireMsg::Peer(ShardMsg::Offer {
            job: c.u32()?,
            round: c.usize()?,
            edge: c.usize()?,
            loads: c.loads()?,
            pinned: c.f64()?,
        }),
        kind::PEER_SETTLE => WireMsg::Peer(ShardMsg::Settle {
            job: c.u32()?,
            round: c.usize()?,
            edge: c.usize()?,
            loads: c.loads()?,
        }),
        kind::REPORT_BATCH => {
            let job = c.u32()?;
            let shard = c.usize()?;
            let n = c.vec_len(40)?;
            let mut rounds = Vec::with_capacity(n);
            for _ in 0..n {
                rounds.push(RoundReport {
                    round: c.usize()?,
                    movements: c.usize()?,
                    min_weight: c.f64()?,
                    max_weight: c.f64()?,
                    peer_msgs: c.usize()?,
                });
            }
            WireMsg::Report(Report::Batch { job, shard, rounds })
        }
        kind::REPORT_WEIGHTS => {
            let job = c.u32()?;
            let shard = c.usize()?;
            let n = c.vec_len(8)?;
            let mut weights = Vec::with_capacity(n);
            for _ in 0..n {
                weights.push(c.f64()?);
            }
            WireMsg::Report(Report::Weights {
                job,
                shard,
                weights,
            })
        }
        kind::REPORT_CHECKPOINT => {
            let job = c.u32()?;
            let shard = c.usize()?;
            let round = c.usize()?;
            let declared = c.u64()?;
            // the declared slice size must fit the frame before any
            // allocation happens (17 bytes per load minimum) ...
            match declared.checked_mul(17) {
                Some(need) if need <= c.remaining() as u64 => {}
                _ => return Err(CodecError::Malformed("length prefix overruns frame")),
            }
            let n = c.vec_len(8)?;
            let mut nodes = Vec::with_capacity(n);
            let mut total = 0u64;
            for _ in 0..n {
                let node = c.loads()?;
                total += node.len() as u64;
                nodes.push(node);
            }
            // ... and must agree with the loads the payload actually
            // carried: a frame whose header promises one slice size but
            // delivers another is corrupt, not trusted
            if total != declared {
                return Err(CodecError::Malformed(
                    "checkpoint declared slice size disagrees with payload",
                ));
            }
            WireMsg::Report(Report::Checkpoint {
                job,
                shard,
                round,
                nodes,
            })
        }
        kind::REPORT_FINAL => {
            let job = c.u32()?;
            let shard = c.usize()?;
            let n = c.vec_len(8)?;
            let mut nodes = Vec::with_capacity(n);
            for _ in 0..n {
                nodes.push(c.loads()?);
            }
            WireMsg::Report(Report::Final { job, shard, nodes })
        }
        kind::REPORT_ERROR => {
            let job = if c.bool()? { Some(c.u32()?) } else { None };
            let shard = c.usize()?;
            let round = if c.bool()? { Some(c.usize()?) } else { None };
            let message = c.str()?;
            WireMsg::Report(Report::Error {
                job,
                shard,
                round,
                message,
            })
        }
        kind::HELLO => {
            let peer_addr = c.str()?;
            let rejoin = if c.bool()? { Some(c.u64()?) } else { None };
            WireMsg::Hello { peer_addr, rejoin }
        }
        kind::INIT => {
            let shard = c.usize()?;
            let shards = c.usize()?;
            let lo = c.usize()?;
            let algo = c.str()?;
            let n = c.vec_len(8)?;
            let mut nodes = Vec::with_capacity(n);
            for _ in 0..n {
                nodes.push(c.loads()?);
            }
            let np = c.vec_len(8)?;
            let mut peers = Vec::with_capacity(np);
            for _ in 0..np {
                peers.push(c.str()?);
            }
            let rejoin = c.bool()?;
            let resume_round = c.usize()?;
            let token = c.u64()?;
            WireMsg::Init(Init {
                shard,
                shards,
                lo,
                algo,
                nodes,
                peers,
                rejoin,
                resume_round,
                token,
            })
        }
        kind::PEER_HELLO => WireMsg::PeerHello { shard: c.usize()? },
        kind::MUX => {
            let shard = c.usize()?;
            let ik = c.u8()?;
            // reject envelope-in-envelope before recursing, so a crafted
            // frame cannot drive the decoder arbitrarily deep
            if ik == kind::MUX || ik == kind::HOST_INIT {
                return Err(CodecError::Malformed("nested mux frame"));
            }
            let rest = c.take(c.remaining())?;
            WireMsg::Mux {
                shard,
                inner: Box::new(decode_payload(ik, rest)?),
            }
        }
        kind::HOST_INIT => {
            let host = c.usize()?;
            let hosts = c.usize()?;
            let shards_per_host = c.usize()?;
            let algo = c.str()?;
            // each shard entry needs at least lo(8) + node count(8)
            let ns = c.vec_len(16)?;
            let mut shards = Vec::with_capacity(ns);
            for _ in 0..ns {
                let lo = c.usize()?;
                let nn = c.vec_len(8)?;
                let mut nodes = Vec::with_capacity(nn);
                for _ in 0..nn {
                    nodes.push(c.loads()?);
                }
                shards.push((lo, nodes));
            }
            let np = c.vec_len(8)?;
            let mut host_peers = Vec::with_capacity(np);
            for _ in 0..np {
                host_peers.push(c.str()?);
            }
            let token = c.u64()?;
            WireMsg::HostInit(HostInit {
                host,
                hosts,
                shards_per_host,
                algo,
                shards,
                host_peers,
                token,
            })
        }
        other => return Err(CodecError::BadKind(other)),
    };
    if c.remaining() != 0 {
        return Err(CodecError::Trailing);
    }
    Ok(msg)
}

/// Decode one frame from the front of `buf`; returns the message and
/// the number of bytes consumed.
pub fn decode_frame(buf: &[u8]) -> Result<(WireMsg, usize), CodecError> {
    if buf.len() < HEADER_LEN {
        return Err(CodecError::Truncated);
    }
    let mut h = Cursor::new(&buf[..HEADER_LEN]);
    let magic = h.u32().expect("header sized");
    if magic != FRAME_MAGIC {
        return Err(CodecError::BadMagic);
    }
    let version = {
        let b = h.take(2).expect("header sized");
        u16::from_le_bytes([b[0], b[1]])
    };
    if version != WIRE_VERSION {
        return Err(CodecError::BadVersion(version));
    }
    let kind = h.u8().expect("header sized");
    let reserved = h.u8().expect("header sized");
    if reserved != 0 {
        // actually reserved: a future revision may repurpose it only if
        // version-1 peers reject nonzero values today
        return Err(CodecError::Malformed("reserved header byte must be 0"));
    }
    let len = h.u32().expect("header sized") as usize;
    let checksum = h.u32().expect("header sized");
    if len > MAX_PAYLOAD {
        return Err(CodecError::Malformed("payload length exceeds MAX_PAYLOAD"));
    }
    if buf.len() < HEADER_LEN + len {
        return Err(CodecError::Truncated);
    }
    let payload = &buf[HEADER_LEN..HEADER_LEN + len];
    if crc32(payload) != checksum {
        return Err(CodecError::BadChecksum);
    }
    let msg = decode_payload(kind, payload)?;
    Ok((msg, HEADER_LEN + len))
}

/// Write one frame to a byte sink (a `TcpStream`), flushing it.
pub fn write_frame<W: Write>(w: &mut W, msg: &WireMsg) -> std::io::Result<()> {
    let frame = encode_frame(msg);
    w.write_all(&frame)?;
    w.flush()
}

/// Read exactly one frame from a byte source (a `TcpStream`).
///
/// Transport-level failures (EOF, reset) surface as the underlying
/// `io::Error`; protocol-level defects (bad magic, checksum, version
/// skew, malformed payload) surface as `io::ErrorKind::InvalidData`
/// wrapping the [`CodecError`]'s description.
pub fn read_frame<R: Read>(r: &mut R) -> std::io::Result<WireMsg> {
    let mut header = [0u8; HEADER_LEN];
    r.read_exact(&mut header)?;
    let len = u32::from_le_bytes([header[8], header[9], header[10], header[11]]) as usize;
    if len > MAX_PAYLOAD {
        return Err(invalid_data(CodecError::Malformed(
            "payload length exceeds MAX_PAYLOAD",
        )));
    }
    let mut frame = Vec::with_capacity(HEADER_LEN + len);
    frame.extend_from_slice(&header);
    frame.resize(HEADER_LEN + len, 0);
    r.read_exact(&mut frame[HEADER_LEN..])?;
    match decode_frame(&frame) {
        Ok((msg, used)) => {
            debug_assert_eq!(used, frame.len());
            Ok(msg)
        }
        Err(e) => Err(invalid_data(e)),
    }
}

fn invalid_data(e: CodecError) -> std::io::Error {
    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(msg: WireMsg) -> WireMsg {
        let frame = encode_frame(&msg);
        let (back, used) = decode_frame(&frame).expect("frame decodes");
        assert_eq!(used, frame.len());
        assert_eq!(back, msg, "round-trip changed the message");
        back
    }

    #[test]
    fn simple_variants_roundtrip() {
        roundtrip(WireMsg::Ctl(Ctl::PollWeights { job: 0 }));
        roundtrip(WireMsg::Ctl(Ctl::Shutdown));
        roundtrip(WireMsg::Ctl(Ctl::CloseJob { job: 9 }));
        roundtrip(WireMsg::Ctl(Ctl::OpenJob {
            job: 3,
            lo: 4,
            algo: "sorted:quick".into(),
            nodes: vec![vec![Load::new(1, 2.5)], vec![]],
        }));
        roundtrip(WireMsg::Ctl(Ctl::AbortJob { job: 12 }));
        roundtrip(WireMsg::Ctl(Ctl::ApplyChurn { job: 7, ops: vec![] }));
        roundtrip(WireMsg::Ctl(Ctl::ApplyChurn {
            job: 7,
            ops: vec![
                ChurnOp::Arrive {
                    node: 3,
                    id: (9u64 << 40) | (3 << 16) | 2,
                    weight: 1.625,
                },
                ChurnOp::Depart {
                    node: 0,
                    k: u64::MAX,
                },
                ChurnOp::Drift {
                    node: 11,
                    k: 42,
                    factor: 0.875,
                },
            ],
        }));
        roundtrip(WireMsg::Ctl(Ctl::Remesh {
            shard: 1,
            addr: "10.0.0.5:4512".into(),
        }));
        roundtrip(WireMsg::PeerHello { shard: 3 });
        roundtrip(WireMsg::Hello {
            peer_addr: "127.0.0.1:4510".into(),
            rejoin: None,
        });
        roundtrip(WireMsg::Hello {
            peer_addr: "127.0.0.1:4510".into(),
            rejoin: Some(0xDEAD_BEEF_u64),
        });
        roundtrip(WireMsg::Report(Report::Checkpoint {
            job: 2,
            shard: 1,
            round: 63,
            nodes: vec![vec![Load::new(5, 1.25)], vec![], vec![Load::pinned(6, 0.5)]],
        }));
        roundtrip(WireMsg::Report(Report::Error {
            job: Some(4),
            shard: 2,
            round: Some(7),
            message: "worker panicked: injected fault".into(),
        }));
        roundtrip(WireMsg::Report(Report::Error {
            job: None,
            shard: 0,
            round: None,
            message: String::new(),
        }));
    }

    #[test]
    fn mux_envelope_roundtrips_every_protocol_kind() {
        // the envelope must be transparent: whatever protocol message
        // goes in comes back out byte-identical, for ctl, peer, and
        // report traffic alike
        roundtrip(WireMsg::Mux {
            shard: 5,
            inner: Box::new(WireMsg::Ctl(Ctl::PollWeights { job: 3 })),
        });
        roundtrip(WireMsg::Mux {
            shard: 0,
            inner: Box::new(WireMsg::Peer(ShardMsg::Offer {
                job: 1,
                round: 17,
                edge: 4,
                loads: vec![Load::new(9, 2.25), Load::pinned(10, 0.5)],
                pinned: 1.75,
            })),
        });
        roundtrip(WireMsg::Mux {
            shard: 7,
            inner: Box::new(WireMsg::Peer(ShardMsg::Settle {
                job: 1,
                round: 17,
                edge: 4,
                loads: vec![],
            })),
        });
        roundtrip(WireMsg::Mux {
            shard: 2,
            inner: Box::new(WireMsg::Report(Report::Error {
                job: None,
                shard: 2,
                round: None,
                message: "worker connection lost: reset".into(),
            })),
        });
    }

    #[test]
    fn host_init_roundtrips() {
        roundtrip(WireMsg::HostInit(HostInit {
            host: 1,
            hosts: 2,
            shards_per_host: 2,
            algo: "sorted:quick".into(),
            shards: vec![
                (8, vec![vec![Load::new(1, 2.5)], vec![]]),
                (10, vec![vec![Load::pinned(2, 0.25)]]),
            ],
            host_peers: vec!["127.0.0.1:4610".into(), "127.0.0.1:4611".into()],
            token: 0xFEED_F00D_u64,
        }));
        roundtrip(WireMsg::HostInit(HostInit {
            host: 0,
            hosts: 1,
            shards_per_host: 1,
            algo: String::new(),
            shards: vec![],
            host_peers: vec![],
            token: 0,
        }));
    }

    #[test]
    fn nested_mux_is_rejected() {
        // hand-build a Mux whose inner kind byte claims another Mux: the
        // decoder must refuse before recursing (bounded nesting depth)
        let mut payload = Vec::new();
        put_usize(&mut payload, 3); // shard
        put_u8(&mut payload, kind::MUX); // inner kind: another envelope
        put_usize(&mut payload, 4); // would-be inner shard
        put_u8(&mut payload, kind::CTL_SHUTDOWN);
        let mut frame = Vec::new();
        put_u32(&mut frame, FRAME_MAGIC);
        put_u16(&mut frame, WIRE_VERSION);
        put_u8(&mut frame, kind::MUX);
        put_u8(&mut frame, 0);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        assert_eq!(
            decode_frame(&frame).unwrap_err(),
            CodecError::Malformed("nested mux frame")
        );

        // a HostInit inner is handshake traffic, equally refused
        let mut payload = Vec::new();
        put_usize(&mut payload, 3); // shard
        put_u8(&mut payload, kind::HOST_INIT);
        let mut frame = Vec::new();
        put_u32(&mut frame, FRAME_MAGIC);
        put_u16(&mut frame, WIRE_VERSION);
        put_u8(&mut frame, kind::MUX);
        put_u8(&mut frame, 0);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        assert_eq!(
            decode_frame(&frame).unwrap_err(),
            CodecError::Malformed("nested mux frame")
        );
    }

    #[test]
    fn f64_bit_patterns_survive() {
        for w in [0.0f64, -0.0, 1.5, 1e-300, 1e300, f64::MIN_POSITIVE] {
            let msg = WireMsg::Peer(ShardMsg::Offer {
                job: 0,
                round: 1,
                edge: 2,
                loads: vec![Load::new(9, w)],
                pinned: w,
            });
            let frame = encode_frame(&msg);
            let (back, _) = decode_frame(&frame).unwrap();
            match back {
                WireMsg::Peer(ShardMsg::Offer { loads, pinned, .. }) => {
                    assert_eq!(loads[0].weight.to_bits(), w.to_bits());
                    assert_eq!(pinned.to_bits(), w.to_bits());
                }
                other => panic!("wrong variant back: {other:?}"),
            }
        }
    }

    #[test]
    fn truncation_is_detected_at_every_cut() {
        let msg = WireMsg::Report(Report::Weights {
            job: 0,
            shard: 1,
            weights: vec![1.0, 2.0, 3.0],
        });
        let frame = encode_frame(&msg);
        for cut in 0..frame.len() {
            assert_eq!(
                decode_frame(&frame[..cut]).unwrap_err(),
                CodecError::Truncated,
                "cut at {cut}"
            );
        }
    }

    #[test]
    fn corruption_version_kind_and_trailing_are_rejected() {
        let msg = WireMsg::Hello {
            peer_addr: "10.0.0.1:9".into(),
            rejoin: None,
        };
        let frame = encode_frame(&msg);

        // flip a payload byte -> checksum mismatch
        let mut bad = frame.clone();
        let last = bad.len() - 1;
        bad[last] ^= 0xFF;
        assert_eq!(decode_frame(&bad).unwrap_err(), CodecError::BadChecksum);

        // bump the version -> version skew
        let mut bad = frame.clone();
        bad[4] = 0xFE;
        bad[5] = 0xCA;
        assert_eq!(
            decode_frame(&bad).unwrap_err(),
            CodecError::BadVersion(0xCAFE)
        );

        // clobber the magic
        let mut bad = frame.clone();
        bad[0] = 0;
        assert_eq!(decode_frame(&bad).unwrap_err(), CodecError::BadMagic);

        // unknown kind (checksum covers only the payload, so this hits
        // the kind check, not the checksum)
        let mut bad = frame.clone();
        bad[6] = 200;
        assert_eq!(decode_frame(&bad).unwrap_err(), CodecError::BadKind(200));

        // nonzero reserved byte is rejected, per the normative spec
        let mut bad = frame.clone();
        bad[7] = 1;
        assert_eq!(
            decode_frame(&bad).unwrap_err(),
            CodecError::Malformed("reserved header byte must be 0")
        );

        // payload padded with an extra byte (length + checksum fixed up)
        let payload_len = frame.len() - HEADER_LEN;
        let mut bad = frame.clone();
        bad.push(0);
        bad[8..12].copy_from_slice(&((payload_len + 1) as u32).to_le_bytes());
        let crc = crc32(&bad[HEADER_LEN..]);
        bad[12..16].copy_from_slice(&crc.to_le_bytes());
        assert_eq!(decode_frame(&bad).unwrap_err(), CodecError::Trailing);
    }

    #[test]
    fn bad_churn_tag_is_malformed() {
        // an ApplyChurn op with an unknown tag byte is rejected; the
        // per-op minimum (13 bytes) also bounds hostile op counts
        let mut payload = Vec::new();
        put_u32(&mut payload, 0); // job
        put_usize(&mut payload, 1); // op count
        put_u8(&mut payload, 9); // unknown tag
        put_u32(&mut payload, 0); // node
        put_u64(&mut payload, 0); // k
        let mut frame = Vec::new();
        put_u32(&mut frame, FRAME_MAGIC);
        put_u16(&mut frame, WIRE_VERSION);
        put_u8(&mut frame, kind::CTL_APPLY_CHURN);
        put_u8(&mut frame, 0);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        assert_eq!(
            decode_frame(&frame).unwrap_err(),
            CodecError::Malformed("bad churn op tag")
        );

        // hostile op count claiming more ops than the frame carries
        let mut payload = Vec::new();
        put_u32(&mut payload, 0); // job
        put_usize(&mut payload, u64::MAX as usize); // op count
        let mut frame = Vec::new();
        put_u32(&mut frame, FRAME_MAGIC);
        put_u16(&mut frame, WIRE_VERSION);
        put_u8(&mut frame, kind::CTL_APPLY_CHURN);
        put_u8(&mut frame, 0);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        assert_eq!(
            decode_frame(&frame).unwrap_err(),
            CodecError::Malformed("length prefix overruns frame")
        );
    }

    #[test]
    fn hostile_length_prefix_is_bounded() {
        // a Weights report whose element count claims more data than the
        // frame carries must be rejected, not allocated
        let mut payload = Vec::new();
        put_u32(&mut payload, 0); // job
        put_usize(&mut payload, 0); // shard
        put_usize(&mut payload, u64::MAX as usize); // weight count
        let mut frame = Vec::new();
        put_u32(&mut frame, FRAME_MAGIC);
        put_u16(&mut frame, WIRE_VERSION);
        put_u8(&mut frame, 7); // REPORT_WEIGHTS
        put_u8(&mut frame, 0);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        assert_eq!(
            decode_frame(&frame).unwrap_err(),
            CodecError::Malformed("length prefix overruns frame")
        );
    }

    /// Build a Checkpoint frame by hand with `declared` as its slice
    /// size; `nodes` is the payload it actually carries.
    fn checkpoint_frame(declared: u64, nodes: &[Vec<Load>]) -> Vec<u8> {
        let mut payload = Vec::new();
        put_u32(&mut payload, 3); // job
        put_usize(&mut payload, 0); // shard
        put_usize(&mut payload, 9); // round
        put_u64(&mut payload, declared);
        put_usize(&mut payload, nodes.len());
        for node in nodes {
            put_loads(&mut payload, node);
        }
        let mut frame = Vec::new();
        put_u32(&mut frame, FRAME_MAGIC);
        put_u16(&mut frame, WIRE_VERSION);
        put_u8(&mut frame, kind::REPORT_CHECKPOINT);
        put_u8(&mut frame, 0);
        put_u32(&mut frame, payload.len() as u32);
        put_u32(&mut frame, crc32(&payload));
        frame.extend_from_slice(&payload);
        frame
    }

    #[test]
    fn checkpoint_declared_size_must_match_payload() {
        let nodes = vec![vec![Load::new(1, 2.0), Load::new(2, 3.0)], vec![Load::new(3, 1.0)]];
        // the honest frame decodes
        assert!(decode_frame(&checkpoint_frame(3, &nodes)).is_ok());
        // a declared size disagreeing with the carried loads is rejected
        assert_eq!(
            decode_frame(&checkpoint_frame(2, &nodes)).unwrap_err(),
            CodecError::Malformed("checkpoint declared slice size disagrees with payload")
        );
        // a hostile declared size larger than the frame can hold is
        // rejected before any allocation
        assert_eq!(
            decode_frame(&checkpoint_frame(u64::MAX / 32, &nodes)).unwrap_err(),
            CodecError::Malformed("length prefix overruns frame")
        );
    }

    #[test]
    fn io_framing_roundtrips_back_to_back_frames() {
        let msgs = vec![
            WireMsg::Ctl(Ctl::PollWeights { job: 0 }),
            WireMsg::Peer(ShardMsg::Settle {
                job: 2,
                round: 4,
                edge: 1,
                loads: vec![Load::new(1, 2.5), Load::pinned(2, 0.5)],
            }),
            WireMsg::Report(Report::Batch {
                job: 0,
                shard: 1,
                rounds: vec![RoundReport {
                    round: 4,
                    movements: 3,
                    min_weight: 0.25,
                    max_weight: 9.75,
                    peer_msgs: 2,
                }],
            }),
        ];
        let mut wire = Vec::new();
        for m in &msgs {
            write_frame(&mut wire, m).unwrap();
        }
        let mut reader = &wire[..];
        for m in &msgs {
            let back = read_frame(&mut reader).unwrap();
            assert_eq!(&back, m);
        }
        assert!(reader.is_empty());
    }

    #[test]
    fn crc32_matches_known_vector() {
        // the canonical IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }
}
