//! The sharded leader: spawns one worker per core (each owning a
//! contiguous node shard), drives the BCM schedule in batches of rounds,
//! folds per-shard metrics, and tears the cluster down into a final
//! `LoadState`.  All I/O goes through a pluggable
//! [`LeaderTransport`]: in-process channels for the thread-per-shard
//! spawns, or TCP sockets ([`Cluster::spawn_tcp`] /
//! [`Cluster::spawn_tcp_connect`]) when the workers are separate OS
//! processes.
//!
//! This is the deployment shape the paper assumes (§1) at shard
//! granularity: the leader is pure control plane (schedule + metrics) —
//! load payloads only ever travel between the shards a cut edge spans,
//! so per-round traffic is O(cross-shard edges + shards / B) where `B`
//! is the round batch: the leader dispatches `B` rounds per
//! [`Ctl::RunBatch`] and receives one coalesced [`Report::Batch`] per
//! shard, amortizing the leader round-trip that dominates wall-clock at
//! large `n`.  Within a batch workers pipeline freely (see
//! [`worker`](super::worker)), synchronized only by their cut edges.
//!
//! Determinism: rounds are keyed by a run seed (`run_seeded`) and every
//! edge draws from `Pcg64::for_edge(seed, round, edge)`, so the trace and
//! final state are **bit-identical** to `bcm::Sequential` (and
//! `bcm::Parallel`) for every (shard count, batch size) combination —
//! asserted by `tests/property_invariants.rs`.

use super::messages::{Ctl, Report};
use super::shard::{resolve_shards, RoundPlan, ShardMap, TierLayout};
use super::transport::tcp::{InitPayload, LeaderListener, TcpLeader};
use super::transport::tiered::{CountingTieredWorker, HostSeed, TierTraffic, TieredLeader};
use super::transport::{local, LeaderTransport, TransportError};
use super::worker::{ShardWorker, WorkerAlgo};
use crate::anyhow;
use crate::balancer::PairAlgorithm;
use crate::bcm::{RoundStats, RunTrace, Schedule};
use crate::load::{Load, LoadState};
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use crate::workload::service_traffic::{id_high_water, ops_for_round, ChurnOp, TrafficConfig};
use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// How long the leader waits on worker reports, per dispatched round,
/// before declaring the cluster wedged (a worker panic no longer blocks
/// forever).  Scaled by the batch size — a `RunBatch` only reports after
/// all of its rounds — and kept above the workers' equally-scaled peer
/// timeout so a genuine fault is blamed on the right shard and round.
const ROUND_TIMEOUT: Duration = Duration::from_secs(60);
const SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(10);

/// How long a recovery drains already-queued reports before deciding
/// which workers are actually gone — long enough for the EOF of a
/// killed process to surface, short enough to not stall a replay.
const RECOVERY_DRAIN: Duration = Duration::from_millis(300);

/// Checkpoints retained per job: the latest plus one predecessor, so a
/// failure *during* checkpoint collection still leaves a complete
/// earlier snapshot to resume from.
const CKPT_RING: usize = 2;

/// Default wait for a replacement worker before falling back to shard
/// reassignment (the `--rejoin-wait` knob).
pub const DEFAULT_REJOIN_WAIT: Duration = Duration::from_secs(5);

/// `ROUND_TIMEOUT` scaled to a batch of `rounds` rounds.
fn batch_timeout(rounds: usize) -> Duration {
    ROUND_TIMEOUT.saturating_mul(u32::try_from(rounds).unwrap_or(u32::MAX))
}

/// Resolve the rounds-per-control-message knob: `0` = auto, which picks
/// `max(1, n / 16384)` — batching only pays once leader round-trips
/// dominate the per-round work, which empirically needs n >= 65536 for
/// B >= 4 (the open ROADMAP scale); smaller networks keep lock-step
/// B = 1.  Any explicit value is used as-is (clamped to >= 1).
pub fn resolve_batch_rounds(batch: usize, n: usize) -> usize {
    if batch == 0 {
        (n / 16384).max(1)
    } else {
        batch
    }
}

/// Carve `state` into per-shard node lists (each worker owns its slice
/// exclusively; the leader keeps only the empty husk).
fn carve(state: &mut LoadState, map: &ShardMap) -> Vec<Vec<Vec<Load>>> {
    (0..map.shards())
        .map(|s| map.range(s).map(|v| state.take_node(v)).collect())
        .collect()
}

/// Clone a state's per-node load lists — the round-0 entry of the
/// checkpoint ring, taken before the state is carved away to the
/// workers (DESIGN.md §8: every job can always resume from *some*
/// checkpoint, even before the first periodic one lands).
fn flatten(state: &LoadState) -> Vec<Vec<Load>> {
    (0..state.n()).map(|v| state.node(v).to_vec()).collect()
}

/// Build the per-worker `Init` payloads of a TCP spawn.
fn tcp_inits(state: &mut LoadState, map: &ShardMap, algo: PairAlgorithm) -> Vec<InitPayload> {
    carve(state, map)
        .into_iter()
        .enumerate()
        .map(|(s, nodes)| InitPayload {
            lo: map.range(s).start,
            algo: algo.name(),
            nodes,
        })
        .collect()
}

/// Leader-side message accounting, used to assert the sharding
/// communication contract: leader traffic is O(shards / batch) per round
/// and worker-to-worker traffic is O(cross-shard edges).
#[derive(Clone, Copy, Debug, Default)]
pub struct MessageStats {
    /// Control messages the leader sent (one per shard per batch/poll).
    pub ctl_sent: usize,
    /// Reports the leader received (one per shard per batch/poll).
    pub reports_received: usize,
    /// Worker-to-worker messages (Offer + Settle: two per cross edge).
    pub peer_msgs: usize,
    /// Cross-shard edges encountered across all rounds run.
    pub cross_edges: usize,
    /// Rounds executed.
    pub rounds: usize,
    /// Batches dispatched (each a `Ctl::RunBatch` per shard).
    pub batches: usize,
}

/// The sharded cluster handle: owns the leader side of the transport
/// (and, on the local backend, the worker threads) and exposes the
/// seeded run API.
pub struct Cluster {
    map: ShardMap,
    transport: Box<dyn LeaderTransport>,
    /// Worker thread handles (empty on the TCP backend, where workers
    /// are separate processes).
    handles: Vec<JoinHandle<()>>,
    stats: MessageStats,
    /// Rounds dispatched per leader control message (0 = auto); resolved
    /// through [`resolve_batch_rounds`] at run time.
    batch_rounds: usize,
    /// Shards that reported a fatal error and exited (they will send no
    /// `Final` on shutdown).
    dead: Vec<bool>,
    /// First worker failure seen, re-surfaced by `shutdown` (cleared by
    /// a successful recovery).
    failure: Option<String>,
    /// Algorithm every worker runs, needed to reopen a recovered epoch.
    algo: PairAlgorithm,
    /// Current epoch: the wire-level job id all traffic is tagged with.
    /// Starts at 0 (the classic single-job id) and increments per
    /// recovery, so stale reports of an aborted epoch are filtered
    /// instead of drained.
    epoch: u32,
    /// Batch-boundary checkpoint cadence in rounds (0 = off: the
    /// classic fail-stop behavior, and the default).
    checkpoint_every: usize,
    /// How long a recovery waits for a replacement worker before
    /// reassigning the dead worker's shard to the survivors.
    rejoin_wait: Duration,
    /// Checkpoint ring: `(resume round, full per-node load lists)`,
    /// newest last, capped at [`CKPT_RING`].  Seeded with the initial
    /// state at spawn (resume round 0).
    ckpts: VecDeque<(usize, Vec<Vec<Load>>)>,
    /// Dead shards a recovery already reassigned away (a rejoined shard
    /// is simply marked live again instead).
    handled: Vec<bool>,
    /// Recoveries performed, capped to rule out a replay loop.
    recoveries: usize,
}

impl Cluster {
    /// Spawn with one worker per available core.
    pub fn spawn(state: LoadState, algo: WorkerAlgo) -> Cluster {
        Self::spawn_sharded(state, algo, 0)
    }

    /// Spawn with an explicit shard count (`0` = one worker per core);
    /// the count is clamped to the node count.
    pub fn spawn_sharded(state: LoadState, algo: WorkerAlgo, shards: usize) -> Cluster {
        Self::spawn_with_algorithm(state, algo.pair(), shards)
    }

    /// Spawn with any local [`PairAlgorithm`] — the entry point that
    /// reproduces an engine run with the same algorithm bit-exactly.
    /// The state is carved into contiguous per-shard slices, each owned
    /// exclusively by its worker.
    pub fn spawn_with_algorithm(
        state: LoadState,
        algo: PairAlgorithm,
        shards: usize,
    ) -> Cluster {
        Self::spawn_inner(state, algo, shards, None)
    }

    /// Fault-injection spawn for tests: worker `fault.0` panics at the
    /// start of global round `fault.1`, exercising the mid-batch
    /// fail-stop contract.
    #[doc(hidden)]
    pub fn spawn_with_fault(
        state: LoadState,
        algo: WorkerAlgo,
        shards: usize,
        fault: (usize, usize),
    ) -> Cluster {
        Self::spawn_inner(state, algo.pair(), shards, Some(fault))
    }

    fn spawn_inner(
        mut state: LoadState,
        algo: PairAlgorithm,
        shards: usize,
        fault: Option<(usize, usize)>,
    ) -> Cluster {
        let map = ShardMap::new(state.n(), shards);
        let k = map.shards();
        let baseline = flatten(&state);
        let shard_nodes = carve(&mut state, &map);
        let (leader, workers) = local::pair(k);
        let mut handles = Vec::with_capacity(k);
        for (s, (transport, nodes)) in workers.into_iter().zip(shard_nodes).enumerate() {
            let mut worker = ShardWorker::new(Box::new(transport));
            worker.install_job(0, map.range(s).start, nodes, algo);
            if let Some((fs, fr)) = fault {
                if fs == s {
                    worker.set_fault(0, fr);
                }
                // a fault strands the victim's peers mid-round; cap
                // their collect wait so the test resolves quickly
                worker.set_peer_wait(Duration::from_millis(500));
            }
            handles.push(std::thread::spawn(move || {
                // a worker's failure already reached the leader as a
                // Report::Error; the return value only matters for
                // worker *processes* (exit codes)
                let _ = worker.run();
            }));
        }
        let mut cluster = Self::from_transport(map, Box::new(leader), algo, baseline);
        cluster.handles = handles;
        cluster
    }

    /// Spawn a cluster whose workers are separate OS processes speaking
    /// TCP: accept `shards` worker connections on `listener` (each
    /// started with `bcm-dlb cluster-worker --connect <addr>`), ship
    /// every worker its shard of `state`, and return the leader handle.
    /// The run API and the bit-identity contract are exactly those of
    /// the in-process spawns.
    pub fn spawn_tcp(
        mut state: LoadState,
        algo: PairAlgorithm,
        shards: usize,
        listener: LeaderListener,
    ) -> Result<Cluster> {
        if shards == 0 {
            return Err(anyhow!(
                "the tcp transport needs an explicit worker count (--shards >= 1): \
                 workers are external processes, not cores"
            ));
        }
        let map = ShardMap::new(state.n(), shards);
        if map.shards() != shards {
            // never leave extra worker processes dangling in the accept
            // queue: surface the clamp instead
            return Err(anyhow!(
                "{} shards requested for a {}-node network (at most one shard per node)",
                shards,
                state.n()
            ));
        }
        let baseline = flatten(&state);
        let inits = tcp_inits(&mut state, &map, algo);
        let transport = TcpLeader::accept(listener, inits)?;
        Ok(Self::from_transport(map, Box::new(transport), algo, baseline))
    }

    /// Spawn a TCP cluster by dialing one listening worker per entry of
    /// `peers` (each started with `bcm-dlb cluster-worker --listen
    /// <addr>`); worker `i` becomes shard `i`.
    pub fn spawn_tcp_connect(
        mut state: LoadState,
        algo: PairAlgorithm,
        peers: &[String],
    ) -> Result<Cluster> {
        if peers.is_empty() {
            return Err(anyhow!("the tcp transport needs at least one worker address"));
        }
        let map = ShardMap::new(state.n(), peers.len());
        if map.shards() != peers.len() {
            return Err(anyhow!(
                "{} worker addresses for a {}-node network (at most one shard per node)",
                peers.len(),
                state.n()
            ));
        }
        let baseline = flatten(&state);
        let inits = tcp_inits(&mut state, &map, algo);
        let transport = TcpLeader::connect(peers, inits)?;
        Ok(Self::from_transport(map, Box::new(transport), algo, baseline))
    }

    /// Spawn the in-process twin of a two-tier deployment: the state is
    /// partitioned by [`ShardMap::partition_tiered`] (host blocks placed
    /// to minimize the inter-host cut of `edges`), each worker thread
    /// classifies its peer sends against `layout`, and the returned
    /// [`TierTraffic`] counts what the slow tier would carry — including
    /// the exact wire bytes of each would-be `Mux` frame.  Routing
    /// decisions match the real TCP two-tier cluster; results are
    /// bit-identical to every other spawn (the tiered partition is just
    /// another contiguous `ShardMap`).
    pub fn spawn_tiered(
        state: LoadState,
        algo: PairAlgorithm,
        layout: TierLayout,
        edges: &[(u32, u32)],
    ) -> (Cluster, Arc<TierTraffic>) {
        Self::spawn_tiered_inner(state, algo, layout, edges, None)
    }

    /// Fault-injection twin of [`spawn_tiered`](Self::spawn_tiered) for
    /// whole-host recovery tests: *every* shard of host `fault.0` panics
    /// at the start of global round `fault.1`, the in-process analogue
    /// of a host process dying with all its workers.
    #[doc(hidden)]
    pub fn spawn_tiered_with_fault(
        state: LoadState,
        algo: PairAlgorithm,
        layout: TierLayout,
        edges: &[(u32, u32)],
        fault: (usize, usize),
    ) -> (Cluster, Arc<TierTraffic>) {
        Self::spawn_tiered_inner(state, algo, layout, edges, Some(fault))
    }

    fn spawn_tiered_inner(
        mut state: LoadState,
        algo: PairAlgorithm,
        layout: TierLayout,
        edges: &[(u32, u32)],
        fault: Option<(usize, usize)>,
    ) -> (Cluster, Arc<TierTraffic>) {
        let map = ShardMap::partition_tiered(state.n(), &layout, edges);
        let k = map.shards();
        let baseline = flatten(&state);
        let shard_nodes = carve(&mut state, &map);
        let traffic = Arc::new(TierTraffic::default());
        let (leader, workers) = local::pair(k);
        let mut handles = Vec::with_capacity(k);
        for (s, (inner, nodes)) in workers.into_iter().zip(shard_nodes).enumerate() {
            let transport = CountingTieredWorker::new(inner, layout, traffic.clone());
            let mut worker = ShardWorker::new(Box::new(transport));
            worker.install_job(0, map.range(s).start, nodes, algo);
            if let Some((fh, fr)) = fault {
                if layout.host_of(s) == fh {
                    worker.set_fault(0, fr);
                }
                // the dead host strands every survivor mid-round; cap
                // their collect wait so the test resolves quickly
                worker.set_peer_wait(Duration::from_millis(500));
            }
            handles.push(std::thread::spawn(move || {
                let _ = worker.run();
            }));
        }
        let mut cluster = Self::from_transport(map, Box::new(leader), algo, baseline);
        cluster.handles = handles;
        (cluster, traffic)
    }

    /// Spawn a real two-tier cluster: accept `layout.hosts` host
    /// processes on `listener` (each `bcm-dlb cluster-worker` running
    /// `layout.shards_per_host` in-process shard workers), partition the
    /// state with [`ShardMap::partition_tiered`], and ship every host
    /// its block of shard slices in one `HostInit`.
    pub fn spawn_tcp_tiered(
        state: LoadState,
        algo: PairAlgorithm,
        layout: TierLayout,
        edges: &[(u32, u32)],
        listener: LeaderListener,
    ) -> Result<Cluster> {
        let (map, baseline, seeds) = Self::tiered_seeds(state, layout, edges)?;
        let transport = TieredLeader::accept(listener, layout, &algo.name(), seeds)?;
        Ok(Self::from_transport(map, Box::new(transport), algo, baseline))
    }

    /// Spawn a two-tier cluster by dialing one listening host process
    /// per entry of `peers` (`layout.hosts` entries, each started with
    /// `bcm-dlb cluster-worker --listen`); host `i` gets shard block
    /// `i`.
    pub fn spawn_tcp_connect_tiered(
        state: LoadState,
        algo: PairAlgorithm,
        layout: TierLayout,
        edges: &[(u32, u32)],
        peers: &[String],
    ) -> Result<Cluster> {
        if peers.len() != layout.hosts {
            return Err(anyhow!(
                "{} host addresses for a {}-host layout",
                peers.len(),
                layout.hosts
            ));
        }
        let (map, baseline, seeds) = Self::tiered_seeds(state, layout, edges)?;
        let transport = TieredLeader::connect(peers, layout, &algo.name(), seeds)?;
        Ok(Self::from_transport(map, Box::new(transport), algo, baseline))
    }

    /// Partition and carve a state for a two-tier spawn: per host, the
    /// block of `(first node, load slice)` pairs its `HostInit` ships.
    fn tiered_seeds(
        mut state: LoadState,
        layout: TierLayout,
        edges: &[(u32, u32)],
    ) -> Result<(ShardMap, Vec<Vec<Load>>, Vec<HostSeed>)> {
        if state.n() < layout.shards() {
            return Err(anyhow!(
                "a {}x{} tiered layout needs at least {} nodes, got {}",
                layout.hosts,
                layout.shards_per_host,
                layout.shards(),
                state.n()
            ));
        }
        let map = ShardMap::partition_tiered(state.n(), &layout, edges);
        let baseline = flatten(&state);
        let mut carved = carve(&mut state, &map).into_iter();
        let mut seeds = Vec::with_capacity(layout.hosts);
        for h in 0..layout.hosts {
            let shards = layout
                .host_range(h)
                .map(|s| {
                    let nodes = carved.next().expect("carve yields one slice per shard");
                    (map.range(s).start, nodes)
                })
                .collect();
            seeds.push(HostSeed { shards });
        }
        Ok((map, baseline, seeds))
    }

    fn from_transport(
        map: ShardMap,
        transport: Box<dyn LeaderTransport>,
        algo: PairAlgorithm,
        baseline: Vec<Vec<Load>>,
    ) -> Cluster {
        let k = map.shards();
        let mut ckpts = VecDeque::with_capacity(CKPT_RING);
        ckpts.push_back((0, baseline));
        Cluster {
            map,
            transport,
            handles: Vec::new(),
            stats: MessageStats::default(),
            batch_rounds: 0,
            dead: vec![false; k],
            failure: None,
            algo,
            epoch: 0,
            checkpoint_every: 0,
            rejoin_wait: DEFAULT_REJOIN_WAIT,
            ckpts,
            handled: vec![false; k],
            recoveries: 0,
        }
    }

    /// Record a worker's fatal report: the shard sends no `Final` on
    /// shutdown, and the failure is re-surfaced there.
    fn worker_error(&mut self, shard: usize, message: String) -> Error {
        self.dead[shard] = true;
        let msg = format!("cluster worker {shard}: {message}");
        if self.failure.is_none() {
            self.failure = Some(msg.clone());
        }
        Error::msg(msg)
    }

    /// Any round/poll error leaves leader and workers desynchronized
    /// (e.g. a timed-out report could be attributed to a later round), so
    /// the cluster fails stop: further rounds are refused until shutdown.
    fn check_failed(&self) -> Result<()> {
        match &self.failure {
            Some(msg) => Err(anyhow!("cluster has failed, shutdown required: {msg}")),
            None => Ok(()),
        }
    }

    /// Record any error escaping a round/poll so [`check_failed`]
    /// poisons subsequent calls.
    fn poison_on_err<T>(&mut self, result: Result<T>) -> Result<T> {
        if let Err(e) = &result {
            if self.failure.is_none() {
                self.failure = Some(e.to_string());
            }
        }
        result
    }

    /// Number of nodes the cluster balances.
    pub fn n(&self) -> usize {
        self.map.n()
    }

    /// Resolved worker count.
    pub fn shards(&self) -> usize {
        self.map.shards()
    }

    /// Set the number of rounds dispatched per leader control message
    /// (`0` = auto, see [`resolve_batch_rounds`]).  Purely a performance
    /// knob: the determinism contract holds at every (shards, batch)
    /// combination because no RNG state crosses messages.
    pub fn set_batch_rounds(&mut self, batch: usize) {
        self.batch_rounds = batch;
    }

    /// The resolved rounds-per-control-message this cluster dispatches.
    pub fn batch_rounds(&self) -> usize {
        resolve_batch_rounds(self.batch_rounds, self.n())
    }

    /// Set the batch-boundary checkpoint cadence in rounds (the
    /// `--checkpoint-every` knob, config key `checkpoint_every`).
    /// `0` — the default — disables checkpointing entirely: failures
    /// keep the classic fail-stop semantics and no extra message ever
    /// travels.  With a cadence `c > 0`, every batch whose end crosses
    /// `c` rounds since the last snapshot asks each worker to follow
    /// its `Report::Batch` with a `Report::Checkpoint` of its slice,
    /// and a worker death or mid-batch failure replays from the newest
    /// complete snapshot instead of poisoning the run (DESIGN.md §8).
    pub fn set_checkpoint_every(&mut self, rounds: usize) {
        self.checkpoint_every = rounds;
    }

    /// The configured checkpoint cadence (`0` = off).
    pub fn checkpoint_every(&self) -> usize {
        self.checkpoint_every
    }

    /// Set how long a recovery holds the cluster open for a replacement
    /// worker before reassigning the dead shard to the survivors (the
    /// `--rejoin-wait` knob; `Duration::ZERO` skips the rejoin window
    /// and reassigns immediately).  Only consulted when a worker dies
    /// and checkpointing is on; defaults to [`DEFAULT_REJOIN_WAIT`].
    pub fn set_rejoin_wait(&mut self, wait: Duration) {
        self.rejoin_wait = wait;
    }

    /// Shards still serving traffic (a reassigned-away shard stays in
    /// the map with an empty range but receives nothing).
    fn live_shards(&self) -> Vec<usize> {
        (0..self.map.shards()).filter(|&s| !self.dead[s]).collect()
    }

    /// Does the batch ending at `end_round` owe a checkpoint?
    fn checkpoint_due(&self, end_round: usize) -> bool {
        self.checkpoint_every > 0
            && end_round
                - self
                    .ckpts
                    .back()
                    .map(|&(r, _)| r)
                    .unwrap_or(0)
                >= self.checkpoint_every
    }

    /// Push an assembled checkpoint onto the bounded ring.
    fn store_checkpoint(&mut self, resume_round: usize, nodes: Vec<Vec<Load>>) {
        while self.ckpts.len() >= CKPT_RING {
            self.ckpts.pop_front();
        }
        self.ckpts.push_back((resume_round, nodes));
    }

    /// Leader-side message accounting since spawn.
    pub fn message_stats(&self) -> MessageStats {
        self.stats
    }

    /// Drive `sweeps` full sweeps of the schedule.  The run seed is drawn
    /// from `rng`; use [`run_seeded`](Self::run_seeded) to reproduce an
    /// engine run bit-exactly.
    pub fn run(
        &mut self,
        schedule: &Schedule,
        sweeps: usize,
        rng: &mut Pcg64,
    ) -> Result<RunTrace> {
        self.run_seeded(schedule, sweeps, rng.next_u64())
    }

    /// Drive `sweeps` sweeps with counter-based per-edge randomness: the
    /// resulting trace and final state are bit-identical to
    /// `bcm::Sequential::run(.., StopRule::sweeps(sweeps), seed)` for any
    /// shard count and any batch size
    /// ([`set_batch_rounds`](Self::set_batch_rounds)).
    ///
    /// With checkpointing on
    /// ([`set_checkpoint_every`](Self::set_checkpoint_every)), a worker
    /// death or mid-batch failure no longer fails the run: the cluster
    /// recovers — replacement rejoin, or shard reassignment onto the
    /// survivors — and replays from the newest checkpoint under a fresh
    /// epoch id.  Replay draws the very same `(seed, round, edge)` RNG
    /// streams, so the trace and final state stay bit-identical to
    /// `bcm::Sequential` across any number of recoveries.
    pub fn run_seeded(
        &mut self,
        schedule: &Schedule,
        sweeps: usize,
        seed: u64,
    ) -> Result<RunTrace> {
        assert_eq!(schedule.n(), self.n(), "state/schedule size mismatch");
        let d = schedule.period();
        // one classification per color, shared across sweeps and batches
        // (zero-copy per dispatch: workers receive Arcs); rebuilt by a
        // recovery that reassigns shards
        let mut plans: Arc<Vec<Arc<RoundPlan>>> = Arc::new(
            (0..d)
                .map(|c| Arc::new(RoundPlan::build(schedule.matching(c), &self.map)))
                .collect(),
        );
        let total = sweeps * d;
        let batch = self.batch_rounds();
        let mut trace = RunTrace {
            initial_discrepancy: self.poll_discrepancy()?,
            rounds: Vec::with_capacity(total),
        };
        let mut start = 0usize;
        while start < total {
            let b = batch.min(total - start);
            let colors = schedule.lookahead_colors(start, b);
            match self.batch_with_plans(start, &colors, seed, &plans) {
                Ok(stats) => {
                    trace.rounds.extend(stats);
                    start += b;
                }
                Err(e) => {
                    // replay is bit-identical, so dropping the rounds at
                    // and after the resume point and re-collecting them
                    // rebuilds the exact same trace
                    let resume = self.recover(schedule, &mut plans, e)?;
                    trace.rounds.truncate(resume);
                    start = resume;
                }
            }
        }
        Ok(trace)
    }

    /// Execute one round (matching `round % d`); the round's seed is
    /// drawn from `rng`.
    pub fn run_single_round(
        &mut self,
        schedule: &Schedule,
        round: usize,
        rng: &mut Pcg64,
    ) -> Result<RoundStats> {
        self.run_round_seeded(schedule, round, rng.next_u64())
    }

    /// Execute one round of a run keyed by `seed` (the per-edge streams
    /// also depend on `round`, so repeating all rounds of a run through
    /// this entry point reproduces [`run_seeded`](Self::run_seeded)).
    pub fn run_round_seeded(
        &mut self,
        schedule: &Schedule,
        round: usize,
        seed: u64,
    ) -> Result<RoundStats> {
        assert_eq!(schedule.n(), self.n(), "state/schedule size mismatch");
        let plans: Arc<Vec<Arc<RoundPlan>>> = Arc::new(vec![Arc::new(RoundPlan::build(
            schedule.matching(round),
            &self.map,
        ))]);
        let colors = [schedule.color_of(round)];
        let mut stats = self.batch_with_plans(round, &colors, seed, &plans)?;
        debug_assert_eq!(stats.len(), 1);
        stats.pop().ok_or_else(|| anyhow!("empty batch result"))
    }

    /// Run one batch behind the fail-stop guard.  `colors[i]` is the
    /// schedule color of round `start_round + i` (recorded in the trace);
    /// the plan of round `r` is `plans[r % plans.len()]`, mirroring the
    /// worker's indexing.
    fn batch_with_plans(
        &mut self,
        start_round: usize,
        colors: &[usize],
        seed: u64,
        plans: &Arc<Vec<Arc<RoundPlan>>>,
    ) -> Result<Vec<RoundStats>> {
        self.check_failed()?;
        let result = self.batch_inner(start_round, colors, seed, plans);
        self.poison_on_err(result)
    }

    fn batch_inner(
        &mut self,
        start_round: usize,
        colors: &[usize],
        seed: u64,
        plans: &Arc<Vec<Arc<RoundPlan>>>,
    ) -> Result<Vec<RoundStats>> {
        let b = colors.len();
        let d = plans.len();
        let mut edges = Vec::with_capacity(b);
        for i in 0..b {
            let plan = &plans[(start_round + i) % d];
            edges.push(plan.edges);
            self.stats.cross_edges += plan.cross_edges;
        }
        self.stats.rounds += b;
        self.stats.batches += 1;
        let live = self.live_shards();
        let want_ckpt = self.checkpoint_due(start_round + b);
        // dispatch: one RunBatch per live shard covers all b rounds
        for &s in &live {
            let msg = Ctl::RunBatch {
                job: self.epoch,
                start_round,
                rounds: b,
                seed,
                plans: plans.clone(),
                checkpoint: want_ckpt,
            };
            if let Err(e) = self.transport.send_ctl(s, msg) {
                let msg = format!("control link closed before batch at round {start_round}: {e}");
                return Err(self.worker_error(s, msg));
            }
            self.stats.ctl_sent += 1;
        }
        // collect: one coalesced report per live shard — plus, when a
        // checkpoint is due, one snapshot slice per live shard riding
        // right behind it (FIFO keeps the pair ordered) — folded per
        // round.  Reports tagged with an aborted epoch are the tail of
        // a recovered failure and are skipped.
        let mut movements = vec![0usize; b];
        let mut min = vec![f64::INFINITY; b];
        let mut max = vec![f64::NEG_INFINITY; b];
        let mut parts: Vec<Option<Vec<Vec<Load>>>> = vec![None; self.map.shards()];
        let mut pending_batches = live.len();
        let mut pending_ckpts = if want_ckpt { live.len() } else { 0 };
        let wait = batch_timeout(b);
        while pending_batches > 0 || pending_ckpts > 0 {
            match self.recv_report("batch reports", wait)? {
                Report::Batch { job, shard, rounds } => {
                    if job != self.epoch {
                        continue;
                    }
                    if rounds.len() != b {
                        return Err(anyhow!(
                            "shard {shard} reported {} rounds for a {b}-round batch \
                             starting at round {start_round}",
                            rounds.len()
                        ));
                    }
                    for (i, r) in rounds.iter().enumerate() {
                        if r.round != start_round + i {
                            return Err(anyhow!(
                                "shard {shard} report out of order: round {} at slot {i} \
                                 of the batch starting at round {start_round}",
                                r.round
                            ));
                        }
                        movements[i] += r.movements;
                        min[i] = min[i].min(r.min_weight);
                        max[i] = max[i].max(r.max_weight);
                        self.stats.peer_msgs += r.peer_msgs;
                    }
                    pending_batches -= 1;
                }
                Report::Checkpoint {
                    job,
                    shard,
                    round,
                    nodes,
                } => {
                    if job != self.epoch {
                        continue;
                    }
                    if round + 1 != start_round + b {
                        return Err(anyhow!(
                            "shard {shard} checkpointed round {round} inside the batch \
                             ending at round {}",
                            start_round + b - 1
                        ));
                    }
                    parts[shard] = Some(nodes);
                    pending_ckpts = pending_ckpts.saturating_sub(1);
                }
                Report::Error {
                    job,
                    shard,
                    round,
                    message,
                } => {
                    let msg = match round {
                        Some(r) => format!("failed at round {r}: {message}"),
                        None => message,
                    };
                    if self.checkpoint_every == 0 {
                        // classic fail-stop: every error is terminal
                        return Err(self.worker_error(shard, msg));
                    }
                    match job {
                        // tail of an epoch an earlier recovery aborted
                        Some(j) if j != self.epoch => continue,
                        // the job died but the worker lives on (it
                        // retired the epoch): replay on this membership
                        Some(_) => return Err(anyhow!("cluster worker {shard}: {msg}")),
                        // the worker itself is gone
                        None => return Err(self.worker_error(shard, msg)),
                    }
                }
                // stale Weights/Final of an aborted epoch
                _ => continue,
            }
        }
        if want_ckpt {
            let mut snapshot: Vec<Vec<Load>> = vec![Vec::new(); self.n()];
            for &s in &live {
                let Some(nodes) = parts[s].take() else {
                    return Err(anyhow!("shard {s} delivered no checkpoint slice"));
                };
                let lo = self.map.range(s).start;
                for (i, loads) in nodes.into_iter().enumerate() {
                    snapshot[lo + i] = loads;
                }
            }
            self.store_checkpoint(start_round + b, snapshot);
        }
        Ok((0..b)
            .map(|i| RoundStats {
                round: start_round + i,
                color: colors[i],
                discrepancy: max[i] - min[i],
                movements: movements[i],
                edges: edges[i],
            })
            .collect())
    }

    /// Poll every shard's node weights and fold the global discrepancy —
    /// the same min/max fold `LoadState::discrepancy` performs.
    pub fn poll_discrepancy(&mut self) -> Result<f64> {
        let w = self.poll_weights()?;
        let max = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = w.iter().cloned().fold(f64::INFINITY, f64::min);
        Ok(max - min)
    }

    /// The per-node weight vector, assembled from one report per shard.
    pub fn poll_weights(&mut self) -> Result<Vec<f64>> {
        self.check_failed()?;
        let result = self.poll_weights_inner();
        self.poison_on_err(result)
    }

    fn poll_weights_inner(&mut self) -> Result<Vec<f64>> {
        let live = self.live_shards();
        for &s in &live {
            if let Err(e) = self
                .transport
                .send_ctl(s, Ctl::PollWeights { job: self.epoch })
            {
                let msg = format!("control link closed during weight poll: {e}");
                return Err(self.worker_error(s, msg));
            }
            self.stats.ctl_sent += 1;
        }
        let mut w = vec![0.0f64; self.n()];
        let mut pending = live.len();
        while pending > 0 {
            match self.recv_report("weight reports", ROUND_TIMEOUT)? {
                Report::Weights { job, shard, weights } => {
                    if job != self.epoch {
                        continue;
                    }
                    let range = self.map.range(shard);
                    debug_assert_eq!(weights.len(), range.len());
                    w[range].copy_from_slice(&weights);
                    pending -= 1;
                }
                Report::Error {
                    job,
                    shard,
                    round: _,
                    message,
                } => {
                    if self.checkpoint_every > 0 && job.is_some_and(|j| j != self.epoch) {
                        continue;
                    }
                    return Err(self.worker_error(shard, message));
                }
                // stale Batch/Checkpoint tail of an aborted epoch
                _ if self.checkpoint_every > 0 => continue,
                other => return Err(anyhow!("unexpected report while polling weights: {other:?}")),
            }
        }
        Ok(w)
    }

    /// Ship one round's churn ops to the shards that own their target
    /// nodes (`workload::service_traffic`).  Reply-free: the FIFO
    /// control link orders each slice ahead of the next
    /// [`run_round_seeded`](Self::run_round_seeded), so that round
    /// balances the post-churn state on every shard.  Callers drive
    /// churning runs round-by-round; this path does not participate in
    /// checkpoint recovery.
    pub fn apply_churn(&mut self, ops: &[ChurnOp]) -> Result<()> {
        self.check_failed()?;
        let result = self.apply_churn_inner(ops);
        self.poison_on_err(result)
    }

    fn apply_churn_inner(&mut self, ops: &[ChurnOp]) -> Result<()> {
        for s in self.live_shards() {
            let range = self.map.range(s);
            let slice: Vec<ChurnOp> = ops
                .iter()
                .filter(|op| range.contains(&(op.node() as usize)))
                .copied()
                .collect();
            if slice.is_empty() {
                continue;
            }
            let msg = Ctl::ApplyChurn {
                job: self.epoch,
                ops: slice,
            };
            if let Err(e) = self.transport.send_ctl(s, msg) {
                let why = format!("control link closed during churn: {e}");
                return Err(self.worker_error(s, why));
            }
            self.stats.ctl_sent += 1;
        }
        Ok(())
    }

    fn recv_report(&mut self, what: &str, wait: Duration) -> Result<Report> {
        match self.transport.recv_report(wait) {
            Ok(r) => {
                self.stats.reports_received += 1;
                Ok(r)
            }
            Err(TransportError::Timeout) => Err(anyhow!(
                "timed out after {}s waiting for {what} (a worker likely panicked)",
                wait.as_secs()
            )),
            Err(TransportError::Closed(why)) => Err(anyhow!(
                "all cluster workers terminated while waiting for {what}: {why}"
            )),
        }
    }

    /// Recover from a failed batch: abort the poisoned epoch, mend the
    /// membership (replacement rejoin or shard reassignment, DESIGN.md
    /// §8), reopen the run under a fresh epoch id seeded from the newest
    /// checkpoint, and return the round to replay from.
    ///
    /// With checkpointing off the original error is simply returned and
    /// the classic fail-stop semantics apply unchanged.
    fn recover(
        &mut self,
        schedule: &Schedule,
        plans: &mut Arc<Vec<Arc<RoundPlan>>>,
        err: Error,
    ) -> Result<usize> {
        if self.checkpoint_every == 0 {
            return Err(err);
        }
        self.recoveries += 1;
        if self.recoveries > 2 * self.map.shards() + 2 {
            return Err(err.context("recovery limit exceeded, failing stop"));
        }
        // Drain the report plane so every casualty of this incident is
        // classified before membership decisions are made: an untagged
        // error (or a synthesized connection-loss) marks its worker
        // dead, everything else is the stale tail of the aborted epoch.
        loop {
            match self.transport.recv_report(RECOVERY_DRAIN) {
                Ok(Report::Error { job: None, shard, .. }) => self.dead[shard] = true,
                Ok(_) => {}
                Err(_) => break,
            }
        }
        let old = self.epoch;
        self.epoch += 1;
        // retire the aborted epoch on every survivor (also clears the
        // job-tagged failure a survivor may have recorded against it)
        for s in self.live_shards() {
            self.stats.ctl_sent += 1;
            if self.transport.send_ctl(s, Ctl::AbortJob { job: old }).is_err() {
                self.dead[s] = true;
            }
        }
        let (resume, snapshot) = {
            let (r, nodes) = self
                .ckpts
                .back()
                .expect("checkpoint ring is seeded at spawn");
            (*r, nodes.clone())
        };
        // mend the membership: hold the door open for a replacement of
        // each newly dead shard, else fold its range onto the survivors
        let casualties: Vec<usize> = (0..self.map.shards())
            .filter(|&s| self.dead[s] && !self.handled[s])
            .collect();
        let mut remapped = false;
        for s in casualties {
            let replacement = if self.rejoin_wait > Duration::ZERO {
                self.transport
                    .await_rejoin(s, resume, self.rejoin_wait)
                    .unwrap_or(None)
            } else {
                None
            };
            if let Some(addr) = replacement {
                self.dead[s] = false;
                // survivors re-dial the replacement's fresh peer listener
                for p in self.live_shards() {
                    if p == s {
                        continue;
                    }
                    self.stats.ctl_sent += 1;
                    let remesh = Ctl::Remesh {
                        shard: s,
                        addr: addr.clone(),
                    };
                    if self.transport.send_ctl(p, remesh).is_err() {
                        self.dead[p] = true;
                    }
                }
            } else {
                self.map = self.map.reassign(s, &self.dead);
                self.handled[s] = true;
                remapped = true;
                // an empty address is the demesh order: survivors drop
                // their link to the reassigned-away shard and purge its
                // queued connection-loss events
                for p in self.live_shards() {
                    self.stats.ctl_sent += 1;
                    let demesh = Ctl::Remesh {
                        shard: s,
                        addr: String::new(),
                    };
                    if self.transport.send_ctl(p, demesh).is_err() {
                        self.dead[p] = true;
                    }
                }
            }
        }
        let live = self.live_shards();
        if live.is_empty() {
            return Err(err.context("no live shard remains to recover onto"));
        }
        if remapped {
            *plans = Arc::new(
                (0..schedule.period())
                    .map(|c| Arc::new(RoundPlan::build(schedule.matching(c), &self.map)))
                    .collect(),
            );
        }
        // reopen the run under the fresh epoch: every live shard —
        // including a rejoined replacement, which carries no state —
        // receives its slice of the newest checkpoint
        for s in live {
            let range = self.map.range(s);
            let open = Ctl::OpenJob {
                job: self.epoch,
                lo: range.start,
                algo: self.algo.name(),
                nodes: range.map(|v| snapshot[v].clone()).collect(),
            };
            self.stats.ctl_sent += 1;
            if self.transport.send_ctl(s, open).is_err() {
                self.dead[s] = true;
            }
        }
        // un-poison the cluster: the run resumes from the checkpoint
        self.failure = None;
        Ok(resume)
    }

    /// Shut the cluster down, join every worker, and reassemble the final
    /// `LoadState`.  Worker panics and protocol violations surface as
    /// errors instead of being silently discarded.
    pub fn shutdown(self) -> Result<LoadState> {
        let Cluster {
            map,
            mut transport,
            handles,
            dead,
            failure,
            ..
        } = self;
        for s in 0..map.shards() {
            // a worker that already exited is surfaced below
            let _ = transport.send_ctl(s, Ctl::Shutdown);
        }
        let mut state = LoadState::empty(map.n());
        let mut first_err: Option<Error> = failure.map(Error::msg);
        // shards that already died reported their error and send no
        // Final; per-shard tracking keeps a late synthesized conn-lost
        // error for one of them from counting a live shard out
        let mut settled = dead.clone();
        let mut timed_out = false;
        while settled.iter().any(|&s| !s) {
            match transport.recv_report(SHUTDOWN_TIMEOUT) {
                Ok(Report::Final { job: _, shard, nodes }) => {
                    if settled[shard] {
                        continue;
                    }
                    let lo = map.range(shard).start;
                    for (i, loads) in nodes.into_iter().enumerate() {
                        for l in loads {
                            state.push(lo + i, l);
                        }
                    }
                    settled[shard] = true;
                }
                Ok(Report::Error {
                    job: _,
                    shard,
                    round,
                    message,
                }) => {
                    if settled[shard] {
                        continue;
                    }
                    // that worker exits without sending a Final
                    first_err.get_or_insert_with(|| match round {
                        Some(r) => {
                            anyhow!("cluster worker {shard}: failed at round {r}: {message}")
                        }
                        None => anyhow!("cluster worker {shard}: {message}"),
                    });
                    settled[shard] = true;
                }
                // stale Batch/Weights reports can remain queued when a
                // run was aborted mid-batch; drain them
                Ok(_) => {}
                Err(_) => {
                    timed_out = true;
                    first_err
                        .get_or_insert_with(|| anyhow!("timed out collecting final shard states"));
                    break;
                }
            }
        }
        if !timed_out {
            // every worker has returned (Final or Error), so the joins
            // are immediate; skip them only when a wedged worker could
            // block forever
            for h in handles {
                if let Err(p) = h.join() {
                    let msg = super::worker::panic_message(p.as_ref());
                    first_err.get_or_insert_with(|| anyhow!("cluster worker panicked: {msg}"));
                }
            }
        }
        match first_err {
            None => Ok(state),
            Some(e) => Err(e),
        }
    }
}

/// One tenant's complete run, submitted to a [`ShardPool`].
pub struct JobSpec {
    /// The initial load state (consumed: the pool carves it into
    /// per-shard slices).
    pub state: LoadState,
    /// The matching schedule driving the run.
    pub schedule: Schedule,
    /// Local balancing algorithm.
    pub algo: PairAlgorithm,
    /// Full sweeps of the schedule to run.
    pub sweeps: usize,
    /// Run seed; the job's trace is bit-identical to
    /// `bcm::Sequential::run(.., StopRule::sweeps(sweeps), seed)`.
    pub seed: u64,
    /// Rounds per control message (`0` = auto, see
    /// [`resolve_batch_rounds`]).
    pub batch: usize,
    /// Batch-boundary checkpoint cadence in rounds (`0` = off, the
    /// classic fail-stop semantics).  With a cadence, a failure inside
    /// this job's batch is recovered by replaying from the newest
    /// checkpoint under a fresh wire id — the tenant sees
    /// [`JobEvent::Recovering`] instead of [`JobEvent::Failed`], and the
    /// trace stays bit-identical to `bcm::Sequential`.
    pub checkpoint_every: usize,
    /// When set, the job runs the dynamic `service_traffic` workload:
    /// before each round the pool ships every shard its slice of the
    /// round's churn-op stream ([`Ctl::ApplyChurn`]).  Churning jobs are
    /// dispatched round-by-round (`batch` is forced to 1 — churn is a
    /// round-boundary mutation) and their trace is bit-identical to
    /// `bcm::Sequential::run_dynamic` under the same config and seed.
    /// Recovery still works: the op stream is a pure function of
    /// `(config, seed, round, n)`, so a replay from a checkpoint
    /// regenerates exactly the ops the failed epoch applied.
    pub churn: Option<TrafficConfig>,
}

/// Progress surfaced by [`ShardPool::step`], in job-lifecycle order:
/// one `Started`, a `Rounds` per completed batch, then exactly one of
/// `Finished` / `Failed`.
#[derive(Debug)]
pub enum JobEvent {
    /// The job's initial weight poll completed.
    Started {
        /// Pool-assigned job id.
        job: u32,
        /// Discrepancy before round 0 (the trace's
        /// `initial_discrepancy`).
        initial_discrepancy: f64,
    },
    /// A batch of rounds completed; stats arrive in round order.
    Rounds {
        /// Pool-assigned job id.
        job: u32,
        /// Per-round statistics of the batch, ready to stream.
        stats: Vec<RoundStats>,
    },
    /// All sweeps ran and the final state was collected; terminal.
    Finished {
        /// Pool-assigned job id.
        job: u32,
        /// The complete run trace (identical to the `Rounds` stream).
        trace: RunTrace,
        /// The reassembled final load state.
        state: LoadState,
    },
    /// The job died (worker panic, dead peer, bad spec); terminal.
    /// Other jobs on the pool are unaffected.
    Failed {
        /// Pool-assigned job id.
        job: u32,
        /// What went wrong, naming the shard and round where known.
        error: String,
    },
    /// A failure inside this job's batch was recovered from a
    /// checkpoint (`JobSpec::checkpoint_every > 0`): the job paused,
    /// its epoch was aborted and reopened, and rounds replay from
    /// `round`.  Not terminal — `Rounds` resume where the tenant left
    /// off (replayed duplicates are suppressed) and the job still ends
    /// in exactly one `Finished` / `Failed`.  Other jobs on the pool
    /// never see this event.
    Recovering {
        /// Pool-assigned job id.
        job: u32,
        /// First round being replayed (the newest checkpoint's cut).
        round: usize,
    },
}

/// What a pool job is waiting for.
enum JobPhase {
    /// Initial weight poll: `pending` shards still owe a `Weights`
    /// report folded into `weights`.
    Weights {
        pending: usize,
        weights: Vec<f64>,
    },
    /// Nothing outstanding; the next [`ShardPool::step`] dispatches a
    /// batch (or the close, once all rounds ran).
    Ready,
    /// A dispatched batch: `pending` shards still owe their
    /// `Report::Batch`, folded per round into the vectors.  When the
    /// batch was dispatched with `ckpt` set, each shard also owes a
    /// `Report::Checkpoint` slice (`ckpt_pending` outstanding,
    /// assembled from `parts` once both counters drain).
    Batch {
        start: usize,
        b: usize,
        colors: Vec<usize>,
        edges: Vec<usize>,
        pending: usize,
        movements: Vec<usize>,
        min: Vec<f64>,
        max: Vec<f64>,
        ckpt: bool,
        ckpt_pending: usize,
        parts: Vec<Option<Vec<Vec<Load>>>>,
    },
    /// `CloseJob` sent: `pending` shards still owe their `Final`,
    /// merged into `state`.
    Closing {
        pending: usize,
        state: LoadState,
    },
}

/// Leader-side state of one pool job.
struct PoolJob {
    map: ShardMap,
    schedule: Schedule,
    plans: Arc<Vec<Arc<RoundPlan>>>,
    algo: PairAlgorithm,
    seed: u64,
    batch: usize,
    total: usize,
    /// Next round to dispatch (advanced when a batch completes).
    next: usize,
    trace: RunTrace,
    phase: JobPhase,
    /// Fail-stop deadline for the current pending phase, renewed on
    /// every report absorbed for this job.
    deadline: Instant,
    /// Checkpoint cadence in rounds (`0` = off, classic fail-stop).
    checkpoint_every: usize,
    /// Wire-protocol job id of the current epoch.  Starts equal to the
    /// pool-assigned id; every recovery retires it and mints a fresh
    /// one, so a stale report of an aborted epoch can never be
    /// mistaken for current traffic.  Tenants only ever see the stable
    /// pool id.
    wire: u32,
    /// Newest-first bounded ring of `(resume round, full snapshot)`
    /// checkpoints; seeded with the initial state when the cadence is
    /// on, so a failure before the first checkpoint replays from round
    /// 0.
    ckpts: VecDeque<(usize, Vec<Vec<Load>>)>,
    /// Dynamic-workload config; `Some` makes every dispatch precede its
    /// (single-round) batch with the round's churn ops.
    churn: Option<TrafficConfig>,
    /// One past the largest load id the job has ever hosted: the
    /// carved-away initial `next_id` folded with every generated
    /// arrival id.  Restored onto the reassembled final state so a
    /// churning pool job's state is bit-identical to the engines',
    /// which bump `next_id` even for arrivals that later depart.
    next_id_hw: u64,
    /// Rounds already surfaced to the tenant as `JobEvent::Rounds` —
    /// the high-water mark that suppresses duplicate events while a
    /// recovery replays.
    emitted: usize,
    /// Recoveries performed for this job, capped against a failure
    /// that reproduces deterministically on every replay.
    recoveries: usize,
}

impl PoolJob {
    /// Shards participating in this job (a job on fewer nodes than the
    /// pool has shards uses a prefix of the workers).
    fn shards(&self) -> usize {
        self.map.shards()
    }
}

/// A shared pool of shard workers serving any number of independent
/// jobs — the event-driven leader behind `bcm-dlb serve`.
///
/// Where [`Cluster`] *blocks* inside `run_seeded` until its single
/// run completes, a `ShardPool` never blocks on one tenant: all
/// leader-side I/O funnels through [`step`](Self::step), a
/// `select`-style turn of the event loop that dispatches at most one
/// batch per ready job (round-robin, so a long job cannot starve a
/// short one) and absorbs whatever reports have arrived, returning the
/// resulting [`JobEvent`]s.  One thread therefore drives every tenant
/// concurrently, and each job's trace stays bit-identical to
/// `bcm::Sequential` because nothing about the interleaving touches a
/// job's `(seed, round, edge)` RNG streams or its carved load slices.
///
/// Failures stay job-scoped: a worker panic or dead peer inside one
/// job's batch surfaces as [`JobEvent::Failed`] for that job while the
/// workers retire the job locally and keep serving the rest.  Only a
/// transport-level loss (a worker thread gone) poisons the whole pool.
pub struct ShardPool {
    shards: usize,
    transport: Box<dyn LeaderTransport>,
    handles: Vec<JoinHandle<()>>,
    jobs: BTreeMap<u32, PoolJob>,
    next_job: u32,
    /// Rotation offset for the round-robin dispatch order.
    cursor: usize,
    poisoned: Option<String>,
    down: bool,
}

impl ShardPool {
    /// Spawn a pool of `shards` local workers (`0` = one per core).
    pub fn spawn(shards: usize) -> ShardPool {
        Self::spawn_tuned(shards, None, None)
    }

    /// Test spawn: inject a panic at `(shard, job, round)` and/or cap
    /// the workers' peer-collect wait so dead-peer paths resolve in
    /// test time.
    #[doc(hidden)]
    pub fn spawn_tuned(
        shards: usize,
        fault: Option<(usize, u32, usize)>,
        peer_wait: Option<Duration>,
    ) -> ShardPool {
        let k = resolve_shards(shards);
        let (leader, workers) = local::pair(k);
        let mut handles = Vec::with_capacity(k);
        for (s, transport) in workers.into_iter().enumerate() {
            let mut worker = ShardWorker::new(Box::new(transport));
            if let Some((fs, fj, fr)) = fault {
                if fs == s {
                    worker.set_fault(fj, fr);
                }
            }
            if let Some(w) = peer_wait {
                worker.set_peer_wait(w);
            }
            handles.push(std::thread::spawn(move || {
                let _ = worker.run();
            }));
        }
        ShardPool {
            shards: k,
            transport: Box::new(leader),
            handles,
            jobs: BTreeMap::new(),
            next_job: 1, // job 0 is the classic single-job id
            cursor: 0,
            poisoned: None,
            down: false,
        }
    }

    /// Worker count of the pool.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Jobs open on the pool (any phase).
    pub fn jobs_active(&self) -> usize {
        self.jobs.len()
    }

    fn check_poisoned(&self) -> Result<()> {
        match &self.poisoned {
            Some(msg) => Err(anyhow!("shard pool has failed: {msg}")),
            None => Ok(()),
        }
    }

    fn poison(&mut self, msg: String) -> Error {
        if self.poisoned.is_none() {
            self.poisoned = Some(msg.clone());
        }
        Error::msg(format!("shard pool has failed: {msg}"))
    }

    /// Open a job: carve its state across the pool (a job smaller than
    /// the pool uses a prefix of the workers), ship each participating
    /// shard its slice, and start the initial weight poll.  Returns the
    /// pool-assigned job id; progress arrives through
    /// [`step`](Self::step).
    pub fn open_job(&mut self, spec: JobSpec) -> Result<u32> {
        self.check_poisoned()?;
        if self.down {
            return Err(anyhow!("shard pool is shut down"));
        }
        let JobSpec {
            mut state,
            schedule,
            algo,
            sweeps,
            seed,
            batch,
            checkpoint_every,
            churn,
        } = spec;
        let n = state.n();
        let next_id_hw = state.next_id();
        if schedule.n() != n {
            return Err(anyhow!(
                "job state has {n} nodes but its schedule covers {}",
                schedule.n()
            ));
        }
        let job = self.next_job;
        self.next_job += 1;
        let map = ShardMap::new(n, self.shards);
        let mut ckpts = VecDeque::with_capacity(CKPT_RING);
        if checkpoint_every > 0 {
            ckpts.push_back((0, flatten(&state)));
        }
        let shard_nodes = carve(&mut state, &map);
        for (s, nodes) in shard_nodes.into_iter().enumerate() {
            let open = Ctl::OpenJob {
                job,
                lo: map.range(s).start,
                algo: algo.name(),
                nodes,
            };
            if let Err(e) = self.transport.send_ctl(s, open) {
                return Err(self.poison(format!("control link to shard {s} closed: {e}")));
            }
            if let Err(e) = self.transport.send_ctl(s, Ctl::PollWeights { job }) {
                return Err(self.poison(format!("control link to shard {s} closed: {e}")));
            }
        }
        let d = schedule.period();
        let plans: Arc<Vec<Arc<RoundPlan>>> = Arc::new(
            (0..d)
                .map(|c| Arc::new(RoundPlan::build(schedule.matching(c), &map)))
                .collect(),
        );
        let pending = map.shards();
        self.jobs.insert(
            job,
            PoolJob {
                map,
                schedule,
                plans,
                algo,
                seed,
                // churn mutates state at round boundaries, so churning
                // jobs go round-by-round
                batch: if churn.is_some() {
                    1
                } else {
                    resolve_batch_rounds(batch, n)
                },
                total: sweeps * d,
                next: 0,
                trace: RunTrace {
                    initial_discrepancy: 0.0,
                    rounds: Vec::new(),
                },
                phase: JobPhase::Weights {
                    pending,
                    weights: vec![0.0; n],
                },
                deadline: Instant::now() + ROUND_TIMEOUT,
                checkpoint_every,
                wire: job,
                ckpts,
                churn,
                next_id_hw,
                emitted: 0,
                recoveries: 0,
            },
        );
        Ok(job)
    }

    /// One turn of the event loop: dispatch a batch (or the close) to
    /// every `Ready` job — round-robin, one batch each, so no tenant
    /// starves — then absorb whatever reports arrive within `wait` and
    /// return the resulting events.  An empty vec just means nothing
    /// completed this turn.
    ///
    /// `Err` means the *pool* is broken (worker thread lost, protocol
    /// violation, wedged shard); per-job failures are reported as
    /// [`JobEvent::Failed`] and leave the pool and its other jobs
    /// running.
    pub fn step(&mut self, wait: Duration) -> Result<Vec<JobEvent>> {
        self.check_poisoned()?;
        let mut events = Vec::new();
        // dispatch phase: rotate over the ready jobs
        let ids: Vec<u32> = self.jobs.keys().copied().collect();
        if !ids.is_empty() {
            let offset = self.cursor % ids.len();
            self.cursor = self.cursor.wrapping_add(1);
            for i in 0..ids.len() {
                let id = ids[(offset + i) % ids.len()];
                if matches!(self.jobs[&id].phase, JobPhase::Ready) {
                    if let Err(e) = self.dispatch(id) {
                        return Err(self.poison(e.to_string()));
                    }
                }
            }
        }
        if self.jobs.is_empty() {
            return Ok(events);
        }
        // absorb phase: block up to `wait` for the first report, then
        // drain whatever else is already queued
        let mut budget = wait;
        loop {
            match self.transport.recv_report(budget) {
                Ok(report) => {
                    if let Err(e) = self.route(report, &mut events) {
                        return Err(self.poison(e.to_string()));
                    }
                }
                Err(TransportError::Timeout) => break,
                Err(TransportError::Closed(why)) => {
                    return Err(self.poison(format!("all pool workers terminated: {why}")));
                }
            }
            budget = Duration::ZERO;
        }
        // fail-stop: a shard that stopped reporting would otherwise
        // wedge its job (and the service connection above it) forever
        let now = Instant::now();
        if let Some((&id, _)) = self
            .jobs
            .iter()
            .find(|(_, j)| !matches!(j.phase, JobPhase::Ready) && j.deadline < now)
        {
            return Err(self.poison(format!(
                "job {id} timed out waiting for shard reports (a worker is wedged)"
            )));
        }
        Ok(events)
    }

    /// Send a `Ready` job its next batch, or its close once all rounds
    /// have run.
    fn dispatch(&mut self, id: u32) -> Result<()> {
        let job = self.jobs.get_mut(&id).expect("dispatch of unknown job");
        let m = job.shards();
        if job.next >= job.total {
            for s in 0..m {
                self.transport
                    .send_ctl(s, Ctl::CloseJob { job: job.wire })
                    .map_err(|e| anyhow!("control link to shard {s} closed: {e}"))?;
            }
            job.phase = JobPhase::Closing {
                pending: m,
                state: LoadState::empty(job.map.n()),
            };
            job.deadline = Instant::now() + SHUTDOWN_TIMEOUT;
            return Ok(());
        }
        let start = job.next;
        let b = job.batch.min(job.total - start);
        let colors = job.schedule.lookahead_colors(start, b);
        let d = job.plans.len();
        let edges = (0..b)
            .map(|i| job.plans[(start + i) % d].edges)
            .collect();
        let ckpt = job.checkpoint_every > 0
            && (start + b) - job.ckpts.back().map(|&(r, _)| r).unwrap_or(0)
                >= job.checkpoint_every;
        if let Some(cfg) = &job.churn {
            // regenerated (not stored) so a recovery replay re-derives
            // exactly the ops the failed epoch applied
            debug_assert_eq!(b, 1, "churning jobs dispatch round-by-round");
            let ops = ops_for_round(cfg, job.seed, start, job.map.n());
            job.next_id_hw = job.next_id_hw.max(id_high_water(&ops));
            for s in 0..m {
                let range = job.map.range(s);
                let slice: Vec<ChurnOp> = ops
                    .iter()
                    .filter(|op| range.contains(&(op.node() as usize)))
                    .copied()
                    .collect();
                if slice.is_empty() {
                    continue;
                }
                let msg = Ctl::ApplyChurn {
                    job: job.wire,
                    ops: slice,
                };
                self.transport
                    .send_ctl(s, msg)
                    .map_err(|e| anyhow!("control link to shard {s} closed: {e}"))?;
            }
        }
        for s in 0..m {
            let msg = Ctl::RunBatch {
                job: job.wire,
                start_round: start,
                rounds: b,
                seed: job.seed,
                plans: job.plans.clone(),
                checkpoint: ckpt,
            };
            self.transport
                .send_ctl(s, msg)
                .map_err(|e| anyhow!("control link to shard {s} closed: {e}"))?;
        }
        job.phase = JobPhase::Batch {
            start,
            b,
            colors,
            edges,
            pending: m,
            movements: vec![0; b],
            min: vec![f64::INFINITY; b],
            max: vec![f64::NEG_INFINITY; b],
            ckpt,
            ckpt_pending: if ckpt { m } else { 0 },
            parts: vec![None; m],
        };
        job.deadline = Instant::now() + batch_timeout(b);
        Ok(())
    }

    /// The pool job currently speaking wire id `wire`, with its stable
    /// pool id.  `None` for the tail of an already-failed or aborted
    /// epoch (e.g. a surviving peer's timeout self-report).
    fn job_by_wire(&mut self, wire: u32) -> Option<(u32, &mut PoolJob)> {
        self.jobs
            .iter_mut()
            .find(|(_, j)| j.wire == wire)
            .map(|(&pid, j)| (pid, j))
    }

    /// Fold one worker report into its job, staging any completed
    /// lifecycle events.  Reports are routed by *wire* id — a job that
    /// recovered speaks a fresh one — and reports for unknown wire ids
    /// are dropped: they are the tail of an already-failed or aborted
    /// epoch.  `Err` poisons the pool.
    fn route(&mut self, report: Report, events: &mut Vec<JobEvent>) -> Result<()> {
        match report {
            Report::Error {
                job: None,
                shard,
                message,
                ..
            } => Err(anyhow!("worker {shard} failed: {message}")),
            Report::Error {
                job: Some(id),
                shard,
                round,
                message,
            } => {
                let Some((pid, job)) = self.job_by_wire(id) else {
                    return Ok(());
                };
                if job.checkpoint_every > 0 && !job.ckpts.is_empty() {
                    return self.recover_job(pid, events);
                }
                self.jobs.remove(&pid);
                let error = match round {
                    Some(r) => format!("shard {shard} failed at round {r}: {message}"),
                    None => format!("shard {shard}: {message}"),
                };
                events.push(JobEvent::Failed { job: pid, error });
                Ok(())
            }
            Report::Weights {
                job: id,
                shard,
                weights,
            } => {
                let Some((pid, job)) = self.job_by_wire(id) else {
                    return Ok(());
                };
                job.deadline = Instant::now() + ROUND_TIMEOUT;
                let JobPhase::Weights { pending, weights: w } = &mut job.phase else {
                    return Err(anyhow!("unexpected weight report for job {pid}"));
                };
                let range = job.map.range(shard);
                debug_assert_eq!(weights.len(), range.len());
                w[range].copy_from_slice(&weights);
                *pending -= 1;
                if *pending == 0 {
                    let max = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
                    let min = w.iter().cloned().fold(f64::INFINITY, f64::min);
                    let disc = max - min;
                    job.trace.initial_discrepancy = disc;
                    job.trace.rounds.reserve(job.total);
                    job.phase = JobPhase::Ready;
                    events.push(JobEvent::Started {
                        job: pid,
                        initial_discrepancy: disc,
                    });
                }
                Ok(())
            }
            Report::Batch {
                job: id,
                shard,
                rounds,
            } => {
                let Some((pid, job)) = self.job_by_wire(id) else {
                    return Ok(());
                };
                job.deadline = Instant::now() + batch_timeout(job.batch);
                let JobPhase::Batch {
                    start,
                    b,
                    pending,
                    movements,
                    min,
                    max,
                    ..
                } = &mut job.phase
                else {
                    return Err(anyhow!("unexpected batch report for job {pid}"));
                };
                if rounds.len() != *b {
                    return Err(anyhow!(
                        "shard {shard} reported {} rounds for a {b}-round batch of job {pid} \
                         starting at round {start}",
                        rounds.len()
                    ));
                }
                for (i, r) in rounds.iter().enumerate() {
                    if r.round != *start + i {
                        return Err(anyhow!(
                            "shard {shard} report out of order: round {} at slot {i} of the \
                             batch of job {pid} starting at round {start}",
                            r.round
                        ));
                    }
                    movements[i] += r.movements;
                    min[i] = min[i].min(r.min_weight);
                    max[i] = max[i].max(r.max_weight);
                }
                *pending -= 1;
                complete_batch(pid, job, events)
            }
            Report::Checkpoint {
                job: id,
                shard,
                round,
                nodes,
            } => {
                let Some((pid, job)) = self.job_by_wire(id) else {
                    return Ok(());
                };
                job.deadline = Instant::now() + batch_timeout(job.batch);
                let JobPhase::Batch {
                    start,
                    b,
                    ckpt_pending,
                    parts,
                    ..
                } = &mut job.phase
                else {
                    return Err(anyhow!("unexpected checkpoint report for job {pid}"));
                };
                if round + 1 != *start + *b {
                    return Err(anyhow!(
                        "shard {shard} checkpointed round {round} inside the batch of job \
                         {pid} ending at round {}",
                        *start + *b - 1
                    ));
                }
                parts[shard] = Some(nodes);
                *ckpt_pending -= 1;
                complete_batch(pid, job, events)
            }
            Report::Final {
                job: id,
                shard,
                nodes,
            } => {
                let Some((pid, job)) = self.job_by_wire(id) else {
                    return Ok(());
                };
                job.deadline = Instant::now() + SHUTDOWN_TIMEOUT;
                let JobPhase::Closing { pending, state } = &mut job.phase else {
                    return Err(anyhow!("unexpected final report for job {pid}"));
                };
                let lo = job.map.range(shard).start;
                for (i, loads) in nodes.into_iter().enumerate() {
                    for l in loads {
                        state.push(lo + i, l);
                    }
                }
                *pending -= 1;
                if *pending == 0 {
                    let job = self.jobs.remove(&pid).expect("job vanished mid-close");
                    let JobPhase::Closing { mut state, .. } = job.phase else {
                        unreachable!("checked above");
                    };
                    // reassembly only sees surviving loads; the engines
                    // bump next_id for every arrival, departed or not
                    state.reserve_ids(job.next_id_hw);
                    events.push(JobEvent::Finished {
                        job: pid,
                        trace: job.trace,
                        state,
                    });
                }
                Ok(())
            }
        }
    }

    /// Recover one pool job from its newest checkpoint: retire the
    /// failed epoch on every worker, reopen the job under a fresh wire
    /// id seeded with the snapshot, and replay.  The tenant sees a
    /// single [`JobEvent::Recovering`]; replayed `Rounds` duplicates
    /// are suppressed by the `emitted` high-water mark.  A job that
    /// keeps failing is eventually declared [`JobEvent::Failed`].
    fn recover_job(&mut self, pid: u32, events: &mut Vec<JobEvent>) -> Result<()> {
        let wire = self.next_job;
        let shards = self.shards;
        let job = self.jobs.get_mut(&pid).expect("recovery of unknown job");
        let old = job.wire;
        job.recoveries += 1;
        if job.recoveries > 2 * shards + 2 {
            for s in 0..job.shards() {
                // best effort: workers drop what they still hold
                let _ = self.transport.send_ctl(s, Ctl::AbortJob { job: old });
            }
            self.jobs.remove(&pid);
            events.push(JobEvent::Failed {
                job: pid,
                error: "recovery limit exceeded: the job fails on every replay".to_string(),
            });
            return Ok(());
        }
        self.next_job += 1;
        job.wire = wire;
        let (resume, snapshot) = job
            .ckpts
            .back()
            .cloned()
            .expect("recover_job without a checkpoint");
        job.next = resume;
        job.trace.rounds.truncate(resume);
        job.phase = JobPhase::Ready;
        job.deadline = Instant::now() + ROUND_TIMEOUT;
        let m = job.shards();
        for s in 0..m {
            self.transport
                .send_ctl(s, Ctl::AbortJob { job: old })
                .map_err(|e| anyhow!("control link to shard {s} closed: {e}"))?;
            let range = job.map.range(s);
            let open = Ctl::OpenJob {
                job: wire,
                lo: range.start,
                algo: job.algo.name(),
                nodes: range.map(|v| snapshot[v].clone()).collect(),
            };
            self.transport
                .send_ctl(s, open)
                .map_err(|e| anyhow!("control link to shard {s} closed: {e}"))?;
        }
        events.push(JobEvent::Recovering {
            job: pid,
            round: resume,
        });
        Ok(())
    }

    /// Shut the pool down and join every worker; idempotent (a second
    /// call is a no-op `Ok`).  Still-open jobs are abandoned: workers
    /// flush a `Final` per open job on their way out, and the drain
    /// below discards them.
    pub fn shutdown(&mut self) -> Result<()> {
        if self.down {
            return Ok(());
        }
        self.down = true;
        for s in 0..self.shards {
            // a worker that already exited is surfaced by the join
            let _ = self.transport.send_ctl(s, Ctl::Shutdown);
        }
        // drain until every worker hangs up, so the joins are immediate
        let mut wedged = false;
        loop {
            match self.transport.recv_report(SHUTDOWN_TIMEOUT) {
                Ok(_) => {}
                Err(TransportError::Closed(_)) => break,
                Err(TransportError::Timeout) => {
                    wedged = true;
                    break;
                }
            }
        }
        if wedged {
            return Err(anyhow!("timed out shutting down the shard pool"));
        }
        for h in self.handles.drain(..) {
            if let Err(p) = h.join() {
                let msg = super::worker::panic_message(p.as_ref());
                return Err(anyhow!("pool worker panicked: {msg}"));
            }
        }
        Ok(())
    }
}

/// Finish a pool batch once *both* its counters drained: fold the
/// per-round stats into the trace, assemble and store the checkpoint
/// when one was requested, and surface the rounds the tenant has not
/// seen yet (a replay's duplicates are cut by the `emitted` mark).
fn complete_batch(pid: u32, job: &mut PoolJob, events: &mut Vec<JobEvent>) -> Result<()> {
    let (pending, ckpt_pending) = match &job.phase {
        JobPhase::Batch {
            pending,
            ckpt_pending,
            ..
        } => (*pending, *ckpt_pending),
        _ => return Err(anyhow!("batch completion outside a batch for job {pid}")),
    };
    if pending > 0 || ckpt_pending > 0 {
        return Ok(());
    }
    let JobPhase::Batch {
        start,
        b,
        colors,
        edges,
        movements,
        min,
        max,
        ckpt,
        parts,
        ..
    } = std::mem::replace(&mut job.phase, JobPhase::Ready)
    else {
        unreachable!("checked above");
    };
    let stats: Vec<RoundStats> = (0..b)
        .map(|i| RoundStats {
            round: start + i,
            color: colors[i],
            discrepancy: max[i] - min[i],
            movements: movements[i],
            edges: edges[i],
        })
        .collect();
    if ckpt {
        let mut snapshot: Vec<Vec<Load>> = vec![Vec::new(); job.map.n()];
        for (s, part) in parts.into_iter().enumerate() {
            let Some(nodes) = part else {
                return Err(anyhow!("shard {s} delivered no checkpoint slice for job {pid}"));
            };
            let lo = job.map.range(s).start;
            for (i, loads) in nodes.into_iter().enumerate() {
                snapshot[lo + i] = loads;
            }
        }
        while job.ckpts.len() >= CKPT_RING {
            job.ckpts.pop_front();
        }
        job.ckpts.push_back((start + b, snapshot));
    }
    job.next = start + b;
    job.trace.rounds.extend(stats.iter().cloned());
    let fresh: Vec<RoundStats> = if job.emitted >= start + b {
        Vec::new()
    } else {
        stats[job.emitted.saturating_sub(start)..].to_vec()
    };
    job.emitted = job.emitted.max(start + b);
    if !fresh.is_empty() {
        events.push(JobEvent::Rounds {
            job: pid,
            stats: fresh,
        });
    }
    Ok(())
}

impl Drop for ShardPool {
    fn drop(&mut self) {
        let _ = self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{PairAlgorithm, SortAlgo};
    use crate::bcm::{Engine, Sequential, StopRule};
    use crate::graph::Graph;
    use crate::load::{Load, Mobility, WeightDistribution};

    fn init(
        n: usize,
        per_node: usize,
        mobility: Mobility,
        seed: u64,
    ) -> (LoadState, Schedule, Pcg64) {
        let mut rng = Pcg64::new(seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            n,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        );
        (state, schedule, rng)
    }

    #[test]
    fn cluster_balances_and_conserves() {
        let (state, schedule, mut rng) = init(8, 30, Mobility::Full, 1);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init_disc = state.discrepancy();
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        let trace = cluster.run(&schedule, 8, &mut rng).unwrap();
        let final_state = cluster.shutdown().unwrap();
        assert_eq!(final_state.all_ids(), ids);
        assert!((final_state.total_weight() - mass).abs() < 1e-6);
        assert!(
            trace.final_discrepancy() < init_disc / 10.0,
            "init {init_disc} final {}",
            trace.final_discrepancy()
        );
        // the trace's own view agrees with the final state
        assert!((final_state.discrepancy() - trace.final_discrepancy()).abs() < 1e-9);
    }

    #[test]
    fn cluster_greedy_runs() {
        let (state, schedule, mut rng) = init(6, 20, Mobility::Partial, 2);
        let lmax = state.max_load_weight();
        let mut cluster = Cluster::spawn_sharded(state, WorkerAlgo::Greedy, 3);
        let trace = cluster.run(&schedule, 4, &mut rng).unwrap();
        // greedy can overshoot by at most the single-load quantum
        assert!(trace.final_discrepancy() <= trace.initial_discrepancy + lmax + 1e-9);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn cluster_bit_identical_to_sequential_engine() {
        // The tentpole contract: same seed => same RunTrace and same
        // final LoadState as the sequential reference, for shard counts
        // 1, 2 and one-per-core.
        let (state0, schedule, _) = init(8, 40, Mobility::Full, 3);
        let seed = 77;
        let sweeps = 6;
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(sweeps),
            seed,
        );
        let cores = crate::coordinator::shard::resolve_shards(0);
        for shards in [1, 2, cores] {
            let mut cluster =
                Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
            let trace = cluster.run_seeded(&schedule, sweeps, seed).unwrap();
            let fin = cluster.shutdown().unwrap();
            assert_eq!(trace, seq_trace, "trace diverged at {shards} shards");
            assert_eq!(fin, seq_state, "state diverged at {shards} shards");
        }
    }

    #[test]
    fn batched_runs_bit_identical_at_every_batch_size() {
        // The batching extension of the tentpole contract: the pipelined
        // batched execution must not be observable in the results, for
        // any (shards, batch) combination including one batch covering
        // the whole run.
        let (state0, schedule, _) = init(10, 25, Mobility::Full, 8);
        let seed = 31;
        let sweeps = 4;
        let total_rounds = sweeps * schedule.period();
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(sweeps),
            seed,
        );
        for shards in [2usize, 3] {
            for batch in [1usize, 3, total_rounds] {
                let mut cluster =
                    Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
                cluster.set_batch_rounds(batch);
                assert_eq!(cluster.batch_rounds(), batch);
                let trace = cluster.run_seeded(&schedule, sweeps, seed).unwrap();
                let fin = cluster.shutdown().unwrap();
                assert_eq!(
                    trace, seq_trace,
                    "trace diverged at {shards} shards, batch {batch}"
                );
                assert_eq!(
                    fin, seq_state,
                    "state diverged at {shards} shards, batch {batch}"
                );
            }
        }
    }

    #[test]
    fn cluster_bit_identical_with_pinned_and_partial_mobility() {
        let (mut state0, schedule, _) = init(12, 8, Mobility::Partial, 9);
        state0.push(3, Load::pinned(10_000, 75.0));
        state0.push(0, Load::pinned(10_001, 5.0));
        let seed = 1234;
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(4),
            seed,
        );
        for shards in [1usize, 2, 3, 5] {
            let mut cluster =
                Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
            let trace = cluster.run_seeded(&schedule, 4, seed).unwrap();
            let fin = cluster.shutdown().unwrap();
            assert_eq!(trace, seq_trace, "trace diverged at {shards} shards");
            assert_eq!(fin, seq_state, "state diverged at {shards} shards");
            // the heavy pinned load never left its host
            assert!(fin.node(3).iter().any(|l| l.id == 10_000 && !l.mobile));
        }
    }

    #[test]
    fn leader_messages_scale_with_cut_not_n() {
        // Contiguous shards on a ring: the cut is exactly `shards` edges,
        // so per-round traffic must be O(shards), not O(n) — and batching
        // must shrink the leader's share by the batch factor.
        let n = 64;
        let shards = 4;
        let sweeps = 3;
        let g = Graph::ring(n);
        let schedule = Schedule::from_graph(&g);
        let mk_state = || {
            let mut rng = Pcg64::new(5);
            LoadState::init_uniform_counts(
                n,
                4,
                &WeightDistribution::paper_section6(),
                Mobility::Full,
                &mut rng,
            )
        };
        let mut cluster = Cluster::spawn_sharded(mk_state(), WorkerAlgo::SortedGreedy, shards);
        cluster.set_batch_rounds(1);
        cluster.run_seeded(&schedule, sweeps, 9).unwrap();
        let stats = cluster.message_stats();
        cluster.shutdown().unwrap();
        let rounds = sweeps * schedule.period();
        assert_eq!(stats.rounds, rounds);
        assert_eq!(stats.batches, rounds);
        // each of the ring's k cut edges appears once per sweep
        assert_eq!(stats.cross_edges, shards * sweeps);
        // exactly one Offer + one Settle per cross-shard edge
        assert_eq!(stats.peer_msgs, 2 * stats.cross_edges);
        // leader traffic: k ctl + k reports per round, plus one weight
        // poll (k + k) for the initial discrepancy — O(shards), never O(n)
        let leader_msgs = stats.ctl_sent + stats.reports_received;
        assert_eq!(leader_msgs, 2 * shards * (rounds + 1));
        assert!(
            leader_msgs < n * rounds,
            "leader messaging is O(n) again: {leader_msgs} msgs for {rounds} rounds"
        );

        // Batched rerun on the same ring: the per-round leader component
        // must shrink to exactly 1/B of the unbatched count (the poll is
        // batch-independent), while peer traffic stays pinned to the cut.
        let batch = 3;
        assert_eq!(rounds % batch, 0, "test wants an integral batch count");
        let mut batched = Cluster::spawn_sharded(mk_state(), WorkerAlgo::SortedGreedy, shards);
        batched.set_batch_rounds(batch);
        batched.run_seeded(&schedule, sweeps, 9).unwrap();
        let bstats = batched.message_stats();
        batched.shutdown().unwrap();
        assert_eq!(bstats.rounds, rounds);
        assert_eq!(bstats.batches, rounds / batch);
        assert_eq!(bstats.cross_edges, stats.cross_edges);
        assert_eq!(bstats.peer_msgs, stats.peer_msgs);
        let batched_leader = bstats.ctl_sent + bstats.reports_received;
        let poll = 2 * shards; // one PollWeights + one Weights per shard
        assert_eq!(
            batched_leader - poll,
            (leader_msgs - poll) / batch,
            "batching did not amortize leader round-trips by {batch}x"
        );
    }

    #[test]
    fn worker_panic_mid_batch_names_the_failing_round() {
        // A worker that dies inside a batch must surface an error naming
        // the round it died in, and the cluster must fail stop.
        let (state, schedule, _) = init(8, 10, Mobility::Full, 11);
        let fail_round = 3;
        let mut cluster =
            Cluster::spawn_with_fault(state, WorkerAlgo::SortedGreedy, 1, (0, fail_round));
        cluster.set_batch_rounds(schedule.period() * 3); // whole run in one batch
        let sweeps = 3;
        assert!(sweeps * schedule.period() > fail_round, "fault round never reached");
        let err = cluster
            .run_seeded(&schedule, sweeps, 5)
            .expect_err("injected fault did not surface")
            .to_string();
        assert!(
            err.contains(&format!("round {fail_round}")),
            "error does not name the failing round: {err}"
        );
        assert!(err.contains("injected fault"), "panic payload lost: {err}");
        // fail-stop: the poisoned cluster refuses further rounds and
        // re-surfaces the failure on shutdown
        assert!(cluster.run_seeded(&schedule, 1, 5).is_err());
        assert!(cluster.shutdown().is_err());
    }

    #[test]
    fn checkpointed_recovery_replays_bit_identical() {
        // The recovery contract (DESIGN.md §8): with a checkpoint
        // cadence set, a mid-run failure no longer fails the run — the
        // epoch is aborted and replayed from the newest checkpoint, and
        // because every edge draws from `Pcg64::for_edge(seed, round,
        // edge)` the replay rebuilds the exact rounds the failure
        // destroyed.  Trace and final state stay bit-identical to the
        // sequential reference.
        let (state0, schedule, _) = init(8, 20, Mobility::Full, 13);
        let seed = 99;
        let sweeps = 3;
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(sweeps),
            seed,
        );
        let fail_round = 5;
        assert!(sweeps * schedule.period() > fail_round, "fault round never reached");
        let mut cluster =
            Cluster::spawn_with_fault(state0, WorkerAlgo::SortedGreedy, 2, (0, fail_round));
        cluster.set_checkpoint_every(2);
        assert_eq!(cluster.checkpoint_every(), 2);
        let trace = cluster
            .run_seeded(&schedule, sweeps, seed)
            .expect("checkpointed run must survive the injected fault");
        let fin = cluster.shutdown().unwrap();
        assert_eq!(trace, seq_trace, "replayed trace diverged");
        assert_eq!(fin, seq_state, "replayed state diverged");
    }

    #[test]
    fn fault_without_checkpointing_keeps_fail_stop() {
        // checkpoint_every = 0 (the default) must preserve the classic
        // contract byte for byte: the same spawn as above, but the run
        // fails and the cluster poisons.
        let (state0, schedule, _) = init(8, 20, Mobility::Full, 13);
        let mut cluster =
            Cluster::spawn_with_fault(state0, WorkerAlgo::SortedGreedy, 2, (0, 5));
        let err = cluster
            .run_seeded(&schedule, 3, 99)
            .expect_err("fail-stop contract broken")
            .to_string();
        assert!(err.contains("round 5"), "error does not name the round: {err}");
        assert!(cluster.shutdown().is_err());
    }

    #[test]
    fn pinned_loads_survive_distributed_run() {
        let mut rng = Pcg64::new(4);
        let g = Graph::ring(4);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::empty(4);
        state.push(1, crate::load::Load::pinned(0, 42.0));
        state.push(0, crate::load::Load::new(1, 1.0));
        state.push(2, crate::load::Load::new(2, 2.0));
        let mut cluster = Cluster::spawn_sharded(state, WorkerAlgo::SortedGreedy, 2);
        cluster.run(&schedule, 3, &mut rng).unwrap();
        let fin = cluster.shutdown().unwrap();
        assert!(fin.node(1).iter().any(|l| l.id == 0 && !l.mobile));
        assert_eq!(fin.total_loads(), 3);
    }

    #[test]
    fn single_round_api_reproduces_full_runs() {
        let (state0, schedule, _) = init(10, 12, Mobility::Full, 6);
        let seed = 42;
        let sweeps = 2;
        let mut a = Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, 2);
        let full = a.run_seeded(&schedule, sweeps, seed).unwrap();
        let fin_a = a.shutdown().unwrap();
        let mut b = Cluster::spawn_sharded(state0, WorkerAlgo::SortedGreedy, 2);
        let mut rounds = Vec::new();
        for round in 0..sweeps * schedule.period() {
            rounds.push(b.run_round_seeded(&schedule, round, seed).unwrap());
        }
        let fin_b = b.shutdown().unwrap();
        assert_eq!(full.rounds, rounds);
        assert_eq!(fin_a, fin_b);
    }

    #[test]
    fn batch_knob_resolution() {
        assert_eq!(resolve_batch_rounds(0, 64), 1); // auto, small n
        assert_eq!(resolve_batch_rounds(0, 16384), 1);
        assert_eq!(resolve_batch_rounds(0, 65536), 4); // auto kicks in
        assert_eq!(resolve_batch_rounds(0, 262144), 16);
        assert_eq!(resolve_batch_rounds(7, 64), 7); // explicit wins
        assert_eq!(resolve_batch_rounds(1, 1 << 20), 1);
    }
}
