//! Network substrate: topologies, edge coloring, matching/round matrices,
//! spectral analysis (paper §2).

pub mod coloring;
pub mod matrix;
pub mod spectral;
pub mod topology;

pub use coloring::EdgeColoring;
pub use matrix::{matching_matrix, round_matrix, Matrix};
pub use topology::{Graph, Topology};
