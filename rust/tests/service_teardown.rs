//! Teardown regressions: shutdown must be idempotent, must release the
//! listen port immediately, and must leave no lingering I/O threads —
//! the poller runs all socket I/O on the calling thread, so after
//! shutdown the process is back to exactly its pre-spawn thread count.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::transport::tcp::LeaderListener;
use bcm_dlb::coordinator::{Cluster, JobEvent, JobSpec, ShardPool};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use std::process::{Child, Command, Stdio};
use std::time::Duration;

const ALGO: PairAlgorithm = PairAlgorithm::SortedGreedy(SortAlgo::Quick);

fn init_scenario(n: usize, seed: u64) -> (LoadState, Schedule) {
    let mut rng = Pcg64::new(seed);
    let g = Graph::random_connected(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        8,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    (state, schedule)
}

fn spawn_workers(addr: &str, k: usize) -> Vec<Child> {
    (0..k)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_bcm-dlb"))
                .args(["cluster-worker", "--connect", addr, "--retry", "40"])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a cluster-worker process")
        })
        .collect()
}

fn run_one_job(pool: &mut ShardPool) {
    let (state, schedule) = init_scenario(16, 3);
    let mut seq_state = state.clone();
    let seq_trace = Sequential.run(&mut seq_state, &schedule, ALGO, StopRule::sweeps(2), 7);
    let id = pool
        .open_job(JobSpec {
            state,
            schedule,
            algo: ALGO,
            sweeps: 2,
            seed: 7,
            batch: 1,
            checkpoint_every: 0,
            churn: None,
        })
        .expect("job opens");
    loop {
        for ev in pool.step(Duration::from_millis(50)).expect("pool healthy") {
            match ev {
                JobEvent::Finished { job, trace, state } => {
                    assert_eq!(job, id);
                    assert_eq!(trace, seq_trace);
                    assert_eq!(state, seq_state);
                    return;
                }
                JobEvent::Failed { error, .. } => panic!("job failed: {error}"),
                _ => {}
            }
        }
    }
}

#[test]
fn pool_shutdown_is_idempotent() {
    let mut pool = ShardPool::spawn(2);
    run_one_job(&mut pool);
    pool.shutdown().expect("first shutdown");
    pool.shutdown().expect("second shutdown is a no-op");
    // a shut-down pool refuses new work instead of wedging
    let (state, schedule) = init_scenario(16, 3);
    let err = pool
        .open_job(JobSpec {
            state,
            schedule,
            algo: ALGO,
            sweeps: 1,
            seed: 1,
            batch: 1,
            checkpoint_every: 0,
            churn: None,
        })
        .expect_err("open_job on a down pool")
        .to_string();
    assert!(err.contains("shut down"), "unexpected error: {err}");
    // Drop after explicit shutdown must not double-join or panic.
    drop(pool);
}

#[test]
fn tcp_shutdown_releases_the_port_for_immediate_rebind() {
    let (state0, schedule) = init_scenario(16, 11);
    let mut seq_state = state0.clone();
    let seq_trace = Sequential.run(&mut seq_state, &schedule, ALGO, StopRule::sweeps(2), 9);

    let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader");
    let addr = listener.local_addr().expect("local addr").to_string();

    // two full lifecycles on the SAME port, back to back: lifecycle 1
    // must have released it synchronously at shutdown
    run_tcp_cycle(listener, &addr, &state0, &schedule, &seq_trace, &seq_state);
    let relisten = LeaderListener::bind(&addr).expect("immediate rebind of the leader port");
    run_tcp_cycle(relisten, &addr, &state0, &schedule, &seq_trace, &seq_state);
}

fn run_tcp_cycle(
    listener: LeaderListener,
    addr: &str,
    state0: &LoadState,
    schedule: &Schedule,
    seq_trace: &bcm_dlb::bcm::RunTrace,
    seq_state: &LoadState,
) {
    let mut workers = spawn_workers(addr, 2);
    let mut cluster = Cluster::spawn_tcp(state0.clone(), ALGO, 2, listener).expect("tcp spawn");
    let trace = cluster.run_seeded(schedule, 2, 9).expect("tcp run");
    let fin = cluster.shutdown().expect("tcp shutdown");
    assert_eq!(&trace, seq_trace);
    assert_eq!(&fin, seq_state);
    for w in &mut workers {
        let status = w.wait().expect("waiting for worker");
        assert!(status.success(), "worker exited nonzero");
    }
}

/// Count this process's kernel threads.
#[cfg(target_os = "linux")]
fn thread_count() -> usize {
    std::fs::read_dir("/proc/self/task").expect("procfs").count()
}

#[test]
#[cfg(target_os = "linux")]
fn no_lingering_threads_after_pool_shutdown() {
    // Other tests in this binary run on sibling threads, so measure
    // relative to a baseline taken right before the spawn and allow the
    // count to settle with a bounded retry.
    let baseline = thread_count();
    let mut pool = ShardPool::spawn(4);
    run_one_job(&mut pool);
    assert!(
        thread_count() > baseline,
        "pool workers should be visible in /proc/self/task"
    );
    pool.shutdown().expect("shutdown");
    let mut last = 0;
    for _ in 0..100 {
        last = thread_count();
        if last <= baseline {
            return; // every worker thread is gone
        }
        std::thread::sleep(Duration::from_millis(50));
    }
    panic!("thread count stuck at {last} (baseline {baseline}) after shutdown");
}
