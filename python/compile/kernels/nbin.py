"""Layer-1 Pallas kernel: batched n-bin greedy placement (offline solver).

The Appendix-C experiments (paper Figs. 4 and 5) study the offline weighted
balls-into-bins problem with n >= 2 bins.  This kernel generalizes
two_bin.py: the scan carry is the full [B, N] bin-sum matrix and each step
places the next ball into the bin with the least current sum (first index
wins ties — the same convention as the Rust reference implementation).

Inputs
------
weights : f32[B, M]  descending-sorted, zero-padded ball weights.
base    : f32[B, N]  initial bin sums (zeros for the classical problem).

Outputs
-------
assign  : i32[B, M]  bin index of each ball.
sums    : f32[B, N]  final bin sums.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nbin_kernel(w_ref, base_ref, assign_ref, sums_ref, *, m: int, nbins: int):
    w = w_ref[...]  # [Bb, M]
    sums0 = base_ref[...]  # [Bb, N]
    assign0 = jnp.zeros(w.shape, jnp.int32)

    def body(i, carry):
        sums, assign = carry
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)  # [Bb, 1]
        light = jnp.argmin(sums, axis=1)  # [Bb], ties -> lowest index
        onehot = (
            jax.lax.broadcasted_iota(jnp.int32, sums.shape, dimension=1)
            == light[:, None]
        ).astype(sums.dtype)
        sums = sums + wi * onehot
        assign = jax.lax.dynamic_update_slice_in_dim(
            assign, light[:, None].astype(jnp.int32), i, axis=1
        )
        return (sums, assign)

    sums, assign = jax.lax.fori_loop(0, m, body, (sums0, assign0))
    assign_ref[...] = assign
    sums_ref[...] = sums


def nbin_greedy(weights, base, *, block_b: int | None = None):
    """Batched greedy n-bin placement of descending-sorted weights.

    Returns ``(assign[B, M] i32, sums[B, N] f32)``.
    """
    b, m = weights.shape
    b2, nbins = base.shape
    if b2 != b:
        raise ValueError(f"batch mismatch: weights {b} vs base {b2}")
    if block_b is None:
        block_b = min(b, 8)
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")

    kernel = functools.partial(_nbin_kernel, m=m, nbins=nbins)
    grid = (b // block_b,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, nbins), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, nbins), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), jnp.int32),
            jax.ShapeDtypeStruct((b, nbins), weights.dtype),
        ],
        interpret=True,
    )(weights, base)
