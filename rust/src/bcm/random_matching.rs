//! The random matching model (RMM) — paper §2.1: "The results we show
//! here for BCM can be extended to the random matching model, where the
//! matching matrices are realizations of a stochastic process."
//!
//! Each round draws a fresh random maximal matching of the graph instead
//! of cycling a fixed coloring.  The standard generator: every edge
//! proposes in random order; an edge joins the matching if both endpoints
//! are still free.  This is the model of Ghosh & Muthukrishnan's seminal
//! analysis and the ablation bench compares its convergence against the
//! deterministic BCM schedule.

use super::trace::{RoundStats, RunTrace};
use crate::balancer::PairAlgorithm;
use crate::bcm::engine::balance_edge;
use crate::graph::Graph;
use crate::load::LoadState;
use crate::util::rng::Pcg64;

/// Draw a uniformly-ordered greedy maximal matching.
pub fn random_maximal_matching(g: &Graph, rng: &mut Pcg64) -> Vec<(u32, u32)> {
    let mut edges: Vec<(u32, u32)> = g.edges().to_vec();
    rng.shuffle(&mut edges);
    let mut used = vec![false; g.n()];
    let mut matching = Vec::new();
    for (u, v) in edges {
        if !used[u as usize] && !used[v as usize] {
            used[u as usize] = true;
            used[v as usize] = true;
            matching.push((u, v));
        }
    }
    matching
}

/// Run `rounds` rounds of the random matching model protocol.
pub fn run_rmm(
    state: &mut LoadState,
    g: &Graph,
    algo: PairAlgorithm,
    rounds: usize,
    rng: &mut Pcg64,
) -> RunTrace {
    assert_eq!(state.n(), g.n());
    let mut trace = RunTrace {
        initial_discrepancy: state.discrepancy(),
        rounds: Vec::new(),
    };
    for round in 0..rounds {
        let pairs = random_maximal_matching(g, rng);
        let mut movements = 0usize;
        for &(u, v) in &pairs {
            movements += balance_edge(state, u as usize, v as usize, algo, rng);
        }
        trace.rounds.push(RoundStats {
            round,
            color: 0, // RMM has no colors
            discrepancy: state.discrepancy(),
            movements,
            edges: pairs.len(),
        });
    }
    trace
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::SortAlgo;
    use crate::load::{Mobility, WeightDistribution};

    #[test]
    fn matching_is_valid_and_maximal() {
        let mut rng = Pcg64::new(1);
        for _ in 0..50 {
            let g = Graph::random_connected(24, &mut rng);
            let m = random_maximal_matching(&g, &mut rng);
            let mut used = vec![false; g.n()];
            for &(u, v) in &m {
                assert!(!used[u as usize] && !used[v as usize]);
                used[u as usize] = true;
                used[v as usize] = true;
            }
            // maximality: no remaining edge has both endpoints free
            for &(u, v) in g.edges() {
                assert!(
                    used[u as usize] || used[v as usize],
                    "edge ({u},{v}) could still be matched"
                );
            }
        }
    }

    #[test]
    fn matchings_vary_between_rounds() {
        let mut rng = Pcg64::new(2);
        let g = Graph::random_connected(16, &mut rng);
        let a = random_maximal_matching(&g, &mut rng);
        let b = random_maximal_matching(&g, &mut rng);
        let c = random_maximal_matching(&g, &mut rng);
        assert!(a != b || b != c, "three identical random matchings");
    }

    #[test]
    fn rmm_converges_like_bcm() {
        let mut rng = Pcg64::new(3);
        let g = Graph::random_connected(16, &mut rng);
        let mut state = LoadState::init_uniform_counts(
            16,
            50,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let init = state.discrepancy();
        let trace = run_rmm(
            &mut state,
            &g,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            80,
            &mut rng,
        );
        assert!(
            trace.final_discrepancy() < init / 20.0,
            "init {init} final {}",
            trace.final_discrepancy()
        );
    }

    #[test]
    fn rmm_conserves_loads() {
        let mut rng = Pcg64::new(4);
        let g = Graph::ring(8);
        let mut state = LoadState::init_uniform_counts(
            8,
            20,
            &WeightDistribution::paper_section6(),
            Mobility::Partial,
            &mut rng,
        );
        let ids = state.all_ids();
        run_rmm(&mut state, &g, PairAlgorithm::Greedy, 30, &mut rng);
        assert_eq!(state.all_ids(), ids);
    }

    #[test]
    fn star_matching_single_edge() {
        // A star's maximal matchings have exactly one edge.
        let mut rng = Pcg64::new(5);
        let g = Graph::star(8);
        for _ in 0..10 {
            assert_eq!(random_maximal_matching(&g, &mut rng).len(), 1);
        }
    }
}
