//! A worker thread = one processor of the network.
//!
//! Owns its load set exclusively; all interaction is via channels.  The
//! per-edge protocol is one-to-one (matching model): slave offers its
//! mobile loads, master solves the two-bin problem with the configured
//! local algorithm and settles the slave's share back.

use super::messages::{Ctl, Peer, Report};
use crate::balancer::{PairAlgorithm, SortAlgo};
use crate::load::Load;
use crate::runtime::{fallback, DeviceAlgo, EdgeProblem};
use std::sync::mpsc::{Receiver, Sender};

/// Algorithm a worker runs on its matched edges.
#[derive(Clone, Copy, Debug)]
pub enum WorkerAlgo {
    Greedy,
    SortedGreedy,
}

impl WorkerAlgo {
    fn device(self) -> DeviceAlgo {
        match self {
            WorkerAlgo::Greedy => DeviceAlgo::Greedy,
            WorkerAlgo::SortedGreedy => DeviceAlgo::SortedGreedy,
        }
    }

    pub fn pair(self) -> PairAlgorithm {
        match self {
            WorkerAlgo::Greedy => PairAlgorithm::Greedy,
            WorkerAlgo::SortedGreedy => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        }
    }
}

pub struct Worker {
    pub id: u32,
    pub loads: Vec<Load>,
    pub algo: WorkerAlgo,
    pub ctl_rx: Receiver<Ctl>,
    pub peer_rx: Receiver<Peer>,
    pub peer_tx: Vec<Sender<Peer>>,
    pub report_tx: Sender<Report>,
}

impl Worker {
    /// Event loop; returns when `Ctl::Shutdown` arrives.
    pub fn run(mut self) {
        while let Ok(msg) = self.ctl_rx.recv() {
            match msg {
                Ctl::Idle => {
                    let _ = self.report_tx.send(Report::RoundAck { node: self.id });
                }
                Ctl::Balance { peer, master, flip } => {
                    if master {
                        self.run_master(peer, flip);
                    } else {
                        self.run_slave(peer);
                    }
                    let _ = self.report_tx.send(Report::RoundAck { node: self.id });
                }
                Ctl::Report => {
                    let weight = self.loads.iter().map(|l| l.weight).sum();
                    let _ = self.report_tx.send(Report::Weight {
                        node: self.id,
                        weight,
                    });
                }
                Ctl::Shutdown => {
                    let _ = self.report_tx.send(Report::Final {
                        node: self.id,
                        loads: std::mem::take(&mut self.loads),
                    });
                    return;
                }
            }
        }
    }

    fn run_master(&mut self, peer: u32, flip: bool) {
        let (their_loads, their_pinned) = match self.peer_rx.recv() {
            Ok(Peer::Offer { loads, pinned }) => (loads, pinned),
            _ => return, // peer died; drop the edge
        };
        let (mine_mobile, mine_pinned): (Vec<Load>, Vec<Load>) =
            std::mem::take(&mut self.loads).into_iter().partition(|l| l.mobile);
        let my_pinned_w: f64 = mine_pinned.iter().map(|l| l.weight).sum();

        // Pool: master's loads then slave's (arrival order), matching the
        // sequential engine's semantics.
        let mut pool: Vec<Load> = mine_mobile;
        let my_count = pool.len();
        pool.extend(their_loads);
        let mut hosts: Vec<u8> = (0..pool.len())
            .map(|i| u8::from(i >= my_count))
            .collect();
        let mut base = [my_pinned_w, their_pinned];
        if flip {
            base.swap(0, 1);
            for h in hosts.iter_mut() {
                *h ^= 1;
            }
        }
        let problem = EdgeProblem {
            weights: pool.iter().map(|l| l.weight).collect(),
            hosts,
            base,
        };
        let sol = fallback::solve(&problem, self.algo.device());

        let mut mine: Vec<Load> = mine_pinned;
        let mut theirs: Vec<Load> = Vec::new();
        for (load, &side) in pool.into_iter().zip(&sol.assign) {
            let to_master = (side == 0) != flip;
            if to_master {
                mine.push(load);
            } else {
                theirs.push(load);
            }
        }
        let _ = self.peer_tx[peer as usize].send(Peer::Settle { loads: theirs });
        self.loads = mine;
        let edge = if self.id < peer {
            (self.id, peer)
        } else {
            (peer, self.id)
        };
        let _ = self.report_tx.send(Report::EdgeDone {
            edge,
            movements: sol.movements,
            local_discrepancy: (sol.sums[0] - sol.sums[1]).abs(),
        });
    }

    fn run_slave(&mut self, peer: u32) {
        let (mobile, pinned): (Vec<Load>, Vec<Load>) =
            std::mem::take(&mut self.loads).into_iter().partition(|l| l.mobile);
        let pinned_w: f64 = pinned.iter().map(|l| l.weight).sum();
        let _ = self.peer_tx[peer as usize].send(Peer::Offer {
            loads: mobile,
            pinned: pinned_w,
        });
        self.loads = pinned;
        if let Ok(Peer::Settle { loads }) = self.peer_rx.recv() {
            self.loads.extend(loads);
        }
    }
}
