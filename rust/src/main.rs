//! bcm-dlb launcher: the Layer-3 coordinator CLI.
//!
//! See `bcm-dlb help` (cli::USAGE) for the command reference.

use bcm_dlb::anyhow;
use bcm_dlb::balancer::PairAlgorithm;
use bcm_dlb::bcm::{run_device, Engine, Parallel, Schedule, Sequential, StopRule};
use bcm_dlb::cli::{Args, USAGE};
use bcm_dlb::config::ExperimentConfig;
use bcm_dlb::coordinator::transport::tcp::{self, LeaderListener, DEFAULT_CONNECT_RETRIES};
use bcm_dlb::coordinator::transport::TransportKind;
use bcm_dlb::coordinator::{resolve_shards, Cluster, TierLayout};
use bcm_dlb::experiments::{figures, run_dynamic_experiment, scaling, validate, SweepParams, E14_CSV};
use bcm_dlb::graph::{round_matrix, spectral, Topology};
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::{default_artifacts_dir, DeviceAlgo, Runtime};
use bcm_dlb::service::{self, ServeOptions, Server};
use bcm_dlb::theory;
use bcm_dlb::util::error::Result;
use bcm_dlb::util::json::Json;
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::stats::Welford;
use bcm_dlb::util::table::{f, Table};
use bcm_dlb::workload::{
    run_driver, run_dynamic_cluster, run_dynamic_cluster_tiered, run_dynamic_engine,
    sustained_stats, DlbPolicy, ParticleSim, TrafficConfig,
};
use std::path::Path;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let args = match Args::parse(&argv) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}\n\n{USAGE}");
            std::process::exit(2);
        }
    };
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn dispatch(args: &Args) -> Result<()> {
    match args.command.as_str() {
        "" | "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        "run" => cmd_run(args),
        "cluster-worker" => cmd_cluster_worker(args),
        "launch" => cmd_launch(args),
        "serve" => cmd_serve(args),
        "submit" => cmd_submit(args),
        "scale" => cmd_scale(args),
        "sweep" => cmd_sweep(args),
        "fig1" | "fig2" | "fig3" | "fig4" | "fig5" => cmd_fig(args),
        "timings" => cmd_timings(args),
        "particle-mesh" => cmd_particle_mesh(args),
        "spectral" => cmd_spectral(args),
        "validate" => cmd_validate(args),
        "artifacts" => cmd_artifacts(),
        other => Err(anyhow!("unknown command '{other}'\n\n{USAGE}")),
    }
}

fn config_from_args(args: &Args) -> Result<ExperimentConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    if let Some(t) = args.get("topology") {
        cfg.topology = Topology::parse(t).ok_or_else(|| anyhow!("bad --topology '{t}'"))?;
    }
    cfg.n = args.get_usize("n", cfg.n).map_err(|e| anyhow!(e))?;
    cfg.loads_per_node = args
        .get_usize("loads", cfg.loads_per_node)
        .map_err(|e| anyhow!(e))?;
    if let Some(a) = args.get("algo") {
        cfg.algorithm = PairAlgorithm::parse(a).ok_or_else(|| anyhow!("bad --algo '{a}'"))?;
    }
    if let Some(m) = args.get("mobility") {
        cfg.mobility = Mobility::parse(m).ok_or_else(|| anyhow!("bad --mobility '{m}'"))?;
    }
    if let Some(d) = args.get("dist") {
        cfg.distribution =
            WeightDistribution::parse(d).ok_or_else(|| anyhow!("bad --dist '{d}'"))?;
    }
    cfg.sweeps = args.get_usize("sweeps", cfg.sweeps).map_err(|e| anyhow!(e))?;
    cfg.reps = args.get_usize("reps", cfg.reps).map_err(|e| anyhow!(e))?;
    cfg.seed = args.get_u64("seed", cfg.seed).map_err(|e| anyhow!(e))?;
    if args.has("device") {
        cfg.use_device = true;
    }
    cfg.threads = args.get_usize("threads", cfg.threads).map_err(|e| anyhow!(e))?;
    cfg.shards = args.get_usize("shards", cfg.shards).map_err(|e| anyhow!(e))?;
    cfg.batch_rounds = args
        .get_usize("batch-rounds", cfg.batch_rounds)
        .map_err(|e| anyhow!(e))?;
    cfg.hosts = args.get_usize("hosts", cfg.hosts).map_err(|e| anyhow!(e))?;
    cfg.shards_per_host = args
        .get_usize("shards-per-host", cfg.shards_per_host)
        .map_err(|e| anyhow!(e))?;
    if let Some(t) = args.get("transport") {
        cfg.transport =
            TransportKind::parse(t).ok_or_else(|| anyhow!("bad --transport '{t}'"))?;
    }
    if let Some(l) = args.get("listen") {
        cfg.listen = l.to_string();
    }
    if let Some(p) = args.get("peers") {
        cfg.peers = p
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect();
    }
    cfg.checkpoint_every = args
        .get_usize("checkpoint-every", cfg.checkpoint_every)
        .map_err(|e| anyhow!(e))?;
    cfg.rejoin_wait_ms = args
        .get_u64("rejoin-wait", cfg.rejoin_wait_ms)
        .map_err(|e| anyhow!(e))?;
    if let Some(w) = args.get("workload") {
        if w != bcm_dlb::config::WORKLOAD_SERVICE_TRAFFIC {
            return Err(anyhow!(
                "bad --workload '{w}' (expected '{}')",
                bcm_dlb::config::WORKLOAD_SERVICE_TRAFFIC
            ));
        }
        cfg.workload = Some(w.to_string());
    }
    if let Some(r) = args.get_f64("arrival-rate").map_err(|e| anyhow!(e))? {
        cfg.arrival_rate = Some(r);
    }
    if let Some(a) = args.get_f64("pareto-alpha").map_err(|e| anyhow!(e))? {
        cfg.pareto_alpha = Some(a);
    }
    if args.get("hotspot-every").is_some() {
        cfg.hotspot_every = Some(args.get_usize("hotspot-every", 0).map_err(|e| anyhow!(e))?);
    }
    // flags may have added churn knobs to a workload-less file config
    cfg.validate_workload()?;
    Ok(cfg)
}

/// `bcm-dlb serve`: the multi-tenant balancer service — accept JSON job
/// specs over a socket and run them concurrently on one shared shard
/// pool, streaming per-round reports back as JSON lines.
fn cmd_serve(args: &Args) -> Result<()> {
    let cfg = match args.get("config") {
        Some(path) => ExperimentConfig::from_file(Path::new(path))?,
        None => ExperimentConfig::default(),
    };
    let opts = ServeOptions {
        listen: args
            .get("listen")
            .unwrap_or(cfg.serve_listen.as_str())
            .to_string(),
        max_jobs: args
            .get_usize("max-jobs", cfg.serve_max_jobs)
            .map_err(|e| anyhow!(e))?,
        shards: args.get_usize("shards", 0).map_err(|e| anyhow!(e))?,
        max_conns: args.get_usize("max-conns", 64).map_err(|e| anyhow!(e))?,
    };
    if opts.max_jobs == 0 {
        return Err(anyhow!("--max-jobs must be >= 1"));
    }
    let mut server = Server::bind(opts)?;
    println!("serving on {}", server.local_addr());
    server.run()
}

/// `bcm-dlb submit`: send one job spec (built from the usual run flags)
/// to a serve instance and stream its event lines to stdout.  Exits
/// nonzero when the served job ends in an error event.
fn cmd_submit(args: &Args) -> Result<()> {
    let addr = args.get("connect").unwrap_or("127.0.0.1:7412").to_string();
    let line = if args.has("shutdown") {
        r#"{"cmd":"shutdown"}"#.to_string()
    } else {
        let cfg = config_from_args(args)?;
        let mut spec = cfg.to_json();
        if let Json::Obj(o) = &mut spec {
            if args.has("verify") {
                o.insert("verify".to_string(), Json::Bool(true));
            }
            if args.has("stats") {
                o.insert("stats".to_string(), Json::Bool(true));
            }
        }
        spec.to_string()
    };
    let mut out = std::io::stdout().lock();
    if service::submit(&addr, &line, &mut out)? {
        Ok(())
    } else {
        Err(anyhow!("the service reported a job error (see the event stream above)"))
    }
}

/// `bcm-dlb cluster-worker`: serve one shard of a TCP cluster, either
/// dialing the leader (`--connect`) or awaiting its dial-in
/// (`--listen`).
fn cmd_cluster_worker(args: &Args) -> Result<()> {
    let retries = args
        .get_usize("retry", DEFAULT_CONNECT_RETRIES)
        .map_err(|e| anyhow!(e))?;
    // --fault-exit R: crash drill — the worker process exits(3) at the
    // start of round R, simulating a kill -9 for recovery tests.
    let fault_exit = match args.get("fault-exit") {
        None => None,
        Some(_) => Some(args.get_usize("fault-exit", 0).map_err(|e| anyhow!(e))?),
    };
    // --no-pin: skip the best-effort per-shard core pinning a two-tier
    // host worker applies by default (flat workers never pin).
    let pin = !args.has("no-pin");
    match (args.get("connect"), args.get("listen")) {
        (Some(addr), None) => tcp::serve_connect(addr, retries, fault_exit, pin),
        (None, Some(addr)) => tcp::serve_listen(addr, fault_exit, pin),
        _ => Err(anyhow!(
            "cluster-worker needs exactly one of --connect or --listen\n\n{USAGE}"
        )),
    }
}

/// `bcm-dlb launch`: emit the per-host command lines of a two-tier
/// cluster — one `cluster-worker` process per host address plus the
/// leader's `run` invocation dialing them all.  Pure text generation:
/// paste each line on its machine (or feed them to ssh/pdsh).
fn cmd_launch(args: &Args) -> Result<()> {
    let hosts: Vec<String> = args
        .get("hosts")
        .ok_or_else(|| anyhow!("launch needs --hosts A,B,C (host addresses)\n\n{USAGE}"))?
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if hosts.is_empty() {
        return Err(anyhow!("--hosts list is empty"));
    }
    let spp = args.get_usize("shards-per-host", 1).map_err(|e| anyhow!(e))?;
    if spp == 0 {
        return Err(anyhow!("--shards-per-host must be >= 1 on launch (0 = per-core \
                            only makes sense on the worker's own machine)"));
    }
    let port = args.get_usize("port", 7411).map_err(|e| anyhow!(e))?;
    let no_pin = if args.has("no-pin") { " --no-pin" } else { "" };
    println!(
        "# two-tier cluster: {} hosts x {} shards/host = {} shard workers",
        hosts.len(),
        spp,
        hosts.len() * spp
    );
    for (h, host) in hosts.iter().enumerate() {
        println!("# host {h} — run on {host}:");
        println!("bcm-dlb cluster-worker --listen {host}:{port}{no_pin}");
    }
    let peers: Vec<String> = hosts.iter().map(|h| format!("{h}:{port}")).collect();
    println!("# leader — run on any machine that reaches the workers:");
    println!(
        "bcm-dlb run --cluster --transport tcp --hosts {} --shards-per-host {spp} --peers {}",
        hosts.len(),
        peers.join(",")
    );
    Ok(())
}

fn cmd_run(args: &Args) -> Result<()> {
    let cfg = config_from_args(args)?;
    if let Some(tcfg) = cfg.traffic() {
        return cmd_run_dynamic(args, &cfg, &tcfg);
    }
    println!("config: {}", cfg.to_json());
    let mut init_d = Welford::new();
    let mut final_d = Welford::new();
    let mut moves = Welford::new();
    let mut rounds = Welford::new();
    let mut runtime = if cfg.use_device {
        let rt = Runtime::new(&default_artifacts_dir())?;
        println!("device: PJRT platform = {}", rt.platform());
        Some(rt)
    } else {
        None
    };
    let use_cluster = args.has("cluster");
    if cfg.threads != 1 && (use_cluster || cfg.use_device) {
        eprintln!(
            "warning: --threads {} is ignored on the {} path (engine threading only \
             applies to the in-process engines{})",
            cfg.threads,
            if use_cluster { "--cluster" } else { "--device" },
            if use_cluster {
                "; use --shards to size the sharded coordinator"
            } else {
                ""
            }
        );
    }
    let tcp_cluster = cfg.transport == TransportKind::Tcp;
    if tcp_cluster && !use_cluster {
        return Err(anyhow!("--transport tcp requires --cluster"));
    }
    if tcp_cluster && cfg.reps > 1 {
        // worker processes serve exactly one cluster lifecycle
        eprintln!(
            "warning: --transport tcp runs a single repetition (requested reps {})",
            cfg.reps
        );
    }
    let reps = if tcp_cluster { 1 } else { cfg.reps };
    for rep in 0..reps {
        let mut rng = Pcg64::new(cfg.seed.wrapping_add(rep as u64));
        let g = cfg.topology.build(cfg.n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            cfg.n,
            cfg.loads_per_node,
            &cfg.distribution,
            cfg.mobility,
            &mut rng,
        );
        let trace = if use_cluster {
            // Seeded like the engines and running the exact configured
            // algorithm, so a cluster run reproduces the sequential /
            // parallel result bit-exactly for any --shards, any
            // --batch-rounds, and either transport backend.
            let verify_src = if args.has("verify") {
                Some(state.clone())
            } else {
                None
            };
            // --hosts H > 0 selects the two-tier hierarchical
            // coordinator: H hosts x --shards-per-host in-process shard
            // workers, shards placed cut-aware against the topology.
            let tier = (cfg.hosts > 0)
                .then(|| TierLayout::new(cfg.hosts, resolve_shards(cfg.shards_per_host)));
            if tier.is_some() && cfg.shards != 0 {
                eprintln!(
                    "warning: --shards {} is ignored with --hosts (the tier layout \
                     fixes the shard count)",
                    cfg.shards
                );
            }
            let mut tier_traffic = None;
            let mut cluster = match (tier, cfg.transport) {
                (None, TransportKind::Local) => {
                    Cluster::spawn_with_algorithm(state, cfg.algorithm, cfg.shards)
                }
                (Some(layout), TransportKind::Local) => {
                    let (c, traffic) =
                        Cluster::spawn_tiered(state, cfg.algorithm, layout, g.edges());
                    tier_traffic = Some(traffic);
                    c
                }
                (None, TransportKind::Tcp) if !cfg.peers.is_empty() => {
                    Cluster::spawn_tcp_connect(state, cfg.algorithm, &cfg.peers)?
                }
                (Some(layout), TransportKind::Tcp) if !cfg.peers.is_empty() => {
                    Cluster::spawn_tcp_connect_tiered(
                        state,
                        cfg.algorithm,
                        layout,
                        g.edges(),
                        &cfg.peers,
                    )?
                }
                (None, TransportKind::Tcp) => {
                    let listener = LeaderListener::bind(&cfg.listen)?;
                    println!(
                        "tcp leader listening on {} for {} cluster-worker processes",
                        listener.local_addr()?,
                        cfg.shards
                    );
                    Cluster::spawn_tcp(state, cfg.algorithm, cfg.shards, listener)?
                }
                (Some(layout), TransportKind::Tcp) => {
                    let listener = LeaderListener::bind(&cfg.listen)?;
                    println!(
                        "tcp leader listening on {} for {} cluster-worker host processes \
                         ({} shards each)",
                        listener.local_addr()?,
                        layout.hosts,
                        layout.shards_per_host
                    );
                    Cluster::spawn_tcp_tiered(state, cfg.algorithm, layout, g.edges(), listener)?
                }
            };
            cluster.set_batch_rounds(cfg.batch_rounds);
            cluster.set_checkpoint_every(cfg.checkpoint_every);
            cluster.set_rejoin_wait(std::time::Duration::from_millis(cfg.rejoin_wait_ms));
            let seed = cfg.seed.wrapping_add(rep as u64);
            let t = cluster.run_seeded(&schedule, cfg.sweeps, seed)?;
            let final_state = cluster.shutdown()?;
            if let Some(traffic) = tier_traffic.take() {
                let (bytes, msgs, intra) = traffic.snapshot();
                println!(
                    "tier traffic: {bytes} inter-host bytes in {msgs} messages, \
                     {intra} intra-host messages (never framed)"
                );
            }
            if let Some(initial) = verify_src {
                let mut seq_state = initial;
                let seq_trace = Sequential.run(
                    &mut seq_state,
                    &schedule,
                    cfg.algorithm,
                    StopRule::sweeps(cfg.sweeps),
                    seed,
                );
                if seq_trace != t || seq_state != final_state {
                    return Err(anyhow!(
                        "cluster run diverged from the sequential reference"
                    ));
                }
                println!(
                    "verified: cluster trace and final state bit-identical to Sequential \
                     ({} transport)",
                    cfg.transport.name()
                );
            }
            t
        } else if let Some(rt) = runtime.as_mut() {
            let algo = match cfg.algorithm {
                PairAlgorithm::Greedy => DeviceAlgo::Greedy,
                _ => DeviceAlgo::SortedGreedy,
            };
            run_device(&mut state, &schedule, algo, cfg.sweeps, Some(rt), &mut rng)?
        } else {
            // Engine runs are keyed on the seed, not the shared stream:
            // the same config reproduces bit-identically at any --threads.
            let engine: Box<dyn Engine> = if cfg.threads == 1 {
                Box::new(Sequential)
            } else {
                Box::new(Parallel::new(cfg.threads))
            };
            engine.run(
                &mut state,
                &schedule,
                cfg.algorithm,
                StopRule::sweeps(cfg.sweeps),
                cfg.seed.wrapping_add(rep as u64),
            )
        };
        init_d.push(trace.initial_discrepancy);
        final_d.push(trace.final_discrepancy());
        moves.push(trace.total_movements() as f64);
        rounds.push(trace.rounds.len() as f64);
        // --trace-out FILE: per-round time series of the first repetition
        if rep == 0 {
            if let Some(path) = args.get("trace-out") {
                let mut t = Table::new(
                    "per-round trace",
                    &["round", "color", "discrepancy", "movements", "edges"],
                );
                for r in &trace.rounds {
                    t.row(vec![
                        r.round.to_string(),
                        r.color.to_string(),
                        f(r.discrepancy, 4),
                        r.movements.to_string(),
                        r.edges.to_string(),
                    ]);
                }
                t.write_csv(Path::new(path))?;
                println!("trace written to {path}");
            }
        }
    }
    let mut t = Table::new("run summary", &["metric", "mean", "std", "min", "max"]);
    for (name, w) in [
        ("initial discrepancy", &init_d),
        ("final discrepancy", &final_d),
        ("total movements", &moves),
        ("rounds", &rounds),
    ] {
        t.row(vec![
            name.into(),
            f(w.mean(), 3),
            f(w.std(), 3),
            f(w.min(), 3),
            f(w.max(), 3),
        ]);
    }
    println!("{}", t.render());
    Ok(())
}

/// The dynamic branch of `bcm-dlb run`: `--workload service-traffic`
/// churns the load set between balancing rounds (seeded arrivals with
/// Pareto costs, departures, cost drift) and reports *sustained*
/// discrepancy over the trailing half of the run plus cumulative
/// migration traffic, then appends the full E14 protocol comparison
/// (results/e14_service_traffic.csv).
fn cmd_run_dynamic(args: &Args, cfg: &ExperimentConfig, tcfg: &TrafficConfig) -> Result<()> {
    println!("config: {}", cfg.to_json());
    if cfg.use_device {
        return Err(anyhow!(
            "--workload service-traffic runs on the host engines (drop --device)"
        ));
    }
    if cfg.transport == TransportKind::Tcp {
        return Err(anyhow!(
            "--workload service-traffic supports the local cluster transport only"
        ));
    }
    let use_cluster = args.has("cluster");
    if cfg.threads != 1 && use_cluster {
        eprintln!(
            "warning: --threads {} is ignored on the --cluster path (use --shards)",
            cfg.threads
        );
    }
    let mut mean_d = Welford::new();
    let mut p99_d = Welford::new();
    let mut max_d = Welford::new();
    let mut moves = Welford::new();
    let mut e14_shape = (0usize, 0usize); // (rounds, window) of rep 0
    for rep in 0..cfg.reps {
        let seed = cfg.seed.wrapping_add(rep as u64);
        let mut rng = Pcg64::new(seed);
        let g = cfg.topology.build(cfg.n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state0 = LoadState::init_uniform_counts(
            cfg.n,
            cfg.loads_per_node,
            &cfg.distribution,
            cfg.mobility,
            &mut rng,
        );
        let rounds = (cfg.sweeps * schedule.period()).max(1);
        // the leading half of the run is the transient away from the
        // static initial state; sustained metrics fold the trailing half
        let window = (rounds / 2).max(1);
        if rep == 0 {
            e14_shape = (rounds, window);
        }
        let (trace, final_state) = if use_cluster && cfg.hosts > 0 {
            let layout = TierLayout::new(cfg.hosts, resolve_shards(cfg.shards_per_host));
            let (trace, fin, traffic) = run_dynamic_cluster_tiered(
                state0.clone(),
                &schedule,
                cfg.algorithm,
                tcfg,
                rounds,
                seed,
                layout,
                g.edges(),
            )?;
            if rep == 0 {
                let (bytes, msgs, intra) = traffic.snapshot();
                println!(
                    "tier traffic: {bytes} inter-host bytes in {msgs} messages, \
                     {intra} intra-host messages"
                );
            }
            (trace, fin)
        } else if use_cluster {
            run_dynamic_cluster(
                state0.clone(),
                &schedule,
                cfg.algorithm,
                tcfg,
                rounds,
                seed,
                cfg.shards,
            )?
        } else {
            let engine: Box<dyn Engine> = if cfg.threads == 1 {
                Box::new(Sequential)
            } else {
                Box::new(Parallel::new(cfg.threads))
            };
            let mut state = state0.clone();
            let trace = run_dynamic_engine(
                engine.as_ref(),
                &mut state,
                &schedule,
                cfg.algorithm,
                tcfg,
                rounds,
                seed,
            );
            (trace, state)
        };
        if args.has("verify") {
            let mut seq_state = state0.clone();
            let seq_trace = run_dynamic_engine(
                &Sequential,
                &mut seq_state,
                &schedule,
                cfg.algorithm,
                tcfg,
                rounds,
                seed,
            );
            if seq_trace != trace || seq_state != final_state {
                return Err(anyhow!("churning run diverged from the sequential reference"));
            }
            println!("verified: churning trace and final state bit-identical to Sequential");
        }
        let s = sustained_stats(&trace, window);
        mean_d.push(s.mean);
        p99_d.push(s.p99);
        max_d.push(s.max);
        moves.push(s.movements as f64);
        if rep == 0 {
            if let Some(path) = args.get("trace-out") {
                let mut t = Table::new(
                    "per-round trace",
                    &["round", "color", "discrepancy", "movements", "edges"],
                );
                for r in &trace.rounds {
                    t.row(vec![
                        r.round.to_string(),
                        r.color.to_string(),
                        f(r.discrepancy, 4),
                        r.movements.to_string(),
                        r.edges.to_string(),
                    ]);
                }
                t.write_csv(Path::new(path))?;
                println!("trace written to {path}");
            }
        }
    }
    let mut t = Table::new(
        "sustained run summary (trailing-window)",
        &["metric", "mean", "std", "min", "max"],
    );
    for (name, w) in [
        ("sustained mean discrepancy", &mean_d),
        ("sustained p99 discrepancy", &p99_d),
        ("sustained max discrepancy", &max_d),
        ("total movements", &moves),
    ] {
        t.row(vec![
            name.into(),
            f(w.mean(), 3),
            f(w.std(), 3),
            f(w.min(), 3),
            f(w.max(), 3),
        ]);
    }
    println!("{}", t.render());
    // the E14 protocol comparison on the rep-0 scenario: BCM sorted /
    // BCM greedy / diffusion under the identical churn stream
    let (rounds, window) = e14_shape;
    let report = run_dynamic_experiment(
        &cfg.topology,
        cfg.n,
        cfg.loads_per_node,
        rounds,
        window,
        cfg.seed,
        tcfg,
    );
    println!("{}", report.table.render());
    report.table.write_csv(Path::new(E14_CSV))?;
    println!("E14 table written to {E14_CSV}");
    Ok(())
}

fn cmd_scale(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 4096).map_err(|e| anyhow!(e))?;
    let topo = Topology::parse(args.get("topology").unwrap_or("torus2d"))
        .ok_or_else(|| anyhow!("bad --topology"))?;
    // --loads accepts a comma-separated L/n ladder; a single value keeps
    // the classic one-table output, more values add the combined
    // (workers x L/n) roofline table.
    let loads_ladder: Vec<usize> = args
        .get("loads")
        .unwrap_or("20")
        .split(',')
        .map(|s| {
            s.trim()
                .parse::<usize>()
                .map_err(|_| anyhow!("--loads expects integers, got '{s}'"))
        })
        .collect::<Result<_>>()?;
    if loads_ladder.is_empty() {
        return Err(anyhow!("--loads ladder is empty"));
    }
    let sweeps = args.get_usize("sweeps", 2).map_err(|e| anyhow!(e))?;
    let seed = args.get_u64("seed", 2013).map_err(|e| anyhow!(e))?;
    let threads: Vec<usize> = match args.get("threads") {
        Some(_) => vec![args.get_usize("threads", 0).map_err(|e| anyhow!(e))?],
        None => vec![2, 4, 0], // ladder ending in auto (one per core)
    };
    let shards: Vec<usize> = match args.get("shards") {
        Some(_) => vec![args.get_usize("shards", 0).map_err(|e| anyhow!(e))?],
        None => vec![2, 0], // shard ladder ending in auto (one per core)
    };
    let batches: Vec<usize> = match args.get("batch-rounds") {
        Some(_) => vec![args.get_usize("batch-rounds", 0).map_err(|e| anyhow!(e))?],
        None => vec![1, 4, 16], // batch ladder (rounds per Ctl message)
    };
    let points =
        scaling::run_roofline(&topo, n, &loads_ladder, sweeps, seed, &threads, &shards, &batches)?;
    for p in &points {
        let t = scaling::scaling_table(&p.report);
        println!("{}", t.render());
        // one classic CSV per L/n point (the single-value invocation
        // keeps the historical path)
        let path = if points.len() == 1 {
            "results/e11_scaling.csv".to_string()
        } else {
            format!("results/e11_scaling_L{}.csv", p.loads_per_node)
        };
        if t.write_csv(Path::new(&path)).is_ok() {
            println!("scaling table for L/n={} written to {path}", p.loads_per_node);
        }
    }
    if points.len() > 1 {
        let t = scaling::roofline_table(&points);
        println!("{}", t.render());
        t.write_csv(Path::new("results/e11_roofline.csv")).ok();
    }
    let best = points
        .iter()
        .map(|p| p.report.best_speedup())
        .fold(0.0f64, f64::max);
    if points.iter().all(|p| p.report.all_identical()) {
        println!(
            "parallel engine and sharded cluster trace-identical to sequential; \
             best speedup {best:.2}x"
        );
        Ok(())
    } else {
        Err(anyhow!("a parallel or cluster trace diverged from the sequential reference"))
    }
}

fn sweep_params(args: &Args) -> SweepParams {
    let mut p = SweepParams::from_env();
    if args.has("quick") {
        p.network_sizes = vec![4, 8, 16, 32, 64];
        p.reps = 10;
        p.sweeps = 10;
    }
    p
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let p = sweep_params(args);
    let out = Path::new("results");
    for t in figures::fig1(&p, out) {
        println!("{}", t.render());
    }
    for t in figures::fig2(&p, out) {
        println!("{}", t.render());
    }
    for t in figures::fig3(&p, out) {
        println!("{}", t.render());
    }
    println!("CSVs written under results/");
    Ok(())
}

fn cmd_fig(args: &Args) -> Result<()> {
    let out = Path::new("results");
    let p = sweep_params(args);
    let quick = args.has("quick")
        || std::env::var("BCM_DLB_QUICK").map(|v| v == "1").unwrap_or(false);
    let tables = match args.command.as_str() {
        "fig1" => figures::fig1(&p, out),
        "fig2" => figures::fig2(&p, out),
        "fig3" => figures::fig3(&p, out),
        "fig4" => figures::fig4(if quick { 100 } else { 1000 }, p.seed, out),
        "fig5" => figures::fig5(if quick { 100 } else { 1000 }, p.seed, out),
        _ => unreachable!(),
    };
    for t in tables {
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_timings(args: &Args) -> Result<()> {
    let reps = args.get_usize("reps", 100).map_err(|e| anyhow!(e))?;
    println!("{}", figures::timings(reps, 2013, Path::new("results")).render());
    Ok(())
}

fn cmd_particle_mesh(args: &Args) -> Result<()> {
    let procs = args.get_usize("procs", 32).map_err(|e| anyhow!(e))?;
    let steps = args.get_usize("steps", 300).map_err(|e| anyhow!(e))?;
    let particles = args.get_usize("particles", 200_000).map_err(|e| anyhow!(e))?;
    let sub_side = args.get_usize("subdomains", 32).map_err(|e| anyhow!(e))?;
    let seed = args.get_u64("seed", 42).map_err(|e| anyhow!(e))?;

    let mut rng = Pcg64::new(seed);
    let g = Topology::RandomConnected.build(procs, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let mut t = Table::new(
        &format!(
            "E9 particle-mesh driver: {procs} procs, {sub_side}x{sub_side} subdomains, {particles} particles, {steps} steps"
        ),
        &["policy", "total_makespan", "efficiency", "migrations", "vs_no_dlb"],
    );
    let mut base: Option<f64> = None;
    for policy in [DlbPolicy::None, DlbPolicy::Greedy, DlbPolicy::SortedGreedy] {
        let mut sim_rng = Pcg64::new(seed ^ 0xFACE);
        let mut sim = ParticleSim::new(sub_side, particles, &mut sim_rng);
        let mut prng = Pcg64::new(seed ^ 0xBEEF);
        let r = run_driver(policy, &mut sim, &schedule, procs, steps, 10, 8, &mut prng);
        let speedup = base.map(|b| b / r.total_makespan).unwrap_or(1.0);
        if base.is_none() {
            base = Some(r.total_makespan);
        }
        t.row(vec![
            policy.label().into(),
            f(r.total_makespan, 0),
            f(r.efficiency(), 3),
            r.migrations.to_string(),
            format!("{}x", f(speedup, 2)),
        ]);
    }
    println!("{}", t.render());
    t.write_csv(Path::new("results/e9_particle_mesh.csv")).ok();
    Ok(())
}

fn cmd_spectral(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 32).map_err(|e| anyhow!(e))?;
    let topo = Topology::parse(args.get("topology").unwrap_or("random"))
        .ok_or_else(|| anyhow!("bad --topology"))?;
    let seed = args.get_u64("seed", 1).map_err(|e| anyhow!(e))?;
    let mut rng = Pcg64::new(seed);
    let g = topo.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let m = round_matrix(n, schedule.matchings());
    let lambda = spectral::contraction_factor(&m, 500, seed);
    let mut t = Table::new(
        &format!("spectral analysis: {} n={n}", topo.name()),
        &["quantity", "value"],
    );
    t.row(vec!["edges".into(), g.num_edges().to_string()]);
    t.row(vec!["max degree".into(), g.max_degree().to_string()]);
    t.row(vec!["colors d".into(), schedule.period().to_string()]);
    t.row(vec!["contraction sigma2(M)".into(), f(lambda, 6)]);
    t.row(vec!["spectral gap".into(), f(1.0 - lambda, 6)]);
    t.row(vec!["ergodic".into(), (lambda < 1.0 - 1e-9).to_string()]);
    t.row(vec![
        "tau_cont(K=100, eps=1)".into(),
        f(
            theory::tau_cont(100.0, 1.0, n, schedule.period(), lambda.min(0.999_999)),
            0,
        ),
    ]);
    t.row(vec![
        "discrete bound (lmax=1)".into(),
        f(theory::discrete_discrepancy_bound(n, 1.0), 2),
    ]);
    println!("{}", t.render());
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 0).map_err(|e| anyhow!(e))?;
    let topo = Topology::parse(args.get("topology").unwrap_or("random"))
        .ok_or_else(|| anyhow!("bad --topology"))?;
    let seed = args.get_u64("seed", 7).map_err(|e| anyhow!(e))?;
    let sizes: Vec<usize> = if n > 0 { vec![n] } else { vec![8, 16, 32, 64] };
    let reports: Vec<_> = sizes
        .iter()
        .map(|&n| validate::validate(&topo, n, 50, seed))
        .collect();
    println!("{}", validate::validation_table(&reports).render());
    if reports.iter().all(|r| r.within_bound) {
        println!("all sizes within the Theorem-1 envelope");
        Ok(())
    } else {
        Err(anyhow!("some sizes exceeded the theory bound"))
    }
}

fn cmd_artifacts() -> Result<()> {
    let dir = default_artifacts_dir();
    let mut rt = Runtime::new(&dir)?;
    println!(
        "platform {} — {} artifacts in {}",
        rt.platform(),
        rt.manifest().artifacts.len(),
        dir.display()
    );
    let names: Vec<String> = rt
        .manifest()
        .artifacts
        .iter()
        .map(|a| a.name.clone())
        .collect();
    for name in names {
        let start = std::time::Instant::now();
        rt.executable(&name)?;
        println!(
            "  compiled {name} in {:.0} ms",
            start.elapsed().as_secs_f64() * 1e3
        );
    }
    println!("all artifacts compile");
    Ok(())
}
