//! Pluggable message transports for the sharded coordinator.
//!
//! PR 4 left the cluster "distributed" across the threads of one
//! process: every [`Ctl`]/[`ShardMsg`]/[`Report`] travelled over a
//! hardwired `std::sync::mpsc` channel.  This module lifts the protocol
//! onto two small traits — [`LeaderTransport`] for the control/report
//! plane and [`WorkerTransport`] for a shard worker's four endpoints —
//! with two backends:
//!
//! * [`local`] — the original in-process channels, now just one
//!   implementation of the traits.  Behavior (and every bit-identity and
//!   fail-stop test) is unchanged.
//! * [`tcp`] — a dependency-free length-prefixed binary codec
//!   ([`codec`]) over `std::net::TcpStream`, so the leader and the shard
//!   workers can run as separate OS processes (`bcm-dlb cluster-worker`)
//!   and still produce traces **bit-identical** to `bcm::Sequential`.
//! * [`tiered`] — the two-tier composition of the other two: each
//!   `cluster-worker` process hosts several in-process shard workers
//!   (mpsc channels inside, one egress pump multiplexing Mux-wrapped
//!   frames onto the TCP host mesh outside), so cross-host wire traffic
//!   scales with the *inter-host* cut instead of the global cut.
//!
//! The protocol (DESIGN.md §6) needs exactly two guarantees from a
//! transport, and both backends provide them:
//!
//! 1. **FIFO per directed link** — messages between one sender and one
//!    receiver arrive in send order (mpsc channels and TCP streams are
//!    both ordered).
//! 2. **Sends never block indefinitely** — the local backend's channels
//!    are unbounded; the TCP backend runs every socket nonblocking under
//!    one readiness [`poll`]er per endpoint, buffering writes that would
//!    block and retrying them on every poll pass, so the kernel's socket
//!    buffers can always empty and a send always completes or fails —
//!    it never wedges.  (Earlier revisions paired each socket with a
//!    detached reader thread; the poller replaced those, so a leader or
//!    worker is exactly one thread with zero I/O helpers to leak.)
//!
//! Failures are *values*, not panics: every operation returns a
//! [`TransportError`] that the coordinator maps onto its existing
//! fail-stop paths (a dead peer mid-round still surfaces as an error
//! naming the round, whichever backend carried the traffic).

pub mod codec;
pub mod local;
pub mod poll;
pub mod tcp;
pub mod tiered;

use super::messages::{Ctl, Report, ShardMsg};
use std::fmt;
use std::time::Duration;

/// A transport-level failure.
///
/// The two cases mirror the two ways `std::sync::mpsc` receives fail,
/// which is exactly the granularity the coordinator's fail-stop logic
/// distinguishes: *nothing arrived in time* vs *the other side is gone*.
#[derive(Debug)]
pub enum TransportError {
    /// The other endpoint is gone: a closed channel, a closed socket, or
    /// a connection that died mid-frame.  Carries a human-readable
    /// description of what was lost.
    Closed(String),
    /// No message arrived within the allowed wait.
    Timeout,
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Closed(why) => write!(f, "{why}"),
            TransportError::Timeout => write!(f, "timed out"),
        }
    }
}

/// Which transport backend a cluster run uses (the `--transport` knob,
/// config key `"transport"`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransportKind {
    /// In-process `mpsc` channels; workers are threads of the leader
    /// process (the default, and the only option before this module).
    Local,
    /// Length-prefixed binary frames over `std::net::TcpStream`; workers
    /// are separate OS processes (`bcm-dlb cluster-worker`).
    Tcp,
}

impl TransportKind {
    /// Parse a CLI/config spelling (`"local"` / `"tcp"`).
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "local" | "mpsc" => Some(TransportKind::Local),
            "tcp" | "socket" => Some(TransportKind::Tcp),
            _ => None,
        }
    }

    /// Canonical spelling, round-trips through [`parse`](Self::parse).
    pub fn name(&self) -> &'static str {
        match self {
            TransportKind::Local => "local",
            TransportKind::Tcp => "tcp",
        }
    }
}

/// The leader's endpoint: a control channel to each of `shards()`
/// workers plus one merged report inbox.
///
/// Implementations must preserve per-link FIFO order and deliver
/// reports from all workers into the single [`recv_report`] queue in
/// per-worker send order (cross-worker interleaving is unspecified, as
/// with the shared mpsc report channel).
///
/// [`recv_report`]: LeaderTransport::recv_report
pub trait LeaderTransport: Send {
    /// Number of workers this endpoint fans out to.
    fn shards(&self) -> usize;

    /// Send a control message to worker `shard`.
    fn send_ctl(&mut self, shard: usize, msg: Ctl) -> Result<(), TransportError>;

    /// Receive the next report from any worker, waiting at most `wait`.
    fn recv_report(&mut self, wait: Duration) -> Result<Report, TransportError>;

    /// Wait up to `wait` for a replacement worker to claim the dead
    /// shard `shard` (the rejoin half of the recovery contract,
    /// `DESIGN.md` §8).  On success the replacement is fully
    /// re-handshaken — ready for `Ctl` traffic — and its fresh
    /// peer-mesh listener address is returned so the leader can
    /// `Ctl::Remesh` the survivors.  `Ok(None)` means no replacement
    /// appeared (or the backend does not support rejoin, the default:
    /// local workers are threads and cannot be restarted from outside).
    fn await_rejoin(
        &mut self,
        shard: usize,
        resume_round: usize,
        wait: Duration,
    ) -> Result<Option<String>, TransportError> {
        let _ = (shard, resume_round, wait);
        Ok(None)
    }
}

/// A shard worker's endpoint: the control inbox, the report channel
/// back to the leader, and the peer data plane to every other shard.
pub trait WorkerTransport: Send {
    /// This worker's shard index.
    fn shard(&self) -> usize;

    /// Total number of shards in the cluster.
    fn shards(&self) -> usize;

    /// Block until the next control message from the leader.
    fn recv_ctl(&mut self) -> Result<Ctl, TransportError>;

    /// Send a report to the leader.
    fn send_report(&mut self, msg: Report) -> Result<(), TransportError>;

    /// Send a peer message to worker `peer`.
    fn send_peer(&mut self, peer: usize, msg: ShardMsg) -> Result<(), TransportError>;

    /// Receive the next peer message from any shard, waiting at most
    /// `wait`.
    fn recv_peer(&mut self, wait: Duration) -> Result<ShardMsg, TransportError>;

    /// Replace the peer link to `shard` with a fresh connection to
    /// `addr` (the survivor half of a rejoin, driven by `Ctl::Remesh`).
    /// The default is a no-op `Ok`: local channels never die, so there
    /// is nothing to re-establish.
    fn remesh_peer(&mut self, shard: usize, addr: &str) -> Result<(), TransportError> {
        let _ = (shard, addr);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transport_kind_parse_roundtrip() {
        for kind in [TransportKind::Local, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(TransportKind::parse("mpsc"), Some(TransportKind::Local));
        assert_eq!(TransportKind::parse("socket"), Some(TransportKind::Tcp));
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }

    #[test]
    fn transport_error_displays() {
        let e = TransportError::Closed("peer 3 hung up".into());
        assert_eq!(e.to_string(), "peer 3 hung up");
        assert_eq!(TransportError::Timeout.to_string(), "timed out");
    }
}
