//! The deterministic multi-threaded BCM engine.
//!
//! Edges within a color class are vertex-disjoint (a matching), so the
//! class can be applied concurrently — the execution model the protocol
//! actually prescribes, which the sequential engine merely simulates.
//! `LoadState::split_pairs` hands each edge a mutable view of exactly its
//! two endpoint load lists; the views are partitioned over
//! `std::thread::scope` workers and balanced in parallel.
//!
//! Determinism: edge `e` of round `t` draws all of its randomness from
//! `Pcg64::for_edge(seed, t, e)` — a counter-based stream keyed on values,
//! not on call order.  Together with the disjointness of the per-edge
//! state mutations this makes the result **bit-identical** to
//! [`Sequential`](super::engine::Sequential) for every thread count
//! (asserted by `tests/property_invariants.rs`).

use super::engine::{drive_with, Engine, StopRule};
use super::schedule::Schedule;
use super::trace::RunTrace;
use crate::balancer::{balance_pair, PairAlgorithm};
use crate::load::{Load, LoadState};
use crate::util::rng::Pcg64;

/// The multi-threaded [`Engine`].
pub struct Parallel {
    threads: usize,
}

impl Parallel {
    /// `threads == 0` means auto (one worker per available core).
    pub fn new(threads: usize) -> Self {
        Self { threads }
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Self { threads: 0 }
    }

    /// The resolved worker count.
    pub fn thread_count(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
    }
}

impl Engine for Parallel {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn run(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        stop: StopRule,
        seed: u64,
    ) -> RunTrace {
        let threads = self.thread_count();
        // The same worker pool also fans out the per-round discrepancy
        // reduction — the O(n) term that would otherwise cap speedup.
        drive_with(state, schedule, stop, threads, |state, pairs, round| {
            parallel_round(state, pairs, round, algo, seed, threads)
        })
    }
}

/// Apply one matching with up to `threads` workers; returns the movement
/// count.  Bit-identical to the per-edge sequential application for any
/// `threads >= 1`.
pub fn parallel_round(
    state: &mut LoadState,
    pairs: &[(u32, u32)],
    round: usize,
    algo: PairAlgorithm,
    seed: u64,
    threads: usize,
) -> usize {
    let threads = threads.max(1).min(pairs.len());
    if threads <= 1 {
        // One worker (or <= 1 edge): skip thread setup, same arithmetic.
        let mut movements = 0usize;
        for (e, &(u, v)) in pairs.iter().enumerate() {
            let mut rng = Pcg64::for_edge(seed, round, e);
            movements += super::engine::balance_edge(state, u as usize, v as usize, algo, &mut rng);
        }
        return movements;
    }
    let mut slots = state.split_pairs(pairs);
    let chunk = pairs.len().div_ceil(threads);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(threads);
        for (ci, part) in slots.chunks_mut(chunk).enumerate() {
            let offset = ci * chunk;
            handles.push(scope.spawn(move || {
                let mut movements = 0usize;
                for (i, (u_loads, v_loads)) in part.iter_mut().enumerate() {
                    let mut rng = Pcg64::for_edge(seed, round, offset + i);
                    movements += balance_slot(u_loads, v_loads, algo, &mut rng);
                }
                movements
            }));
        }
        handles
            .into_iter()
            .map(|h| h.join().expect("parallel BCM worker panicked"))
            .sum()
    })
}

/// Rebalance one matched edge through its split views; returns the
/// movement count.  Mirrors `engine::balance_edge` exactly: pinned loads
/// keep their order, the rebalanced mobile loads are appended.
fn balance_slot(
    u_loads: &mut Vec<Load>,
    v_loads: &mut Vec<Load>,
    algo: PairAlgorithm,
    rng: &mut Pcg64,
) -> usize {
    let out = balance_pair(u_loads, v_loads, algo, rng);
    u_loads.retain(|l| !l.mobile);
    v_loads.retain(|l| !l.mobile);
    u_loads.extend(out.to_u);
    v_loads.extend(out.to_v);
    out.movements
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::SortAlgo;
    use crate::graph::Graph;
    use crate::load::{Mobility, WeightDistribution};

    fn setup(n: usize, per_node: usize, mobility: Mobility, seed: u64) -> (LoadState, Schedule) {
        let mut rng = Pcg64::new(seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            n,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        );
        (state, schedule)
    }

    #[test]
    fn matches_sequential_for_every_thread_count() {
        let (state0, schedule) = setup(24, 25, Mobility::Partial, 1);
        let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
        let stop = StopRule::sweeps(5);
        let mut seq = state0.clone();
        let seq_trace = super::super::engine::Sequential.run(&mut seq, &schedule, algo, stop, 7);
        for threads in [1, 2, 3, 4, 7] {
            let mut par = state0.clone();
            let trace = Parallel::new(threads).run(&mut par, &schedule, algo, stop, 7);
            assert_eq!(trace, seq_trace, "trace diverged at {threads} threads");
            assert_eq!(par, seq, "state diverged at {threads} threads");
        }
    }

    #[test]
    fn auto_thread_count_resolves() {
        let p = Parallel::auto();
        assert!(p.thread_count() >= 1);
        assert_eq!(Parallel::new(3).thread_count(), 3);
        assert_eq!(p.name(), "parallel");
    }

    #[test]
    fn converges_and_conserves() {
        let (mut state, schedule) = setup(32, 30, Mobility::Full, 2);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init = state.discrepancy();
        let trace = Parallel::new(4).run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(10),
            3,
        );
        assert!(trace.final_discrepancy() < init / 20.0);
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6);
    }

    #[test]
    fn empty_matching_round_is_a_noop() {
        let (mut state, _) = setup(8, 10, Mobility::Full, 3);
        let before = state.clone();
        let moves = parallel_round(&mut state, &[], 0, PairAlgorithm::Greedy, 1, 4);
        assert_eq!(moves, 0);
        assert_eq!(state, before);
    }

    #[test]
    fn threaded_metrics_reduction_keeps_traces_identical_at_scale() {
        // n large enough that `discrepancy_threaded` takes the chunked
        // path inside the parallel engine while the sequential reference
        // still folds scalar — the traces must stay bit-identical.
        let n = 2 * crate::load::state::REDUCE_CHUNK_MIN;
        let mut rng = Pcg64::new(5);
        let g = Graph::ring(n);
        let schedule = Schedule::from_graph(&g);
        let state0 = LoadState::init_uniform_counts(
            n,
            2,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let algo = PairAlgorithm::Greedy;
        let stop = StopRule::sweeps(1);
        let mut seq = state0.clone();
        let seq_trace = super::super::engine::Sequential.run(&mut seq, &schedule, algo, stop, 11);
        let mut par = state0.clone();
        let par_trace = Parallel::new(4).run(&mut par, &schedule, algo, stop, 11);
        assert_eq!(par_trace, seq_trace);
        assert_eq!(par, seq);
    }

    #[test]
    fn more_threads_than_edges_is_fine() {
        let (state0, schedule) = setup(6, 10, Mobility::Full, 4);
        let algo = PairAlgorithm::Greedy;
        let stop = StopRule::sweeps(2);
        let mut a = state0.clone();
        let ta = Parallel::new(64).run(&mut a, &schedule, algo, stop, 5);
        let mut b = state0.clone();
        let tb = super::super::engine::Sequential.run(&mut b, &schedule, algo, stop, 5);
        assert_eq!(ta, tb);
        assert_eq!(a, b);
    }
}
