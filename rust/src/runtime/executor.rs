//! Batched execution of the per-round rebalance on the PJRT device path.
//!
//! One BCM round = one matching = up to n/2 independent two-bin problems.
//! The executor packs them into the `[B, M]` layout of the AOT
//! `balance_two_bin` (SortedGreedy) / `greedy_two_bin` (Greedy) artifacts,
//! launches once per shape bucket, and unpacks assignments back to load
//! ids.  A pure-Rust fallback with identical semantics serves when no
//! bucket fits (or `artifacts/` was never built).

use super::client::Runtime;
use super::fallback;
use crate::anyhow;
use crate::bail;
use crate::util::error::Result;

/// One two-bin problem: the mobile pool (arrival order) and the pinned
/// base sums.  `hosts[i]` is the original side (0/1) of ball `i`.
#[derive(Clone, Debug, Default)]
pub struct EdgeProblem {
    pub weights: Vec<f64>,
    pub hosts: Vec<u8>,
    pub base: [f64; 2],
}

/// Solution: `assign[i]` is the final side of ball `i` (input order).
#[derive(Clone, Debug)]
pub struct EdgeSolution {
    pub assign: Vec<u8>,
    pub sums: [f64; 2],
    pub movements: usize,
}

/// Which device entry point to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DeviceAlgo {
    /// bitonic sort + greedy placement (SortedGreedy).
    SortedGreedy,
    /// greedy placement in arrival order.
    Greedy,
}

impl DeviceAlgo {
    fn entry(&self) -> &'static str {
        match self {
            DeviceAlgo::SortedGreedy => "balance_two_bin",
            DeviceAlgo::Greedy => "greedy_two_bin",
        }
    }
}

/// How a batch was executed (for metrics / tests).
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ExecPath {
    Device { artifact: String, launches: usize },
    Fallback,
}

/// Solve a whole round's edge problems.
///
/// `runtime = None` forces the pure-Rust path.  With a runtime, problems
/// are solved on-device in as few launches as possible; problems too large
/// for every bucket fall back to Rust individually.
pub fn solve_batch(
    runtime: Option<&mut Runtime>,
    algo: DeviceAlgo,
    problems: &[EdgeProblem],
) -> Result<(Vec<EdgeSolution>, ExecPath)> {
    match runtime {
        None => Ok((
            problems.iter().map(|p| fallback::solve(p, algo)).collect(),
            ExecPath::Fallback,
        )),
        Some(rt) => solve_on_device(rt, algo, problems),
    }
}

fn solve_on_device(
    rt: &mut Runtime,
    algo: DeviceAlgo,
    problems: &[EdgeProblem],
) -> Result<(Vec<EdgeSolution>, ExecPath)> {
    if problems.is_empty() {
        return Ok((Vec::new(), ExecPath::Device { artifact: String::new(), launches: 0 }));
    }
    let max_m = problems.iter().map(|p| p.weights.len()).max().unwrap_or(0);
    let spec = match rt
        .manifest()
        .pick_bucket_for_batch(algo.entry(), problems.len(), max_m.max(1))
    {
        Some(s) => s.clone(),
        None => {
            // no bucket can hold the largest problem: full fallback
            return Ok((
                problems.iter().map(|p| fallback::solve(p, algo)).collect(),
                ExecPath::Fallback,
            ));
        }
    };
    let (bucket_b, bucket_m) = spec
        .batch_shape()
        .ok_or_else(|| anyhow!("artifact {} has no batch shape", spec.name))?;

    let mut solutions: Vec<EdgeSolution> = Vec::with_capacity(problems.len());
    let mut launches = 0usize;
    for chunk in problems.chunks(bucket_b) {
        let mut weights = vec![0.0f32; bucket_b * bucket_m];
        let mut base = vec![0.0f32; bucket_b * 2];
        for (r, p) in chunk.iter().enumerate() {
            if p.weights.len() > bucket_m {
                bail!("problem of {} balls exceeds bucket M={bucket_m}", p.weights.len());
            }
            for (i, &w) in p.weights.iter().enumerate() {
                weights[r * bucket_m + i] = w as f32;
            }
            base[r * 2] = p.base[0] as f32;
            base[r * 2 + 1] = p.base[1] as f32;
        }
        let outs = rt.executable(&spec.name)?.run_f32(&[weights, base])?;
        launches += 1;

        // output order per aot.py: SortedGreedy -> (sorted_w, perm,
        // assign, sums); Greedy -> (assign, sums).
        let (perm, assign, sums): (Option<&[i32]>, &[f32], &[f32]) = match algo {
            DeviceAlgo::SortedGreedy => {
                (Some(outs[1].as_i32()), outs[2].as_f32(), outs[3].as_f32())
            }
            DeviceAlgo::Greedy => (None, outs[0].as_f32(), outs[1].as_f32()),
        };

        for (r, p) in chunk.iter().enumerate() {
            let mlen = p.weights.len();
            let mut a = vec![0u8; mlen];
            match perm {
                Some(perm) => {
                    // assign is in sorted order; perm maps sorted pos ->
                    // original index.  Padding has weight 0 and maps to
                    // indices >= mlen only when mlen < bucket_m... padding
                    // zeros sort AFTER real weights (non-negative), but
                    // real zeros may interleave with padding — both have
                    // weight 0 and either side assignment is valid, so
                    // clamp to indices < mlen.
                    for i in 0..bucket_m {
                        let orig = perm[r * bucket_m + i] as usize;
                        if orig < mlen {
                            a[orig] = assign[r * bucket_m + i] as u8;
                        }
                    }
                }
                None => {
                    for (i, slot) in a.iter_mut().enumerate() {
                        *slot = assign[r * bucket_m + i] as u8;
                    }
                }
            }
            let movements = a
                .iter()
                .zip(&p.hosts)
                .filter(|(a, h)| **a != **h)
                .count();
            // Recompute exact f64 sums from the assignment (device sums
            // are f32 and include padding-tie noise).
            let mut s = p.base;
            for (i, &w) in p.weights.iter().enumerate() {
                s[a[i] as usize] += w;
            }
            let _ = sums;
            solutions.push(EdgeSolution {
                assign: a,
                sums: s,
                movements,
            });
        }
    }
    Ok((
        solutions,
        ExecPath::Device {
            artifact: spec.name,
            launches,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn problem(ws: &[f64], hosts: &[u8], base: [f64; 2]) -> EdgeProblem {
        EdgeProblem {
            weights: ws.to_vec(),
            hosts: hosts.to_vec(),
            base,
        }
    }

    #[test]
    fn fallback_path_solves() {
        let p = problem(&[5.0, 4.0, 3.0, 2.0], &[0, 0, 1, 1], [0.0, 0.0]);
        let (sols, path) = solve_batch(None, DeviceAlgo::SortedGreedy, &[p]).unwrap();
        assert_eq!(path, ExecPath::Fallback);
        assert_eq!(sols.len(), 1);
        let s = &sols[0];
        assert!((s.sums[0] + s.sums[1] - 14.0).abs() < 1e-9);
        assert!((s.sums[0] - s.sums[1]).abs() <= 5.0);
    }

    #[test]
    fn fallback_greedy_vs_sorted_differ() {
        // adversarial order: Greedy splits badly, SortedGreedy well
        let ws = [0.1, 0.1, 0.1, 0.1, 0.1, 0.1, 5.0];
        let p = problem(&ws, &[0; 7], [0.0, 0.0]);
        let (sg, _) = solve_batch(None, DeviceAlgo::SortedGreedy, &[p.clone()]).unwrap();
        let (g, _) = solve_batch(None, DeviceAlgo::Greedy, &[p]).unwrap();
        let d_s = (sg[0].sums[0] - sg[0].sums[1]).abs();
        let d_g = (g[0].sums[0] - g[0].sums[1]).abs();
        // SortedGreedy places the 5.0 first and backfills: 5.0 vs 0.6;
        // Greedy splits the 0.1s first and the 5.0 lands on a half-full
        // bin: 5.3 vs 0.3.  Sorted is strictly better.
        assert!(d_s < d_g);
        // movements counted against hosts
        assert!(sg[0].movements <= 7);
    }

    #[test]
    fn empty_batch() {
        let (sols, _) = solve_batch(None, DeviceAlgo::Greedy, &[]).unwrap();
        assert!(sols.is_empty());
    }
}
