//! Message types of the distributed BCM protocol.
//!
//! The communication structure mirrors the matching model the paper
//! assumes (§1, §2): in each round a node talks to *at most one* neighbor.
//! Per matched edge the lower-id endpoint acts as the edge master: the
//! slave ships its mobile loads over, the master solves the two-bin
//! problem locally and ships the slave's new loads back.  The leader only
//! orchestrates rounds and aggregates metrics — it never touches loads.

use crate::load::Load;

/// Leader -> worker control messages.
#[derive(Debug)]
pub enum Ctl {
    /// Balance with `peer` this round; `master` says which endpoint runs
    /// the placement; `flip` is the leader-drawn orientation bit (the
    /// E[e]=0 symmetry of paper §3 cond. 3).
    Balance { peer: u32, master: bool, flip: bool },
    /// Sit this round out (unmatched).
    Idle,
    /// Report current total weight to the leader.
    Report,
    /// Terminate and return the final load set.
    Shutdown,
}

/// Worker -> worker payloads (peer channel).
#[derive(Debug)]
pub enum Peer {
    /// Slave -> master: my mobile loads and my pinned weight.
    Offer { loads: Vec<Load>, pinned: f64 },
    /// Master -> slave: your new mobile loads.
    Settle { loads: Vec<Load> },
}

/// Worker -> leader reports.
#[derive(Debug)]
pub enum Report {
    /// Edge done (sent by the master only).
    EdgeDone {
        edge: (u32, u32),
        movements: usize,
        local_discrepancy: f64,
    },
    /// Round acknowledged (sent by every worker every round).
    RoundAck { node: u32 },
    /// Current node weight (in response to `Ctl::Report`).
    Weight { node: u32, weight: f64 },
    /// Final load set (in response to `Ctl::Shutdown`).
    Final { node: u32, loads: Vec<Load> },
}
