//! Ball/load weight distributions.
//!
//! The paper's §6 experiments sample weights uniformly from [0, 100] and
//! Appendix C from [0, 1]; §4 explicitly does "not restrict the
//! distribution from which the balls sample their weights", so the
//! framework ships the standard families used in weighted balls-into-bins
//! analyses (finite second moment is what Talwar & Wieder's discrepancy
//! result needs — Pareto with alpha <= 2 deliberately violates it for
//! stress tests).

use crate::util::rng::Pcg64;

#[derive(Clone, Debug, PartialEq)]
pub enum WeightDistribution {
    /// U[lo, hi)
    Uniform { lo: f64, hi: f64 },
    /// Exp(mean), unbounded
    Exponential { mean: f64 },
    /// N(mean, std) truncated at zero (weights must be non-negative)
    Normal { mean: f64, std: f64 },
    /// Pareto(scale, alpha); heavy tail, infinite variance for alpha <= 2
    Pareto { scale: f64, alpha: f64 },
    /// Mixture: w.p. `p_hi` sample U[hi_lo, hi_hi), else U[lo_lo, lo_hi)
    Bimodal {
        p_hi: f64,
        lo_lo: f64,
        lo_hi: f64,
        hi_lo: f64,
        hi_hi: f64,
    },
    /// All weights equal (the Lemma-5 worst case)
    Constant { w: f64 },
}

impl WeightDistribution {
    /// The paper's §6 setting: U[0, 100).
    pub fn paper_section6() -> Self {
        WeightDistribution::Uniform { lo: 0.0, hi: 100.0 }
    }

    /// The paper's Appendix-C setting: U[0, 1).
    pub fn paper_appendix_c() -> Self {
        WeightDistribution::Uniform { lo: 0.0, hi: 1.0 }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        match *self {
            WeightDistribution::Uniform { lo, hi } => rng.uniform(lo, hi),
            WeightDistribution::Exponential { mean } => rng.exponential(mean),
            WeightDistribution::Normal { mean, std } => rng.normal(mean, std).max(0.0),
            WeightDistribution::Pareto { scale, alpha } => rng.pareto(scale, alpha),
            WeightDistribution::Bimodal {
                p_hi,
                lo_lo,
                lo_hi,
                hi_lo,
                hi_hi,
            } => {
                if rng.next_f64() < p_hi {
                    rng.uniform(hi_lo, hi_hi)
                } else {
                    rng.uniform(lo_lo, lo_hi)
                }
            }
            WeightDistribution::Constant { w } => w,
        }
    }

    pub fn sample_n(&self, n: usize, rng: &mut Pcg64) -> Vec<f64> {
        (0..n).map(|_| self.sample(rng)).collect()
    }

    /// Population mean (used by theory checks; None if undefined).
    pub fn mean(&self) -> Option<f64> {
        match *self {
            WeightDistribution::Uniform { lo, hi } => Some((lo + hi) / 2.0),
            WeightDistribution::Exponential { mean } => Some(mean),
            WeightDistribution::Normal { mean, .. } => Some(mean), // approx (truncation)
            WeightDistribution::Pareto { scale, alpha } => {
                (alpha > 1.0).then(|| alpha * scale / (alpha - 1.0))
            }
            WeightDistribution::Bimodal {
                p_hi,
                lo_lo,
                lo_hi,
                hi_lo,
                hi_hi,
            } => Some(p_hi * (hi_lo + hi_hi) / 2.0 + (1.0 - p_hi) * (lo_lo + lo_hi) / 2.0),
            WeightDistribution::Constant { w } => Some(w),
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        let parts: Vec<&str> = s.split(':').collect();
        match parts.as_slice() {
            ["uniform", lo, hi] => Some(WeightDistribution::Uniform {
                lo: lo.parse().ok()?,
                hi: hi.parse().ok()?,
            }),
            ["uniform"] => Some(WeightDistribution::paper_section6()),
            ["exp", mean] => Some(WeightDistribution::Exponential {
                mean: mean.parse().ok()?,
            }),
            ["normal", mean, std] => Some(WeightDistribution::Normal {
                mean: mean.parse().ok()?,
                std: std.parse().ok()?,
            }),
            ["pareto", scale, alpha] => Some(WeightDistribution::Pareto {
                scale: scale.parse().ok()?,
                alpha: alpha.parse().ok()?,
            }),
            ["constant", w] => Some(WeightDistribution::Constant {
                w: w.parse().ok()?,
            }),
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            WeightDistribution::Uniform { lo, hi } => format!("uniform:{lo}:{hi}"),
            WeightDistribution::Exponential { mean } => format!("exp:{mean}"),
            WeightDistribution::Normal { mean, std } => format!("normal:{mean}:{std}"),
            WeightDistribution::Pareto { scale, alpha } => format!("pareto:{scale}:{alpha}"),
            WeightDistribution::Bimodal { .. } => "bimodal".into(),
            WeightDistribution::Constant { w } => format!("constant:{w}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_mean(d: &WeightDistribution, n: usize, seed: u64) -> f64 {
        let mut rng = Pcg64::new(seed);
        d.sample_n(n, &mut rng).iter().sum::<f64>() / n as f64
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let d = WeightDistribution::paper_section6();
        let mut rng = Pcg64::new(1);
        for _ in 0..10_000 {
            let w = d.sample(&mut rng);
            assert!((0.0..100.0).contains(&w));
        }
        assert!((sample_mean(&d, 100_000, 2) - 50.0).abs() < 0.5);
    }

    #[test]
    fn exponential_mean() {
        let d = WeightDistribution::Exponential { mean: 4.0 };
        assert!((sample_mean(&d, 100_000, 3) - 4.0).abs() < 0.1);
    }

    #[test]
    fn normal_truncated_nonnegative() {
        let d = WeightDistribution::Normal { mean: 1.0, std: 2.0 };
        let mut rng = Pcg64::new(4);
        assert!((0..10_000).all(|_| d.sample(&mut rng) >= 0.0));
    }

    #[test]
    fn pareto_mean_finite_alpha() {
        let d = WeightDistribution::Pareto { scale: 1.0, alpha: 3.0 };
        let want = d.mean().unwrap(); // 1.5
        assert!((sample_mean(&d, 200_000, 5) - want).abs() < 0.05);
        assert_eq!(
            WeightDistribution::Pareto { scale: 1.0, alpha: 0.9 }.mean(),
            None
        );
    }

    #[test]
    fn constant_is_constant() {
        let d = WeightDistribution::Constant { w: 2.5 };
        let mut rng = Pcg64::new(6);
        assert!((0..100).all(|_| d.sample(&mut rng) == 2.5));
    }

    #[test]
    fn bimodal_hits_both_modes() {
        let d = WeightDistribution::Bimodal {
            p_hi: 0.5,
            lo_lo: 0.0,
            lo_hi: 1.0,
            hi_lo: 10.0,
            hi_hi: 11.0,
        };
        let mut rng = Pcg64::new(7);
        let xs = d.sample_n(1000, &mut rng);
        assert!(xs.iter().any(|&x| x < 1.0));
        assert!(xs.iter().any(|&x| x > 10.0));
        assert!(xs.iter().all(|&x| x < 1.0 || x >= 10.0));
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["uniform:0:100", "exp:2", "normal:5:1", "pareto:1:3", "constant:7"] {
            let d = WeightDistribution::parse(s).unwrap();
            assert_eq!(WeightDistribution::parse(&d.name()).unwrap(), d);
        }
        assert_eq!(WeightDistribution::parse("bogus"), None);
    }
}
