//! Summary statistics used by the experiment harness.

/// Streaming mean/variance (Welford) plus min/max.
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    pub fn new() -> Self {
        Self {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Sample standard deviation (n-1 denominator).
    pub fn std(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            (self.m2 / (self.n - 1) as f64).sqrt()
        }
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }
}

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation of a slice.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// `q`-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (q / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Geometric mean (values must be positive; zeros are clamped to `eps`).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let eps = 1e-300;
    (xs.iter().map(|x| x.max(eps).ln()).sum::<f64>() / xs.len() as f64).exp()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.std() - std(&xs)).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
        assert_eq!(w.count(), 5);
    }

    #[test]
    fn empty_behaviour() {
        assert!(mean(&[]).is_nan());
        assert_eq!(std(&[1.0]), 0.0);
        assert!(Welford::new().mean().is_nan());
    }

    #[test]
    fn percentiles() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn geomean_basics() {
        assert!((geomean(&[1.0, 100.0]) - 10.0).abs() < 1e-9);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }
}
