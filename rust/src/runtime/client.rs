//! PJRT client wrapper: load HLO-text artifacts, compile once, execute.
//!
//! Follows the /opt/xla-example/load_hlo pattern: text (not serialized
//! proto) is the interchange format, outputs come back as a tuple
//! (`return_tuple=True` at lowering time).
//!
//! The real client requires the `xla` crate, which is not vendored in the
//! offline image; it is gated behind the `pjrt` cargo feature.  With the
//! feature off (the default) an API-compatible stub is compiled instead:
//! `Runtime::new` fails with an actionable message, so explicit device
//! requests (`--device`, `artifacts`) error out cleanly, while every path
//! that runs with `runtime = None` uses the pure-Rust fallback
//! (`runtime::fallback`), which carries identical semantics.

pub use imp::{Executable, OutputBuffer, Runtime};

#[cfg(feature = "pjrt")]
mod imp {
    use crate::bail;
    use crate::runtime::manifest::{ArtifactSpec, Manifest};
    use crate::util::error::{Context, Result};
    use std::collections::HashMap;

    /// A compiled artifact ready to execute.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub spec: ArtifactSpec,
    }

    impl Executable {
        /// Execute with f32 row-major buffers (one per manifest input).
        /// Returns one `Vec<f32>`-convertible literal per manifest output.
        pub fn run_f32(&self, inputs: &[Vec<f32>]) -> Result<Vec<OutputBuffer>> {
            if inputs.len() != self.spec.inputs.len() {
                bail!(
                    "{}: expected {} inputs, got {}",
                    self.spec.name,
                    self.spec.inputs.len(),
                    inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (buf, ts) in inputs.iter().zip(&self.spec.inputs) {
                let numel: usize = ts.shape.iter().product();
                if buf.len() != numel {
                    bail!(
                        "{}: input '{}' expects {numel} elements, got {}",
                        self.spec.name,
                        ts.name,
                        buf.len()
                    );
                }
                let dims: Vec<i64> = ts.shape.iter().map(|&d| d as i64).collect();
                literals.push(xla::Literal::vec1(buf).reshape(&dims)?);
            }
            let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
                .to_literal_sync()?;
            let parts = result.to_tuple()?;
            if parts.len() != self.spec.outputs.len() {
                bail!(
                    "{}: expected {} outputs, got {}",
                    self.spec.name,
                    self.spec.outputs.len(),
                    parts.len()
                );
            }
            parts
                .into_iter()
                .zip(&self.spec.outputs)
                .map(|(lit, ts)| OutputBuffer::from_literal(lit, ts.dtype.clone()))
                .collect()
        }
    }

    /// A decoded output tensor (f32 or i32).
    pub enum OutputBuffer {
        F32(Vec<f32>),
        I32(Vec<i32>),
    }

    impl OutputBuffer {
        fn from_literal(lit: xla::Literal, dtype: String) -> Result<Self> {
            match dtype.as_str() {
                "f32" => Ok(OutputBuffer::F32(lit.to_vec::<f32>()?)),
                "i32" => Ok(OutputBuffer::I32(lit.to_vec::<i32>()?)),
                other => bail!("unsupported output dtype {other}"),
            }
        }

        pub fn as_f32(&self) -> &[f32] {
            match self {
                OutputBuffer::F32(v) => v,
                _ => panic!("expected f32 output"),
            }
        }

        pub fn as_i32(&self) -> &[i32] {
            match self {
                OutputBuffer::I32(v) => v,
                _ => panic!("expected i32 output"),
            }
        }
    }

    /// Owns the PJRT CPU client and the compiled-executable cache.
    pub struct Runtime {
        client: xla::PjRtClient,
        manifest: Manifest,
        cache: HashMap<String, Executable>,
    }

    impl Runtime {
        /// Create a CPU PJRT client and load the manifest from `dir`.
        pub fn new(dir: &std::path::Path) -> Result<Self> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Self {
                client,
                manifest,
                cache: HashMap::new(),
            })
        }

        pub fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Compile (or fetch from cache) the named artifact.
        pub fn executable(&mut self, name: &str) -> Result<&Executable> {
            if !self.cache.contains_key(name) {
                let spec = self
                    .manifest
                    .by_name(name)
                    .with_context(|| format!("artifact '{name}' not in manifest"))?
                    .clone();
                let path = self.manifest.path_of(&spec);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().context("non-utf8 path")?,
                )
                .with_context(|| format!("parsing {}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = self
                    .client
                    .compile(&comp)
                    .with_context(|| format!("compiling {name}"))?;
                self.cache.insert(name.to_string(), Executable { exe, spec });
            }
            Ok(&self.cache[name])
        }

        /// Compile every artifact of an entry point (warm-up).
        pub fn warm_entry(&mut self, entry: &str) -> Result<usize> {
            let names: Vec<String> = self
                .manifest
                .entries(entry)
                .iter()
                .map(|a| a.name.clone())
                .collect();
            for n in &names {
                self.executable(n)?;
            }
            Ok(names.len())
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use crate::bail;
    use crate::runtime::manifest::{ArtifactSpec, Manifest};
    use crate::util::error::Result;

    /// Stub of the compiled-artifact handle (the `pjrt` feature is off).
    pub struct Executable {
        pub spec: ArtifactSpec,
    }

    impl Executable {
        pub fn run_f32(&self, _inputs: &[Vec<f32>]) -> Result<Vec<OutputBuffer>> {
            bail!(
                "{}: PJRT execution requires the `pjrt` feature",
                self.spec.name
            )
        }
    }

    /// A decoded output tensor (f32 or i32).
    pub enum OutputBuffer {
        F32(Vec<f32>),
        I32(Vec<i32>),
    }

    impl OutputBuffer {
        pub fn as_f32(&self) -> &[f32] {
            match self {
                OutputBuffer::F32(v) => v,
                _ => panic!("expected f32 output"),
            }
        }

        pub fn as_i32(&self) -> &[i32] {
            match self {
                OutputBuffer::I32(v) => v,
                _ => panic!("expected i32 output"),
            }
        }
    }

    /// Stub runtime: `new` always fails, so no instance ever exists and
    /// the instance methods below are unreachable — explicit device
    /// requests fail fast, and the `runtime = None` paths carry on with
    /// the pure-Rust fallback.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn new(dir: &std::path::Path) -> Result<Self> {
            // Validate the manifest anyway so configuration errors surface
            // with the same message whether or not the feature is on.
            let _ = Manifest::load(dir)?;
            bail!(
                "PJRT runtime unavailable: built without the `pjrt` cargo feature \
                 (artifacts in {} cannot be executed on-device; the pure-Rust \
                 fallback engine carries identical semantics)",
                dir.display()
            )
        }

        pub fn manifest(&self) -> &Manifest {
            unreachable!("stub Runtime cannot be constructed (pjrt feature off)")
        }

        pub fn platform(&self) -> String {
            unreachable!("stub Runtime cannot be constructed (pjrt feature off)")
        }

        pub fn executable(&mut self, _name: &str) -> Result<&Executable> {
            unreachable!("stub Runtime cannot be constructed (pjrt feature off)")
        }

        pub fn warm_entry(&mut self, _entry: &str) -> Result<usize> {
            unreachable!("stub Runtime cannot be constructed (pjrt feature off)")
        }
    }
}
