"""Pure-numpy/jnp oracles for every Layer-1 kernel.

These are the build-time correctness ground truth: python/tests/ asserts
allclose between each Pallas kernel and its oracle over hypothesis-driven
shape/value sweeps.  They intentionally mirror the *paper's* scalar
formulation (sequential loops), not the kernels' vectorized one.
"""

from __future__ import annotations

import numpy as np


def ref_two_bin(weights: np.ndarray, base: np.ndarray):
    """Sequential greedy two-bin placement (paper Alg. 4.2 with n=2).

    weights[B, M] assumed sorted descending; base[B, 2] initial sums.
    Returns (assign[B, M] f32, sums[B, 2] f32); tie -> bin 0.
    """
    weights = np.asarray(weights, np.float32)
    b, m = weights.shape
    assign = np.zeros((b, m), np.float32)
    sums = np.array(base, np.float32).copy()
    for r in range(b):
        for i in range(m):
            k = 1 if sums[r, 1] < sums[r, 0] else 0
            assign[r, i] = float(k)
            sums[r, k] += weights[r, i]
    return assign, sums


def ref_nbin(weights: np.ndarray, base: np.ndarray):
    """Sequential greedy n-bin placement (paper Alg. 4.2); tie -> lowest idx."""
    weights = np.asarray(weights, np.float32)
    b, m = weights.shape
    sums = np.array(base, np.float32).copy()
    assign = np.zeros((b, m), np.int32)
    for r in range(b):
        for i in range(m):
            k = int(np.argmin(sums[r]))
            assign[r, i] = k
            sums[r, k] += weights[r, i]
    return assign, sums


def ref_sort_desc(weights: np.ndarray):
    """Descending sort + a valid permutation (stable on ties)."""
    weights = np.asarray(weights, np.float32)
    # np.argsort is stable with kind="stable"; negate for descending.
    perm = np.argsort(-weights, axis=1, kind="stable").astype(np.int32)
    sorted_w = np.take_along_axis(weights, perm, axis=1)
    return sorted_w, perm


def ref_diffusion(x: np.ndarray, m: np.ndarray):
    """Continuous-case round: x @ m in float32."""
    return np.asarray(x, np.float32) @ np.asarray(m, np.float32)


def discrepancy(sums: np.ndarray):
    """Per-row discrepancy max_k U_k - min_k U_k (paper Eq. 12)."""
    s = np.asarray(sums)
    return s.max(axis=-1) - s.min(axis=-1)
