//! Dependency-free support code: errors, RNG, JSON, statistics, tables.

pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
