//! Load model: indivisible real-valued loads, weight distributions,
//! network load state, mobility (paper §2, §6.1).

pub mod distribution;
pub mod item;
pub mod state;

pub use distribution::WeightDistribution;
pub use item::Load;
pub use state::{EdgeGather, EdgeViews, LoadState, Mobility, NodeIter, NodeView};
