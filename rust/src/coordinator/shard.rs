//! Node sharding for the cluster coordinator: contiguous shard maps and
//! per-round edge classification.
//!
//! The sharded runtime spawns one worker per core, each owning a
//! contiguous slice of the node range.  A round's matching is classified
//! once into a [`RoundPlan`]: edges with both endpoints in one shard are
//! solved locally with no messaging at all, and only the edges crossing a
//! shard boundary exchange messages — so per-round traffic is
//! O(cut edges + shards) instead of the O(n) of the historical
//! one-thread-per-processor cluster.

use std::ops::Range;

/// A partition of `n` nodes into `k` contiguous shards of near-equal
/// size (the first `n mod k` shards get one extra node).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ShardMap {
    /// `k + 1` ascending boundaries; shard `s` owns `starts[s]..starts[s+1]`.
    starts: Vec<usize>,
}

impl ShardMap {
    /// Partition `n` nodes into `shards` contiguous shards.  `shards == 0`
    /// means one shard per available core; the count is clamped to
    /// `[1, n]` so every shard owns at least one node.
    pub fn new(n: usize, shards: usize) -> ShardMap {
        assert!(n > 0, "ShardMap: empty network");
        let k = resolve_shards(shards).min(n);
        let base = n / k;
        let extra = n % k;
        let mut starts = Vec::with_capacity(k + 1);
        starts.push(0);
        let mut at = 0;
        for s in 0..k {
            at += base + usize::from(s < extra);
            starts.push(at);
        }
        ShardMap { starts }
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.starts.len() - 1
    }

    /// Number of nodes partitioned.
    pub fn n(&self) -> usize {
        *self.starts.last().unwrap()
    }

    /// The shard owning `node`.
    pub fn shard_of(&self, node: usize) -> usize {
        debug_assert!(node < self.n(), "node {node} out of range");
        self.starts.partition_point(|&b| b <= node) - 1
    }

    /// The node range owned by shard `s`.
    pub fn range(&self, s: usize) -> Range<usize> {
        self.starts[s]..self.starts[s + 1]
    }

    /// Repartition after shard `lost` leaves the cluster: its contiguous
    /// node range is merged into the nearest surviving neighbor (left
    /// first, right if no survivor sits left of it) and `lost`'s own
    /// range becomes empty.  `dead[s]` marks shards that cannot inherit
    /// — `lost` itself plus any shard already lost in an earlier
    /// reassignment (their ranges are empty, so contiguity survives
    /// repeated deaths).  Shard indices are stable: survivors keep
    /// their identity and the coordinator simply stops routing to
    /// shards with empty ranges (an empty range puts no edges in any
    /// [`RoundPlan`]), which is what lets recovery rebuild plans
    /// without renumbering workers.
    ///
    /// Panics if no live shard remains to inherit the range.
    pub fn reassign(&self, lost: usize, dead: &[bool]) -> ShardMap {
        let k = self.shards();
        assert!(lost < k, "reassign: no shard {lost}");
        assert_eq!(dead.len(), k, "reassign: liveness vector length");
        let mut starts = self.starts.clone();
        if let Some(heir) = (0..lost).rev().find(|&s| !dead[s]) {
            // the nearest live left neighbor absorbs: every boundary
            // between it and lost's end slides right (the shards in
            // between are already empty from earlier reassignments)
            for b in &mut starts[heir + 1..=lost] {
                *b = self.starts[lost + 1];
            }
        } else {
            let heir = (lost + 1..k)
                .find(|&s| !dead[s])
                .expect("reassign: no surviving shard to inherit");
            // the nearest live right neighbor absorbs: every boundary
            // between lost and it slides left
            for b in &mut starts[lost + 1..=heir] {
                *b = self.starts[lost];
            }
        }
        ShardMap { starts }
    }
}

/// The two-tier shape of a hierarchical cluster: `hosts` processes, each
/// running `shards_per_host` in-process shard workers.  Global shard
/// indices are host-major — shard `s` lives on host `s / shards_per_host`
/// — so a contiguous [`ShardMap`] automatically gives every host a
/// contiguous super-range of nodes, and the inter-host cut is exactly
/// the set of edges crossing a host-block boundary.
///
/// The layout is pure bookkeeping: it never changes which shard owns a
/// node, only which *transport tier* a cross-shard edge's messages ride
/// (shared-memory channels inside a host, TCP frames between hosts), so
/// the determinism contract is untouched by construction.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TierLayout {
    /// Number of worker processes (hosts or NUMA nodes).
    pub hosts: usize,
    /// In-process shard workers per host.
    pub shards_per_host: usize,
}

impl TierLayout {
    /// A layout of `hosts` x `shards_per_host` shards.  Both counts must
    /// be at least 1.
    pub fn new(hosts: usize, shards_per_host: usize) -> TierLayout {
        assert!(hosts >= 1, "TierLayout: need at least one host");
        assert!(
            shards_per_host >= 1,
            "TierLayout: need at least one shard per host"
        );
        TierLayout {
            hosts,
            shards_per_host,
        }
    }

    /// Total shard count (`hosts * shards_per_host`).
    pub fn shards(&self) -> usize {
        self.hosts * self.shards_per_host
    }

    /// The host running global shard `s`.
    pub fn host_of(&self, shard: usize) -> usize {
        debug_assert!(shard < self.shards(), "shard {shard} out of layout");
        shard / self.shards_per_host
    }

    /// The global shard indices hosted by `host`.
    pub fn host_range(&self, host: usize) -> Range<usize> {
        debug_assert!(host < self.hosts, "host {host} out of layout");
        host * self.shards_per_host..(host + 1) * self.shards_per_host
    }

    /// Whether an edge between shards `a` and `b` crosses the slow tier.
    pub fn is_inter_host(&self, a: usize, b: usize) -> bool {
        self.host_of(a) != self.host_of(b)
    }
}

impl ShardMap {
    /// Topology-aware two-tier partition: place `layout.shards()`
    /// contiguous shards so that the cut crossing the *host* boundaries
    /// — the slow tier, where every edge costs a TCP frame — is
    /// minimized, while intra-host shard boundaries stay at their even
    /// split (intra-host edges ride shared memory and are nearly free).
    ///
    /// Each of the `hosts - 1` host-block boundaries starts at its even
    /// split position and slides within a +/- window to the position
    /// crossed by the fewest edges of `edges` (the graph's full edge
    /// set; for a contiguous partition an edge `(u, v)` crosses
    /// boundary `b` iff `min < b <= max`, counted for all `b` in one
    /// O(n + |edges|) prefix-sum pass).  Boundaries are chosen left to
    /// right and clamped so every host keeps at least
    /// `shards_per_host` nodes — every shard stays nonempty.  Within a
    /// host block, shards split evenly exactly like [`ShardMap::new`].
    ///
    /// The result is just another contiguous `ShardMap`, so every
    /// bit-identity guarantee of the flat cluster carries over
    /// unchanged; only the message *routing* improves.
    ///
    /// Panics if `n < layout.shards()` (a tiered partition needs at
    /// least one node per shard).
    pub fn partition_tiered(n: usize, layout: &TierLayout, edges: &[(u32, u32)]) -> ShardMap {
        let (hosts, spp) = (layout.hosts, layout.shards_per_host);
        assert!(
            n >= hosts * spp,
            "partition_tiered: {n} nodes cannot fill {hosts} x {spp} shards"
        );
        // crossings[b] = edges cut by a boundary at node index b
        let mut diff = vec![0i64; n + 1];
        for &(u, v) in edges {
            let (lo, hi) = if u <= v { (u, v) } else { (v, u) };
            diff[lo as usize + 1] += 1;
            if (hi as usize) < n {
                diff[hi as usize + 1] -= 1;
            }
        }
        let mut crossings = vec![0i64; n + 1];
        let mut acc = 0i64;
        for b in 1..=n {
            acc += diff[b];
            crossings[b] = acc;
        }
        // host boundaries: even split +/- a quarter-block window
        let window = (n / hosts / 4).max(1);
        let mut host_bounds = Vec::with_capacity(hosts + 1);
        host_bounds.push(0usize);
        for h in 1..hosts {
            let target = h * n / hosts;
            let lo_lim = host_bounds[h - 1] + spp;
            let hi_lim = n - spp * (hosts - h);
            let lo = target.saturating_sub(window).max(lo_lim);
            let hi = (target + window).min(hi_lim);
            let best = (lo..=hi)
                .min_by_key(|&b| (crossings[b], b.abs_diff(target)))
                .unwrap_or(target.clamp(lo_lim, hi_lim));
            host_bounds.push(best);
        }
        host_bounds.push(n);
        // within each host block, the even split of ShardMap::new
        let mut starts = Vec::with_capacity(hosts * spp + 1);
        starts.push(0usize);
        for h in 0..hosts {
            let (blk_lo, blk_hi) = (host_bounds[h], host_bounds[h + 1]);
            let len = blk_hi - blk_lo;
            let base = len / spp;
            let extra = len % spp;
            let mut at = blk_lo;
            for s in 0..spp {
                at += base + usize::from(s < extra);
                starts.push(at);
            }
        }
        ShardMap { starts }
    }
}

impl RoundPlan {
    /// Classify this plan's cross-shard edges by tier:
    /// `(intra_host, inter_host)` counts under `layout`.  Intra-host
    /// cross edges exchange their `Offer`/`Settle` over shared-memory
    /// channels and never touch the codec; only the inter-host count
    /// pays wire bytes.  A method rather than a stored field because
    /// `RoundPlan` crosses the wire (the tier split is leader-side
    /// bookkeeping, not protocol state).
    pub fn cut_by_tier(&self, layout: &TierLayout) -> (usize, usize) {
        let mut intra = 0usize;
        let mut inter = 0usize;
        for (s, plan) in self.per_shard.iter().enumerate() {
            for &(_, _, _, sv) in &plan.master {
                if layout.is_inter_host(s, sv) {
                    inter += 1;
                } else {
                    intra += 1;
                }
            }
        }
        (intra, inter)
    }
}

/// Resolve a shard-count knob: `0` = one shard per available core.
pub fn resolve_shards(shards: usize) -> usize {
    if shards == 0 {
        std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1)
    } else {
        shards
    }
}

/// One shard's slice of a round's matching.
///
/// Every entry carries the edge's index within the matching — the key of
/// its counter-based RNG stream (`Pcg64::for_edge`), which is what makes
/// the sharded execution bit-identical to the in-process engines no
/// matter how edges are distributed over shards.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ShardPlan {
    /// `(edge index, u, v)` — both endpoints owned by this shard; solved
    /// locally with zero messages.
    pub local: Vec<(usize, u32, u32)>,
    /// `(edge index, u, v, slave shard)` — this shard owns `u` and runs
    /// the placement for the cross-shard edge.
    pub master: Vec<(usize, u32, u32, usize)>,
    /// `(edge index, v, master shard)` — this shard owns `v`; it offers
    /// `v`'s mobile loads and receives the settled share back.
    pub slave: Vec<(usize, u32, usize)>,
}

/// One matching classified against a [`ShardMap`].  For a cross-shard
/// edge `(u, v)` the owner of `u` is the edge master, so the pooled load
/// order (u's loads then v's) matches the sequential engine exactly.
#[derive(Clone, Debug, PartialEq)]
pub struct RoundPlan {
    /// Each shard's slice of the matching, indexed by shard.
    pub per_shard: Vec<ShardPlan>,
    /// Edges whose endpoints live in different shards.
    pub cross_edges: usize,
    /// Total edges in the matching.
    pub edges: usize,
}

impl RoundPlan {
    /// Classify the matching `pairs` against `map`: every edge lands in
    /// exactly one shard's `local` or `master` list (plus the matching
    /// `slave` entry on the other endpoint's shard for cross edges).
    pub fn build(pairs: &[(u32, u32)], map: &ShardMap) -> RoundPlan {
        let mut per_shard = vec![ShardPlan::default(); map.shards()];
        let mut cross_edges = 0usize;
        for (e, &(u, v)) in pairs.iter().enumerate() {
            let su = map.shard_of(u as usize);
            let sv = map.shard_of(v as usize);
            if su == sv {
                per_shard[su].local.push((e, u, v));
            } else {
                cross_edges += 1;
                per_shard[su].master.push((e, u, v, sv));
                per_shard[sv].slave.push((e, v, su));
            }
        }
        RoundPlan {
            per_shard,
            cross_edges,
            edges: pairs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bcm::Schedule;
    use crate::graph::Graph;

    #[test]
    fn balanced_contiguous_partition() {
        let m = ShardMap::new(10, 3); // sizes 4, 3, 3
        assert_eq!(m.shards(), 3);
        assert_eq!(m.n(), 10);
        assert_eq!(m.range(0), 0..4);
        assert_eq!(m.range(1), 4..7);
        assert_eq!(m.range(2), 7..10);
        for v in 0..10 {
            let s = m.shard_of(v);
            assert!(m.range(s).contains(&v), "node {v} not in its shard {s}");
        }
    }

    #[test]
    fn shard_count_clamped_and_resolved() {
        assert_eq!(ShardMap::new(3, 64).shards(), 3); // never more shards than nodes
        let single = ShardMap::new(5, 1);
        assert_eq!(single.shards(), 1);
        assert_eq!(single.range(0), 0..5);
        let auto = ShardMap::new(1024, 0);
        assert!(auto.shards() >= 1);
        assert_eq!(auto.n(), 1024);
        assert!(resolve_shards(0) >= 1);
        assert_eq!(resolve_shards(7), 7);
    }

    #[test]
    fn ring_plan_cut_is_shard_count() {
        // Contiguous shards on a ring: the cut is exactly the k boundary
        // edges (k-1 interior boundaries + the wrap edge), each appearing
        // once per sweep.
        let g = Graph::ring(16);
        let schedule = Schedule::from_graph(&g);
        let map = ShardMap::new(16, 4);
        let (mut cross, mut total) = (0usize, 0usize);
        for c in 0..schedule.period() {
            let plan = RoundPlan::build(schedule.matching(c), &map);
            cross += plan.cross_edges;
            total += plan.edges;
            // every edge is listed exactly once as local or master
            let listed: usize = plan
                .per_shard
                .iter()
                .map(|p| p.local.len() + p.master.len())
                .sum();
            assert_eq!(listed, plan.edges);
            // and every cross edge has exactly one slave entry
            let slaves: usize = plan.per_shard.iter().map(|p| p.slave.len()).sum();
            assert_eq!(slaves, plan.cross_edges);
        }
        assert_eq!(total, 16);
        assert_eq!(cross, 4);
    }

    #[test]
    fn reassign_merges_into_nearest_live_neighbor() {
        let m = ShardMap::new(10, 3); // 0..4, 4..7, 7..10
        // middle shard dies: left neighbor inherits
        let r = m.reassign(1, &[false, true, false]);
        assert_eq!(r.range(0), 0..7);
        assert!(r.range(1).is_empty());
        assert_eq!(r.range(2), 7..10);
        assert_eq!(r.n(), 10);
        // shard 0 dies: right neighbor inherits
        let r = m.reassign(0, &[true, false, false]);
        assert!(r.range(0).is_empty());
        assert_eq!(r.range(1), 0..7);
        assert_eq!(r.range(2), 7..10);
        // every node still maps to a non-empty owning shard
        for v in 0..10 {
            let s = r.shard_of(v);
            assert!(r.range(s).contains(&v), "node {v} mapped to shard {s}");
            assert_ne!(s, 0, "node {v} mapped to the dead shard");
        }
    }

    #[test]
    fn reassign_survives_sequential_deaths() {
        let m = ShardMap::new(12, 4); // 0..3, 3..6, 6..9, 9..12
        let mut dead = vec![false; 4];
        dead[1] = true;
        let r1 = m.reassign(1, &dead); // shard 0 takes 3..6
        assert_eq!(r1.range(0), 0..6);
        dead[0] = true;
        let r2 = r1.reassign(0, &dead); // shard 2 is nearest live heir
        assert!(r2.range(0).is_empty());
        assert!(r2.range(1).is_empty());
        assert_eq!(r2.range(2), 0..9);
        assert_eq!(r2.range(3), 9..12);
        for v in 0..12 {
            let s = r2.shard_of(v);
            assert!(!dead[s], "node {v} mapped to dead shard {s}");
            assert!(r2.range(s).contains(&v));
        }
        // plans built against the reassigned map route nothing to the
        // dead shards
        let plan = RoundPlan::build(&[(0, 4), (8, 10), (2, 3)], &r2);
        assert!(plan.per_shard[0].local.is_empty() && plan.per_shard[0].master.is_empty());
        assert!(plan.per_shard[1].local.is_empty() && plan.per_shard[1].master.is_empty());
        assert!(plan.per_shard[0].slave.is_empty() && plan.per_shard[1].slave.is_empty());
        assert_eq!(plan.edges, 3);
    }

    #[test]
    fn tier_layout_maps_shards_host_major() {
        let l = TierLayout::new(3, 2);
        assert_eq!(l.shards(), 6);
        assert_eq!(l.host_of(0), 0);
        assert_eq!(l.host_of(1), 0);
        assert_eq!(l.host_of(2), 1);
        assert_eq!(l.host_of(5), 2);
        assert_eq!(l.host_range(1), 2..4);
        assert!(l.is_inter_host(1, 2));
        assert!(!l.is_inter_host(2, 3));
    }

    #[test]
    fn tiered_partition_is_contiguous_and_nonempty() {
        let g = Graph::ring(24);
        let layout = TierLayout::new(2, 3);
        let m = ShardMap::partition_tiered(24, &layout, g.edges());
        assert_eq!(m.shards(), 6);
        assert_eq!(m.n(), 24);
        for s in 0..6 {
            assert!(!m.range(s).is_empty(), "shard {s} empty");
        }
        for v in 0..24 {
            assert!(m.range(m.shard_of(v)).contains(&v));
        }
        // host blocks are contiguous super-ranges: the shards of one
        // host tile that host's node block with no gaps
        for h in 0..2 {
            let r = layout.host_range(h);
            let block_lo = m.range(r.start).start;
            let block_hi = m.range(r.end - 1).end;
            let mut at = block_lo;
            for s in r {
                assert_eq!(m.range(s).start, at);
                at = m.range(s).end;
            }
            assert_eq!(at, block_hi);
        }
    }

    #[test]
    fn tiered_partition_moves_host_boundary_off_a_dense_seam() {
        // 16 nodes in two 8-node cliques joined by one bridge edge
        // (7, 8).  The even split at node 8 happens to be optimal; bias
        // the scenario instead: cliques of 6 and 10 with the bridge at
        // (5, 6), so the even host boundary (8) would cut through the
        // second clique — 5 of its internal edges span index 8 — while
        // the seam at 6 cuts only the bridge.  The optimizer must find
        // the seam within its window.
        let mut edges: Vec<(u32, u32)> = Vec::new();
        for u in 0..6u32 {
            for v in (u + 1)..6 {
                edges.push((u, v));
            }
        }
        for u in 6..16u32 {
            for v in (u + 1)..16 {
                edges.push((u, v));
            }
        }
        edges.push((5, 6));
        let layout = TierLayout::new(2, 2);
        let m = ShardMap::partition_tiered(16, &layout, &edges);
        // host boundary = start of the second host's first shard
        assert_eq!(m.range(2).start, 6, "host boundary missed the seam");
        // the inter-host cut under the full edge set is the bridge alone
        let plan = RoundPlan::build(&edges, &m);
        let (_, inter) = plan.cut_by_tier(&layout);
        assert_eq!(inter, 1, "inter-host cut should be the single bridge");
        // an even (untiered) split of the same shard count cuts more
        let even = ShardMap::new(16, 4);
        let even_plan = RoundPlan::build(&edges, &even);
        let (_, even_inter) = even_plan.cut_by_tier(&layout);
        assert!(even_inter > inter, "optimizer no better than even split");
    }

    #[test]
    fn cut_by_tier_splits_the_cross_count() {
        let g = Graph::ring(16);
        let layout = TierLayout::new(2, 2);
        let map = ShardMap::partition_tiered(16, &layout, g.edges());
        let schedule = Schedule::from_graph(&g);
        for c in 0..schedule.period() {
            let plan = RoundPlan::build(schedule.matching(c), &map);
            let (intra, inter) = plan.cut_by_tier(&layout);
            assert_eq!(intra + inter, plan.cross_edges);
        }
        // whole-graph totals on a ring with 4 contiguous shards over 2
        // hosts: 4 boundaries cut, 2 of them host boundaries (the
        // interior host seam + the wrap edge)
        let plan = RoundPlan::build(g.edges(), &map);
        let (intra, inter) = plan.cut_by_tier(&layout);
        assert_eq!(intra + inter, 4);
        assert_eq!(inter, 2);
    }

    #[test]
    fn master_owns_u_and_slave_owns_v() {
        let map = ShardMap::new(8, 2);
        let plan = RoundPlan::build(&[(0, 1), (3, 4), (7, 2)], &map);
        assert_eq!(plan.edges, 3);
        assert_eq!(plan.cross_edges, 2);
        assert_eq!(plan.per_shard[0].local, vec![(0, 0, 1)]);
        assert_eq!(plan.per_shard[0].master, vec![(1, 3, 4, 1)]);
        assert_eq!(plan.per_shard[1].slave, vec![(1, 4, 0)]);
        assert_eq!(plan.per_shard[1].master, vec![(2, 7, 2, 0)]);
        assert_eq!(plan.per_shard[0].slave, vec![(2, 2, 1)]);
    }
}
