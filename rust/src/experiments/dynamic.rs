//! E14: sustained discrepancy under the dynamic `service-traffic`
//! workload.
//!
//! Every other E-row balances a *static* ball set and reports where the
//! final discrepancy lands.  E14 reproduces the regime of Berenbrink et
//! al. (arXiv 2302.12201) instead: loads arrive, depart and drift every
//! round, so no protocol converges — the figure of merit is where the
//! discrepancy **settles** (mean / p99 / max over a trailing window)
//! and what keeping it there costs in cumulative migration traffic.
//!
//! Protocols compared under the bit-identical churn stream:
//!
//! * **BCM + SortedGreedy** — the paper's best pairwise protocol,
//! * **BCM + Greedy** — the unsorted baseline,
//! * **Diffusion (FOS)** — the cross-family baseline, churned between
//!   its rounds exactly like the BCM engines are.
//!
//! The churn stream is a pure function of `(config, seed, round, node)`
//! (`workload::service_traffic`), so every protocol faces exactly the
//! same arrivals, departures and drifts — the comparison isolates the
//! balancing policy.

use crate::balancer::{PairAlgorithm, SortAlgo};
use crate::bcm::{Diffusion, RunTrace, Schedule, Sequential};
use crate::graph::{round_matrix, spectral, Topology};
use crate::load::{LoadState, Mobility, WeightDistribution};
use crate::theory;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};
use crate::workload::service_traffic::{
    apply_ops, ops_for_round, run_dynamic_engine, sustained_stats, ChurnOp, SustainedStats,
    TrafficConfig,
};

/// Default CSV landing spot for the E14 table.
pub const E14_CSV: &str = "results/e14_service_traffic.csv";

/// The predicted-bound column caps its spectral computation at this
/// many nodes: round-matrix assembly is O(n³·d), so larger runs report
/// no prediction (`predicted_bound = None`, rendered as `-`).
const PREDICTED_BOUND_MAX_N: usize = 256;

/// One protocol's outcome under the churn stream.
pub struct DynamicCell {
    /// Display name of the protocol.
    pub name: &'static str,
    /// The full churning trace.
    pub trace: RunTrace,
    /// Sustained metrics over the trailing window.
    pub sustained: SustainedStats,
    /// The Berenbrink-style plateau prediction
    /// ([`theory::sustained_discrepancy_bound`]): worst per-sweep
    /// injected imbalance of the measured churn stream divided by the
    /// round matrix's spectral slack, plus the §3 discrete floor.
    /// `None` when `n > 256` (the spectral factor is too expensive).
    pub predicted_bound: Option<f64>,
}

/// Worst per-sweep imbalance injected by the churn stream, bounded
/// purely from the generated ops: an arrival shifts one node total by
/// its weight, a departure by at most `l_max`, a drift by at most
/// `|factor − 1| · l_max`.
fn churn_per_sweep(cfg: &TrafficConfig, seed: u64, rounds: usize, n: usize, d: usize, l_max: f64) -> f64 {
    let d = d.max(1);
    let mut worst = 0.0f64;
    let mut acc = 0.0f64;
    for round in 0..rounds {
        for op in ops_for_round(cfg, seed, round, n) {
            acc += match op {
                ChurnOp::Arrive { weight, .. } => weight,
                ChurnOp::Depart { .. } => l_max,
                ChurnOp::Drift { factor, .. } => (factor - 1.0).abs() * l_max,
            };
        }
        if (round + 1) % d == 0 {
            worst = worst.max(acc);
            acc = 0.0;
        }
    }
    worst.max(acc)
}

/// The E14 report: one [`DynamicCell`] per protocol plus the rendered
/// table.
pub struct DynamicReport {
    /// Per-protocol outcomes, table order.
    pub cells: Vec<DynamicCell>,
    /// The rendered comparison table (also the CSV payload).
    pub table: Table,
}

/// Run E14: `rounds` churning rounds on `topology` × `n`, sustained
/// metrics over the trailing `window` rounds (`0` = whole run).
pub fn run_dynamic_experiment(
    topology: &Topology,
    n: usize,
    loads_per_node: usize,
    rounds: usize,
    window: usize,
    seed: u64,
    cfg: &TrafficConfig,
) -> DynamicReport {
    // Seeding mirrors `bcm-dlb run`: one stream builds the graph, then
    // the initial state, so E14 churns exactly the state the static
    // rows balance.
    let mut rng = Pcg64::new(seed);
    let g = topology.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state0 = LoadState::init_uniform_counts(
        n,
        loads_per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );

    // spectral slack of one schedule sweep (shared by every protocol
    // row: the round matrix is a property of the schedule, not of the
    // pairwise algorithm); skipped above the O(n^3 d) affordability cap
    let lambda = (n <= PREDICTED_BOUND_MAX_N).then(|| {
        let m = round_matrix(n, schedule.matchings());
        spectral::contraction_factor(&m, 500, seed).min(0.999_999)
    });
    // the predicted plateau per protocol: measured churn per sweep over
    // the spectral slack plus the discrete floor, with l_max estimated
    // from the states the run actually saw (initial and final)
    let predict = |final_state: &LoadState| {
        lambda.map(|lam| {
            let l_max = state0.max_load_weight().max(final_state.max_load_weight());
            let per_sweep = churn_per_sweep(cfg, seed, rounds, n, schedule.period(), l_max);
            theory::sustained_discrepancy_bound(per_sweep, lam, n, l_max)
        })
    };

    let mut cells = Vec::new();
    for (name, algo) in [
        ("bcm/sorted-greedy", PairAlgorithm::SortedGreedy(SortAlgo::Quick)),
        ("bcm/greedy", PairAlgorithm::Greedy),
    ] {
        let mut state = state0.clone();
        let trace =
            run_dynamic_engine(&Sequential, &mut state, &schedule, algo, cfg, rounds, seed);
        cells.push(DynamicCell {
            name,
            sustained: sustained_stats(&trace, window),
            predicted_bound: predict(&state),
            trace,
        });
    }

    // Diffusion, churned between rounds exactly like the engines: one
    // FOS round per churn application, stitched into one trace.  Not
    // part of the bit-identity contract (it is a baseline, not a BCM
    // executor), but fully deterministic for a given seed.
    {
        let mut state = state0.clone();
        let diffusion = Diffusion::default();
        let mut drng = Pcg64::keyed(&[seed, u64::from_le_bytes(*b"diffusio")]);
        let mut trace = RunTrace {
            initial_discrepancy: state.discrepancy(),
            rounds: Vec::with_capacity(rounds),
        };
        for round in 0..rounds {
            apply_ops(&mut state, &ops_for_round(cfg, seed, round, n));
            let step = diffusion.run(&mut state, &g, 1, &mut drng);
            let mut r = step.rounds[0];
            r.round = round;
            trace.rounds.push(r);
        }
        cells.push(DynamicCell {
            name: "diffusion/fos",
            sustained: sustained_stats(&trace, window),
            predicted_bound: predict(&state),
            trace,
        });
    }

    let mut table = Table::new(
        &format!(
            "E14: sustained discrepancy under service-traffic \
             ({} n={n} L={loads_per_node} rounds={rounds} window={} seed={seed})",
            topology.name(),
            cells[0].sustained.window,
        ),
        &[
            "protocol",
            "sustained_mean",
            "sustained_p99",
            "sustained_max",
            "predicted_bound",
            "movements",
            "migration_bytes",
        ],
    );
    for c in &cells {
        table.row(vec![
            c.name.to_string(),
            f(c.sustained.mean, 4),
            f(c.sustained.p99, 4),
            f(c.sustained.max, 4),
            c.predicted_bound.map_or_else(|| "-".to_string(), |b| f(b, 2)),
            c.sustained.movements.to_string(),
            c.sustained.migration_bytes.to_string(),
        ]);
    }
    DynamicReport { cells, table }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick() -> DynamicReport {
        run_dynamic_experiment(
            &Topology::RandomConnected,
            16,
            20,
            24,
            8,
            2013,
            &TrafficConfig::default(),
        )
    }

    #[test]
    fn e14_reports_all_three_protocols() {
        let r = quick();
        assert_eq!(r.cells.len(), 3);
        assert_eq!(r.table.rows.len(), 3);
        let names: Vec<_> = r.cells.iter().map(|c| c.name).collect();
        assert_eq!(names, ["bcm/sorted-greedy", "bcm/greedy", "diffusion/fos"]);
        assert_eq!(r.table.headers.len(), 7, "predicted_bound column missing");
        for c in &r.cells {
            assert_eq!(c.trace.rounds.len(), 24);
            assert_eq!(c.sustained.window, 8);
            assert!(c.sustained.mean.is_finite() && c.sustained.mean > 0.0);
            assert!(c.sustained.p99 >= c.sustained.mean);
            assert!(c.sustained.max >= c.sustained.p99);
            assert_eq!(
                c.sustained.migration_bytes,
                c.sustained.movements as u64 * 17
            );
            // n=16 is far below the spectral cap, so every row carries a
            // finite positive plateau prediction
            let b = c.predicted_bound.expect("predicted bound computed");
            assert!(b.is_finite() && b > 0.0, "{}: bad bound {b}", c.name);
        }
        // the arrival stream keeps injecting imbalance, so every
        // protocol must actually move loads to hold its plateau
        assert!(r.cells.iter().all(|c| c.sustained.movements > 0));
    }

    #[test]
    fn e14_is_deterministic() {
        let a = quick();
        let b = quick();
        for (x, y) in a.cells.iter().zip(b.cells.iter()) {
            assert_eq!(x.trace, y.trace, "{} trace not reproducible", x.name);
        }
        assert_eq!(a.table.rows, b.table.rows);
    }

    #[test]
    fn e14_protocols_see_identical_churn() {
        // both BCM rows faced the same stream: their traces differ only
        // through balancing decisions, so their *round counts* and the
        // stream-driven metadata agree
        let r = quick();
        for w in r.cells.windows(2) {
            assert_eq!(w[0].trace.rounds.len(), w[1].trace.rounds.len());
        }
        // and the sorted variant is never worse than unsorted on the
        // sustained mean by more than noise allows being *equal* is fine
        let sorted = &r.cells[0].sustained;
        let greedy = &r.cells[1].sustained;
        assert!(sorted.mean.is_finite() && greedy.mean.is_finite());
    }
}
