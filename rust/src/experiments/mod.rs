//! Paper experiment drivers (E1–E8): shared by the CLI and the benches.

pub mod common;
pub mod figures;
pub mod validate;

pub use common::{find, run_cell, run_sweep, CellStats, SweepParams, Variant};
