//! Dependency-free support code: RNG, JSON, statistics, tables.

pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
