//! PPM-style particle-mesh workload (E9) — the paper's motivating
//! application (§1, §8: the authors plan to integrate the DLB into the
//! Parallel Particle-Mesh library).
//!
//! A 2-D periodic domain is decomposed into S×S fixed subdomains; each
//! subdomain is an *indivisible* work packet whose real-valued cost is the
//! number of particles currently inside it (costs drift as particles
//! advect — exactly the unpredictable-cost regime DLB targets).  The
//! subdomains are distributed over P processors; every `dlb_interval`
//! steps the BCM protocol rebalances them.

use crate::balancer::PairAlgorithm;
use crate::bcm::{run, Schedule, StopRule};
use crate::load::{Load, LoadState};
use crate::util::rng::Pcg64;

/// The particle simulation: swirl advection on the unit torus.
pub struct ParticleSim {
    /// subdomain grid side (S×S subdomains)
    pub s: usize,
    pub particles: Vec<(f64, f64)>,
    time: f64,
}

impl ParticleSim {
    /// `n_particles` clustered initial condition (two Gaussian blobs), so
    /// the initial decomposition is strongly imbalanced.
    pub fn new(s: usize, n_particles: usize, rng: &mut Pcg64) -> Self {
        let mut particles = Vec::with_capacity(n_particles);
        for i in 0..n_particles {
            let (cx, cy) = if i % 2 == 0 { (0.3, 0.3) } else { (0.7, 0.6) };
            let x = (cx + 0.08 * rng.normal(0.0, 1.0)).rem_euclid(1.0);
            let y = (cy + 0.08 * rng.normal(0.0, 1.0)).rem_euclid(1.0);
            particles.push((x, y));
        }
        Self {
            s,
            particles,
            time: 0.0,
        }
    }

    /// Advect every particle one step through a time-dependent swirl
    /// (Taylor–Green-like vortex plus a slow drift).
    pub fn step(&mut self, dt: f64) {
        use std::f64::consts::PI;
        let t = self.time;
        for (x, y) in self.particles.iter_mut() {
            let u = (PI * *x).sin().powi(2) * (2.0 * PI * *y).sin() * (0.3 * t).cos()
                + 0.05;
            let v = -(PI * *y).sin().powi(2) * (2.0 * PI * *x).sin() * (0.3 * t).cos()
                + 0.02;
            *x = (*x + dt * u).rem_euclid(1.0);
            *y = (*y + dt * v).rem_euclid(1.0);
        }
        self.time += dt;
    }

    /// Particles per subdomain (row-major S×S).
    pub fn counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.s * self.s];
        let s = self.s as f64;
        for &(x, y) in &self.particles {
            let i = ((y * s) as usize).min(self.s - 1);
            let j = ((x * s) as usize).min(self.s - 1);
            counts[i * self.s + j] += 1;
        }
        counts
    }
}

/// Which rebalancing policy the driver applies at each DLB epoch.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DlbPolicy {
    /// Never rebalance (static block decomposition).
    None,
    /// BCM with Greedy per matching.
    Greedy,
    /// BCM with SortedGreedy per matching.
    SortedGreedy,
}

impl DlbPolicy {
    pub fn label(&self) -> &'static str {
        match self {
            DlbPolicy::None => "no-DLB",
            DlbPolicy::Greedy => "Greedy-BCM",
            DlbPolicy::SortedGreedy => "SortedGreedy-BCM",
        }
    }
}

/// Result of a full driver run.
#[derive(Clone, Debug)]
pub struct DriverResult {
    pub policy: DlbPolicy,
    /// Σ_steps max_proc cost — the simulated parallel makespan.
    pub total_makespan: f64,
    /// Σ_steps mean_proc cost — the perfect-balance lower bound.
    pub ideal_makespan: f64,
    /// Subdomain migrations performed by DLB.
    pub migrations: usize,
    /// Makespan time series (per step).
    pub makespans: Vec<f64>,
}

impl DriverResult {
    /// Parallel efficiency vs the perfect-balance bound.
    pub fn efficiency(&self) -> f64 {
        self.ideal_makespan / self.total_makespan
    }
}

/// Run the particle-mesh workload under a DLB policy.
///
/// `procs` processors connected as `schedule`'s graph; `steps` simulation
/// steps; DLB every `dlb_interval` steps with `sweeps` BCM sweeps.
#[allow(clippy::too_many_arguments)]
pub fn run_driver(
    policy: DlbPolicy,
    sim: &mut ParticleSim,
    schedule: &Schedule,
    procs: usize,
    steps: usize,
    dlb_interval: usize,
    sweeps: usize,
    rng: &mut Pcg64,
) -> DriverResult {
    let nsub = sim.s * sim.s;
    // static block decomposition: contiguous stripes of subdomains
    let mut assignment: Vec<u32> = (0..nsub)
        .map(|i| (i * procs / nsub) as u32)
        .collect();
    let mut total_makespan = 0.0;
    let mut ideal_makespan = 0.0;
    let mut migrations = 0usize;
    let mut makespans = Vec::with_capacity(steps);

    for step in 0..steps {
        sim.step(0.05);
        let counts = sim.counts();
        // cost model: per-particle work + fixed per-subdomain mesh work
        let costs: Vec<f64> = counts.iter().map(|&c| c as f64 + 0.25).collect();

        if policy != DlbPolicy::None && step % dlb_interval == 0 {
            // Build the load state from the current assignment + costs.
            let mut state = LoadState::empty(procs);
            for (sub, &proc) in assignment.iter().enumerate() {
                state.push(proc as usize, Load::new(sub as u64, costs[sub]));
            }
            let algo = match policy {
                DlbPolicy::Greedy => PairAlgorithm::Greedy,
                DlbPolicy::SortedGreedy => {
                    PairAlgorithm::SortedGreedy(crate::balancer::SortAlgo::Quick)
                }
                DlbPolicy::None => unreachable!(),
            };
            let trace = run(&mut state, schedule, algo, StopRule::sweeps(sweeps), rng);
            migrations += trace.total_movements();
            for proc in 0..procs {
                for l in state.node(proc) {
                    assignment[l.id as usize] = proc as u32;
                }
            }
        }

        // parallel step cost = max processor load
        let mut per_proc = vec![0.0f64; procs];
        for (sub, &proc) in assignment.iter().enumerate() {
            per_proc[proc as usize] += costs[sub];
        }
        let makespan = per_proc.iter().cloned().fold(0.0, f64::max);
        let total: f64 = per_proc.iter().sum();
        total_makespan += makespan;
        ideal_makespan += total / procs as f64;
        makespans.push(makespan);
    }
    DriverResult {
        policy,
        total_makespan,
        ideal_makespan,
        migrations,
        makespans,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;

    #[test]
    fn particles_stay_in_domain() {
        let mut rng = Pcg64::new(1);
        let mut sim = ParticleSim::new(8, 1000, &mut rng);
        for _ in 0..50 {
            sim.step(0.05);
        }
        assert!(sim
            .particles
            .iter()
            .all(|&(x, y)| (0.0..1.0).contains(&x) && (0.0..1.0).contains(&y)));
        assert_eq!(sim.counts().iter().sum::<usize>(), 1000);
    }

    #[test]
    fn clustered_start_is_imbalanced() {
        let mut rng = Pcg64::new(2);
        let sim = ParticleSim::new(8, 4000, &mut rng);
        let counts = sim.counts();
        let max = *counts.iter().max().unwrap();
        let mean = 4000.0 / counts.len() as f64;
        assert!(max as f64 > 3.0 * mean, "max {max} mean {mean}");
    }

    #[test]
    fn dlb_beats_static_and_sorted_beats_greedy() {
        let procs = 8;
        let mut rng = Pcg64::new(3);
        let g = Graph::random_connected(procs, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let run_policy = |policy: DlbPolicy| -> DriverResult {
            let mut seed_rng = Pcg64::new(42);
            let mut sim = ParticleSim::new(16, 20_000, &mut seed_rng);
            let mut prng = Pcg64::new(7);
            run_driver(policy, &mut sim, &schedule, procs, 60, 5, 6, &mut prng)
        };
        let none = run_policy(DlbPolicy::None);
        let greedy = run_policy(DlbPolicy::Greedy);
        let sorted = run_policy(DlbPolicy::SortedGreedy);
        assert!(
            sorted.total_makespan < none.total_makespan,
            "sorted {} vs none {}",
            sorted.total_makespan,
            none.total_makespan
        );
        assert!(
            sorted.total_makespan <= greedy.total_makespan * 1.05,
            "sorted {} vs greedy {}",
            sorted.total_makespan,
            greedy.total_makespan
        );
        assert!(sorted.efficiency() > none.efficiency());
        assert!(sorted.migrations > 0);
    }

    #[test]
    fn efficiency_bounded_by_one() {
        let mut rng = Pcg64::new(5);
        let g = Graph::ring(4);
        let schedule = Schedule::from_graph(&g);
        let mut sim = ParticleSim::new(8, 2000, &mut rng);
        let mut prng = Pcg64::new(9);
        let r = run_driver(
            DlbPolicy::SortedGreedy,
            &mut sim,
            &schedule,
            4,
            20,
            4,
            4,
            &mut prng,
        );
        assert!(r.efficiency() <= 1.0 + 1e-9);
        assert!(r.efficiency() > 0.0);
        assert_eq!(r.makespans.len(), 20);
    }
}
