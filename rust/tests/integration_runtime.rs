//! Device-path integration: load the AOT HLO-text artifacts through PJRT,
//! execute them, and check numerics against the pure-Rust fallback (the
//! same contract python/tests validates kernel-vs-oracle).
//!
//! These tests require `make artifacts` to have run; they are skipped
//! (with a message) when artifacts/ is absent so `cargo test` stays green
//! on a fresh checkout.

use bcm_dlb::bcm::{run_device, Schedule};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::{fallback, solve_batch, DeviceAlgo, EdgeProblem, ExecPath, Runtime};
use bcm_dlb::util::rng::Pcg64;
use std::path::PathBuf;

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    dir.join("manifest.json").exists().then_some(dir)
}

macro_rules! require_artifacts {
    () => {
        match artifacts_dir() {
            Some(d) => d,
            None => {
                eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
                return;
            }
        }
    };
}

fn random_problems(n: usize, max_m: usize, seed: u64) -> Vec<EdgeProblem> {
    let mut rng = Pcg64::new(seed);
    (0..n)
        .map(|_| {
            let m = rng.range_inclusive(0, max_m);
            EdgeProblem {
                weights: (0..m).map(|_| rng.uniform(0.0, 100.0)).collect(),
                hosts: (0..m).map(|_| rng.below(2) as u8).collect(),
                base: [rng.uniform(0.0, 50.0), rng.uniform(0.0, 50.0)],
            }
        })
        .collect()
}

#[test]
fn device_client_loads_and_compiles() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).expect("runtime");
    assert!(!rt.platform().is_empty());
    let exe = rt.executable("balance_two_bin_b8_m64").expect("compile");
    assert_eq!(exe.spec.entry, "balance_two_bin");
}

#[test]
fn device_sorted_greedy_matches_fallback() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).expect("runtime");
    let problems = random_problems(20, 60, 42);
    let (dev, path) = solve_batch(Some(&mut rt), DeviceAlgo::SortedGreedy, &problems).unwrap();
    assert!(matches!(path, ExecPath::Device { .. }), "{path:?}");
    for (p, d) in problems.iter().zip(&dev) {
        let f = fallback::solve(p, DeviceAlgo::SortedGreedy);
        // identical placement decisions modulo f32 rounding inside the
        // kernel: compare final sums, not per-ball bits (ties among equal
        // f32 weights may be permuted by the bitonic network)
        let total: f64 = p.weights.iter().sum::<f64>() + p.base[0] + p.base[1];
        assert!((d.sums[0] + d.sums[1] - total).abs() < 1e-6);
        let d_dev = (d.sums[0] - d.sums[1]).abs();
        let d_fb = (f.sums[0] - f.sums[1]).abs();
        assert!(
            (d_dev - d_fb).abs() < 1e-2,
            "device disc {d_dev} vs fallback {d_fb} (m={})",
            p.weights.len()
        );
    }
}

#[test]
fn device_greedy_matches_fallback_exactly() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).expect("runtime");
    let problems = random_problems(12, 50, 7);
    let (dev, _) = solve_batch(Some(&mut rt), DeviceAlgo::Greedy, &problems).unwrap();
    for (p, d) in problems.iter().zip(&dev) {
        let f = fallback::solve(p, DeviceAlgo::Greedy);
        // No sorting stage: arrival order is deterministic, so the
        // placements must agree bit-for-bit up to f32-vs-f64 tie edges,
        // which are measure-zero for uniform draws.
        assert_eq!(d.assign, f.assign, "m={}", p.weights.len());
        assert_eq!(d.movements, f.movements);
    }
}

#[test]
fn device_handles_batch_larger_than_bucket() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).expect("runtime");
    // 100 problems forces chunking over any bucket's B
    let problems = random_problems(100, 30, 11);
    let (dev, path) = solve_batch(Some(&mut rt), DeviceAlgo::SortedGreedy, &problems).unwrap();
    assert_eq!(dev.len(), 100);
    if let ExecPath::Device { launches, .. } = path {
        assert!(launches >= 2, "expected chunked launches, got {launches}");
    } else {
        panic!("expected device path");
    }
}

#[test]
fn device_full_bcm_protocol_run() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).expect("runtime");
    let mut rng = Pcg64::new(3);
    let g = Graph::random_connected(16, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::init_uniform_counts(
        16,
        20,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let ids = state.all_ids();
    let init = state.discrepancy();
    let trace = run_device(
        &mut state,
        &schedule,
        DeviceAlgo::SortedGreedy,
        6,
        Some(&mut rt),
        &mut rng,
    )
    .unwrap();
    assert_eq!(state.all_ids(), ids, "loads lost on device path");
    assert!(
        trace.final_discrepancy() < init / 10.0,
        "init {init}, final {}",
        trace.final_discrepancy()
    );
}

#[test]
fn device_oversized_problem_falls_back() {
    let dir = require_artifacts!();
    let mut rt = Runtime::new(&dir).expect("runtime");
    // 10_000 balls exceeds every two-bin bucket (max M = 512)
    let problems = random_problems(2, 10_000, 13);
    let has_big = problems.iter().any(|p| p.weights.len() > 512);
    let (sols, path) = solve_batch(Some(&mut rt), DeviceAlgo::SortedGreedy, &problems).unwrap();
    assert_eq!(sols.len(), 2);
    if has_big {
        assert_eq!(path, ExecPath::Fallback);
    }
}
