//! The indivisible, real-valued load (the paper's central object).

/// An atomic work packet: constant real-valued cost, cannot be subdivided,
/// can only be migrated whole between processors (paper §1, §3).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Load {
    /// Stable identity across migrations.
    pub id: u64,
    /// The real-valued cost.  Constant during a DLB epoch.
    pub weight: f64,
    /// `false` => pinned to its current processor (partial mobility,
    /// paper §6.1: e.g. subdomains that must keep processor-neighborhood
    /// relationships).
    pub mobile: bool,
}

impl Load {
    pub fn new(id: u64, weight: f64) -> Self {
        Self {
            id,
            weight,
            mobile: true,
        }
    }

    pub fn pinned(id: u64, weight: f64) -> Self {
        Self {
            id,
            weight,
            mobile: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors() {
        let l = Load::new(3, 1.5);
        assert!(l.mobile);
        let p = Load::pinned(4, 2.5);
        assert!(!p.mobile);
        assert_eq!(p.weight, 2.5);
    }
}
