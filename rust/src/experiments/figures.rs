//! Regenerators for every figure/table of the paper (E1–E7).
//!
//! Each function returns `Table`s shaped like the paper's plot series and
//! writes a CSV under `results/`.  Shape expectations (who wins, by what
//! order of magnitude) are documented per figure in EXPERIMENTS.md.

use super::common::{find, run_sweep, SweepParams, Variant};
use crate::balancer::{self, SortAlgo};
use crate::util::rng::Pcg64;
use crate::util::stats::Welford;
use crate::util::table::{f, Table};
use std::path::Path;

/// Fig. 1 (a)–(i): average final discrepancy ± std vs n, for each L/n and
/// each of the four variants.  One table per L/n ratio (3 panels worth of
/// series per table).
pub fn fig1(params: &SweepParams, out_dir: &Path) -> Vec<Table> {
    let cells = run_sweep(params);
    let mut tables = Vec::new();
    for &per in &params.loads_per_node {
        let mut t = Table::new(
            &format!("Fig.1 L/n={per}: final discrepancy (mean±std over {} reps)", params.reps),
            &[
                "n",
                "init_disc",
                "SG/full",
                "SG/full_std",
                "SG/partial",
                "SG/partial_std",
                "G/full",
                "G/full_std",
                "G/partial",
                "G/partial_std",
            ],
        );
        for &n in &params.network_sizes {
            let get = |v: Variant| find(&cells, v, n, per).unwrap();
            t.row(vec![
                n.to_string(),
                f(get(Variant::SortedFull).initial_disc.mean(), 1),
                f(get(Variant::SortedFull).final_disc.mean(), 3),
                f(get(Variant::SortedFull).final_disc.std(), 3),
                f(get(Variant::SortedPartial).final_disc.mean(), 3),
                f(get(Variant::SortedPartial).final_disc.std(), 3),
                f(get(Variant::GreedyFull).final_disc.mean(), 3),
                f(get(Variant::GreedyFull).final_disc.std(), 3),
                f(get(Variant::GreedyPartial).final_disc.mean(), 3),
                f(get(Variant::GreedyPartial).final_disc.std(), 3),
            ]);
        }
        t.write_csv(&out_dir.join(format!("fig1_ln{per}.csv"))).ok();
        tables.push(t);
    }
    tables
}

/// Fig. 2: ratio of average load movements per edge, SortedGreedy/Greedy,
/// for full (left panel) and partial (right panel) mobility.
pub fn fig2(params: &SweepParams, out_dir: &Path) -> Vec<Table> {
    let cells = run_sweep(params);
    let mut tables = Vec::new();
    // Two Greedy readings per mobility model: the pooled Alg-4.2 Greedy
    // and the movement-frugal incremental Greedy.  The paper's measured
    // 14-30x ratios are only reachable under the incremental reading —
    // pooled re-splitting moves ~m/2 loads for *both* algorithms (ratio
    // ~1).  See EXPERIMENTS.md §Fig.2 for the analysis.
    for (mob, num, den, reading) in [
        ("full", Variant::SortedFull, Variant::GreedyFull, "pooled"),
        ("partial", Variant::SortedPartial, Variant::GreedyPartial, "pooled"),
        ("full", Variant::SortedFull, Variant::GreedyIncFull, "incremental"),
        (
            "partial",
            Variant::SortedPartial,
            Variant::GreedyIncPartial,
            "incremental",
        ),
    ] {
        let mut t = Table::new(
            &format!(
                "Fig.2 ({mob} mobility, {reading} Greedy): alpha_SortedGreedy / alpha_Greedy per edge"
            ),
            &["n", "L/n=10", "L/n=50", "L/n=100"],
        );
        for &n in &params.network_sizes {
            let mut row = vec![n.to_string()];
            for &per in &params.loads_per_node {
                let s = find(&cells, num, n, per).unwrap().movements_per_edge.mean();
                let g = find(&cells, den, n, per).unwrap().movements_per_edge.mean();
                row.push(if g > 0.0 { f(s / g, 2) } else { "inf".into() });
            }
            // Pad missing L/n columns if params deviate from default.
            while row.len() < 4 {
                row.push("-".into());
            }
            t.row(row);
        }
        t.write_csv(&out_dir.join(format!("fig2_{mob}_{reading}.csv"))).ok();
        tables.push(t);
    }
    tables
}

/// Fig. 3 + §7: relative figure of merit S_rel (Eq. 6) per cell, plus the
/// paper's headline averages (E7).
pub fn fig3(params: &SweepParams, out_dir: &Path) -> Vec<Table> {
    let cells = run_sweep(params);
    let mut tables = Vec::new();
    let mut headline = Table::new(
        "E7 headline scalars (paper §6.1/§7 vs measured)",
        &["metric", "paper", "measured"],
    );
    for (mob, num, den, reading) in [
        ("full", Variant::SortedFull, Variant::GreedyFull, "pooled"),
        ("partial", Variant::SortedPartial, Variant::GreedyPartial, "pooled"),
        ("full", Variant::SortedFull, Variant::GreedyIncFull, "incremental"),
        (
            "partial",
            Variant::SortedPartial,
            Variant::GreedyIncPartial,
            "incremental",
        ),
    ] {
        let mut t = Table::new(
            &format!("Fig.3 ({mob} mobility, {reading} Greedy): S_rel = S_SortedGreedy / S_Greedy"),
            &["n", "L/n=10", "L/n=50", "L/n=100"],
        );
        let mut srel_all = Welford::new();
        let mut disc_ratio_all = Welford::new();
        let mut move_ratio_all = Welford::new();
        for &n in &params.network_sizes {
            let mut row = vec![n.to_string()];
            for &per in &params.loads_per_node {
                let s = find(&cells, num, n, per).unwrap();
                let g = find(&cells, den, n, per).unwrap();
                let srel = s.merit.mean() / g.merit.mean();
                srel_all.push(srel);
                disc_ratio_all.push(g.final_disc.mean() / s.final_disc.mean().max(1e-12));
                move_ratio_all.push(
                    s.total_movements.mean() / g.total_movements.mean().max(1e-12),
                );
                row.push(f(srel, 2));
            }
            while row.len() < 4 {
                row.push("-".into());
            }
            t.row(row);
        }
        t.write_csv(&out_dir.join(format!("fig3_{mob}_{reading}.csv"))).ok();
        tables.push(t);

        let (paper_srel, paper_disc, paper_move) = if mob == "full" {
            ("22x", "135x", "14x")
        } else {
            ("24x", "21x", "2x")
        };
        headline.row(vec![
            format!("S_rel mean ({mob}, {reading})"),
            paper_srel.into(),
            format!("{}x", f(srel_all.mean(), 1)),
        ]);
        headline.row(vec![
            format!("disc ratio G/SG ({mob}, {reading})"),
            paper_disc.into(),
            format!("{}x", f(disc_ratio_all.mean(), 1)),
        ]);
        headline.row(vec![
            format!("movement ratio SG/G ({mob}, {reading})"),
            paper_move.into(),
            format!("{}x", f(move_ratio_all.mean(), 1)),
        ]);
    }
    headline.write_csv(&out_dir.join("e7_headline.csv")).ok();
    tables.push(headline);
    tables
}

/// Fig. 4: offline balls-into-bins discrepancy vs m for n ∈ {2, 8} bins,
/// U[0,1] weights, `reps` repetitions (paper: 1000).
pub fn fig4(reps: usize, seed: u64, out_dir: &Path) -> Vec<Table> {
    let ms: Vec<usize> = (1..=12).map(|k| 1usize << k).collect(); // 2..4096
    let mut tables = Vec::new();
    for nbins in [2usize, 8] {
        let mut t = Table::new(
            &format!("Fig.4 n={nbins} bins: discrepancy vs m ({reps} reps)"),
            &["m", "greedy_mean", "greedy_std", "sorted_mean", "sorted_std", "ratio"],
        );
        for &m in &ms {
            let mut wg = Welford::new();
            let mut ws = Welford::new();
            for rep in 0..reps {
                let mut rng = Pcg64::new(seed.wrapping_add((m * 1009 + rep) as u64));
                let weights: Vec<f64> = (0..m).map(|_| rng.next_f64()).collect();
                wg.push(balancer::greedy(&weights, nbins).discrepancy());
                ws.push(
                    balancer::sorted_greedy(&weights, nbins, SortAlgo::Quick).discrepancy(),
                );
            }
            let ratio = wg.mean() / ws.mean().max(1e-15);
            t.row(vec![
                m.to_string(),
                f(wg.mean(), 4),
                f(wg.std(), 4),
                f(ws.mean(), 6),
                f(ws.std(), 6),
                f(ratio, 1),
            ]);
        }
        t.write_csv(&out_dir.join(format!("fig4_n{nbins}.csv"))).ok();
        tables.push(t);
    }
    tables
}

/// Fig. 5: discrepancy vs number of bins for m ∈ {1024, 3027}.
pub fn fig5(reps: usize, seed: u64, out_dir: &Path) -> Vec<Table> {
    let bins: Vec<usize> = vec![2, 4, 8, 16, 32, 64, 128, 256, 512];
    let mut tables = Vec::new();
    for m in [1024usize, 3027] {
        let mut t = Table::new(
            &format!("Fig.5 m={m} balls: discrepancy vs bins ({reps} reps)"),
            &["bins", "greedy_mean", "greedy_std", "sorted_mean", "sorted_std"],
        );
        for &nb in &bins {
            let mut wg = Welford::new();
            let mut ws = Welford::new();
            for rep in 0..reps {
                let mut rng = Pcg64::new(seed.wrapping_add((m * 31 + nb * 7 + rep) as u64));
                let weights: Vec<f64> = (0..m).map(|_| rng.next_f64()).collect();
                wg.push(balancer::greedy(&weights, nb).discrepancy());
                ws.push(balancer::sorted_greedy(&weights, nb, SortAlgo::Quick).discrepancy());
            }
            t.row(vec![
                nb.to_string(),
                f(wg.mean(), 4),
                f(wg.std(), 4),
                f(ws.mean(), 5),
                f(ws.std(), 5),
            ]);
        }
        t.write_csv(&out_dir.join(format!("fig5_m{m}.csv"))).ok();
        tables.push(t);
    }
    tables
}

/// §11.3 timing table: runtime of Greedy vs SortedGreedy (per sort
/// algorithm) on the two-bin problem with m = 2^13 balls.
pub fn timings(reps: usize, seed: u64, out_dir: &Path) -> Table {
    let m = 1usize << 13;
    let mut t = Table::new(
        &format!("§11.3 timings: two-bin, m=2^13, {reps} reps (mean wall time)"),
        &["algorithm", "mean_us", "vs_greedy", "sort_overhead_%"],
    );
    let gen = |rep: usize| -> Vec<f64> {
        let mut rng = Pcg64::new(seed.wrapping_add(rep as u64));
        (0..m).map(|_| rng.next_f64()).collect()
    };
    let time_of = |f: &dyn Fn(&[f64])| -> f64 {
        // warmup
        let w = gen(usize::MAX / 2);
        f(&w);
        let start = std::time::Instant::now();
        for rep in 0..reps {
            let w = gen(rep);
            f(&w);
        }
        start.elapsed().as_secs_f64() / reps as f64 * 1e6
    };
    let greedy_us = time_of(&|w| {
        std::hint::black_box(balancer::greedy(w, 2));
    });
    t.row(vec![
        "Greedy".into(),
        f(greedy_us, 1),
        "1.00".into(),
        "0.0".into(),
    ]);
    for sort in [SortAlgo::Quick, SortAlgo::Merge, SortAlgo::Flash, SortAlgo::Std] {
        let us = time_of(&|w| {
            std::hint::black_box(balancer::sorted_greedy(w, 2, sort));
        });
        t.row(vec![
            format!("SortedGreedy/{}", sort.name()),
            f(us, 1),
            f(us / greedy_us, 2),
            f((us - greedy_us) / us.max(1e-12) * 100.0, 1),
        ]);
    }
    t.write_csv(&out_dir.join("timings.csv")).ok();
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp() -> std::path::PathBuf {
        let d = std::env::temp_dir().join("bcm_dlb_fig_tests");
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn tiny_params() -> SweepParams {
        SweepParams {
            network_sizes: vec![4, 8],
            loads_per_node: vec![10],
            reps: 2,
            sweeps: 6,
            seed: 5,
        }
    }

    #[test]
    fn fig1_tables_render() {
        let tables = fig1(&tiny_params(), &tmp());
        assert_eq!(tables.len(), 1);
        assert_eq!(tables[0].rows.len(), 2);
        assert!(tables[0].render().contains("Fig.1"));
    }

    #[test]
    fn fig2_and_fig3_render() {
        let p = tiny_params();
        assert_eq!(fig2(&p, &tmp()).len(), 4); // 2 mobility x 2 Greedy readings
        let f3 = fig3(&p, &tmp());
        assert_eq!(f3.len(), 5); // 4 panels + headline
        assert!(f3[4].render().contains("headline"));
    }

    #[test]
    fn fig4_shape_holds_small() {
        let tables = fig4(30, 99, &tmp());
        assert_eq!(tables.len(), 2);
        // last row (m=4096, n=2): ratio should exceed 10x
        let last = tables[0].rows.last().unwrap();
        let ratio: f64 = last[5].parse().unwrap();
        assert!(ratio > 10.0, "ratio {ratio}");
    }

    #[test]
    fn fig5_renders() {
        let tables = fig5(5, 1, &tmp());
        assert_eq!(tables.len(), 2);
        assert_eq!(tables[0].rows.len(), 9);
    }

    #[test]
    fn timings_table_renders() {
        let t = timings(3, 1, &tmp());
        assert_eq!(t.rows.len(), 5);
    }
}
