//! Distributed BCM runtime: a leader thread orchestrating one shard
//! worker per core, communicating over channels.  Intra-shard edges are
//! solved locally; only cross-shard edges exchange (offer -> placement ->
//! settle) messages, and every edge draws from the counter-based
//! `Pcg64::for_edge` streams, so cluster runs are bit-identical to the
//! in-process engines for any shard count.

pub mod cluster;
pub mod messages;
pub mod shard;
pub mod worker;

pub use cluster::{Cluster, MessageStats};
pub use shard::{resolve_shards, RoundPlan, ShardMap, ShardPlan};
pub use worker::{ShardWorker, WorkerAlgo};
