"""Layer-1 Pallas kernel: round-matrix application (continuous oracle).

The continuous (arbitrarily divisible) case evolves the load vector as a
linear system xi^(t) = xi^(t-1) . M (paper §3, Appendix A Lemma 3).  The
theory module compares the indivisible trajectories against this oracle, so
the coordinator needs a fast batched matvec  X <- X @ M  where M is the
n x n round matrix (doubly stochastic, symmetric for BCM matchings).

This is the one MXU-shaped kernel in the stack: a classic tiled matmul with
the K axis kept whole (n <= a few hundred for the paper's networks) and the
output tiled over (B, N) blocks.

Inputs:  x f32[B, N], m f32[N, N].   Output: f32[B, N] = x @ m.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, m_ref, o_ref):
    o_ref[...] = jnp.dot(
        x_ref[...], m_ref[...], preferred_element_type=jnp.float32
    )


def diffusion_step(x, m, *, block_b: int | None = None, block_n: int | None = None):
    """One continuous-case BCM round for a batch of load vectors."""
    b, n = x.shape
    if m.shape != (n, n):
        raise ValueError(f"round matrix must be [{n}, {n}], got {m.shape}")
    if block_b is None:
        block_b = min(b, 8)
    if block_n is None:
        block_n = n  # K and N whole: paper networks are n <= 128
    if b % block_b != 0 or n % block_n != 0:
        raise ValueError("block sizes must divide array dims")

    grid = (b // block_b, n // block_n)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, n), lambda i, j: (i, 0)),
            pl.BlockSpec((n, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((b, n), x.dtype),
        interpret=True,
    )(x, m)
