//! Paper experiment drivers (E1–E8) plus the engine-scaling study (E11):
//! shared by the CLI and the benches.

pub mod common;
pub mod figures;
pub mod scaling;
pub mod validate;

pub use common::{find, run_cell, run_sweep, CellStats, SweepParams, Variant};
pub use scaling::{
    large_scenarios, run_scaling, scaling_table, ScalingReport, ScalingScenario,
    ThreadMeasurement,
};
