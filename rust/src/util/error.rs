//! Minimal error handling (anyhow is not vendored in this offline image).
//!
//! Provides the small slice of `anyhow`'s API the crate uses: a
//! message-carrying [`Error`], a defaulted [`Result`] alias, the
//! [`Context`] extension trait for `Result` and `Option`, and the
//! crate-root [`anyhow!`](crate::anyhow) / [`bail!`](crate::bail) macros.

use std::fmt;

/// A message-style error (the offline stand-in for `anyhow::Error`).
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Self {
        Self { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

// Any std error converts via `?`.  `Error` itself deliberately does NOT
// implement `std::error::Error`, so this blanket impl cannot overlap the
// reflexive `From<T> for T`.
impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Self { msg: e.to_string() }
    }
}

/// `Result` defaulted to [`Error`], like `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (`Result`) or missing values (`Option`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{ctx}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(ctx.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value
/// (the offline stand-in for `anyhow::anyhow!`).
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(, $arg:expr)* $(,)?) => {
        $crate::util::error::Error::msg(format!($msg $(, $arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::util::error::Error::msg($err.to_string())
    };
}

/// Early-return with an error (the offline stand-in for `anyhow::bail!`).
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn macro_forms() {
        let e = crate::anyhow!("plain");
        assert_eq!(e.to_string(), "plain");
        let x = 3;
        let e = crate::anyhow!("value {x} and {}", 4);
        assert_eq!(e.to_string(), "value 3 and 4");
        let s = String::from("owned");
        let e = crate::anyhow!(s);
        assert_eq!(e.to_string(), "owned");
    }

    #[test]
    fn bail_returns_err() {
        fn f(fail: bool) -> Result<u32> {
            if fail {
                crate::bail!("failed with {}", 7);
            }
            Ok(1)
        }
        assert_eq!(f(false).unwrap(), 1);
        assert_eq!(f(true).unwrap_err().to_string(), "failed with 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/a/file")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), &str> = Err("inner");
        let e = r.context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: inner");

        let o: Option<u32> = None;
        let e = o.with_context(|| format!("missing {}", "thing")).unwrap_err();
        assert_eq!(e.to_string(), "missing thing");
        assert_eq!(Some(5u32).context("ignored").unwrap(), 5);
    }
}
