//! BCM engine over the PJRT device path (the production hot path).
//!
//! Per round, all matched edges are packed into one batched kernel launch
//! (`runtime::solve_batch`); the sequential `engine::run` is the semantic
//! reference.  With `runtime = None` the same code runs on the pure-Rust
//! fallback — bit-identical semantics, useful for differential tests.

use super::schedule::Schedule;
use super::trace::{RoundStats, RunTrace};
use crate::load::{Load, LoadState};
use crate::runtime::{solve_batch, DeviceAlgo, EdgeProblem, Runtime};
use crate::util::error::Result;
use crate::util::rng::Pcg64;

/// Run `sweeps` full sweeps of the schedule through the device path.
pub fn run_device(
    state: &mut LoadState,
    schedule: &Schedule,
    algo: DeviceAlgo,
    sweeps: usize,
    mut runtime: Option<&mut Runtime>,
    rng: &mut Pcg64,
) -> Result<RunTrace> {
    assert_eq!(state.n(), schedule.n(), "state/schedule size mismatch");
    let mut trace = RunTrace {
        initial_discrepancy: state.discrepancy(),
        rounds: Vec::new(),
    };
    let d = schedule.period();
    let mut round = 0usize;
    for _ in 0..sweeps {
        for color in 0..d {
            let pairs = schedule.matching(round).to_vec();
            let movements = balance_round(state, &pairs, algo, runtime.as_deref_mut(), rng)?;
            trace.rounds.push(RoundStats {
                round,
                color,
                discrepancy: state.discrepancy(),
                movements,
                edges: pairs.len(),
            });
            round += 1;
        }
    }
    Ok(trace)
}

/// Balance one round's matching as a single batch; returns movements.
pub fn balance_round(
    state: &mut LoadState,
    pairs: &[(u32, u32)],
    algo: DeviceAlgo,
    runtime: Option<&mut Runtime>,
    rng: &mut Pcg64,
) -> Result<usize> {
    // Gather: pull each pair's mobile loads, build the batched problems.
    let mut problems = Vec::with_capacity(pairs.len());
    let mut pools: Vec<Vec<Load>> = Vec::with_capacity(pairs.len());
    let mut flips = Vec::with_capacity(pairs.len());
    for &(u, v) in pairs {
        let (u, v) = (u as usize, v as usize);
        let mut pool = state.take_mobile(u);
        let u_count = pool.len();
        pool.extend(state.take_mobile(v));
        let flip = rng.coin();
        let mut base = [state.pinned_weight(u), state.pinned_weight(v)];
        let mut hosts: Vec<u8> = (0..pool.len())
            .map(|i| u8::from(i >= u_count))
            .collect();
        if flip {
            base.swap(0, 1);
            for h in hosts.iter_mut() {
                *h ^= 1;
            }
        }
        problems.push(EdgeProblem {
            weights: pool.iter().map(|l| l.weight).collect(),
            hosts,
            base,
        });
        pools.push(pool);
        flips.push(flip);
    }

    let (solutions, _path) = solve_batch(runtime, algo, &problems)?;

    // Scatter: apply assignments back (undoing the orientation flip).
    let mut movements = 0usize;
    for (((&(u, v), pool), sol), flip) in pairs
        .iter()
        .zip(pools)
        .zip(&solutions)
        .zip(&flips)
    {
        movements += sol.movements;
        for (load, &side) in pool.into_iter().zip(&sol.assign) {
            let to_u = (side == 0) != *flip;
            state.push(if to_u { u as usize } else { v as usize }, load);
        }
    }
    Ok(movements)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::load::{Mobility, WeightDistribution};

    #[test]
    fn fallback_device_engine_balances() {
        let mut rng = Pcg64::new(1);
        let g = Graph::random_connected(16, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            16,
            50,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let init = state.discrepancy();
        let trace =
            run_device(&mut state, &schedule, DeviceAlgo::SortedGreedy, 8, None, &mut rng)
                .unwrap();
        assert!(trace.final_discrepancy() < init / 20.0);
    }

    #[test]
    fn conservation_through_device_engine() {
        let mut rng = Pcg64::new(2);
        let g = Graph::ring(8);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            8,
            20,
            &WeightDistribution::paper_section6(),
            Mobility::Partial,
            &mut rng,
        );
        let ids = state.all_ids();
        let mass = state.total_weight();
        run_device(&mut state, &schedule, DeviceAlgo::Greedy, 5, None, &mut rng).unwrap();
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6);
    }

    #[test]
    fn sequential_and_device_fallback_agree_statistically() {
        // Same protocol, independent RNG streams: final discrepancies
        // should land in the same ballpark (they share semantics).
        let mut rng = Pcg64::new(3);
        let g = Graph::random_connected(12, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state0 = LoadState::init_uniform_counts(
            12,
            40,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );

        let mut s1 = state0.clone();
        let mut r1 = Pcg64::new(100);
        let t1 = run_device(&mut s1, &schedule, DeviceAlgo::SortedGreedy, 10, None, &mut r1)
            .unwrap();

        let mut s2 = state0.clone();
        let mut r2 = Pcg64::new(200);
        let t2 = crate::bcm::engine::run(
            &mut s2,
            &schedule,
            crate::balancer::PairAlgorithm::SortedGreedy(crate::balancer::SortAlgo::Quick),
            crate::bcm::engine::StopRule::sweeps(10),
            &mut r2,
        );

        let a = t1.final_discrepancy();
        let b = t2.final_discrepancy();
        assert!(a < t1.initial_discrepancy / 10.0);
        assert!(b < t2.initial_discrepancy / 10.0);
        // both tiny; ratio within 100x of each other (stochastic)
        assert!(a / b < 100.0 && b / a < 100.0, "a={a} b={b}");
    }
}
