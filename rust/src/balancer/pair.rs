//! Pairwise (two-bin) rebalancing — the per-matching step of the BCM.
//!
//! In every matching [u:v], the union of the two nodes' *mobile* loads is
//! redistributed across the pair as evenly as possible, with the pinned
//! loads contributing fixed base sums (paper §4, §6.1).  This is exactly
//! the offline weighted balls-into-bins problem with two bins.

use super::sorting::SortAlgo;
use crate::load::Load;
use crate::util::rng::Pcg64;

/// Result of rebalancing one matched edge.
#[derive(Clone, Debug)]
pub struct PairOutcome {
    /// New mobile loads of u / of v (pinned loads are not included; they
    /// never move).
    pub to_u: Vec<Load>,
    pub to_v: Vec<Load>,
    /// Number of loads whose host changed (the paper's communication-cost
    /// metric alpha, §6.2).
    pub movements: usize,
    /// |weight(u) − weight(v)| after the rebalance, counting pinned loads.
    pub local_discrepancy: f64,
}

/// Which local (per-matching) algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PairAlgorithm {
    /// Paper Alg. 4.2 applied to the pooled mobile loads: place balls in
    /// arrival order into the lighter bin, rebuilding both bins from
    /// scratch.  This is the Appendix-C offline Greedy; in the *protocol*
    /// it moves ~m/2 loads per matching (every re-split reshuffles hosts).
    Greedy,
    /// Movement-frugal protocol Greedy: keep every load on its current
    /// host and relocate a load (arrival order) only when its host is
    /// heavier by more than the load's weight, i.e. when the move
    /// strictly shrinks the pair imbalance.  This is the reading of the
    /// paper's §5 "Greedy" DLB strategy consistent with Fig. 2 (Greedy
    /// moves 14-30x fewer loads than SortedGreedy) and with §6.1 (Greedy
    /// reduces the discrepancy at most ~4.5x): pooled Alg-4.2 Greedy
    /// would show movement *parity* with SortedGreedy.  See DESIGN.md
    /// §Substitutions.
    GreedyIncremental,
    /// Paper Alg. 4.1: sort descending, then pooled Greedy.
    SortedGreedy(SortAlgo),
    /// Baseline: each mobile load to a uniformly random side.
    Random,
}

impl PairAlgorithm {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "greedy" => Some(PairAlgorithm::Greedy),
            "greedy-inc" | "incremental" => Some(PairAlgorithm::GreedyIncremental),
            "sorted" | "sorted-greedy" | "sortedgreedy" => {
                Some(PairAlgorithm::SortedGreedy(SortAlgo::Quick))
            }
            "random" => Some(PairAlgorithm::Random),
            s if s.starts_with("sorted:") => {
                SortAlgo::parse(&s[7..]).map(PairAlgorithm::SortedGreedy)
            }
            _ => None,
        }
    }

    pub fn name(&self) -> String {
        match self {
            PairAlgorithm::Greedy => "greedy".into(),
            PairAlgorithm::GreedyIncremental => "greedy-inc".into(),
            PairAlgorithm::SortedGreedy(a) => format!("sorted:{}", a.name()),
            PairAlgorithm::Random => "random".into(),
        }
    }
}

/// Caller-owned, reusable per-edge working memory: the mobile pool and
/// the per-entry destination column [`decide_pool`] fills.  One scratch
/// per worker makes the whole edge solve allocation-free in steady
/// state — the buffers grow to the largest edge seen and are then
/// reused forever (pinned by `tests/alloc_budget.rs`).
#[derive(Debug, Default)]
pub struct EdgeScratch {
    /// The pooled mobile loads, each tagged with its current bin
    /// (0 = u, 1 = v), in arrival order (u's loads then v's).
    pub pool: Vec<(Load, u8)>,
    /// Destination bin per pool entry, parallel to `pool` (filled by
    /// [`decide_pool`]; entries are 0 = u, 1 = v).
    pub dest: Vec<u8>,
}

impl EdgeScratch {
    pub fn new() -> Self {
        Self::default()
    }
}

/// The scalar outcome of one edge decision ([`decide_pool`]); the load
/// routing itself lives in the caller's `dest` column.
#[derive(Clone, Copy, Debug)]
pub struct EdgeDecision {
    /// Number of loads whose host changed (the paper's communication-cost
    /// metric alpha, §6.2).
    pub movements: usize,
    /// |weight(u) − weight(v)| after the rebalance, counting pinned loads.
    pub local_discrepancy: f64,
}

/// Rebalance a matched edge.
///
/// `u_loads` / `v_loads` are each node's full load lists (mobile +
/// pinned).  The zero-expected-error condition (paper §3 cond. 3,
/// Appendix A req. 3) requires the symmetric tie-breaking of the first
/// ball; we realize it by randomly orienting the pair: with probability
/// 1/2 the roles of the two bins are swapped before the deterministic
/// placement.
pub fn balance_pair(
    u_loads: &[Load],
    v_loads: &[Load],
    algo: PairAlgorithm,
    rng: &mut Pcg64,
) -> PairOutcome {
    // Mobile pool keeps arrival order (u's loads then v's) — this is the
    // Greedy baseline's input order.  Track the original host of each.
    let mut pool: Vec<(Load, u8)> = Vec::with_capacity(u_loads.len() + v_loads.len());
    let mut base = [0.0f64; 2];
    for l in u_loads {
        if l.mobile {
            pool.push((*l, 0));
        } else {
            base[0] += l.weight;
        }
    }
    for l in v_loads {
        if l.mobile {
            pool.push((*l, 1));
        } else {
            base[1] += l.weight;
        }
    }
    balance_pool(pool, base, algo, rng)
}

/// Rebalance an already-pooled edge: `pool` holds the two nodes' mobile
/// loads in arrival order (u's then v's), each tagged with its current
/// bin (0 = u, 1 = v); `base` holds the bins' pinned weight sums.
///
/// This is the classic allocating façade over [`decide_pool`], kept for
/// the sharded coordinator's message paths and for tests; the hot paths
/// call [`decide_pool`] with a reusable [`EdgeScratch`] instead.  Both
/// consume the per-edge RNG stream *exactly* alike — the orientation
/// flip is always the stream's first draw — which is what keeps cluster
/// runs bit-identical to `bcm::Sequential`.
pub fn balance_pool(
    mut pool: Vec<(Load, u8)>,
    base: [f64; 2],
    algo: PairAlgorithm,
    rng: &mut Pcg64,
) -> PairOutcome {
    let mut dest = Vec::with_capacity(pool.len());
    let d = decide_pool(&mut pool, &mut dest, base, algo, rng);
    let mut to_u = Vec::new();
    let mut to_v = Vec::new();
    for (i, &(l, _)) in pool.iter().enumerate() {
        if dest[i] == 0 {
            to_u.push(l);
        } else {
            to_v.push(l);
        }
    }
    PairOutcome {
        to_u,
        to_v,
        movements: d.movements,
        local_discrepancy: d.local_discrepancy,
    }
}

/// The allocation-free two-bin solve: decide a destination bin for every
/// pool entry, writing it to the parallel `dest` column instead of
/// copying loads into staging vectors.
///
/// Bitwise identical to the historical `balance_pool`, which *toggled*
/// every tag and *swapped* the base sums on a heads orientation flip and
/// un-swapped the outputs at the end.  Here the flip stays logical: with
/// `f = flip as u8`, logical bin `b` is physical bin `b ^ f`, so the
/// base sums are read flipped, every host tag is read as `tag ^ f`, and
/// every decided logical bin is written back as `k ^ f`.  The RNG
/// stream is consumed in exactly the historical order (the flip coin
/// first, then — for `Random` — one draw per pool entry in pool order),
/// the placement comparisons see identical f64 values, and the
/// un-flipped outputs match because `^ f` is its own inverse.  The
/// `SortedGreedy` sort permutes `pool` in place; tags ride along
/// untouched, and since the sort compares weights only, the permutation
/// is the same one the tag-toggled implementation produced.
pub fn decide_pool(
    pool: &mut [(Load, u8)],
    dest: &mut Vec<u8>,
    base: [f64; 2],
    algo: PairAlgorithm,
    rng: &mut Pcg64,
) -> EdgeDecision {
    dest.clear();
    dest.reserve(pool.len());
    // Random orientation: swap bin labels with probability 1/2.
    let f = u8::from(rng.coin());
    let fi = f as usize;

    if let PairAlgorithm::SortedGreedy(sort) = algo {
        sort.sort_desc_pairs(pool);
    }

    // Logical-bin sums, i.e. sums[b] tracks physical bin b ^ f.
    let mut sums = [base[fi], base[1 - fi]];
    let mut movements = 0usize;
    if algo == PairAlgorithm::GreedyIncremental {
        // Bins start at the status quo; one arrival-order pass relocates
        // a load only when that strictly shrinks the imbalance.
        for &(l, h) in pool.iter() {
            sums[(h ^ f) as usize] += l.weight;
        }
        for &(load, host) in pool.iter() {
            let h = (host ^ f) as usize;
            let o = 1 - h;
            let k = if sums[h] - sums[o] > load.weight {
                sums[h] -= load.weight;
                sums[o] += load.weight;
                movements += 1;
                o
            } else {
                h
            };
            dest.push(k as u8 ^ f);
        }
    } else {
        for &(load, host) in pool.iter() {
            let k = match algo {
                PairAlgorithm::Random => rng.below(2),
                _ => usize::from(sums[1] < sums[0]),
            };
            sums[k] += load.weight;
            if k != (host ^ f) as usize {
                movements += 1;
            }
            dest.push(k as u8 ^ f);
        }
    }

    EdgeDecision {
        movements,
        // |a - b| is orientation-invariant, so the logical sums serve.
        local_discrepancy: (sums[0] - sums[1]).abs(),
    }
}

/// Whether an edge decision provably rewrites both endpoints to exactly
/// their current content, letting the caller skip the write-back.
///
/// True requires: no load changed host, both endpoints already store
/// every pinned load before any mobile one (so the pinned-compaction
/// part of a write-back is the identity — guaranteed from each node's
/// first write-back on), and the algorithm did not permute the pool
/// (`SortedGreedy` re-sorts, so even a zero-movement edge rewrites its
/// mobile loads in a new order there).
pub fn apply_is_noop(algo: PairAlgorithm, movements: usize, partitioned: [bool; 2]) -> bool {
    movements == 0
        && partitioned[0]
        && partitioned[1]
        && !matches!(algo, PairAlgorithm::SortedGreedy(_))
}

impl super::sorting::Keyed for (Load, u8) {
    #[inline]
    fn key(&self) -> f64 {
        self.0.weight
    }
}

impl SortAlgo {
    /// Sort (Load, host) pairs descending by load weight, in place.
    fn sort_desc_pairs(&self, pool: &mut [(Load, u8)]) {
        self.sort_desc(pool);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn loads(ws: &[f64], start_id: u64) -> Vec<Load> {
        ws.iter()
            .enumerate()
            .map(|(i, &w)| Load::new(start_id + i as u64, w))
            .collect()
    }

    fn total(out: &PairOutcome) -> f64 {
        out.to_u.iter().chain(&out.to_v).map(|l| l.weight).sum()
    }

    #[test]
    fn conserves_loads_and_mass() {
        let mut rng = Pcg64::new(1);
        let u = loads(&[5.0, 1.0, 2.0], 0);
        let v = loads(&[9.0, 0.5], 100);
        let out = balance_pair(&u, &v, PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut rng);
        assert_eq!(out.to_u.len() + out.to_v.len(), 5);
        assert!((total(&out) - 17.5).abs() < 1e-12);
        let mut ids: Vec<u64> = out.to_u.iter().chain(&out.to_v).map(|l| l.id).collect();
        ids.sort_unstable();
        assert_eq!(ids, vec![0, 1, 2, 100, 101]);
    }

    #[test]
    fn sorted_greedy_beats_greedy_on_average() {
        let mut rng = Pcg64::new(2);
        let mut d_greedy = 0.0;
        let mut d_sorted = 0.0;
        for rep in 0..200 {
            let mut r2 = Pcg64::new(1000 + rep);
            let u: Vec<Load> = (0..20)
                .map(|i| Load::new(i, r2.uniform(0.0, 1.0)))
                .collect();
            let v: Vec<Load> = (0..20)
                .map(|i| Load::new(100 + i, r2.uniform(0.0, 1.0)))
                .collect();
            d_greedy += balance_pair(&u, &v, PairAlgorithm::Greedy, &mut rng).local_discrepancy;
            d_sorted += balance_pair(
                &u,
                &v,
                PairAlgorithm::SortedGreedy(SortAlgo::Quick),
                &mut rng,
            )
            .local_discrepancy;
        }
        assert!(
            d_sorted < d_greedy / 5.0,
            "sorted {d_sorted} vs greedy {d_greedy}"
        );
    }

    #[test]
    fn pinned_loads_never_move() {
        let mut rng = Pcg64::new(3);
        let u = vec![Load::pinned(0, 100.0), Load::new(1, 1.0)];
        let v = vec![Load::new(2, 1.0)];
        for algo in [
            PairAlgorithm::Greedy,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            PairAlgorithm::Random,
        ] {
            let out = balance_pair(&u, &v, algo, &mut rng);
            // pinned id 0 is not in either output list
            assert!(out.to_u.iter().chain(&out.to_v).all(|l| l.id != 0));
            // but its weight is counted in the discrepancy
            assert!(out.local_discrepancy > 90.0, "{algo:?}");
        }
    }

    #[test]
    fn pinned_base_steers_placement() {
        let mut rng = Pcg64::new(4);
        // u has a heavy pinned load; all mobile weight should flow to v.
        let u = vec![Load::pinned(0, 50.0)];
        let v = vec![Load::new(1, 5.0), Load::new(2, 5.0)];
        let out = balance_pair(&u, &v, PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut rng);
        assert!(out.to_u.is_empty());
        assert_eq!(out.to_v.len(), 2);
        assert_eq!(out.movements, 0); // both stayed on v
    }

    #[test]
    fn movements_counted_against_original_host() {
        let mut rng = Pcg64::new(5);
        // Everything starts on u; roughly half must move to v.
        let u = loads(&[1.0; 10], 0);
        let out = balance_pair(&u, &[], PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut rng);
        assert_eq!(out.movements, 5);
        assert_eq!(out.to_u.len(), 5);
        assert_eq!(out.to_v.len(), 5);
    }

    #[test]
    fn equal_weights_perfectly_split() {
        let mut rng = Pcg64::new(6);
        let u = loads(&[2.0; 8], 0);
        let v = loads(&[2.0; 8], 100);
        let out = balance_pair(&u, &v, PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut rng);
        assert_eq!(out.local_discrepancy, 0.0);
    }

    #[test]
    fn empty_inputs_ok() {
        let mut rng = Pcg64::new(7);
        let out = balance_pair(&[], &[], PairAlgorithm::Greedy, &mut rng);
        assert!(out.to_u.is_empty() && out.to_v.is_empty());
        assert_eq!(out.movements, 0);
        assert_eq!(out.local_discrepancy, 0.0);
    }

    #[test]
    fn orientation_randomization_is_symmetric() {
        // With a single ball and empty bins, the ball should land on u
        // about half the time (E[e] = 0 condition).
        let mut rng = Pcg64::new(8);
        let u = vec![Load::new(0, 1.0)];
        let mut u_wins = 0;
        for _ in 0..2000 {
            let out = balance_pair(&u, &[], PairAlgorithm::SortedGreedy(SortAlgo::Quick), &mut rng);
            if !out.to_u.is_empty() {
                u_wins += 1;
            }
        }
        assert!(
            (800..1200).contains(&u_wins),
            "orientation biased: {u_wins}/2000"
        );
    }

    #[test]
    fn random_baseline_places_everything() {
        let mut rng = Pcg64::new(9);
        let u = loads(&[1.0; 30], 0);
        let out = balance_pair(&u, &[], PairAlgorithm::Random, &mut rng);
        assert_eq!(out.to_u.len() + out.to_v.len(), 30);
    }

    #[test]
    fn balance_pool_consumes_the_stream_exactly_like_balance_pair() {
        // The sharded coordinator rebuilds the pool from Offer messages;
        // the outcome must be bitwise the one balance_pair computes from
        // the full slices with the same RNG stream.
        let u = vec![Load::new(0, 3.0), Load::pinned(1, 2.0), Load::new(2, 1.5)];
        let v = vec![Load::pinned(3, 0.5), Load::new(4, 4.0), Load::new(5, 0.25)];
        for algo in [
            PairAlgorithm::Greedy,
            PairAlgorithm::GreedyIncremental,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            PairAlgorithm::Random,
        ] {
            for seed in 0..10u64 {
                let mut r1 = Pcg64::new(seed);
                let mut r2 = Pcg64::new(seed);
                let a = balance_pair(&u, &v, algo, &mut r1);
                let pool = vec![(u[0], 0u8), (u[2], 0), (v[1], 1), (v[2], 1)];
                let b = balance_pool(pool, [2.0, 0.5], algo, &mut r2);
                assert_eq!(a.to_u, b.to_u, "{algo:?} seed {seed}");
                assert_eq!(a.to_v, b.to_v, "{algo:?} seed {seed}");
                assert_eq!(a.movements, b.movements);
                assert_eq!(a.local_discrepancy, b.local_discrepancy);
                // both consumed the same number of draws
                assert_eq!(r1.next_u64(), r2.next_u64());
            }
        }
    }

    #[test]
    fn parse_roundtrip() {
        for s in ["greedy", "sorted:quick", "sorted:flash", "random"] {
            let a = PairAlgorithm::parse(s).unwrap();
            assert_eq!(PairAlgorithm::parse(&a.name()), Some(a));
        }
        assert_eq!(
            PairAlgorithm::parse("sorted"),
            Some(PairAlgorithm::SortedGreedy(SortAlgo::Quick))
        );
        assert_eq!(PairAlgorithm::parse("zzz"), None);
    }
}
