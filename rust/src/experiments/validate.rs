//! E8: check measured protocol behaviour against the §3 theory bounds.

use crate::balancer::{PairAlgorithm, SortAlgo};
use crate::bcm::{run, Schedule, StopRule};
use crate::graph::{round_matrix, spectral, Topology};
use crate::load::{LoadState, Mobility, WeightDistribution};
use crate::theory;
use crate::util::rng::Pcg64;
use crate::util::table::{f, Table};

/// Result of one theory-vs-measurement check.
#[derive(Clone, Debug)]
pub struct ValidationReport {
    pub n: usize,
    pub d: usize,
    pub lambda: f64,
    pub tau_bound_rounds: f64,
    pub measured_rounds: Option<usize>,
    pub discrete_bound: f64,
    pub measured_final_disc: f64,
    pub l_max: f64,
    /// measured_final_disc <= discrete_bound (the Theorem-1 check)
    pub within_bound: bool,
}

/// Run the SortedGreedy BCM and compare against the theory envelope.
pub fn validate(
    topology: &Topology,
    n: usize,
    loads_per_node: usize,
    seed: u64,
) -> ValidationReport {
    let mut rng = Pcg64::new(seed);
    let g = topology.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let d = schedule.period();
    let m = round_matrix(n, schedule.matchings());
    let lambda = spectral::contraction_factor(&m, 400, seed ^ 0x5eed);

    let mut state = LoadState::init_uniform_counts(
        n,
        loads_per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let k = state.discrepancy();
    let l_max = state.max_load_weight();
    let discrete_bound = theory::discrete_discrepancy_bound(n.max(2), l_max);
    // Number of ROUNDS (matchings) the continuous process needs to reach
    // the bound's epsilon; measured process should reach the discrete
    // bound in the same order of rounds.
    let tau = theory::tau_cont(k.max(1e-9), l_max.max(1e-9), n, d, lambda.min(0.999_999));

    let trace = run(
        &mut state,
        &schedule,
        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        StopRule::sweeps(200),
        &mut rng,
    );
    let measured_rounds = trace.rounds_to_reach(discrete_bound);
    let final_disc = trace.final_discrepancy();

    ValidationReport {
        n,
        d,
        lambda,
        tau_bound_rounds: tau,
        measured_rounds,
        discrete_bound,
        measured_final_disc: final_disc,
        l_max,
        within_bound: final_disc <= discrete_bound,
    }
}

/// Render a batch of validations as a table.
pub fn validation_table(reports: &[ValidationReport]) -> Table {
    let mut t = Table::new(
        "E8: theory bounds vs measured (SortedGreedy, full mobility)",
        &[
            "n",
            "d",
            "lambda",
            "tau_cont(K,lmax)",
            "rounds_to_bound",
            "bound=sqrt(12 ln n)+1 x lmax",
            "final_disc",
            "within",
        ],
    );
    for r in reports {
        t.row(vec![
            r.n.to_string(),
            r.d.to_string(),
            f(r.lambda, 4),
            f(r.tau_bound_rounds, 0),
            r.measured_rounds
                .map(|x| x.to_string())
                .unwrap_or_else(|| "-".into()),
            f(r.discrete_bound, 1),
            f(r.measured_final_disc, 2),
            r.within_bound.to_string(),
        ]);
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_validates_within_bound() {
        let r = validate(&Topology::Ring, 16, 50, 11);
        assert!(r.lambda < 1.0, "ring BCM must be ergodic");
        assert!(r.within_bound, "final {} > bound {}", r.measured_final_disc, r.discrete_bound);
        assert!(r.measured_rounds.is_some());
        // the measured rounds should not exceed the continuous bound's
        // order (tau is conservative)
        let measured = r.measured_rounds.unwrap() as f64;
        assert!(
            measured <= r.tau_bound_rounds.max(1.0) * 4.0,
            "measured {measured} vs tau {}",
            r.tau_bound_rounds
        );
    }

    #[test]
    fn random_graph_validates() {
        let r = validate(&Topology::RandomConnected, 32, 20, 5);
        assert!(r.within_bound);
    }

    #[test]
    fn table_renders() {
        let r = validate(&Topology::Ring, 8, 10, 3);
        let t = validation_table(&[r]);
        assert!(t.render().contains("E8"));
    }
}
