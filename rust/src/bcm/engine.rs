//! The sequential BCM engine — the reference implementation of the
//! paper's §5 DLB protocol.
//!
//! Per round, one color class (a matching) is applied: every matched pair
//! pools its mobile loads and rebalances them with the configured local
//! algorithm.  Edges within a class are vertex-disjoint, so sequential
//! application is observationally identical to the concurrent execution
//! the distributed coordinator performs.
//!
//! Two entry points share the sweep/stop-rule driver:
//!
//! * [`run`] — the historical stream-based API: edges consume one shared
//!   RNG stream in order, so results depend on edge iteration order.
//! * the [`Engine`] trait ([`Sequential`] here, `Parallel` in
//!   `bcm::parallel`) — counter-based: edge `e` of round `t` draws from
//!   `Pcg64::for_edge(seed, t, e)`, making the run a pure function of
//!   `(seed, schedule, state)`.  `Sequential` and `Parallel` are
//!   bit-identical for every thread count.

use super::schedule::Schedule;
use super::trace::{RoundStats, RunTrace};
use crate::balancer::{apply_is_noop, decide_pool, EdgeScratch, PairAlgorithm};
use crate::load::LoadState;
use crate::util::rng::Pcg64;

/// Stop conditions for a protocol run.
#[derive(Clone, Copy, Debug)]
pub struct StopRule {
    /// Hard cap on sweeps (one sweep = all d colors once).
    pub max_sweeps: usize,
    /// Early-exit when the discrepancy improves by less than `rel_tol`
    /// (relatively) over a full sweep.  Disabled when <= 0.
    pub rel_tol: f64,
}

impl Default for StopRule {
    fn default() -> Self {
        Self {
            max_sweeps: 30,
            rel_tol: 1e-4,
        }
    }
}

impl StopRule {
    pub fn sweeps(max_sweeps: usize) -> Self {
        Self {
            max_sweeps,
            rel_tol: 0.0,
        }
    }
}

/// A BCM round executor.
///
/// Implementations differ only in *how* a round's matching is applied
/// (one thread, many threads, a device, ...); the protocol semantics and
/// the randomness are fixed by the counter-based per-edge streams, so any
/// two engines given the same `(state, schedule, algo, stop, seed)` must
/// produce bit-identical traces and final states.
pub trait Engine {
    /// Engine name for tables and logs.
    fn name(&self) -> &'static str;

    /// Run the protocol on `state`, mutating it in place.
    fn run(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        stop: StopRule,
        seed: u64,
    ) -> RunTrace;

    /// Run exactly `rounds` rounds of a *dynamic* workload: before each
    /// round's matching is applied, `churn(state, round)` mutates the
    /// load population (arrivals, departures, cost drift — see
    /// `workload::service_traffic`).
    ///
    /// The trace's `initial_discrepancy` is recorded before any churn,
    /// and each round's stats after that round's matching.  There is no
    /// plateau rule: a churning system never converges, so the round
    /// count is the contract.  The determinism guarantee of [`run`]
    /// carries over unchanged — the churn hook is called at the same
    /// round boundaries by every engine, so engines fed the same hook
    /// stream stay bit-identical.
    fn run_dynamic(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        rounds: usize,
        seed: u64,
        churn: &mut dyn FnMut(&mut LoadState, usize),
    ) -> RunTrace;
}

/// The single-threaded [`Engine`]: edges applied in matching order, each
/// with its own `(seed, round, edge)` stream.
pub struct Sequential;

impl Engine for Sequential {
    fn name(&self) -> &'static str {
        "sequential"
    }

    fn run(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        stop: StopRule,
        seed: u64,
    ) -> RunTrace {
        // One scratch for the whole run: after the first few rounds the
        // pool/dest buffers have grown to the largest edge and the loop
        // stops allocating (tests/alloc_budget.rs).
        let mut scratch = EdgeScratch::new();
        drive(state, schedule, stop, |state, pairs, round| {
            let mut movements = 0usize;
            for (e, &(u, v)) in pairs.iter().enumerate() {
                let mut rng = Pcg64::for_edge(seed, round, e);
                movements +=
                    balance_edge_with(state, u as usize, v as usize, algo, &mut rng, &mut scratch);
            }
            movements
        })
    }

    fn run_dynamic(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        rounds: usize,
        seed: u64,
        churn: &mut dyn FnMut(&mut LoadState, usize),
    ) -> RunTrace {
        let mut scratch = EdgeScratch::new();
        drive_dynamic_with(state, schedule, rounds, 1, churn, |state, pairs, round| {
            let mut movements = 0usize;
            for (e, &(u, v)) in pairs.iter().enumerate() {
                let mut rng = Pcg64::for_edge(seed, round, e);
                movements +=
                    balance_edge_with(state, u as usize, v as usize, algo, &mut rng, &mut scratch);
            }
            movements
        })
    }
}

/// The shared sweep loop: round-robin over the schedule's colors, record
/// per-round stats, stop on `stop.max_sweeps` or the plateau rule.
/// `round_fn(state, pairs, round)` applies one matching and returns the
/// movement count.  Single-threaded metrics reduction; see [`drive_with`].
pub(crate) fn drive(
    state: &mut LoadState,
    schedule: &Schedule,
    stop: StopRule,
    round_fn: impl FnMut(&mut LoadState, &[(u32, u32)], usize) -> usize,
) -> RunTrace {
    drive_with(state, schedule, stop, 1, round_fn)
}

/// [`drive`] with the per-round discrepancy reduction fanned out over up
/// to `reduce_threads` workers (`LoadState::discrepancy_threaded`).
///
/// The reduction was the last single-threaded O(n) term of the round loop
/// (the Amdahl bottleneck once matchings are applied in parallel at
/// n >> 4096).  Because the chunked min/max fold is bit-identical to the
/// scalar one, the resulting `RunTrace` — including plateau-rule stop
/// decisions — is identical for every value of `reduce_threads`.
pub(crate) fn drive_with(
    state: &mut LoadState,
    schedule: &Schedule,
    stop: StopRule,
    reduce_threads: usize,
    mut round_fn: impl FnMut(&mut LoadState, &[(u32, u32)], usize) -> usize,
) -> RunTrace {
    assert_eq!(state.n(), schedule.n(), "state/schedule size mismatch");
    let mut trace = RunTrace {
        initial_discrepancy: state.discrepancy_threaded(reduce_threads),
        rounds: Vec::new(),
    };
    let d = schedule.period();
    let mut round = 0usize;
    let mut last_sweep_disc = trace.initial_discrepancy;
    for _sweep in 0..stop.max_sweeps {
        for color in 0..d {
            let pairs = schedule.matching(round);
            let movements = round_fn(state, pairs, round);
            trace.rounds.push(RoundStats {
                round,
                color,
                discrepancy: state.discrepancy_threaded(reduce_threads),
                movements,
                edges: pairs.len(),
            });
            round += 1;
        }
        // the state is unchanged since the sweep's last round recorded
        // its discrepancy, so reuse it instead of re-reducing O(n)
        let disc = trace
            .rounds
            .last()
            .map_or(trace.initial_discrepancy, |r| r.discrepancy);
        if stop.rel_tol > 0.0 {
            let improved = (last_sweep_disc - disc).max(0.0);
            if improved <= stop.rel_tol * last_sweep_disc.max(1e-300) {
                break;
            }
        }
        last_sweep_disc = disc;
    }
    trace
}

/// The dynamic-workload sibling of [`drive_with`]: run exactly `rounds`
/// rounds (no plateau rule — a churning system never converges), calling
/// `churn(state, round)` before each round's matching is applied.
///
/// `initial_discrepancy` is recorded before any churn so the trace
/// cleanly separates the starting imbalance from what the arrival
/// process injects.  Like [`drive_with`], the per-round discrepancy
/// reduction may fan out over `reduce_threads` workers without changing
/// a single bit of the trace.
pub(crate) fn drive_dynamic_with(
    state: &mut LoadState,
    schedule: &Schedule,
    rounds: usize,
    reduce_threads: usize,
    churn: &mut dyn FnMut(&mut LoadState, usize),
    mut round_fn: impl FnMut(&mut LoadState, &[(u32, u32)], usize) -> usize,
) -> RunTrace {
    assert_eq!(state.n(), schedule.n(), "state/schedule size mismatch");
    let mut trace = RunTrace {
        initial_discrepancy: state.discrepancy_threaded(reduce_threads),
        rounds: Vec::new(),
    };
    let d = schedule.period();
    for round in 0..rounds {
        churn(state, round);
        let pairs = schedule.matching(round);
        let movements = round_fn(state, pairs, round);
        trace.rounds.push(RoundStats {
            round,
            color: round % d,
            discrepancy: state.discrepancy_threaded(reduce_threads),
            movements,
            edges: pairs.len(),
        });
    }
    trace
}

/// Run the BCM protocol on `state`, mutating it in place.
///
/// This is the historical stream-based API (one shared RNG consumed in
/// edge order); prefer the [`Engine`] implementations for runs that must
/// be reproducible independent of execution order.
pub fn run(
    state: &mut LoadState,
    schedule: &Schedule,
    algo: PairAlgorithm,
    stop: StopRule,
    rng: &mut Pcg64,
) -> RunTrace {
    let mut scratch = EdgeScratch::new();
    drive(state, schedule, stop, |state, pairs, _round| {
        let mut movements = 0usize;
        for &(u, v) in pairs {
            movements += balance_edge_with(state, u as usize, v as usize, algo, rng, &mut scratch);
        }
        movements
    })
}

/// Rebalance one matched edge in place; returns the movement count.
///
/// Convenience wrapper over [`balance_edge_with`] that pays a fresh
/// [`EdgeScratch`] per call — fine for one-off edges and tests; round
/// loops should hold a scratch and call [`balance_edge_with`].
pub fn balance_edge(
    state: &mut LoadState,
    u: usize,
    v: usize,
    algo: PairAlgorithm,
    rng: &mut Pcg64,
) -> usize {
    let mut scratch = EdgeScratch::new();
    balance_edge_with(state, u, v, algo, rng, &mut scratch)
}

/// Rebalance one matched edge through a caller-owned [`EdgeScratch`] —
/// the zero-allocation hot path (DESIGN.md §9).
///
/// Gathers both endpoints' mobile loads into the scratch pool, decides
/// a destination per load (`decide_pool` — bitwise the historical
/// `balance_pair` placement and RNG stream), and writes the result
/// back in place.  When the decision provably changes nothing
/// (`apply_is_noop`) the write-back is skipped entirely, so a
/// no-movement `GreedyIncremental` edge touches no state at all.
pub fn balance_edge_with(
    state: &mut LoadState,
    u: usize,
    v: usize,
    algo: PairAlgorithm,
    rng: &mut Pcg64,
    scratch: &mut EdgeScratch,
) -> usize {
    let gather = state.gather_edge(u, v, &mut scratch.pool);
    let decision = decide_pool(&mut scratch.pool, &mut scratch.dest, gather.base, algo, rng);
    if !apply_is_noop(algo, decision.movements, gather.partitioned) {
        state.apply_edge(u, v, &scratch.pool, &scratch.dest);
    }
    decision.movements
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::SortAlgo;
    use crate::graph::Graph;
    use crate::load::{Load, Mobility, WeightDistribution};

    fn setup(
        n: usize,
        per_node: usize,
        mobility: Mobility,
        seed: u64,
    ) -> (LoadState, Schedule, Pcg64) {
        let mut rng = Pcg64::new(seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            n,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        );
        (state, schedule, rng)
    }

    #[test]
    fn sequential_engine_is_a_pure_function_of_seed() {
        let (state0, schedule, _) = setup(12, 20, Mobility::Partial, 8);
        let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
        let mut s1 = state0.clone();
        let t1 = Sequential.run(&mut s1, &schedule, algo, StopRule::sweeps(4), 99);
        let mut s2 = state0.clone();
        let t2 = Sequential.run(&mut s2, &schedule, algo, StopRule::sweeps(4), 99);
        assert_eq!(t1, t2);
        assert_eq!(s1, s2);
        // a different seed takes a different trajectory
        let mut s3 = state0.clone();
        let t3 = Sequential.run(&mut s3, &schedule, algo, StopRule::sweeps(4), 100);
        assert_ne!(t1, t3);
    }

    #[test]
    fn sequential_engine_converges_and_conserves() {
        let (mut state, schedule, _) = setup(16, 50, Mobility::Full, 9);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init = state.discrepancy();
        let trace = Sequential.run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(10),
            1,
        );
        assert!(trace.final_discrepancy() < init / 20.0);
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6);
        assert_eq!(Sequential.name(), "sequential");
    }

    #[test]
    fn discrepancy_drops_sorted_greedy() {
        let (mut state, schedule, mut rng) = setup(16, 50, Mobility::Full, 1);
        let initial = state.discrepancy();
        let trace = run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(10),
            &mut rng,
        );
        assert_eq!(trace.initial_discrepancy, initial);
        assert!(
            trace.final_discrepancy() < initial / 20.0,
            "init {initial} final {}",
            trace.final_discrepancy()
        );
    }

    #[test]
    fn greedy_also_improves_but_less() {
        let (mut s1, sched, mut rng) = setup(16, 50, Mobility::Full, 2);
        let mut s2 = s1.clone();
        let t_sorted = run(
            &mut s1,
            &sched,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(8),
            &mut rng,
        );
        let t_greedy = run(
            &mut s2,
            &sched,
            PairAlgorithm::Greedy,
            StopRule::sweeps(8),
            &mut rng,
        );
        assert!(t_greedy.final_discrepancy() < t_greedy.initial_discrepancy);
        assert!(t_sorted.final_discrepancy() < t_greedy.final_discrepancy());
    }

    #[test]
    fn conservation_of_loads_and_mass() {
        let (mut state, schedule, mut rng) = setup(12, 20, Mobility::Partial, 3);
        let ids_before = state.all_ids();
        let mass_before = state.total_weight();
        run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(5),
            &mut rng,
        );
        assert_eq!(state.all_ids(), ids_before);
        assert!((state.total_weight() - mass_before).abs() < 1e-6);
    }

    #[test]
    fn pinned_loads_stay_home() {
        let mut rng = Pcg64::new(4);
        let g = Graph::ring(4);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::empty(4);
        state.push(0, Load::pinned(0, 100.0));
        state.push(0, Load::new(1, 1.0));
        state.push(2, Load::new(2, 1.0));
        run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(5),
            &mut rng,
        );
        assert!(state.node(0).iter().any(|l| l.id == 0), "pinned load moved");
    }

    #[test]
    fn partial_mobility_cannot_beat_pinned_imbalance() {
        // All weight pinned on node 0: discrepancy cannot drop below the
        // pinned imbalance no matter how long we run.
        let mut rng = Pcg64::new(5);
        let g = Graph::ring(4);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::empty(4);
        state.push(0, Load::pinned(0, 50.0));
        for i in 0..8 {
            state.push((i % 4) as usize, Load::new(1 + i, 1.0));
        }
        let trace = run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(20),
            &mut rng,
        );
        assert!(trace.final_discrepancy() >= 50.0 - 8.0);
    }

    #[test]
    fn early_stop_on_plateau() {
        let (mut state, schedule, mut rng) = setup(8, 10, Mobility::Full, 6);
        let trace = run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule {
                max_sweeps: 1000,
                rel_tol: 1e-3,
            },
            &mut rng,
        );
        // plateau detection must kick in long before 1000 sweeps
        assert!(trace.rounds.len() < 200 * schedule.period());
    }

    #[test]
    fn max_never_increases_min_never_decreases_network_extremes() {
        // Paper §3 condition 1 at the network level: the heaviest node
        // can only lose weight, the lightest only gain (per round).
        let (mut state, schedule, mut rng) = setup(10, 30, Mobility::Full, 7);
        let mut prev_max = state.load_vector().iter().cloned().fold(f64::MIN, f64::max);
        let mut prev_min = state.load_vector().iter().cloned().fold(f64::MAX, f64::min);
        for round in 0..20 {
            let pairs = schedule.matching(round).to_vec();
            for &(u, v) in &pairs {
                balance_edge(
                    &mut state,
                    u as usize,
                    v as usize,
                    PairAlgorithm::SortedGreedy(SortAlgo::Quick),
                    &mut rng,
                );
            }
            let x = state.load_vector();
            let max = x.iter().cloned().fold(f64::MIN, f64::max);
            let min = x.iter().cloned().fold(f64::MAX, f64::min);
            // Local balancing can overshoot by at most the largest single
            // load; the monotone statement holds up to that quantum.
            let lmax = state.max_load_weight();
            assert!(max <= prev_max + lmax + 1e-9);
            assert!(min >= prev_min - lmax - 1e-9);
            prev_max = max;
            prev_min = min;
        }
    }
}
