//! E6 — regenerates the §11.3 timing table: Greedy vs SortedGreedy wall
//! time on the two-bin problem with m = 2^13 balls, 100 repetitions.
//!
//! The paper's claim: sorting adds ~0.02% overhead (MATLAB quicksort).
//! We report every sorting backend (quick / merge / flash / std) so the
//! distribution-sort discussion of §4.1 is covered too.

use bcm_dlb::experiments::figures;
use std::path::Path;

fn main() {
    let start = std::time::Instant::now();
    println!("{}", figures::timings(100, 2013, Path::new("results")).render());
    eprintln!("timings completed in {:.1}s", start.elapsed().as_secs_f64());
}
