//! E1 — regenerates paper Fig. 1 (a)–(i): final discrepancy vs network
//! size for SortedGreedy/Greedy × full/partial mobility, L/n ∈ {10,50,100}.
//!
//! `BCM_DLB_QUICK=1 cargo bench --bench fig1_discrepancy` derates the
//! sweep for CI.  CSVs land in results/.

use bcm_dlb::experiments::{figures, SweepParams};
use std::path::Path;

fn main() {
    let params = SweepParams::from_env();
    eprintln!(
        "fig1: n in {:?}, L/n in {:?}, {} reps, {} sweeps",
        params.network_sizes, params.loads_per_node, params.reps, params.sweeps
    );
    let start = std::time::Instant::now();
    for t in figures::fig1(&params, Path::new("results")) {
        println!("{}", t.render());
    }
    eprintln!("fig1 completed in {:.1}s", start.elapsed().as_secs_f64());
}
