//! A dependency-free readiness poller: many nonblocking sockets, one
//! thread, zero helper threads.
//!
//! The TCP transport originally paired every socket with a detached
//! reader thread draining into an mpsc queue.  That bought "sends never
//! block" and "reads are always drained" at the cost of `O(sockets)`
//! threads per endpoint that nobody ever joined.  This module provides
//! the same two guarantees from a single loop:
//!
//! * every registered connection is **nonblocking**; a poll pass reads
//!   whatever bytes are available and reassembles them incrementally —
//!   wire frames via [`codec::decode_frame`] (whose `Truncated` result
//!   is exactly the "wait for more bytes" signal) or newline-delimited
//!   text lines for the JSON service;
//! * [`Poller::send`] appends to a per-connection write buffer and
//!   flushes opportunistically; leftover bytes are retried on **every**
//!   subsequent poll pass, so a send never wedges behind a slow reader —
//!   the write buffer plays the role the unbounded mpsc queue used to.
//!
//! There is no epoll/kqueue underneath (the crate vendors nothing and
//! calls no libc): a poll pass sweeps all registered sockets and the
//! loop sleeps ~1 ms between empty sweeps, the same polling discipline
//! `accept_with_deadline` has used since the first TCP backend.  For a
//! coordinator exchanging batched protocol frames this costs microseconds
//! per pass and keeps the implementation auditable.
//!
//! The poller is deliberately policy-free: it turns socket readiness
//! into [`Event`]s and leaves routing (is this frame a `Ctl` or a
//! `ShardMsg`? is this connection the leader or a peer?) to the caller.

use super::codec::{self, CodecError, WireMsg};
use std::collections::VecDeque;
use std::io::{self, ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::{Duration, Instant};

/// Upper bound on one text line (the JSON service's job specs); a peer
/// streaming an unterminated line must not grow the buffer unboundedly.
/// Mirrors the codec's `MAX_PAYLOAD` hostile-length rejection.
pub const MAX_LINE: usize = 1 << 20;

/// Sleep between empty poll passes.
const PASS_NAP: Duration = Duration::from_millis(1);

/// Read chunk size per pass.
const READ_CHUNK: usize = 64 * 1024;

/// How a connection's inbound bytes are reassembled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Mode {
    /// Length-prefixed wire frames ([`codec`]).
    Frames,
    /// Newline-delimited UTF-8 lines (the JSON service protocol).
    Lines,
}

/// Something that happened on a registered socket.
#[derive(Debug)]
pub enum Event {
    /// A listener accepted a new connection; register it with
    /// [`Poller::add_frame_conn`]/[`Poller::add_line_conn`] to read it.
    Accepted {
        /// Token of the listener that accepted.
        listener: usize,
        /// The accepted stream (blocking; registering it flips it).
        stream: TcpStream,
    },
    /// A complete wire frame arrived on a frame-mode connection.
    Frame {
        /// Token of the connection.
        token: usize,
        /// The decoded message.
        msg: WireMsg,
    },
    /// A complete line arrived on a line-mode connection (terminator
    /// stripped, trailing `\r` trimmed).
    Line {
        /// Token of the connection.
        token: usize,
        /// The line's text.
        line: String,
    },
    /// The connection is gone: EOF, an I/O error, or a protocol defect
    /// (bad frame, oversized or non-UTF-8 line).  Emitted at most once
    /// per connection and never after [`Poller::set_done`].
    Closed {
        /// Token of the connection.
        token: usize,
        /// Human-readable description of what happened.
        reason: String,
    },
}

struct Conn {
    stream: TcpStream,
    mode: Mode,
    rx: Vec<u8>,
    tx: Vec<u8>,
    /// Socket is dead (EOF / error seen, or a decode defect); no further
    /// I/O is attempted and sends fail fast.
    closed: bool,
    /// Caller saw this connection's terminal message: suppress any
    /// further read events (a clean shutdown must not surface the
    /// subsequent EOF as an error).  Writes still work.
    done: bool,
    /// `Closed` was already emitted (or suppressed); never emit twice.
    reported: bool,
}

enum Slot {
    Vacant,
    Listener(TcpListener),
    Conn(Box<Conn>),
}

/// A set of nonblocking sockets polled from one thread.
///
/// Tokens returned by the `add_*` methods are stable for the lifetime of
/// the slot and are never reused after [`remove`](Poller::remove).
pub struct Poller {
    slots: Vec<Slot>,
}

impl Default for Poller {
    fn default() -> Self {
        Poller::new()
    }
}

impl Poller {
    /// An empty poller.
    pub fn new() -> Poller {
        Poller { slots: Vec::new() }
    }

    fn push(&mut self, slot: Slot) -> usize {
        self.slots.push(slot);
        self.slots.len() - 1
    }

    /// Register a listener; accepted streams surface as
    /// [`Event::Accepted`].
    pub fn add_listener(&mut self, listener: TcpListener) -> io::Result<usize> {
        listener.set_nonblocking(true)?;
        Ok(self.push(Slot::Listener(listener)))
    }

    /// Register a stream carrying wire frames.
    pub fn add_frame_conn(&mut self, stream: TcpStream) -> io::Result<usize> {
        self.add_conn(stream, Mode::Frames)
    }

    /// Register a stream carrying newline-delimited text.
    pub fn add_line_conn(&mut self, stream: TcpStream) -> io::Result<usize> {
        self.add_conn(stream, Mode::Lines)
    }

    fn add_conn(&mut self, stream: TcpStream, mode: Mode) -> io::Result<usize> {
        stream.set_nonblocking(true)?;
        let _ = stream.set_nodelay(true);
        Ok(self.push(Slot::Conn(Box::new(Conn {
            stream,
            mode,
            rx: Vec::new(),
            tx: Vec::new(),
            closed: false,
            done: false,
            reported: false,
        }))))
    }

    /// Drop a slot; its token is retired (never reused).
    pub fn remove(&mut self, token: usize) {
        if token < self.slots.len() {
            self.slots[token] = Slot::Vacant;
        }
    }

    /// Mark a connection as terminally handled: no further read events
    /// (including the eventual EOF) will be emitted for it.  Sends still
    /// work — a worker acknowledges `Shutdown` on the very connection it
    /// just marked done.
    pub fn set_done(&mut self, token: usize) {
        if let Some(Slot::Conn(c)) = self.slots.get_mut(token) {
            c.done = true;
        }
    }

    /// Whether the connection's socket is known dead.
    pub fn is_closed(&self, token: usize) -> bool {
        match self.slots.get(token) {
            Some(Slot::Conn(c)) => c.closed,
            _ => true,
        }
    }

    /// Bytes queued but not yet flushed on a connection.
    pub fn pending_tx(&self, token: usize) -> usize {
        match self.slots.get(token) {
            Some(Slot::Conn(c)) => c.tx.len(),
            _ => 0,
        }
    }

    /// Queue a wire frame on a connection and flush as much as the
    /// socket will take without blocking.  Returns an error if the
    /// connection is gone; bytes accepted into the buffer are
    /// guaranteed to be (re)tried on every later poll pass.
    pub fn send(&mut self, token: usize, msg: &WireMsg) -> io::Result<()> {
        let frame = codec::encode_frame(msg);
        self.send_bytes(token, &frame)
    }

    /// Queue one text line (`line` + `\n`) on a line-mode connection.
    pub fn send_line(&mut self, token: usize, line: &str) -> io::Result<()> {
        let mut bytes = Vec::with_capacity(line.len() + 1);
        bytes.extend_from_slice(line.as_bytes());
        bytes.push(b'\n');
        self.send_bytes(token, &bytes)
    }

    /// Queue raw bytes on a connection (the JSON service's streaming
    /// emitter writes through this).
    pub fn send_bytes(&mut self, token: usize, bytes: &[u8]) -> io::Result<()> {
        let conn = match self.slots.get_mut(token) {
            Some(Slot::Conn(c)) => c,
            _ => {
                return Err(io::Error::new(
                    ErrorKind::NotConnected,
                    "no such connection",
                ))
            }
        };
        if conn.closed {
            return Err(io::Error::new(ErrorKind::BrokenPipe, "connection closed"));
        }
        conn.tx.extend_from_slice(bytes);
        match flush_tx(conn) {
            Ok(()) => Ok(()),
            Err(e) => {
                conn.closed = true;
                Err(e)
            }
        }
    }

    /// Run poll passes until at least one event is produced or `wait`
    /// elapses; events are appended to `events` and their count
    /// returned.  `Duration::ZERO` runs exactly one pass.
    pub fn poll(&mut self, wait: Duration, events: &mut VecDeque<Event>) -> usize {
        let deadline = Instant::now() + wait;
        let before = events.len();
        loop {
            self.pass(events);
            if events.len() > before || Instant::now() >= deadline {
                return events.len() - before;
            }
            std::thread::sleep(PASS_NAP.min(wait));
        }
    }

    /// One nonblocking sweep over every slot.
    fn pass(&mut self, events: &mut VecDeque<Event>) {
        for token in 0..self.slots.len() {
            match &mut self.slots[token] {
                Slot::Vacant => {}
                Slot::Listener(l) => loop {
                    match l.accept() {
                        Ok((stream, _)) => events.push_back(Event::Accepted {
                            listener: token,
                            stream,
                        }),
                        Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                        Err(e) if e.kind() == ErrorKind::Interrupted => continue,
                        // transient accept failures (aborted handshake):
                        // skip this pass rather than kill the listener
                        Err(_) => break,
                    }
                },
                Slot::Conn(conn) => {
                    if conn.closed {
                        continue;
                    }
                    // retry buffered writes first: this is what keeps
                    // "sends never block indefinitely" true under
                    // bidirectional pressure
                    if let Err(e) = flush_tx(conn) {
                        close(conn, token, format!("write failed: {e}"), events);
                        continue;
                    }
                    match read_some(conn) {
                        ReadOutcome::Bytes(true) => decode(conn, token, events),
                        ReadOutcome::Bytes(false) => {}
                        ReadOutcome::Eof => {
                            // deliver frames already buffered ahead of
                            // the EOF before reporting the close
                            decode(conn, token, events);
                            if !conn.closed {
                                let reason = if conn.rx.is_empty() {
                                    "connection closed".to_string()
                                } else {
                                    "connection closed mid-frame".to_string()
                                };
                                close(conn, token, reason, events);
                            }
                        }
                        ReadOutcome::Err(e) => {
                            close(conn, token, format!("read failed: {e}"), events)
                        }
                    }
                }
            }
        }
    }
}

enum ReadOutcome {
    /// Read returned; the flag says whether any new bytes arrived.
    Bytes(bool),
    Eof,
    Err(io::Error),
}

fn read_some(conn: &mut Conn) -> ReadOutcome {
    let mut any = false;
    let mut buf = [0u8; READ_CHUNK];
    loop {
        match conn.stream.read(&mut buf) {
            Ok(0) => return ReadOutcome::Eof,
            Ok(n) => {
                conn.rx.extend_from_slice(&buf[..n]);
                any = true;
                if n < buf.len() {
                    return ReadOutcome::Bytes(any);
                }
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return ReadOutcome::Bytes(any),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return ReadOutcome::Err(e),
        }
    }
}

fn flush_tx(conn: &mut Conn) -> io::Result<()> {
    while !conn.tx.is_empty() {
        match conn.stream.write(&conn.tx) {
            Ok(0) => return Err(io::Error::new(ErrorKind::WriteZero, "socket wrote 0 bytes")),
            Ok(n) => {
                conn.tx.drain(..n);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) => return Err(e),
        }
    }
    Ok(())
}

/// Mark the connection dead and emit `Closed` unless the caller already
/// marked it done (clean-shutdown EOFs stay silent).
fn close(conn: &mut Conn, token: usize, reason: String, events: &mut VecDeque<Event>) {
    conn.closed = true;
    if !conn.done && !conn.reported {
        conn.reported = true;
        events.push_back(Event::Closed { token, reason });
    }
}

/// Reassemble whatever complete frames/lines sit in the rx buffer.
fn decode(conn: &mut Conn, token: usize, events: &mut VecDeque<Event>) {
    match conn.mode {
        Mode::Frames => decode_frames(conn, token, events),
        Mode::Lines => decode_lines(conn, token, events),
    }
}

fn decode_frames(conn: &mut Conn, token: usize, events: &mut VecDeque<Event>) {
    let mut off = 0;
    while !conn.closed {
        match codec::decode_frame(&conn.rx[off..]) {
            Ok((msg, used)) => {
                off += used;
                if !conn.done {
                    events.push_back(Event::Frame { token, msg });
                }
            }
            Err(CodecError::Truncated) => break,
            Err(e) => {
                conn.rx.drain(..off);
                close(conn, token, e.to_string(), events);
                return;
            }
        }
    }
    conn.rx.drain(..off);
}

fn decode_lines(conn: &mut Conn, token: usize, events: &mut VecDeque<Event>) {
    let mut off = 0;
    while !conn.closed {
        match conn.rx[off..].iter().position(|&b| b == b'\n') {
            Some(nl) => {
                let raw = &conn.rx[off..off + nl];
                let raw = raw.strip_suffix(b"\r").unwrap_or(raw);
                match std::str::from_utf8(raw) {
                    Ok(s) => {
                        if !conn.done {
                            let line = s.to_string();
                            events.push_back(Event::Line { token, line });
                        }
                        off += nl + 1;
                    }
                    Err(_) => {
                        conn.rx.drain(..off);
                        close(conn, token, "non-utf8 line".to_string(), events);
                        return;
                    }
                }
            }
            None => {
                if conn.rx.len() - off > MAX_LINE {
                    conn.rx.drain(..off);
                    close(
                        conn,
                        token,
                        format!("line exceeds {MAX_LINE} bytes"),
                        events,
                    );
                    return;
                }
                break;
            }
        }
    }
    conn.rx.drain(..off);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::messages::Ctl;
    use std::net::TcpListener;

    /// A connected loopback socket pair.
    fn sock_pair() -> (TcpStream, TcpStream) {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server, _) = l.accept().unwrap();
        (client, server)
    }

    fn drain(poller: &mut Poller, wait_ms: u64) -> Vec<Event> {
        let mut q = VecDeque::new();
        poller.poll(Duration::from_millis(wait_ms), &mut q);
        q.into_iter().collect()
    }

    #[test]
    fn frames_reassemble_across_split_writes() {
        let (mut client, server) = sock_pair();
        let mut poller = Poller::new();
        let tok = poller.add_frame_conn(server).unwrap();

        let frame = codec::encode_frame(&WireMsg::Ctl(Ctl::PollWeights { job: 7 }));
        let cut = frame.len() / 2;
        client.write_all(&frame[..cut]).unwrap();
        client.flush().unwrap();
        // a partial frame must produce nothing, not an error
        assert!(drain(&mut poller, 30).is_empty());

        client.write_all(&frame[cut..]).unwrap();
        client.flush().unwrap();
        let events = drain(&mut poller, 1000);
        match &events[..] {
            [Event::Frame { token, msg }] => {
                assert_eq!(*token, tok);
                assert_eq!(*msg, WireMsg::Ctl(Ctl::PollWeights { job: 7 }));
            }
            other => panic!("expected one frame, got {other:?}"),
        }
    }

    #[test]
    fn back_to_back_frames_arrive_in_order() {
        let (mut client, server) = sock_pair();
        let mut poller = Poller::new();
        poller.add_frame_conn(server).unwrap();
        let mut wire = Vec::new();
        for job in 0..5u32 {
            wire.extend_from_slice(&codec::encode_frame(&WireMsg::Ctl(Ctl::CloseJob { job })));
        }
        client.write_all(&wire).unwrap();
        let mut got = Vec::new();
        let deadline = Instant::now() + Duration::from_secs(5);
        while got.len() < 5 && Instant::now() < deadline {
            for ev in drain(&mut poller, 100) {
                match ev {
                    Event::Frame { msg, .. } => got.push(msg),
                    other => panic!("unexpected event {other:?}"),
                }
            }
        }
        let want: Vec<WireMsg> = (0..5u32).map(|job| WireMsg::Ctl(Ctl::CloseJob { job })).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn eof_mid_frame_reports_a_dirty_close() {
        let (mut client, server) = sock_pair();
        let mut poller = Poller::new();
        let tok = poller.add_frame_conn(server).unwrap();
        let frame = codec::encode_frame(&WireMsg::Ctl(Ctl::Shutdown));
        client.write_all(&frame[..frame.len() - 1]).unwrap();
        drop(client);
        let events = drain(&mut poller, 2000);
        match &events[..] {
            [Event::Closed { token, reason }] => {
                assert_eq!(*token, tok);
                assert!(reason.contains("mid-frame"), "reason: {reason}");
            }
            other => panic!("expected a dirty close, got {other:?}"),
        }
        assert!(poller.is_closed(tok));
    }

    #[test]
    fn done_connections_swallow_the_eof() {
        let (client, server) = sock_pair();
        let mut poller = Poller::new();
        let tok = poller.add_frame_conn(server).unwrap();
        poller.set_done(tok);
        drop(client);
        assert!(drain(&mut poller, 50).is_empty(), "done conn surfaced events");
    }

    #[test]
    fn lines_split_and_reassemble() {
        let (mut client, server) = sock_pair();
        let mut poller = Poller::new();
        let tok = poller.add_line_conn(server).unwrap();
        client.write_all(b"hello\r\nwor").unwrap();
        client.flush().unwrap();
        let events = drain(&mut poller, 1000);
        match &events[..] {
            [Event::Line { token, line }] => {
                assert_eq!((*token, line.as_str()), (tok, "hello"));
            }
            other => panic!("expected one line, got {other:?}"),
        }
        client.write_all(b"ld\n").unwrap();
        client.flush().unwrap();
        let events = drain(&mut poller, 1000);
        match &events[..] {
            [Event::Line { line, .. }] => assert_eq!(line, "world"),
            other => panic!("expected the second line, got {other:?}"),
        }
    }

    #[test]
    fn overlong_line_closes_the_connection() {
        let (mut client, server) = sock_pair();
        let mut poller = Poller::new();
        let tok = poller.add_line_conn(server).unwrap();
        // stream > MAX_LINE bytes with no terminator, in chunks so the
        // client never outruns its own socket buffer
        let chunk = vec![b'x'; 64 * 1024];
        let mut sent = 0;
        let mut closed = None;
        'outer: while sent <= MAX_LINE + chunk.len() {
            if client.write_all(&chunk).is_err() {
                break; // poller already hung up on us
            }
            sent += chunk.len();
            for ev in drain(&mut poller, 10) {
                if let Event::Closed { token, reason } = ev {
                    closed = Some((token, reason));
                    break 'outer;
                }
            }
        }
        // one more poll in case the close races the last write
        if closed.is_none() {
            for ev in drain(&mut poller, 2000) {
                if let Event::Closed { token, reason } = ev {
                    closed = Some((token, reason));
                }
            }
        }
        let (token, reason) = closed.expect("oversized line never closed");
        assert_eq!(token, tok);
        assert!(reason.contains("exceeds"), "reason: {reason}");
    }

    #[test]
    fn listener_accepts_surface_as_events() {
        let l = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = l.local_addr().unwrap();
        let mut poller = Poller::new();
        let ltok = poller.add_listener(l).unwrap();
        let _client = TcpStream::connect(addr).unwrap();
        let events = drain(&mut poller, 2000);
        match &events[..] {
            [Event::Accepted { listener, .. }] => assert_eq!(*listener, ltok),
            other => panic!("expected an accept, got {other:?}"),
        }
    }

    #[test]
    fn buffered_sends_flush_on_later_passes() {
        let (client, server) = sock_pair();
        let mut poller = Poller::new();
        let tok = poller.add_frame_conn(server).unwrap();
        // fill until the kernel buffer pushes back and bytes start
        // queueing in the poller
        let msg = WireMsg::Hello {
            peer_addr: "x".repeat(4096),
            rejoin: None,
        };
        let mut queued = 0;
        for _ in 0..4096 {
            poller.send(tok, &msg).unwrap();
            queued = poller.pending_tx(tok);
            if queued > 0 {
                break;
            }
        }
        assert!(queued > 0, "kernel swallowed 4096 jumbo frames without backpressure");
        // drain the peer side; poll passes must retire the backlog
        let mut reader = client;
        reader.set_nonblocking(true).unwrap();
        let mut sink = [0u8; 64 * 1024];
        let deadline = Instant::now() + Duration::from_secs(10);
        while poller.pending_tx(tok) > 0 && Instant::now() < deadline {
            loop {
                match reader.read(&mut sink) {
                    Ok(0) => panic!("writer hung up"),
                    Ok(_) => {}
                    Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                    Err(e) => panic!("reader failed: {e}"),
                }
            }
            let mut q = VecDeque::new();
            poller.poll(Duration::ZERO, &mut q);
            assert!(q.is_empty());
        }
        assert_eq!(poller.pending_tx(tok), 0, "write backlog never drained");
    }
}
