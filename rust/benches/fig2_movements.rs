//! E2 — regenerates paper Fig. 2: the ratio of average load movements per
//! edge (alpha_SortedGreedy / alpha_Greedy) for full and partial mobility.

use bcm_dlb::experiments::{figures, SweepParams};
use std::path::Path;

fn main() {
    let params = SweepParams::from_env();
    let start = std::time::Instant::now();
    for t in figures::fig2(&params, Path::new("results")) {
        println!("{}", t.render());
    }
    eprintln!("fig2 completed in {:.1}s", start.elapsed().as_secs_f64());
}
