//! Approximate minimum edge coloring -> BCM matching schedule.
//!
//! The BCM applies a pre-determined sequence of d matchings covering every
//! edge at least once (paper §2.1, §5).  An edge coloring partitions E into
//! matchings (color classes); the paper assumes an "(in practice
//! approximate) minimum edge-coloring algorithm" computed before the DLB
//! protocol runs.
//!
//! We implement the standard greedy edge coloring: process edges in order
//! and give each the smallest color unused at both endpoints.  This uses at
//! most 2Δ−1 colors (Vizing guarantees Δ+1 exists; greedy is the
//! "approximate" algorithm the paper refers to).  A `recolor` pass then
//! tries to empty small color classes by moving their edges into earlier
//! classes, which in practice lands close to Δ+1.

use super::topology::Graph;

/// A proper edge coloring: `classes[c]` is a matching (disjoint edges).
#[derive(Clone, Debug)]
pub struct EdgeColoring {
    classes: Vec<Vec<(u32, u32)>>,
}

impl EdgeColoring {
    /// Greedy coloring with a compaction pass.
    pub fn greedy(g: &Graph) -> Self {
        let n = g.n();
        // used[v] is a bitmask over colors < 64, spilled into a Vec<bool>
        // per vertex for high-degree graphs.
        let max_colors = 2 * g.max_degree().max(1);
        let mut used = vec![vec![false; max_colors]; n];
        let mut classes: Vec<Vec<(u32, u32)>> = Vec::new();

        for &(u, v) in g.edges() {
            let (iu, iv) = (u as usize, v as usize);
            let c = (0..max_colors)
                .find(|&c| !used[iu][c] && !used[iv][c])
                .expect("2*maxdeg colors always suffice for greedy");
            used[iu][c] = true;
            used[iv][c] = true;
            if c == classes.len() {
                classes.push(Vec::new());
            }
            while classes.len() <= c {
                classes.push(Vec::new());
            }
            classes[c].push((u, v));
        }

        let mut coloring = Self { classes };
        coloring.compact(n);
        coloring
    }

    /// Try to move edges out of the smallest classes into earlier classes;
    /// drop classes that become empty.
    fn compact(&mut self, n: usize) {
        loop {
            // occupancy[c][v] = vertex v is matched in class c
            let k = self.classes.len();
            if k <= 1 {
                break;
            }
            let mut occupancy = vec![vec![false; n]; k];
            for (c, class) in self.classes.iter().enumerate() {
                for &(u, v) in class {
                    occupancy[c][u as usize] = true;
                    occupancy[c][v as usize] = true;
                }
            }
            // smallest class index
            let (last, _) = self
                .classes
                .iter()
                .enumerate()
                .min_by_key(|(_, c)| c.len())
                .unwrap();
            let edges = self.classes[last].clone();
            let mut moved_all = true;
            let mut moves: Vec<(usize, (u32, u32))> = Vec::new();
            let mut occ_copy = occupancy.clone();
            for &(u, v) in &edges {
                let mut placed = false;
                for c in 0..k {
                    if c == last {
                        continue;
                    }
                    if !occ_copy[c][u as usize] && !occ_copy[c][v as usize] {
                        occ_copy[c][u as usize] = true;
                        occ_copy[c][v as usize] = true;
                        moves.push((c, (u, v)));
                        placed = true;
                        break;
                    }
                }
                if !placed {
                    moved_all = false;
                    break;
                }
            }
            if !moved_all {
                break;
            }
            for (c, e) in moves {
                self.classes[c].push(e);
            }
            self.classes.remove(last);
        }
        for class in &mut self.classes {
            class.sort_unstable();
        }
    }

    pub fn num_colors(&self) -> usize {
        self.classes.len()
    }

    pub fn classes(&self) -> &[Vec<(u32, u32)>] {
        &self.classes
    }

    /// Validity: every class is a matching, every edge appears exactly once.
    pub fn validate(&self, g: &Graph) -> Result<(), String> {
        let mut all: Vec<(u32, u32)> = Vec::new();
        for (c, class) in self.classes.iter().enumerate() {
            let mut seen = vec![false; g.n()];
            for &(u, v) in class {
                if u >= v {
                    return Err(format!("class {c}: non-canonical edge ({u},{v})"));
                }
                if seen[u as usize] || seen[v as usize] {
                    return Err(format!("class {c}: vertex reused by ({u},{v})"));
                }
                seen[u as usize] = true;
                seen[v as usize] = true;
                all.push((u, v));
            }
        }
        all.sort_unstable();
        let mut expected = g.edges().to_vec();
        expected.sort_unstable();
        if all != expected {
            return Err("colored edge set != graph edge set".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn ring_even_two_colors() {
        let g = Graph::ring(8);
        let c = EdgeColoring::greedy(&g);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 2);
    }

    #[test]
    fn ring_odd_three_colors() {
        let g = Graph::ring(7);
        let c = EdgeColoring::greedy(&g);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 3); // odd cycle needs 3 (Vizing class 2)
    }

    #[test]
    fn star_needs_degree_colors() {
        let g = Graph::star(9);
        let c = EdgeColoring::greedy(&g);
        c.validate(&g).unwrap();
        assert_eq!(c.num_colors(), 8); // all edges share the hub
    }

    #[test]
    fn hypercube_exactly_d_colors() {
        let g = Graph::hypercube(4);
        let c = EdgeColoring::greedy(&g);
        c.validate(&g).unwrap();
        // dimension-exchange coloring is optimal: greedy+compact should
        // stay within Δ+1
        assert!(c.num_colors() <= 5, "{}", c.num_colors());
    }

    #[test]
    fn random_graphs_valid_and_near_vizing() {
        let mut rng = Pcg64::new(23);
        for n in [8, 32, 64] {
            let g = Graph::random_connected(n, &mut rng);
            let c = EdgeColoring::greedy(&g);
            c.validate(&g).unwrap();
            let delta = g.max_degree();
            assert!(
                c.num_colors() <= 2 * delta - 1,
                "n={n}: {} colors for Δ={delta}",
                c.num_colors()
            );
        }
    }

    #[test]
    fn complete_graph_colors() {
        let g = Graph::complete(6);
        let c = EdgeColoring::greedy(&g);
        c.validate(&g).unwrap();
        // K_6 is class 1: χ' = 5; allow greedy slack up to 2Δ-1 = 9 but
        // compaction should do much better.
        assert!(c.num_colors() <= 7, "{}", c.num_colors());
    }

    #[test]
    fn validate_catches_bad_matching() {
        let g = Graph::path(3);
        let bad = EdgeColoring {
            classes: vec![vec![(0, 1), (1, 2)]],
        };
        assert!(bad.validate(&g).is_err());
    }

    #[test]
    fn validate_catches_missing_edge() {
        let g = Graph::path(3);
        let bad = EdgeColoring {
            classes: vec![vec![(0, 1)]],
        };
        assert!(bad.validate(&g).is_err());
    }
}
