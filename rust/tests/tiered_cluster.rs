//! Two-tier cluster acceptance: bit-identity across every (hosts ×
//! shards-per-host × batch) layout, inter-host traffic scaling with the
//! inter-host cut rather than the global cut, real host *processes* on
//! loopback TCP, and whole-host failure recovery.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::transport::tcp::LeaderListener;
use bcm_dlb::coordinator::{resolve_shards, Cluster, RoundPlan, ShardMap, TierLayout};
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::workload::{run_dynamic_cluster_tiered, run_dynamic_engine, TrafficConfig};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

const ALGO: PairAlgorithm = PairAlgorithm::SortedGreedy(SortAlgo::Quick);

fn init_scenario(n: usize, per_node: usize, seed: u64) -> (Graph, LoadState, Schedule) {
    let mut rng = Pcg64::new(seed);
    let g = Graph::random_connected(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::init_uniform_counts(
        n,
        per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    // pinned loads must survive the tiered paths too
    state.push(0, Load::pinned(90_000, 17.5));
    state.push(n / 2, Load::pinned(90_001, 3.25));
    (g, state, schedule)
}

fn sequential_reference(
    state0: &LoadState,
    schedule: &Schedule,
    sweeps: usize,
    seed: u64,
) -> (bcm_dlb::bcm::RunTrace, LoadState) {
    let mut state = state0.clone();
    let trace = Sequential.run(&mut state, schedule, ALGO, StopRule::sweeps(sweeps), seed);
    (trace, state)
}

#[test]
fn tiered_layouts_bit_identical_to_sequential() {
    // The acceptance sweep: hosts {1,2} x shards-per-host {1,2,cores} x
    // batch {lock-step, auto}.  A tiered partition is just another
    // contiguous ShardMap, so every cell must reproduce the Sequential
    // engine bit for bit — trace AND final state.
    let n = 24;
    let (g, state0, schedule) = init_scenario(n, 10, 41);
    let sweeps = 4;
    let seed = 77u64;
    let (seq_trace, seq_state) = sequential_reference(&state0, &schedule, sweeps, seed);
    // cap the per-core option so hosts * spp never exceeds n
    let cores = resolve_shards(0).clamp(1, n / 2);
    for hosts in [1usize, 2] {
        for spp in [1usize, 2, cores] {
            for batch in [1usize, 0] {
                let layout = TierLayout::new(hosts, spp);
                let (mut cluster, traffic) =
                    Cluster::spawn_tiered(state0.clone(), ALGO, layout, g.edges());
                assert_eq!(cluster.shards(), hosts * spp);
                cluster.set_batch_rounds(batch);
                let trace = cluster
                    .run_seeded(&schedule, sweeps, seed)
                    .expect("tiered run");
                let fin = cluster.shutdown().expect("tiered shutdown");
                assert_eq!(
                    trace, seq_trace,
                    "trace diverged at {hosts}x{spp} batch {batch}"
                );
                assert_eq!(
                    fin, seq_state,
                    "state diverged at {hosts}x{spp} batch {batch}"
                );
                assert!(fin.node(0).iter().any(|l| l.id == 90_000 && !l.mobile));
                let (bytes, inter, _intra) = traffic.snapshot();
                if hosts == 1 {
                    // a single host has no slow tier: nothing may be framed
                    assert_eq!(
                        (bytes, inter),
                        (0, 0),
                        "single-host layout leaked onto the wire"
                    );
                } else {
                    // a connected graph split across hosts always pays
                    // some inter-host traffic
                    assert!(inter > 0, "{hosts}x{spp}: no inter-host messages counted");
                    assert!(bytes > 0, "{hosts}x{spp}: inter-host messages cost no bytes");
                }
            }
        }
    }
}

#[test]
fn tiered_churning_job_bit_identical_to_sequential() {
    // The dynamic acceptance case: the churn stream is applied between
    // rounds through the tiered cluster and must still reproduce the
    // Sequential dynamic engine exactly.
    let n = 16;
    let (g, state0, schedule) = init_scenario(n, 8, 53);
    let cfg = TrafficConfig::default();
    let rounds = 12;
    let seed = 29u64;
    let mut seq_state = state0.clone();
    let seq_trace = run_dynamic_engine(
        &Sequential,
        &mut seq_state,
        &schedule,
        ALGO,
        &cfg,
        rounds,
        seed,
    );
    let layout = TierLayout::new(2, 2);
    let (trace, fin, traffic) = run_dynamic_cluster_tiered(
        state0, &schedule, ALGO, &cfg, rounds, seed, layout, g.edges(),
    )
    .expect("tiered churning run");
    assert_eq!(trace, seq_trace, "churning tiered trace diverged");
    assert_eq!(fin, seq_state, "churning tiered state diverged");
    assert!(traffic.snapshot().1 > 0, "no inter-host traffic during churn run");
}

#[test]
fn inter_host_bytes_scale_with_inter_host_cut_not_global_cut() {
    // E15's core claim, asserted exactly: on a torus3d the egress pump
    // frames ONLY edges whose endpoints live on different hosts.  The
    // wire message count equals 2x the summed inter-host cut of the
    // executed round plans (one Offer + one Settle per cut edge), while
    // intra-host cross-shard edges — the rest of the global cut — ride
    // shared-memory channels and never touch the codec.
    let g = Graph::torus3d(2, 3, 4);
    let n = 24;
    let schedule = Schedule::from_graph(&g);
    let mut rng = Pcg64::new(7);
    let state0 = LoadState::init_uniform_counts(
        n,
        10,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let layout = TierLayout::new(2, 2);
    let map = ShardMap::partition_tiered(n, &layout, g.edges());
    let sweeps = 3;
    // predicted cut, summed over every executed round
    let (mut intra_cut, mut inter_cut) = (0usize, 0usize);
    for round in 0..sweeps * schedule.period() {
        let plan = RoundPlan::build(&schedule.matchings()[round % schedule.period()], &map);
        let (ra, re) = plan.cut_by_tier(&layout);
        intra_cut += ra;
        inter_cut += re;
    }
    assert!(inter_cut > 0, "torus3d split across hosts must cut something");
    assert!(
        intra_cut > 0,
        "cut-aware partition left no intra-host cross edges to save"
    );
    let (mut cluster, traffic) = Cluster::spawn_tiered(state0, ALGO, layout, g.edges());
    cluster.set_batch_rounds(1);
    cluster.run_seeded(&schedule, sweeps, 3).expect("tiered run");
    cluster.shutdown().expect("tiered shutdown");
    let (bytes, inter_msgs, intra_msgs) = traffic.snapshot();
    assert_eq!(
        inter_msgs as usize,
        2 * inter_cut,
        "wire messages are not 2x the inter-host cut"
    );
    assert_eq!(
        intra_msgs as usize,
        2 * intra_cut,
        "shared-memory messages are not 2x the intra-host cut"
    );
    assert!(bytes > 0, "inter-host messages carried no bytes");
    // the global cut is what a flat one-shard-per-host-pair deployment
    // would pay: a 4x1 layout makes EVERY cross-shard edge inter-host.
    let flat = TierLayout::new(4, 1);
    let flat_map = ShardMap::partition_tiered(n, &flat, g.edges());
    let (mut flat_inter, mut flat_intra) = (0usize, 0usize);
    for round in 0..sweeps * schedule.period() {
        let plan =
            RoundPlan::build(&schedule.matchings()[round % schedule.period()], &flat_map);
        let (ra, re) = plan.cut_by_tier(&flat);
        flat_intra += ra;
        flat_inter += re;
    }
    assert_eq!(flat_intra, 0, "a 4x1 layout has no intra-host cross edges");
    assert!(
        inter_cut < flat_inter,
        "two-tier inter-host cut {inter_cut} did not beat the global cut {flat_inter}"
    );
}

/// Spawn `k` host worker processes dialing the leader at `addr`; each
/// auto-detects its two-tier role from the leader's init frame.
fn spawn_host_workers(addr: &str, k: usize) -> Vec<Child> {
    (0..k)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_bcm-dlb"))
                .args(["cluster-worker", "--connect", addr, "--retry", "40"])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a cluster-worker host process")
        })
        .collect()
}

#[test]
fn tcp_tiered_host_processes_bit_identical_to_sequential() {
    // The real deployment shape: 2 host OS processes on loopback, each
    // running 2 in-process shard workers behind one egress pump, and the
    // result must still be bit-identical to bcm::Sequential.
    let (g, state0, schedule) = init_scenario(24, 10, 41);
    let sweeps = 4;
    let seed = 77u64;
    let (seq_trace, seq_state) = sequential_reference(&state0, &schedule, sweeps, seed);
    let layout = TierLayout::new(2, 2);
    for batch in [1usize, 0] {
        let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader");
        let addr = listener.local_addr().expect("local addr").to_string();
        let mut workers = spawn_host_workers(&addr, layout.hosts);
        let mut cluster =
            Cluster::spawn_tcp_tiered(state0.clone(), ALGO, layout, g.edges(), listener)
                .expect("tcp tiered spawn");
        assert_eq!(cluster.shards(), 4, "2x2 layout must expose 4 shards");
        cluster.set_batch_rounds(batch);
        let trace = cluster
            .run_seeded(&schedule, sweeps, seed)
            .expect("tcp tiered run");
        let fin = cluster.shutdown().expect("tcp tiered shutdown");
        assert_eq!(trace, seq_trace, "TCP tiered trace diverged at batch {batch}");
        assert_eq!(fin, seq_state, "TCP tiered state diverged at batch {batch}");
        assert!(fin.node(0).iter().any(|l| l.id == 90_000 && !l.mobile));
        for w in &mut workers {
            let status = w.wait().expect("waiting for host worker");
            assert!(status.success(), "host worker exited nonzero at batch {batch}");
        }
    }
}

#[test]
fn whole_host_failure_recovers_bit_identically() {
    // A host process dying takes ALL its shard workers down at once.
    // With checkpointing on, the leader must abort the epoch, reassign
    // every dead shard of the lost host to the survivors, replay from
    // the newest checkpoint, and still land bit-identical to Sequential
    // — multi-casualty recovery, not the single-shard drill.
    let (g, state0, schedule) = init_scenario(16, 12, 13);
    let seed = 99u64;
    let sweeps = 3;
    let (seq_trace, seq_state) = sequential_reference(&state0, &schedule, sweeps, seed);
    let fail_round = 5;
    assert!(
        sweeps * schedule.period() > fail_round,
        "fault round never reached"
    );
    let layout = TierLayout::new(2, 2);
    let (mut cluster, _traffic) =
        Cluster::spawn_tiered_with_fault(state0, ALGO, layout, g.edges(), (1, fail_round));
    cluster.set_batch_rounds(1);
    cluster.set_checkpoint_every(2);
    cluster.set_rejoin_wait(Duration::ZERO);
    let trace = cluster
        .run_seeded(&schedule, sweeps, seed)
        .expect("checkpointed run must survive losing a whole host");
    let fin = cluster.shutdown().expect("shutdown after recovery");
    assert_eq!(trace, seq_trace, "post-recovery trace diverged");
    assert_eq!(fin, seq_state, "post-recovery state diverged");
}

#[test]
fn whole_host_failure_without_checkpointing_fail_stops() {
    // checkpoint_every = 0 keeps the classic contract even when the
    // casualty is an entire host: the run fails naming the round, and
    // the cluster poisons.
    let (g, state0, schedule) = init_scenario(16, 12, 13);
    let layout = TierLayout::new(2, 2);
    let (mut cluster, _traffic) =
        Cluster::spawn_tiered_with_fault(state0, ALGO, layout, g.edges(), (1, 5));
    let err = cluster
        .run_seeded(&schedule, 3, 99)
        .expect_err("fail-stop contract broken for a host loss")
        .to_string();
    assert!(err.contains("round 5"), "error does not name the round: {err}");
    assert!(cluster.shutdown().is_err());
}
