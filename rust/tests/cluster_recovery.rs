//! Elastic recovery tests: a worker killed mid-run (`--fault-exit`,
//! the simulated `kill -9`) must not fail a checkpointed run.  Both
//! recovery paths — a replacement process rejoining the dead shard,
//! and the leader reassigning its node range onto the survivors — must
//! finish with a trace and final state **bit-identical** to
//! `bcm::Sequential`, and the multi-tenant [`ShardPool`] must pause
//! and replay only the affected job.  The recovery contract under test
//! is DESIGN.md §8; the operator-facing procedures are OPERATIONS.md.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, RunTrace, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::transport::tcp::LeaderListener;
use bcm_dlb::coordinator::{Cluster, JobEvent, JobSpec, ShardPool};
use bcm_dlb::graph::{Graph, Topology};
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::workload::{run_dynamic_engine, TrafficConfig};
use std::collections::BTreeMap;
use std::process::{Child, Command, Stdio};
use std::time::{Duration, Instant};

const ALGO: PairAlgorithm = PairAlgorithm::SortedGreedy(SortAlgo::Quick);

fn init_scenario(n: usize, per_node: usize, seed: u64) -> (LoadState, Schedule) {
    let mut rng = Pcg64::new(seed);
    let g = Graph::random_connected(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    (state, schedule)
}

fn sequential_reference(
    state0: &LoadState,
    schedule: &Schedule,
    sweeps: usize,
    seed: u64,
) -> (RunTrace, LoadState) {
    let mut state = state0.clone();
    let trace = Sequential.run(&mut state, schedule, ALGO, StopRule::sweeps(sweeps), seed);
    (trace, state)
}

/// Spawn one `cluster-worker` process dialing the leader; `fault_exit`
/// makes it simulate a crash (`exit 3`) at the start of that round.
fn spawn_worker(addr: &str, fault_exit: Option<usize>) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_bcm-dlb"));
    cmd.args(["cluster-worker", "--connect", addr, "--retry", "80"]);
    if let Some(round) = fault_exit {
        cmd.args(["--fault-exit", &round.to_string()]);
    }
    cmd.stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawning a cluster-worker process")
}

#[test]
fn killed_worker_rejoins_and_the_run_stays_bit_identical() {
    let (state0, schedule) = init_scenario(16, 6, 21);
    let (sweeps, seed) = (3usize, 9u64);
    let (seq_trace, seq_state) = sequential_reference(&state0, &schedule, sweeps, seed);
    assert!(
        seq_trace.rounds.len() > 6,
        "scenario too short to crash at round 5 and still have work left"
    );

    let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader");
    let addr = listener.local_addr().expect("local addr").to_string();
    let mut victim = spawn_worker(&addr, Some(5));
    let mut peer = spawn_worker(&addr, None);
    let mut cluster =
        Cluster::spawn_tcp(state0.clone(), ALGO, 2, listener).expect("tcp spawn");
    // The replacement dials in now and parks in the listen backlog; the
    // leader only accepts it once the victim dies and the rejoin window
    // of the recovery opens.
    let mut replacement = spawn_worker(&addr, None);
    cluster.set_batch_rounds(1);
    cluster.set_checkpoint_every(2);
    cluster.set_rejoin_wait(Duration::from_secs(20));

    let trace = cluster
        .run_seeded(&schedule, sweeps, seed)
        .expect("a checkpointed run must survive the crash");
    let fin = cluster.shutdown().expect("clean shutdown after recovery");
    assert_eq!(trace, seq_trace, "rejoin replay diverged from Sequential");
    assert_eq!(fin, seq_state, "final state diverged after rejoin");

    // exit-code contract (OPERATIONS.md): the simulated crash exits 3,
    // every worker that served to the end exits 0
    assert_eq!(victim.wait().expect("victim").code(), Some(3));
    assert!(peer.wait().expect("peer").success(), "survivor exited nonzero");
    assert!(
        replacement.wait().expect("replacement").success(),
        "replacement exited nonzero"
    );
}

#[test]
fn dead_shard_is_reassigned_to_survivors_bit_identically() {
    let (state0, schedule) = init_scenario(18, 5, 33);
    let (sweeps, seed) = (3usize, 13u64);
    let (seq_trace, seq_state) = sequential_reference(&state0, &schedule, sweeps, seed);
    assert!(seq_trace.rounds.len() > 5);

    let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader");
    let addr = listener.local_addr().expect("local addr").to_string();
    let mut victim = spawn_worker(&addr, Some(4));
    let mut peers = vec![spawn_worker(&addr, None), spawn_worker(&addr, None)];
    let mut cluster =
        Cluster::spawn_tcp(state0.clone(), ALGO, 3, listener).expect("tcp spawn");
    cluster.set_batch_rounds(1);
    cluster.set_checkpoint_every(2);
    // no rejoin window: the dead shard's nodes go straight to the
    // survivors and the run replays on the shrunken membership
    cluster.set_rejoin_wait(Duration::ZERO);

    let trace = cluster
        .run_seeded(&schedule, sweeps, seed)
        .expect("reassignment must carry the run to completion");
    let fin = cluster.shutdown().expect("clean shutdown after reassignment");
    assert_eq!(trace, seq_trace, "reassignment replay diverged from Sequential");
    assert_eq!(fin, seq_state, "final state diverged after reassignment");

    assert_eq!(victim.wait().expect("victim").code(), Some(3));
    for (i, p) in peers.iter_mut().enumerate() {
        assert!(p.wait().expect("peer").success(), "survivor {i} exited nonzero");
    }
}

// ------------------------------------------------------- shard pool

/// A pool tenant plus its solo sequential reference.
fn tenant(
    topo: &str,
    n: usize,
    sweeps: usize,
    seed: u64,
    checkpoint_every: usize,
) -> (JobSpec, RunTrace, LoadState) {
    let topo = Topology::parse(topo).expect("test topology");
    let mut rng = Pcg64::new(seed);
    let g = topo.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        8,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let mut seq_state = state.clone();
    let seq_trace =
        Sequential.run(&mut seq_state, &schedule, ALGO, StopRule::sweeps(sweeps), seed);
    (
        JobSpec {
            state,
            schedule,
            algo: ALGO,
            sweeps,
            seed,
            batch: 1,
            checkpoint_every,
            churn: None,
        },
        seq_trace,
        seq_state,
    )
}

#[derive(Default)]
struct Outcome {
    rounds: Vec<bcm_dlb::bcm::RoundStats>,
    recoveries: Vec<usize>,
    finished: Option<(RunTrace, LoadState)>,
    failed: Option<String>,
}

/// Drive the pool until every job in `ids` reaches a terminal event.
fn drive(pool: &mut ShardPool, ids: &[u32]) -> BTreeMap<u32, Outcome> {
    let mut out: BTreeMap<u32, Outcome> =
        ids.iter().map(|&id| (id, Outcome::default())).collect();
    let deadline = Instant::now() + Duration::from_secs(120);
    while out.values().any(|o| o.finished.is_none() && o.failed.is_none()) {
        assert!(Instant::now() < deadline, "pool jobs did not settle in time");
        for ev in pool.step(Duration::from_millis(50)).expect("pool healthy") {
            match ev {
                JobEvent::Started { .. } => {}
                JobEvent::Rounds { job, stats } => {
                    out.get_mut(&job).unwrap().rounds.extend(stats)
                }
                JobEvent::Recovering { job, round } => {
                    out.get_mut(&job).unwrap().recoveries.push(round)
                }
                JobEvent::Finished { job, trace, state } => {
                    out.get_mut(&job).unwrap().finished = Some((trace, state))
                }
                JobEvent::Failed { job, error } => {
                    out.get_mut(&job).unwrap().failed = Some(error)
                }
            }
        }
    }
    out
}

#[test]
fn pool_recovers_a_churning_tenant_bit_identically() {
    // The elasticity drill under live churn: a worker dies *while* the
    // service-traffic stream is mutating the load set every round.  The
    // replay must regenerate the identical churn ops (they are a pure
    // function of (config, seed, round, node)) on the reassigned
    // membership and land bit-identical to the solo Sequential dynamic
    // run — including the next_id high-water mark of departed arrivals.
    let cfg = TrafficConfig::default();
    let topo = Topology::parse("torus2d").expect("test topology");
    let (n, sweeps, seed) = (16usize, 3usize, 27u64);
    let mut rng = Pcg64::new(seed);
    let g = topo.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        8,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let mut seq_state = state.clone();
    let rounds = sweeps * schedule.period();
    let seq_trace =
        run_dynamic_engine(&Sequential, &mut seq_state, &schedule, ALGO, &cfg, rounds, seed);
    assert!(rounds > 3, "scenario too short to crash at round 2");

    // the injected panic hits shard 0 of wire job 1 at round 2 — after
    // the churn ops of rounds 0..=2 have already mutated shard lists
    let mut pool =
        ShardPool::spawn_tuned(2, Some((0, 1, 2)), Some(Duration::from_millis(250)));
    let id = pool
        .open_job(JobSpec {
            state,
            schedule,
            algo: ALGO,
            sweeps,
            seed,
            batch: 1,
            checkpoint_every: 1,
            churn: Some(cfg),
        })
        .expect("churning job opens");
    let out = drive(&mut pool, &[id]);

    let o = &out[&id];
    assert_eq!(o.failed, None, "churning tenant failed: {:?}", o.failed);
    assert!(
        !o.recoveries.is_empty(),
        "the mid-churn crash should surface as a Recovering event"
    );
    let (trace, fin) = o.finished.as_ref().expect("churning tenant finishes");
    assert_eq!(trace, &seq_trace, "mid-churn replay diverged from Sequential");
    assert_eq!(fin, &seq_state, "final state diverged after mid-churn recovery");
    assert_eq!(o.rounds, trace.rounds, "replay duplicated Rounds events");
    pool.shutdown().expect("clean shutdown");
}

#[test]
fn pool_recovers_one_tenant_while_others_run_undisturbed() {
    // ids are assigned from 1 in open order: steady=1, flaky=2.  The
    // injected panic hits shard 0 of wire job 2 at round 1; only the
    // flaky tenant — which opted into checkpointing — may notice.
    let (steady_spec, steady_trace, steady_state) = tenant("ring", 24, 3, 5, 0);
    let (flaky_spec, flaky_trace, flaky_state) = tenant("torus2d", 16, 3, 6, 1);

    let mut pool =
        ShardPool::spawn_tuned(2, Some((0, 2, 1)), Some(Duration::from_millis(250)));
    let id_steady = pool.open_job(steady_spec).expect("steady opens");
    let id_flaky = pool.open_job(flaky_spec).expect("flaky opens");
    assert_eq!((id_steady, id_flaky), (1, 2));

    let out = drive(&mut pool, &[id_steady, id_flaky]);

    // the flaky tenant recovered instead of failing, and its replayed
    // run is still bit-identical to Sequential
    let flaky = &out[&id_flaky];
    assert_eq!(flaky.failed, None, "flaky tenant failed: {:?}", flaky.failed);
    assert!(
        !flaky.recoveries.is_empty(),
        "the injected crash should surface as a Recovering event"
    );
    let (trace, state) = flaky.finished.as_ref().expect("flaky finishes");
    assert_eq!(trace, &flaky_trace, "flaky trace diverged after recovery");
    assert_eq!(state, &flaky_state, "flaky state diverged after recovery");
    // replay must not duplicate streamed rounds: the event stream is
    // exactly the trace, delivered incrementally
    assert_eq!(flaky.rounds, trace.rounds, "replay duplicated Rounds events");

    // the steady tenant never saw any of it
    let steady = &out[&id_steady];
    assert_eq!(steady.failed, None, "steady tenant poisoned");
    assert!(steady.recoveries.is_empty(), "steady tenant saw a recovery");
    let (trace, state) = steady.finished.as_ref().expect("steady finishes");
    assert_eq!(trace, &steady_trace);
    assert_eq!(state, &steady_state);
    assert_eq!(steady.rounds, trace.rounds);

    pool.shutdown().expect("clean shutdown");
}
