import os
import sys

# Make `compile` (the python/compile package) importable regardless of the
# pytest invocation directory.
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
