//! Offline weighted balls-into-bins playground (paper §4, Appendix B/C).
//!
//! ```bash
//! cargo run --release --example balls_into_bins
//! ```
//!
//! Places m weighted balls into n bins with Greedy, SortedGreedy, a
//! random baseline, and SortedGreedy + swap refinement (our extension),
//! across several weight distributions — including a heavy-tailed Pareto
//! that violates the finite-second-moment assumption of Talwar & Wieder.

use bcm_dlb::balancer::refine::swap_refine;
use bcm_dlb::balancer::{greedy, random_place, sorted_greedy, SortAlgo};
use bcm_dlb::load::WeightDistribution;
use bcm_dlb::theory;
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::stats::Welford;
use bcm_dlb::util::table::{f, Table};

fn main() {
    let m = 1024;
    let nbins = 8;
    let reps = 200;

    let dists = [
        ("uniform[0,1)", WeightDistribution::paper_appendix_c()),
        ("exponential(1)", WeightDistribution::Exponential { mean: 1.0 }),
        (
            "pareto(1, 1.5)  [infinite variance]",
            WeightDistribution::Pareto {
                scale: 1.0,
                alpha: 1.5,
            },
        ),
        ("constant(1)  [Lemma-5 worst case]", WeightDistribution::Constant { w: 1.0 }),
    ];

    println!("offline balls-into-bins: m={m}, n={nbins} bins, {reps} reps\n");
    let mut t = Table::new(
        "mean discrepancy by algorithm and weight distribution",
        &["distribution", "random", "greedy", "sorted", "sorted+refine", "greedy/sorted"],
    );
    for (name, dist) in &dists {
        let mut wr = Welford::new();
        let mut wg = Welford::new();
        let mut ws = Welford::new();
        let mut wf = Welford::new();
        for rep in 0..reps {
            let mut rng = Pcg64::new(1000 + rep);
            let weights: Vec<f64> = (0..m).map(|_| dist.sample(&mut rng)).collect();
            wr.push(random_place(&weights, nbins, &mut rng).discrepancy());
            wg.push(greedy(&weights, nbins).discrepancy());
            let mut p = sorted_greedy(&weights, nbins, SortAlgo::Quick);
            ws.push(p.discrepancy());
            swap_refine(&weights, &mut p, 50);
            wf.push(p.discrepancy());
        }
        t.row(vec![
            name.to_string(),
            f(wr.mean(), 4),
            f(wg.mean(), 4),
            f(ws.mean(), 5),
            f(wf.mean(), 5),
            format!("{}x", f(wg.mean() / ws.mean().max(1e-12), 0)),
        ]);
    }
    println!("{}", t.render());

    // Theory check: the last-step bound ΔG_m <= 1/m for uniform weights.
    println!(
        "theory: for uniform weights the last-step discrepancy change obeys ΔG_m <= 1/m = {:.5}",
        theory::sorted_greedy_last_step_bound(m)
    );
    println!(
        "        Lemma 5 worst case (all weights equal w): max error w/2 — see the constant row,\n         where SortedGreedy cannot beat w/2 when m is odd."
    );
}
