"""Layer-1 Pallas kernel: batched two-bin greedy placement.

Each BCM matching [u:v] reduces to an offline weighted balls-into-bins
problem with two bins (paper §4): the union of the mobile loads of u and v
must be split across the two nodes as evenly as possible.  All matchings of
one BCM round are independent, so the coordinator batches them on a leading
axis B and this kernel solves all of them in one launch.

Inputs
------
weights : f32[B, M]   per-matching ball weights, sorted in DESCENDING order
                      (the SortedGreedy precondition; see bitonic.py),
                      zero-padded on the right.  Zero-weight padding balls
                      are placed like any other ball but change no bin sum,
                      so they are harmless; the coordinator ignores their
                      assignments.
base    : f32[B, 2]   initial bin sums.  Full mobility => zeros; partial
                      mobility => the pre-summed weights of the pinned
                      (immobile) loads on each side (paper §6.1).

Outputs
-------
assign  : f32[B, M]   0.0 => ball i goes to bin 0 (node u), 1.0 => bin 1.
sums    : f32[B, 2]   final bin sums (base + placed weights).

Placement rule: ball i goes to the *strictly lighter* bin; ties go to bin 0.
The paper requires the first ball to be placed uniformly at random for the
zero-expected-error condition (§3 cond. 3, Appendix A req. 3); the kernel is
deterministic and the Rust coordinator restores the symmetry by randomly
orienting each matched edge (swapping the roles of u and v) per round.

TPU mapping (DESIGN.md §Hardware-Adaptation): the scan over M is inherently
sequential (each decision depends on the running bin sums), so parallelism
comes from the batch axis: B is tiled into VMEM-resident blocks by the
BlockSpec, and every scan step is a VPU-vectorized op over the block's
lanes.  VMEM footprint per block is block_b*(2*M+4)*4 bytes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _two_bin_kernel(w_ref, base_ref, assign_ref, sums_ref, *, m: int):
    w = w_ref[...]  # [Bb, M]
    base = base_ref[...]  # [Bb, 2]
    s0 = base[:, 0]
    s1 = base[:, 1]
    assign0 = jnp.zeros_like(w)

    def body(i, carry):
        s0, s1, assign = carry
        wi = jax.lax.dynamic_slice_in_dim(w, i, 1, axis=1)[:, 0]  # [Bb]
        go1 = s1 < s0  # strictly lighter bin wins; tie -> bin 0
        a = go1.astype(w.dtype)
        assign = jax.lax.dynamic_update_slice_in_dim(
            assign, a[:, None], i, axis=1
        )
        s0 = s0 + jnp.where(go1, jnp.zeros_like(wi), wi)
        s1 = s1 + jnp.where(go1, wi, jnp.zeros_like(wi))
        return (s0, s1, assign)

    s0, s1, assign = jax.lax.fori_loop(0, m, body, (s0, s1, assign0))
    assign_ref[...] = assign
    sums_ref[...] = jnp.stack([s0, s1], axis=1)


def two_bin_greedy(weights, base, *, block_b: int | None = None):
    """Batched greedy two-bin placement of descending-sorted weights.

    Returns ``(assign[B, M], sums[B, 2])``.  See module docstring.
    """
    b, m = weights.shape
    if base.shape != (b, 2):
        raise ValueError(f"base must be [{b}, 2], got {base.shape}")
    if block_b is None:
        block_b = min(b, 8)
    if b % block_b != 0:
        raise ValueError(f"batch {b} not divisible by block_b {block_b}")

    kernel = functools.partial(_two_bin_kernel, m=m)
    grid = (b // block_b,)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 2), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_b, m), lambda i: (i, 0)),
            pl.BlockSpec((block_b, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, m), weights.dtype),
            jax.ShapeDtypeStruct((b, 2), weights.dtype),
        ],
        interpret=True,  # CPU PJRT cannot execute Mosaic custom-calls
    )(weights, base)
