//! CLI binary end-to-end: commands run, configs load, exit codes correct.

use std::path::PathBuf;
use std::process::Command;

fn bin() -> PathBuf {
    // target/{debug,release}/bcm-dlb next to the test executable
    let mut p = std::env::current_exe().unwrap();
    p.pop(); // deps/
    p.pop();
    p.join("bcm-dlb")
}

fn run_cli(args: &[&str]) -> (i32, String, String) {
    let out = Command::new(bin())
        .args(args)
        .current_dir(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("spawn bcm-dlb");
    (
        out.status.code().unwrap_or(-1),
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

#[test]
fn help_prints_usage() {
    let (code, stdout, _) = run_cli(&["help"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("USAGE"));
    assert!(stdout.contains("particle-mesh"));
}

#[test]
fn unknown_command_fails() {
    let (code, _, stderr) = run_cli(&["frobnicate"]);
    assert_ne!(code, 0);
    assert!(stderr.contains("unknown command"));
}

#[test]
fn bad_flag_value_fails() {
    let (code, _, stderr) = run_cli(&["run", "--n", "banana"]);
    assert_ne!(code, 0);
    assert!(stderr.contains("integer"));
}

#[test]
fn run_small_experiment() {
    let (code, stdout, stderr) = run_cli(&[
        "run", "--n", "8", "--loads", "10", "--reps", "2", "--sweeps", "5",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("final discrepancy"));
}

#[test]
fn run_with_greedy_and_partial() {
    let (code, stdout, _) = run_cli(&[
        "run", "--n", "8", "--loads", "10", "--reps", "1", "--sweeps", "3",
        "--algo", "greedy", "--mobility", "partial", "--topology", "ring",
    ]);
    assert_eq!(code, 0);
    assert!(stdout.contains("\"algorithm\":\"greedy\""));
    assert!(stdout.contains("\"mobility\":\"partial\""));
}

#[test]
fn run_from_config_file() {
    let dir = std::env::temp_dir().join("bcm_dlb_cli_test");
    std::fs::create_dir_all(&dir).unwrap();
    let cfg = dir.join("exp.json");
    std::fs::write(
        &cfg,
        r#"{"n": 6, "loads_per_node": 5, "algorithm": "sorted:flash", "reps": 1, "sweeps": 3}"#,
    )
    .unwrap();
    let (code, stdout, stderr) = run_cli(&["run", "--config", cfg.to_str().unwrap()]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("sorted:flash"));
}

#[test]
fn run_with_parallel_engine() {
    let (code, stdout, stderr) = run_cli(&[
        "run", "--n", "16", "--loads", "10", "--reps", "1", "--sweeps", "3",
        "--threads", "4",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("\"threads\":4"));
    assert!(stdout.contains("final discrepancy"));
}

#[test]
fn scale_command_small() {
    let (code, stdout, stderr) = run_cli(&[
        "scale", "--n", "32", "--topology", "torus2d", "--loads", "5", "--sweeps", "1",
        "--threads", "2", "--shards", "2",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("speedup"));
    assert!(stdout.contains("cluster"));
    assert!(stdout.contains("edges_per_s"));
    assert!(stdout.contains("trace-identical"));
}

#[test]
fn run_with_sharded_cluster() {
    let (code, stdout, stderr) = run_cli(&[
        "run", "--n", "16", "--loads", "8", "--reps", "1", "--sweeps", "3",
        "--cluster", "--shards", "2",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("\"shards\":2"));
    assert!(stdout.contains("final discrepancy"));
}

#[test]
fn run_with_batched_cluster() {
    let (code, stdout, stderr) = run_cli(&[
        "run", "--n", "16", "--loads", "8", "--reps", "1", "--sweeps", "4",
        "--cluster", "--shards", "2", "--batch-rounds", "4",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("\"batch_rounds\":4"));
    assert!(stdout.contains("final discrepancy"));
}

#[test]
fn scale_with_batch_ladder_pinned() {
    let (code, stdout, stderr) = run_cli(&[
        "scale", "--n", "32", "--topology", "ring", "--loads", "4", "--sweeps", "2",
        "--threads", "2", "--shards", "2", "--batch-rounds", "2",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("ldr_msgs_per_round"));
    assert!(stdout.contains("trace-identical"));
}

#[test]
fn cluster_worker_requires_an_endpoint() {
    let (code, _, stderr) = run_cli(&["cluster-worker"]);
    assert_ne!(code, 0);
    assert!(stderr.contains("--connect or --listen"), "stderr: {stderr}");
}

#[test]
fn tcp_transport_requires_cluster() {
    let (code, _, stderr) = run_cli(&["run", "--n", "8", "--transport", "tcp"]);
    assert_ne!(code, 0);
    assert!(stderr.contains("requires --cluster"), "stderr: {stderr}");
}

#[test]
fn scale_loads_ladder_emits_roofline() {
    let (code, stdout, stderr) = run_cli(&[
        "scale", "--n", "16", "--topology", "ring", "--loads", "4,8", "--sweeps", "1",
        "--threads", "2", "--shards", "2", "--batch-rounds", "1",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("roofline"), "no roofline table: {stdout}");
    assert!(stdout.contains("eps@L4"));
    assert!(stdout.contains("eps@L8"));
    assert!(stdout.contains("trace-identical"));
}

#[test]
fn run_service_traffic_workload_verified() {
    // dynamic mode end to end: churn + parallel engine + verify against
    // the sequential dynamic reference, sustained metrics + E14 table
    let (code, stdout, stderr) = run_cli(&[
        "run", "--n", "8", "--loads", "6", "--reps", "1", "--sweeps", "2",
        "--workload", "service-traffic", "--arrival-rate", "1.5",
        "--threads", "2", "--verify",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("\"workload\":\"service-traffic\""));
    assert!(stdout.contains("\"arrival_rate\":1.5"));
    assert!(stdout.contains("verified: churning trace"), "no verify line: {stdout}");
    assert!(stdout.contains("sustained mean discrepancy"));
    assert!(stdout.contains("sustained p99 discrepancy"));
    assert!(stdout.contains("migration_bytes"));
    assert!(stdout.contains("e14_service_traffic.csv"), "no E14 csv: {stdout}");
}

#[test]
fn run_service_traffic_on_cluster_verified() {
    let (code, stdout, stderr) = run_cli(&[
        "run", "--n", "8", "--loads", "6", "--reps", "1", "--sweeps", "2",
        "--workload", "service-traffic", "--cluster", "--shards", "2", "--verify",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("verified: churning trace"), "no verify line: {stdout}");
    assert!(stdout.contains("sustained mean discrepancy"));
}

#[test]
fn churn_knobs_require_the_workload_flag() {
    for knob in [
        &["run", "--n", "8", "--arrival-rate", "2.0"][..],
        &["run", "--n", "8", "--pareto-alpha", "3.0"],
        &["run", "--n", "8", "--hotspot-every", "16"],
    ] {
        let (code, _, stderr) = run_cli(knob);
        assert_ne!(code, 0, "accepted {knob:?} without --workload");
        assert!(stderr.contains("requires workload"), "stderr: {stderr}");
    }
}

#[test]
fn workload_flag_rejects_bad_values() {
    let (code, _, stderr) = run_cli(&["run", "--n", "8", "--workload", "batch"]);
    assert_ne!(code, 0);
    assert!(stderr.contains("bad --workload"), "stderr: {stderr}");

    let (code, _, stderr) = run_cli(&[
        "run", "--n", "8", "--workload", "service-traffic", "--pareto-alpha", "1.0",
    ]);
    assert_ne!(code, 0);
    assert!(stderr.contains("pareto_alpha"), "stderr: {stderr}");

    let (code, _, stderr) = run_cli(&[
        "run", "--n", "8", "--workload", "service-traffic", "--arrival-rate", "lots",
    ]);
    assert_ne!(code, 0);
    assert!(stderr.contains("expects a number"), "stderr: {stderr}");
}

#[test]
fn spectral_command() {
    let (code, stdout, _) = run_cli(&["spectral", "--topology", "ring", "--n", "8"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("spectral gap"));
    assert!(stdout.contains("ergodic"));
}

#[test]
fn validate_command_small() {
    let (code, stdout, stderr) = run_cli(&["validate", "--n", "8", "--topology", "ring"]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("within"));
    assert!(stdout.contains("envelope"));
}

#[test]
fn timings_command_small() {
    let (code, stdout, _) = run_cli(&["timings", "--reps", "3"]);
    assert_eq!(code, 0);
    assert!(stdout.contains("SortedGreedy/quick"));
}

#[test]
fn artifacts_command_if_built() {
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.json");
    if !manifest.exists() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let (code, stdout, stderr) = run_cli(&["artifacts"]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("all artifacts compile"));
}

#[test]
fn particle_mesh_tiny() {
    let (code, stdout, stderr) = run_cli(&[
        "particle-mesh", "--procs", "4", "--steps", "10", "--particles", "2000",
        "--subdomains", "8",
    ]);
    assert_eq!(code, 0, "stderr: {stderr}");
    assert!(stdout.contains("SortedGreedy-BCM"));
}
