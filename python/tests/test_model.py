"""Layer-2 model entry points: shapes, composition, SortedGreedy semantics."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref


def test_balance_two_bin_shapes():
    w = jnp.zeros((8, 64))
    base = jnp.zeros((8, 2))
    sw, perm, assign, sums = model.balance_two_bin(w, base)
    assert sw.shape == (8, 64)
    assert perm.shape == (8, 64)
    assert assign.shape == (8, 64)
    assert sums.shape == (8, 2)


def test_balance_two_bin_is_sorted_greedy():
    """model.balance_two_bin == ref sort + ref greedy placement."""
    rng = np.random.default_rng(11)
    w = rng.uniform(0, 100, (4, 32)).astype(np.float32)
    base = np.zeros((4, 2), np.float32)
    sw, perm, assign, sums = model.balance_two_bin(jnp.asarray(w), jnp.asarray(base))
    rsw, _ = ref.ref_sort_desc(w)
    ra, rs = ref.ref_two_bin(rsw, base)
    np.testing.assert_allclose(np.asarray(sw), rsw)
    np.testing.assert_allclose(np.asarray(assign), ra)
    np.testing.assert_allclose(np.asarray(sums), rs, rtol=1e-5)


def test_greedy_two_bin_skips_sort():
    w = np.array([[1.0, 5.0, 2.0, 4.0]], np.float32)
    base = np.zeros((1, 2), np.float32)
    assign, sums = model.greedy_two_bin(jnp.asarray(w), jnp.asarray(base))
    ra, rs = ref.ref_two_bin(w, base)  # oracle on UNSORTED input
    np.testing.assert_allclose(np.asarray(assign), ra)
    np.testing.assert_allclose(np.asarray(sums), rs, rtol=1e-6)


def test_offline_nbin_composition():
    rng = np.random.default_rng(13)
    w = rng.uniform(0, 1, (2, 64)).astype(np.float32)
    base = np.zeros((2, 8), np.float32)
    sw, perm, assign, sums = model.offline_nbin(jnp.asarray(w), jnp.asarray(base))
    rsw, _ = ref.ref_sort_desc(w)
    ra, rs = ref.ref_nbin(rsw, base)
    np.testing.assert_array_equal(np.asarray(assign), ra)
    np.testing.assert_allclose(np.asarray(sums), rs, rtol=1e-5)


def test_continuous_round_tuple():
    x = jnp.ones((8, 128))
    m = jnp.eye(128)
    (out,) = model.continuous_round(x, m)
    np.testing.assert_allclose(np.asarray(out), np.ones((8, 128)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_sorted_discrepancy_beats_greedy_on_average(seed):
    """The paper's core claim at the matching level (Fig. 4)."""
    rng = np.random.default_rng(seed)
    w = rng.uniform(0, 1, (8, 128)).astype(np.float32)
    base = np.zeros((8, 2), np.float32)
    _, _, _, s_sorted = model.balance_two_bin(jnp.asarray(w), jnp.asarray(base))
    _, s_greedy = model.greedy_two_bin(jnp.asarray(w), jnp.asarray(base))
    d_sorted = ref.discrepancy(np.asarray(s_sorted)).mean()
    d_greedy = ref.discrepancy(np.asarray(s_greedy)).mean()
    assert d_sorted <= d_greedy + 1e-5
