//! Shared drivers for the paper's evaluation sweeps (§6).
//!
//! The same random graphs and initial load distributions are reused for
//! every algorithm/mobility variant within a repetition, exactly as the
//! paper does ("The same graphs and initial load distributions are used
//! for both SortedGreedy and Greedy").

use crate::balancer::{PairAlgorithm, SortAlgo};
use crate::bcm::{run, Schedule, StopRule};
use crate::graph::Graph;
use crate::load::{LoadState, Mobility, WeightDistribution};
use crate::util::rng::Pcg64;
use crate::util::stats::Welford;

/// The four protocol variants of Fig. 1–3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    SortedFull,
    SortedPartial,
    GreedyFull,
    GreedyPartial,
    /// The movement-frugal incremental Greedy reading (see
    /// `PairAlgorithm::GreedyIncremental`), reported alongside the pooled
    /// Alg-4.2 Greedy because the paper's Fig. 2 movement ratios are only
    /// consistent with an incremental implementation.
    GreedyIncFull,
    GreedyIncPartial,
}

impl Variant {
    pub const ALL: [Variant; 6] = [
        Variant::SortedFull,
        Variant::SortedPartial,
        Variant::GreedyFull,
        Variant::GreedyPartial,
        Variant::GreedyIncFull,
        Variant::GreedyIncPartial,
    ];

    pub fn algo(&self) -> PairAlgorithm {
        match self {
            Variant::SortedFull | Variant::SortedPartial => {
                PairAlgorithm::SortedGreedy(SortAlgo::Quick)
            }
            Variant::GreedyFull | Variant::GreedyPartial => PairAlgorithm::Greedy,
            Variant::GreedyIncFull | Variant::GreedyIncPartial => {
                PairAlgorithm::GreedyIncremental
            }
        }
    }

    pub fn mobility(&self) -> Mobility {
        match self {
            Variant::SortedFull | Variant::GreedyFull | Variant::GreedyIncFull => {
                Mobility::Full
            }
            Variant::SortedPartial | Variant::GreedyPartial | Variant::GreedyIncPartial => {
                Mobility::Partial
            }
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            Variant::SortedFull => "SortedGreedy/full",
            Variant::SortedPartial => "SortedGreedy/partial",
            Variant::GreedyFull => "Greedy/full",
            Variant::GreedyPartial => "Greedy/partial",
            Variant::GreedyIncFull => "GreedyInc/full",
            Variant::GreedyIncPartial => "GreedyInc/partial",
        }
    }
}

/// Aggregated result of one (n, L/n, variant) sweep cell.
#[derive(Clone, Debug)]
pub struct CellStats {
    pub variant: Variant,
    pub n: usize,
    pub loads_per_node: usize,
    pub initial_disc: Welford,
    pub final_disc: Welford,
    pub disc_reduction: Welford,
    pub movements_per_edge: Welford,
    pub total_movements: Welford,
    pub merit: Welford,
}

impl CellStats {
    fn new(variant: Variant, n: usize, loads_per_node: usize) -> Self {
        Self {
            variant,
            n,
            loads_per_node,
            initial_disc: Welford::new(),
            final_disc: Welford::new(),
            disc_reduction: Welford::new(),
            movements_per_edge: Welford::new(),
            total_movements: Welford::new(),
            merit: Welford::new(),
        }
    }
}

/// Sweep parameters; `quick()` derates repetitions for CI runs.
#[derive(Clone, Debug)]
pub struct SweepParams {
    pub network_sizes: Vec<usize>,
    pub loads_per_node: Vec<usize>,
    pub reps: usize,
    pub sweeps: usize,
    pub seed: u64,
}

impl Default for SweepParams {
    fn default() -> Self {
        Self {
            // paper §6: n from 4 to 128, L/n in {10, 50, 100}, 50 reps
            network_sizes: vec![4, 8, 16, 32, 64, 128],
            loads_per_node: vec![10, 50, 100],
            reps: 50,
            sweeps: 15,
            seed: 2013,
        }
    }
}

impl SweepParams {
    /// Environment-controlled derating: `BCM_DLB_QUICK=1` shrinks the
    /// sweep so `cargo bench` finishes in minutes on 1 core.
    pub fn from_env() -> Self {
        let mut p = Self::default();
        if std::env::var("BCM_DLB_QUICK").map(|v| v == "1").unwrap_or(false) {
            p.network_sizes = vec![4, 8, 16, 32, 64];
            p.reps = 10;
            p.sweeps = 10;
        }
        p
    }
}

/// Run every variant over one sweep cell (n, loads_per_node).
pub fn run_cell(n: usize, loads_per_node: usize, params: &SweepParams) -> Vec<CellStats> {
    let mut cells: Vec<CellStats> = Variant::ALL
        .iter()
        .map(|&v| CellStats::new(v, n, loads_per_node))
        .collect();
    for rep in 0..params.reps {
        // One graph + one weight draw per repetition, shared by all
        // variants; partial mobility additionally pins (same pins for
        // both algorithms).
        let cell_seed = params
            .seed
            .wrapping_mul(1_000_003)
            .wrapping_add((n * 131 + loads_per_node * 17 + rep) as u64);
        let mut rng = Pcg64::new(cell_seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let base_state = LoadState::init_uniform_counts(
            n,
            loads_per_node,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let mut partial_state = base_state.clone();
        partial_state.pin_random(&mut rng);

        for cell in cells.iter_mut() {
            let mut state = match cell.variant.mobility() {
                Mobility::Full => base_state.clone(),
                Mobility::Partial => partial_state.clone(),
            };
            let mut run_rng = Pcg64::new(cell_seed ^ 0xDEAD_BEEF);
            let trace = run(
                &mut state,
                &schedule,
                cell.variant.algo(),
                StopRule::sweeps(params.sweeps),
                &mut run_rng,
            );
            cell.initial_disc.push(trace.initial_discrepancy);
            cell.final_disc.push(trace.final_discrepancy());
            cell.disc_reduction
                .push(trace.discrepancy_reduction().min(1e12));
            cell.movements_per_edge.push(trace.movements_per_edge());
            cell.total_movements.push(trace.total_movements() as f64);
            cell.merit.push(trace.figure_of_merit().min(1e12));
        }
    }
    cells
}

/// Full sweep over all (n, L/n) cells.
pub fn run_sweep(params: &SweepParams) -> Vec<CellStats> {
    let mut out = Vec::new();
    for &per in &params.loads_per_node {
        for &n in &params.network_sizes {
            out.extend(run_cell(n, per, params));
        }
    }
    out
}

/// Find a cell in sweep output.
pub fn find<'a>(
    cells: &'a [CellStats],
    variant: Variant,
    n: usize,
    per: usize,
) -> Option<&'a CellStats> {
    cells
        .iter()
        .find(|c| c.variant == variant && c.n == n && c.loads_per_node == per)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> SweepParams {
        SweepParams {
            network_sizes: vec![8],
            loads_per_node: vec![10],
            reps: 3,
            sweeps: 8,
            seed: 7,
        }
    }

    #[test]
    fn run_cell_produces_all_variants() {
        let cells = run_cell(8, 10, &tiny());
        assert_eq!(cells.len(), 6);
        for c in &cells {
            assert_eq!(c.initial_disc.count(), 3);
            assert!(c.final_disc.mean() <= c.initial_disc.mean());
        }
    }

    #[test]
    fn sorted_beats_greedy_in_cell() {
        let mut p = tiny();
        p.reps = 5;
        p.loads_per_node = vec![50];
        let cells = run_cell(8, 50, &p);
        let sf = find(&cells, Variant::SortedFull, 8, 50).unwrap();
        let gf = find(&cells, Variant::GreedyFull, 8, 50).unwrap();
        assert!(
            sf.final_disc.mean() < gf.final_disc.mean(),
            "sorted {} vs greedy {}",
            sf.final_disc.mean(),
            gf.final_disc.mean()
        );
    }

    #[test]
    fn sweep_covers_grid() {
        let mut p = tiny();
        p.network_sizes = vec![4, 8];
        p.loads_per_node = vec![10, 50];
        p.reps = 1;
        let cells = run_sweep(&p);
        assert_eq!(cells.len(), 2 * 2 * 6);
        assert!(find(&cells, Variant::GreedyPartial, 4, 50).is_some());
    }
}
