//! Cross-module integration: engines agree, the distributed cluster
//! matches the sequential reference, topologies converge, and failure
//! injection (weird graphs, degenerate loads) does not break anything.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{run, run_device, Schedule, StopRule};
use bcm_dlb::coordinator::{Cluster, WorkerAlgo};
use bcm_dlb::graph::{Graph, Topology};
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::DeviceAlgo;
use bcm_dlb::util::rng::Pcg64;

fn sorted() -> PairAlgorithm {
    PairAlgorithm::SortedGreedy(SortAlgo::Quick)
}

#[test]
fn all_topologies_converge() {
    let mut rng = Pcg64::new(1);
    for topo in [
        Topology::Ring,
        Topology::Path,
        Topology::Complete,
        Topology::Star,
        Topology::Grid2d,
        Topology::Torus2d,
        Topology::Hypercube,
        Topology::RandomConnected,
    ] {
        let g = topo.build(16, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            16,
            30,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let init = state.discrepancy();
        let trace = run(&mut state, &schedule, sorted(), StopRule::sweeps(40), &mut rng);
        assert!(
            trace.final_discrepancy() < init / 5.0,
            "{topo:?}: init {init}, final {}",
            trace.final_discrepancy()
        );
    }
}

#[test]
fn three_engines_agree_on_convergence() {
    // sequential, device-fallback, and threaded cluster: same protocol,
    // independent code paths — all should reach tiny discrepancies.
    let mut rng = Pcg64::new(2);
    let g = Graph::random_connected(12, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state0 = LoadState::init_uniform_counts(
        12,
        40,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let init = state0.discrepancy();
    let target = init / 10.0;

    let mut s1 = state0.clone();
    let mut r = Pcg64::new(10);
    let t1 = run(&mut s1, &schedule, sorted(), StopRule::sweeps(10), &mut r);

    let mut s2 = state0.clone();
    let mut r = Pcg64::new(20);
    let t2 = run_device(&mut s2, &schedule, DeviceAlgo::SortedGreedy, 10, None, &mut r).unwrap();

    let mut r = Pcg64::new(30);
    let mut cluster = Cluster::spawn(state0, WorkerAlgo::SortedGreedy);
    let t3 = cluster.run(&schedule, 10, &mut r).unwrap();
    cluster.shutdown().unwrap();

    for (name, t) in [("sequential", &t1), ("device-fallback", &t2), ("cluster", &t3)] {
        assert!(
            t.final_discrepancy() < target,
            "{name}: {} >= {target}",
            t.final_discrepancy()
        );
    }
}

#[test]
fn minimal_networks() {
    // n=2 path: single edge, balances in one matching.
    let mut rng = Pcg64::new(3);
    let g = Graph::path(2);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::empty(2);
    for i in 0..10 {
        state.push(0, Load::new(i, 1.0));
    }
    let trace = run(&mut state, &schedule, sorted(), StopRule::sweeps(1), &mut rng);
    assert_eq!(trace.final_discrepancy(), 0.0);
}

#[test]
fn empty_and_degenerate_loads() {
    let mut rng = Pcg64::new(4);
    let g = Graph::ring(4);
    let schedule = Schedule::from_graph(&g);

    // no loads at all
    let mut empty = LoadState::empty(4);
    let t = run(&mut empty, &schedule, sorted(), StopRule::sweeps(3), &mut rng);
    assert_eq!(t.final_discrepancy(), 0.0);
    assert_eq!(t.total_movements(), 0);

    // all zero-weight loads
    let mut zeros = LoadState::empty(4);
    for i in 0..20 {
        zeros.push((i % 4) as usize, Load::new(i, 0.0));
    }
    let t = run(&mut zeros, &schedule, sorted(), StopRule::sweeps(3), &mut rng);
    assert_eq!(t.final_discrepancy(), 0.0);

    // a single giant load: discrepancy cannot go below its weight
    let mut giant = LoadState::empty(4);
    giant.push(0, Load::new(0, 1000.0));
    let t = run(&mut giant, &schedule, sorted(), StopRule::sweeps(5), &mut rng);
    assert!((t.final_discrepancy() - 1000.0).abs() < 1e-9);
}

#[test]
fn all_loads_pinned_is_a_noop() {
    let mut rng = Pcg64::new(5);
    let g = Graph::ring(4);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::empty(4);
    for i in 0..12 {
        state.push((i % 4) as usize, Load::pinned(i, (i + 1) as f64));
    }
    let before = state.load_vector();
    let trace = run(&mut state, &schedule, sorted(), StopRule::sweeps(5), &mut rng);
    assert_eq!(state.load_vector(), before);
    assert_eq!(trace.total_movements(), 0);
}

#[test]
fn heavy_tail_distribution_still_converges_to_lmax_scale() {
    let mut rng = Pcg64::new(6);
    let g = Graph::random_connected(16, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::init_uniform_counts(
        16,
        50,
        &WeightDistribution::Pareto { scale: 1.0, alpha: 1.5 },
        Mobility::Full,
        &mut rng,
    );
    let lmax = state.max_load_weight();
    let trace = run(&mut state, &schedule, sorted(), StopRule::sweeps(30), &mut rng);
    // indivisibility floor: final discrepancy is at most ~lmax
    assert!(
        trace.final_discrepancy() <= lmax + 1e-6,
        "final {} vs lmax {lmax}",
        trace.final_discrepancy()
    );
}

#[test]
fn cluster_with_single_edge_network() {
    let mut rng = Pcg64::new(7);
    let g = Graph::path(2);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::empty(2);
    for i in 0..40 {
        state.push(0, Load::new(i, 1.0 + (i as f64 % 3.0)));
    }
    let mass = state.total_weight();
    let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
    let trace = cluster.run(&schedule, 2, &mut rng).unwrap();
    let fin = cluster.shutdown().unwrap();
    assert!((fin.total_weight() - mass).abs() < 1e-9);
    assert!(trace.final_discrepancy() <= 3.0);
}

#[test]
fn stress_cluster_one_shard_per_node() {
    // 64 single-node shards (the degenerate worst case for the sharded
    // protocol: every edge is cross-shard): exercises the full
    // offer/settle messaging path on a random dense-ish graph.
    let mut rng = Pcg64::new(8);
    let g = Graph::random_connected(64, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        64,
        10,
        &WeightDistribution::paper_section6(),
        Mobility::Partial,
        &mut rng,
    );
    let ids = state.all_ids();
    let lmax = state.max_load_weight();
    let mut cluster = Cluster::spawn_sharded(state, WorkerAlgo::Greedy, 64);
    assert_eq!(cluster.shards(), 64);
    let trace = cluster.run(&schedule, 3, &mut rng).unwrap();
    let fin = cluster.shutdown().unwrap();
    assert_eq!(fin.all_ids(), ids);
    // greedy can overshoot by at most the single-load quantum
    assert!(trace.final_discrepancy() <= trace.initial_discrepancy + lmax + 1e-9);
}

#[test]
fn incremental_greedy_moves_far_fewer_loads() {
    // The Fig.2 phenomenon at the protocol level.
    let mut rng = Pcg64::new(9);
    let g = Graph::random_connected(32, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state0 = LoadState::init_uniform_counts(
        32,
        100,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let mut s1 = state0.clone();
    let mut r = Pcg64::new(1);
    let t_sorted = run(&mut s1, &schedule, sorted(), StopRule::sweeps(10), &mut r);
    let mut s2 = state0;
    let mut r = Pcg64::new(2);
    let t_inc = run(
        &mut s2,
        &schedule,
        PairAlgorithm::GreedyIncremental,
        StopRule::sweeps(10),
        &mut r,
    );
    assert!(
        t_sorted.total_movements() > 10 * t_inc.total_movements(),
        "sorted {} vs incremental {}",
        t_sorted.total_movements(),
        t_inc.total_movements()
    );
}
