"""nbin_greedy Pallas kernel vs the sequential oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

from compile.kernels.nbin import nbin_greedy
from compile.kernels import ref


def run_both(w, base, **kw):
    a, s = nbin_greedy(jnp.asarray(w), jnp.asarray(base), **kw)
    ra, rs = ref.ref_nbin(w, base)
    return np.asarray(a), np.asarray(s), ra, rs


def test_matches_two_bin_semantics():
    w = -np.sort(-np.random.default_rng(0).uniform(0, 1, (4, 16)), axis=1)
    w = w.astype(np.float32)
    base = np.zeros((4, 2), np.float32)
    a, s, ra, rs = run_both(w, base)
    np.testing.assert_array_equal(a, ra)
    np.testing.assert_allclose(s, rs, rtol=1e-5)


def test_round_robin_on_equal_weights():
    """Equal balls into empty bins spread one per bin first."""
    w = np.full((1, 4), 1.0, np.float32)
    base = np.zeros((1, 4), np.float32)
    a, s, _, _ = run_both(w, base)
    assert sorted(a[0].tolist()) == [0, 1, 2, 3]
    np.testing.assert_allclose(s[0], 1.0)


def test_tie_prefers_lowest_index():
    w = np.array([[1.0]], np.float32)
    base = np.zeros((1, 8), np.float32)
    a, _, _, _ = run_both(w, base)
    assert a[0, 0] == 0


def test_base_offsets():
    w = np.array([[1.0, 1.0]], np.float32)
    base = np.array([[0.0, 5.0, 5.0]], np.float32)
    a, s, ra, rs = run_both(w, base)
    np.testing.assert_array_equal(a[0], [0, 0])
    np.testing.assert_allclose(s, rs)


def test_mass_conservation():
    rng = np.random.default_rng(5)
    w = -np.sort(-rng.uniform(0, 100, (8, 64)).astype(np.float32), axis=1)
    base = rng.uniform(0, 50, (8, 8)).astype(np.float32)
    a, s, ra, rs = run_both(w, base)
    np.testing.assert_allclose(
        s.sum(axis=1), w.sum(axis=1) + base.sum(axis=1), rtol=1e-4
    )
    np.testing.assert_array_equal(a, ra)


def test_rejects_batch_mismatch():
    with pytest.raises(ValueError):
        nbin_greedy(jnp.zeros((4, 8)), jnp.zeros((2, 4)))


@settings(max_examples=20, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4]),
    m=st.sampled_from([1, 5, 16, 33]),
    n=st.sampled_from([2, 3, 8, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_matches_oracle(b, m, n, seed):
    rng = np.random.default_rng(seed)
    w = -np.sort(-rng.uniform(0, 1, (b, m)).astype(np.float32), axis=1)
    base = np.zeros((b, n), np.float32)
    a, s, ra, rs = run_both(w, base, block_b=1)
    np.testing.assert_array_equal(a, ra)
    np.testing.assert_allclose(s, rs, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_sorted_input_beats_greedy_discrepancy(seed):
    """Paper Fig. 4: SortedGreedy discrepancy <= ~Greedy discrepancy
    (statistically; we assert on the mean over a small batch)."""
    rng = np.random.default_rng(seed)
    raw = rng.uniform(0, 1, (8, 256)).astype(np.float32)
    srt = -np.sort(-raw, axis=1)
    base = np.zeros((8, 2), np.float32)
    _, s_sorted, _, _ = run_both(srt, base)
    _, s_raw, _, _ = run_both(raw, base)
    d_sorted = ref.discrepancy(s_sorted).mean()
    d_raw = ref.discrepancy(s_raw).mean()
    assert d_sorted <= d_raw + 1e-4
