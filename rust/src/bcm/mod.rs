//! The balancing circuit model protocol (paper §2.1, §5).

pub mod device_engine;
pub mod diffusion;
pub mod engine;
pub mod parallel;
pub mod random_matching;
pub mod schedule;
pub mod trace;

pub use device_engine::{balance_round, run_device};
pub use diffusion::Diffusion;
pub use engine::{balance_edge, balance_edge_with, run, Engine, Sequential, StopRule};
pub use parallel::{parallel_round, parallel_round_ctx, Parallel, RoundCtx};
pub use random_matching::{random_maximal_matching, run_rmm};
pub use schedule::Schedule;
pub use trace::{RoundStats, RunTrace};
