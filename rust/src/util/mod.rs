//! Dependency-free support code: errors, RNG, JSON, statistics, tables,
//! and best-effort CPU affinity.

pub mod affinity;
pub mod error;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
