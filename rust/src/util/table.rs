//! Text tables + CSV output for the benchmark harness.
//!
//! Every bench prints the same rows the paper's figure/table reports and
//! also drops a CSV under `results/` for offline plotting.

use std::fs;
use std::io::Write as _;
use std::path::Path;

/// A simple column-aligned table with a title.
#[derive(Clone, Debug)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Self {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row arity mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("## {}\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            cells
                .iter()
                .zip(widths)
                .map(|(c, w)| format!("{c:>w$}", w = w))
                .collect::<Vec<_>>()
                .join("  ")
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        out.push_str(&"-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Write the table as CSV (creates parent dirs).
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            fs::create_dir_all(parent)?;
        }
        let mut f = fs::File::create(path)?;
        writeln!(f, "{}", csv_row(&self.headers))?;
        for row in &self.rows {
            writeln!(f, "{}", csv_row(row))?;
        }
        Ok(())
    }
}

fn csv_row(cells: &[String]) -> String {
    cells
        .iter()
        .map(|c| {
            if c.contains(',') || c.contains('"') || c.contains('\n') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.clone()
            }
        })
        .collect::<Vec<_>>()
        .join(",")
}

/// Shorthand for formatting floats at a fixed precision.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["n", "value"]);
        t.row(vec!["4".into(), "1.25".into()]);
        t.row(vec!["128".into(), "0.5".into()]);
        let s = t.render();
        assert!(s.contains("## demo"));
        assert!(s.contains("128"));
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(vec!["1".into()]);
    }

    #[test]
    fn csv_escaping() {
        assert_eq!(
            csv_row(&["a,b".to_string(), "q\"t".to_string(), "z".to_string()]),
            "\"a,b\",\"q\"\"t\",z"
        );
    }

    #[test]
    fn csv_write_and_readback() {
        let mut t = Table::new("demo", &["x"]);
        t.row(vec!["1".into()]);
        let dir = std::env::temp_dir().join("bcm_dlb_table_test");
        let path = dir.join("t.csv");
        t.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "x\n1\n");
    }
}
