"""two_bin_greedy Pallas kernel vs the sequential oracle."""

import numpy as np
import jax.numpy as jnp
import pytest

pytest.importorskip("hypothesis")  # property tests need it; skip cleanly offline
from hypothesis import given, settings, strategies as st

from compile.kernels.two_bin import two_bin_greedy
from compile.kernels import ref


def run_both(w, base, **kw):
    a, s = two_bin_greedy(jnp.asarray(w), jnp.asarray(base), **kw)
    ra, rs = ref.ref_two_bin(w, base)
    return np.asarray(a), np.asarray(s), ra, rs


def test_simple_descending():
    w = np.array([[5.0, 4.0, 3.0, 2.0]], np.float32)
    base = np.zeros((1, 2), np.float32)
    a, s, ra, rs = run_both(w, base)
    # 5->bin0, 4->bin1, 3->bin1 (4<5), 2->bin0? sums (5,4): 4<5 -> bin1
    np.testing.assert_allclose(a, ra)
    np.testing.assert_allclose(s, rs, rtol=1e-6)
    assert s[0].sum() == pytest.approx(w.sum())


def test_tie_goes_to_bin_zero():
    w = np.array([[1.0, 1.0]], np.float32)
    base = np.zeros((1, 2), np.float32)
    a, s, ra, rs = run_both(w, base)
    assert a[0, 0] == 0.0  # tie at (0, 0) -> bin 0
    assert a[0, 1] == 1.0  # now bin1 lighter
    np.testing.assert_allclose(a, ra)


def test_base_offsets_respected():
    """Partial mobility: pinned loads pre-summed into the base."""
    w = np.array([[3.0, 1.0]], np.float32)
    base = np.array([[10.0, 0.0]], np.float32)
    a, s, ra, rs = run_both(w, base)
    # everything should flow to bin 1 until it catches up
    assert a[0, 0] == 1.0 and a[0, 1] == 1.0
    np.testing.assert_allclose(s, rs, rtol=1e-6)


def test_zero_padding_harmless():
    w = np.array([[2.0, 1.0, 0.0, 0.0]], np.float32)
    base = np.zeros((1, 2), np.float32)
    _, s, _, _ = run_both(w, base)
    np.testing.assert_allclose(sorted(s[0]), [1.0, 2.0])


def test_mass_conservation_batch():
    rng = np.random.default_rng(7)
    w = -np.sort(-rng.uniform(0, 100, (16, 32)).astype(np.float32), axis=1)
    base = rng.uniform(0, 10, (16, 2)).astype(np.float32)
    a, s, ra, rs = run_both(w, base)
    np.testing.assert_allclose(
        s.sum(axis=1), w.sum(axis=1) + base.sum(axis=1), rtol=1e-5
    )
    np.testing.assert_allclose(a, ra)


def test_block_b_variants_agree():
    rng = np.random.default_rng(3)
    w = -np.sort(-rng.uniform(0, 1, (8, 16)).astype(np.float32), axis=1)
    base = np.zeros((8, 2), np.float32)
    a1, s1 = two_bin_greedy(jnp.asarray(w), jnp.asarray(base), block_b=8)
    a2, s2 = two_bin_greedy(jnp.asarray(w), jnp.asarray(base), block_b=2)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2))


def test_rejects_bad_base_shape():
    with pytest.raises(ValueError):
        two_bin_greedy(jnp.zeros((4, 8)), jnp.zeros((4, 3)))


def test_rejects_indivisible_block():
    with pytest.raises(ValueError):
        two_bin_greedy(jnp.zeros((6, 8)), jnp.zeros((6, 2)), block_b=4)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 4, 8]),
    m=st.sampled_from([1, 2, 3, 8, 17, 32]),
    seed=st.integers(0, 2**31 - 1),
    scale=st.sampled_from([1.0, 100.0]),
)
def test_hypothesis_matches_oracle(b, m, seed, scale):
    rng = np.random.default_rng(seed)
    w = -np.sort(-rng.uniform(0, scale, (b, m)).astype(np.float32), axis=1)
    base = rng.uniform(0, scale, (b, 2)).astype(np.float32)
    a, s, ra, rs = run_both(w, base, block_b=1)
    np.testing.assert_allclose(a, ra)
    np.testing.assert_allclose(s, rs, rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_hypothesis_discrepancy_bounded_by_lmax(seed):
    """Lemma 5: |d_max| <= l_1 / 2 . 2 = l_1: final two-bin discrepancy
    never exceeds the largest ball when base sums are equal."""
    rng = np.random.default_rng(seed)
    w = -np.sort(-rng.uniform(0, 1, (4, 64)).astype(np.float32), axis=1)
    base = np.zeros((4, 2), np.float32)
    _, s, _, _ = run_both(w, base)
    disc = ref.discrepancy(s)
    assert (disc <= w[:, 0] + 1e-5).all()
