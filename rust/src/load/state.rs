//! Per-network load state: which loads live on which processor.
//!
//! # Memory layout (DESIGN.md §9)
//!
//! Since the zero-allocation hot-path rework the state is a
//! structure-of-arrays **arena**: three parallel columns hold every
//! load's id, weight and mobility bit, and each node owns a contiguous
//! segment of slots described by a `Seg`-style `(start, len, cap)`
//! triple.  The weight column is a flat `Vec<f64>` (vectorizable folds,
//! one cache line per eight weights), mobility is a bitset (one cache
//! line per 512 loads), and a per-node `totals` column caches each
//! node's weight sum so the per-round discrepancy reduction reads `n`
//! floats instead of re-summing every load.
//!
//! ```text
//!   ids:     [ u64 | u64 | ... ]                       (arena column)
//!   weights: [ f64 | f64 | ... ]                       (arena column)
//!   mobile:  [ 1 bit per slot, packed in u64 words ]   (arena column)
//!   segs:    node v  ->  { start, len, cap }           (slot range)
//!   totals:  node v  ->  cached left-fold of weights   (O(1) node_weight)
//! ```
//!
//! Segments carry power-of-two slack (`cap >= len`), so a node that
//! grows within its cap rewrites slots in place — no allocation.  A
//! node that outgrows its cap is **relocated** to the arena frontier;
//! abandoned ranges are reclaimed by an amortized-O(1) compaction pass
//! when the waste reaches the live capacity.  In steady state (node
//! sizes fluctuating within their caps) a whole BCM round performs
//! zero heap allocations — pinned by `tests/alloc_budget.rs`.
//!
//! The `totals` cache is maintained **bitwise** equal to a fresh
//! left-fold of the node's weight column: appends add (`fold(xs ++ [w])
//! == fold(xs) + w` exactly), every rewrite refolds.  That is what lets
//! `node_weight`/`weight_extremes` read cached sums while every trace
//! stays bit-identical to the pre-arena implementation, which folded
//! each node's list from scratch in the same order.

use super::distribution::WeightDistribution;
use super::item::Load;
use crate::util::rng::Pcg64;
use std::marker::PhantomData;
use std::sync::atomic::{AtomicU64, Ordering};

/// Load mobility model (paper §6.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mobility {
    /// All loads are free to move.
    Full,
    /// On each node with m loads, r ~ U{1, .., m-1} of them are pinned
    /// uniformly at random ("we uniformly at random set r ∈ [1, …, l−1]
    /// of them to be immobile").
    Partial,
}

impl Mobility {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => Some(Mobility::Full),
            "partial" => Some(Mobility::Partial),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mobility::Full => "full",
            Mobility::Partial => "partial",
        }
    }
}

/// One node's slot range in the arena columns.
#[derive(Clone, Copy, Debug)]
struct Seg {
    /// First arena slot owned by the node.
    start: usize,
    /// Occupied slots (the node's load count).
    len: usize,
    /// Owned slots; `len <= cap`, growth within `cap` never allocates.
    cap: usize,
}

/// Segment capacity for a node of `len` loads: the next power of two,
/// floored at 4 — the slack is what keeps steady-state rounds free of
/// relocations (and therefore of allocations).
fn seg_cap_for(len: usize) -> usize {
    len.next_power_of_two().max(4)
}

/// The assignment of loads to the n processors, stored as a
/// structure-of-arrays arena (see the module docs for the layout).
///
/// Equality is *logical*: two states are equal when every node carries
/// the same load sequence (and the id counter matches), regardless of
/// how the slots happen to be laid out in the arena.
#[derive(Clone, Debug)]
pub struct LoadState {
    /// Arena column: load ids.
    ids: Vec<u64>,
    /// Arena column: load weights.
    weights: Vec<f64>,
    /// Arena column: mobility bitset, one bit per slot.
    mobile: Vec<u64>,
    /// Per-node slot ranges.
    segs: Vec<Seg>,
    /// Per-node cached weight sums — bitwise equal to a fresh left-fold
    /// of the node's weights at all times.
    totals: Vec<f64>,
    /// First arena slot not owned by any segment.
    frontier: usize,
    /// Sum of segment capacities; `frontier - live` is the abandoned
    /// (relocated-away-from) space the next compaction reclaims.
    live: usize,
    next_id: u64,
}

impl PartialEq for LoadState {
    fn eq(&self, other: &Self) -> bool {
        if self.n() != other.n() || self.next_id != other.next_id {
            return false;
        }
        (0..self.n()).all(|v| {
            let (a, b) = (self.node(v), other.node(v));
            a.len() == b.len() && a.iter().eq(b.iter())
        })
    }
}

/// Minimum nodes per worker before the chunked weight reduction spawns
/// threads.
///
/// Retuned for the arena layout: the reduction now scans the cached
/// per-node `totals` column (~1 ns/node of pure streaming arithmetic)
/// instead of re-summing every load, while a scoped spawn/join barrier
/// still costs tens of microseconds.  The break-even is therefore
/// ~50–100k nodes *per worker*; below that, threading the fold would
/// regress the round loop it is meant to speed up.  See EXPERIMENTS.md
/// §Perf for the retune note (the old AoS threshold was 8192).
pub const REDUCE_CHUNK_MIN: usize = 262_144;

impl LoadState {
    pub fn empty(n: usize) -> Self {
        Self {
            ids: Vec::new(),
            weights: Vec::new(),
            mobile: Vec::new(),
            segs: vec![
                Seg {
                    start: 0,
                    len: 0,
                    cap: 0
                };
                n
            ],
            totals: vec![0.0; n],
            frontier: 0,
            live: 0,
            next_id: 0,
        }
    }

    /// The paper's §6 initialization: `per_node` loads on every node, each
    /// weight drawn i.i.d. from `dist`, then the mobility model applied.
    pub fn init_uniform_counts(
        n: usize,
        per_node: usize,
        dist: &WeightDistribution,
        mobility: Mobility,
        rng: &mut Pcg64,
    ) -> Self {
        let mut state = Self::empty(n);
        // Pre-size every segment with its steady-state slack in one
        // allocation, so the fill below never relocates.
        let cap = if per_node == 0 { 0 } else { seg_cap_for(per_node) };
        state.grow_columns(n * cap);
        for (v, seg) in state.segs.iter_mut().enumerate() {
            *seg = Seg {
                start: v * cap,
                len: 0,
                cap,
            };
        }
        state.frontier = n * cap;
        state.live = n * cap;
        for v in 0..n {
            for _ in 0..per_node {
                let id = state.next_id;
                state.next_id += 1;
                let w = dist.sample(rng);
                let s = state.segs[v].start + state.segs[v].len;
                state.ids[s] = id;
                state.weights[s] = w;
                state.set_bit(s, true);
                state.segs[v].len += 1;
                state.totals[v] += w;
            }
        }
        if mobility == Mobility::Partial {
            state.pin_random(rng);
        }
        state
    }

    /// Pin r ∈ U{1..m−1} random loads on every node with m ≥ 2 loads.
    pub fn pin_random(&mut self, rng: &mut Pcg64) {
        for v in 0..self.segs.len() {
            let seg = self.segs[v];
            let m = seg.len;
            if m < 2 {
                continue;
            }
            let r = rng.range_inclusive(1, m - 1);
            for idx in rng.sample_indices(m, r) {
                self.set_bit(seg.start + idx, false);
            }
        }
    }

    pub fn n(&self) -> usize {
        self.segs.len()
    }

    /// Read-only view of node v's load sequence.
    pub fn node(&self, v: usize) -> NodeView<'_> {
        let seg = self.segs[v];
        NodeView {
            ids: &self.ids,
            weights: &self.weights,
            bits: &self.mobile,
            start: seg.start,
            len: seg.len,
        }
    }

    pub fn push(&mut self, v: usize, load: Load) {
        self.next_id = self.next_id.max(load.id + 1);
        self.append_slot(v, load);
    }

    /// Total weight on node v — O(1): the cached total is maintained
    /// bitwise equal to a fresh in-order fold of the node's weights.
    pub fn node_weight(&self, v: usize) -> f64 {
        self.totals[v]
    }

    /// Weight of the pinned loads on node v.
    pub fn pinned_weight(&self, v: usize) -> f64 {
        let seg = self.segs[v];
        let mut w = 0.0f64;
        for k in seg.start..seg.start + seg.len {
            if !self.bit(k) {
                w += self.weights[k];
            }
        }
        w
    }

    /// The load vector x^(t) (paper §2).
    pub fn load_vector(&self) -> Vec<f64> {
        self.totals.clone()
    }

    pub fn total_weight(&self) -> f64 {
        self.totals.iter().sum()
    }

    pub fn total_loads(&self) -> usize {
        self.segs.iter().map(|s| s.len).sum()
    }

    /// Discrepancy: weight difference between heaviest and lightest node.
    pub fn discrepancy(&self) -> f64 {
        let (min, max) = self.weight_extremes();
        max - min
    }

    /// `(min, max)` node weight, folded in node order over the cached
    /// totals — the scalar reduction behind
    /// [`discrepancy`](Self::discrepancy), now O(n) in nodes rather
    /// than O(total loads).
    pub fn weight_extremes(&self) -> (f64, f64) {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for &w in &self.totals {
            min = min.min(w);
            max = max.max(w);
        }
        (min, max)
    }

    /// [`weight_extremes`](Self::weight_extremes) fanned out over up to
    /// `threads` scoped workers, each folding a contiguous chunk of the
    /// totals column.
    ///
    /// Bit-identical to the scalar fold for every thread count: both
    /// paths read the same cached totals, and f64 min/max are exactly
    /// associative and commutative (no rounding), so chunking cannot
    /// change the result.  Small states (under [`REDUCE_CHUNK_MIN`]
    /// nodes per worker) take the scalar path — the thread fan-out
    /// would cost more than the fold.
    pub fn weight_extremes_threaded(&self, threads: usize) -> (f64, f64) {
        self.weight_extremes_chunked(threads, REDUCE_CHUNK_MIN)
    }

    /// The chunked reduction with an explicit spawn threshold — lets
    /// tests exercise the threaded path at test-sized n without waiting
    /// on a [`REDUCE_CHUNK_MIN`]-sized state.
    pub(crate) fn weight_extremes_chunked(&self, threads: usize, chunk_min: usize) -> (f64, f64) {
        let workers = threads
            .max(1)
            .min((self.totals.len() / chunk_min.max(1)).max(1));
        if workers <= 1 {
            return self.weight_extremes();
        }
        let chunk = self.totals.len().div_ceil(workers);
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .totals
                .chunks(chunk)
                .map(|part| {
                    scope.spawn(move || {
                        let mut min = f64::INFINITY;
                        let mut max = f64::NEG_INFINITY;
                        for &w in part {
                            min = min.min(w);
                            max = max.max(w);
                        }
                        (min, max)
                    })
                })
                .collect();
            handles.into_iter().fold(
                (f64::INFINITY, f64::NEG_INFINITY),
                |(amin, amax), h| {
                    let (min, max) = h.join().expect("reduction worker panicked");
                    (amin.min(min), amax.max(max))
                },
            )
        })
    }

    /// [`discrepancy`](Self::discrepancy) over the chunked reduction.
    pub fn discrepancy_threaded(&self, threads: usize) -> f64 {
        let (min, max) = self.weight_extremes_threaded(threads);
        max - min
    }

    /// Largest single load in the network (l_max, Appendix A req. 4).
    pub fn max_load_weight(&self) -> f64 {
        let mut max = 0.0f64;
        for seg in &self.segs {
            for k in seg.start..seg.start + seg.len {
                max = max.max(self.weights[k]);
            }
        }
        max
    }

    /// Remove and return the mobile loads of node v (pinned loads stay,
    /// compacted in order to the front of the segment).
    pub fn take_mobile(&mut self, v: usize) -> Vec<Load> {
        let seg = self.segs[v];
        let mut mobile = Vec::new();
        let mut w = 0usize;
        for k in 0..seg.len {
            let s = seg.start + k;
            if self.bit(s) {
                mobile.push(Load {
                    id: self.ids[s],
                    weight: self.weights[s],
                    mobile: true,
                });
            } else {
                let d = seg.start + w;
                if d != s {
                    self.ids[d] = self.ids[s];
                    self.weights[d] = self.weights[s];
                    self.set_bit(d, false);
                }
                w += 1;
            }
        }
        self.segs[v].len = w;
        self.refold_total(v);
        mobile
    }

    /// Remove and return *all* of node v's loads (the sharded
    /// coordinator's carve step; the id counter is untouched).
    pub fn take_node(&mut self, v: usize) -> Vec<Load> {
        let out = self.node(v).to_vec();
        self.segs[v].len = 0;
        self.totals[v] = 0.0;
        out
    }

    /// Append loads to node v.
    pub fn give(&mut self, v: usize, loads: impl IntoIterator<Item = Load>) {
        for l in loads {
            self.append_slot(v, l);
        }
    }

    /// Gather the edge (u, v) into `pool`: u's mobile loads tagged 0,
    /// then v's tagged 1, in node order — exactly the pool
    /// `balancer::balance_pair` builds — plus the pinned base sums.
    /// `partitioned[side]` reports whether that node already stores all
    /// pinned loads before any mobile one, which is what lets a no-move
    /// decision skip the write-back entirely
    /// (`balancer::apply_is_noop`).
    pub fn gather_edge(&self, u: usize, v: usize, pool: &mut Vec<(Load, u8)>) -> EdgeGather {
        pool.clear();
        let mut base = [0.0f64; 2];
        let mut partitioned = [true; 2];
        for (side, x) in [u, v].into_iter().enumerate() {
            let seg = self.segs[x];
            let mut seen_mobile = false;
            for k in seg.start..seg.start + seg.len {
                if self.bit(k) {
                    seen_mobile = true;
                    pool.push((
                        Load {
                            id: self.ids[k],
                            weight: self.weights[k],
                            mobile: true,
                        },
                        side as u8,
                    ));
                } else {
                    if seen_mobile {
                        partitioned[side] = false;
                    }
                    base[side] += self.weights[k];
                }
            }
        }
        EdgeGather { base, partitioned }
    }

    /// Write an edge decision back: each node becomes its pinned loads
    /// (compacted in order) followed by the pool entries routed to it
    /// (`dest[i]` is 0 for u, 1 for v) in pool order — the same
    /// sequence the historical `take_mobile` + `give` pair produced.
    pub fn apply_edge(&mut self, u: usize, v: usize, pool: &[(Load, u8)], dest: &[u8]) {
        debug_assert_eq!(pool.len(), dest.len());
        self.apply_side(u, 0, pool, dest);
        self.apply_side(v, 1, pool, dest);
    }

    fn apply_side(&mut self, x: usize, tag: u8, pool: &[(Load, u8)], dest: &[u8]) {
        let incoming = dest.iter().filter(|&&d| d == tag).count();
        let seg = self.segs[x];
        let mut pinned = 0usize;
        for k in seg.start..seg.start + seg.len {
            if !self.bit(k) {
                pinned += 1;
            }
        }
        if pinned + incoming > seg.cap {
            self.relocate(x, seg_cap_for(pinned + incoming));
        }
        let seg = self.segs[x];
        let mut w = 0usize;
        for k in 0..seg.len {
            let s = seg.start + k;
            if !self.bit(s) {
                let d = seg.start + w;
                if d != s {
                    self.ids[d] = self.ids[s];
                    self.weights[d] = self.weights[s];
                    self.set_bit(d, false);
                }
                w += 1;
            }
        }
        for (i, &(l, _)) in pool.iter().enumerate() {
            if dest[i] == tag {
                let s = seg.start + w;
                self.ids[s] = l.id;
                self.weights[s] = l.weight;
                self.set_bit(s, true);
                w += 1;
            }
        }
        debug_assert_eq!(w, pinned + incoming);
        self.segs[x].len = w;
        self.refold_total(x);
    }

    /// Hand out concurrently-usable views of the matching `pairs`.
    ///
    /// Edges within one BCM color class are vertex-disjoint by
    /// construction, so every edge's two segments alias nothing another
    /// edge touches: the views can be balanced concurrently (the
    /// foundation of `bcm::parallel`).  Panics if `pairs` is not a
    /// matching (a vertex repeats, a self-loop, or an index out of
    /// range) — the disjointness check is what makes the pointer
    /// fan-out sound.  `seen` is a caller-owned scratch buffer
    /// (re-zeroed here) so steady-state rounds validate without
    /// allocating.
    pub fn split_pairs<'a>(
        &'a mut self,
        pairs: &'a [(u32, u32)],
        seen: &mut Vec<bool>,
    ) -> EdgeViews<'a> {
        let n = self.segs.len();
        seen.clear();
        seen.resize(n, false);
        for &(u, v) in pairs {
            let (u, v) = (u as usize, v as usize);
            assert!(u < n && v < n, "split_pairs: edge ({u},{v}) out of range for n={n}");
            assert!(u != v, "split_pairs: self-loop ({u},{v})");
            assert!(
                !seen[u] && !seen[v],
                "split_pairs: vertex reused by ({u},{v}) — pairs are not a matching"
            );
            seen[u] = true;
            seen[v] = true;
        }
        EdgeViews {
            ids: self.ids.as_mut_ptr(),
            weights: self.weights.as_mut_ptr(),
            bits: self.mobile.as_mut_ptr(),
            segs: self.segs.as_mut_ptr(),
            totals: self.totals.as_mut_ptr(),
            pairs,
            _state: PhantomData,
        }
    }

    /// The next id [`push`](Self::push) would consider fresh — the
    /// high-water mark over every id this state has ever stored.
    pub fn next_id(&self) -> u64 {
        self.next_id
    }

    /// Raise the id high-water mark to at least `next` without storing a
    /// load.  Used when a state is reassembled from surviving loads
    /// (cluster shutdown) but the original run also *saw* ids that have
    /// since departed: equality with the reference state requires the
    /// same high-water mark, not just the same survivors.
    pub fn reserve_ids(&mut self, next: u64) {
        self.next_id = self.next_id.max(next);
    }

    /// Remove the `k % mobile-count`-th mobile load of node v (by
    /// occurrence order), preserving the relative order of everything
    /// else — the churn `Depart` op.  No-op returning `None` when the
    /// node has no mobile load.  The cached total is re-folded so it
    /// stays bitwise equal to a fresh in-order fold.
    pub fn remove_mobile_mod(&mut self, v: usize, k: u64) -> Option<Load> {
        let seg = self.segs[v];
        let mobiles = (0..seg.len).filter(|&i| self.bit(seg.start + i)).count();
        if mobiles == 0 {
            return None;
        }
        let target = (k % mobiles as u64) as usize;
        let mut seen = 0usize;
        let mut at = usize::MAX;
        for i in 0..seg.len {
            if self.bit(seg.start + i) {
                if seen == target {
                    at = i;
                    break;
                }
                seen += 1;
            }
        }
        debug_assert_ne!(at, usize::MAX);
        let s = seg.start + at;
        let out = Load {
            id: self.ids[s],
            weight: self.weights[s],
            mobile: true,
        };
        for i in at + 1..seg.len {
            let s = seg.start + i;
            self.ids[s - 1] = self.ids[s];
            self.weights[s - 1] = self.weights[s];
            let b = self.bit(s);
            self.set_bit(s - 1, b);
        }
        self.segs[v].len -= 1;
        self.refold_total(v);
        Some(out)
    }

    /// Scale the weight of the `k % len`-th load of node v by `factor`
    /// in place — the churn `Drift` op.  No-op returning `false` when
    /// the node is empty.  Multiplication is a single IEEE-754 rounding,
    /// so the result is bitwise deterministic; the cached total is
    /// re-folded afterwards.
    pub fn scale_load_mod(&mut self, v: usize, k: u64, factor: f64) -> bool {
        let seg = self.segs[v];
        if seg.len == 0 {
            return false;
        }
        let s = seg.start + (k % seg.len as u64) as usize;
        self.weights[s] *= factor;
        self.refold_total(v);
        true
    }

    /// Sorted ids across the whole network (conservation checks).
    pub fn all_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = Vec::with_capacity(self.total_loads());
        for seg in &self.segs {
            ids.extend_from_slice(&self.ids[seg.start..seg.start + seg.len]);
        }
        ids.sort_unstable();
        ids
    }

    // ---- arena internals ----

    #[inline]
    fn bit(&self, i: usize) -> bool {
        (self.mobile[i >> 6] >> (i & 63)) & 1 == 1
    }

    #[inline]
    fn set_bit(&mut self, i: usize, v: bool) {
        let mask = 1u64 << (i & 63);
        if v {
            self.mobile[i >> 6] |= mask;
        } else {
            self.mobile[i >> 6] &= !mask;
        }
    }

    /// Re-fold node v's cached total from its weight column (in slot
    /// order — the same order the AoS implementation summed).
    fn refold_total(&mut self, v: usize) {
        let seg = self.segs[v];
        let mut t = 0.0f64;
        for k in seg.start..seg.start + seg.len {
            t += self.weights[k];
        }
        self.totals[v] = t;
    }

    /// Grow the arena columns to at least `cap` slots.
    fn grow_columns(&mut self, cap: usize) {
        if self.ids.len() < cap {
            self.ids.resize(cap, 0);
            self.weights.resize(cap, 0.0);
        }
        let words = cap.div_ceil(64);
        if self.mobile.len() < words {
            self.mobile.resize(words, 0);
        }
    }

    /// Append one load to node v, relocating the segment if it is full.
    fn append_slot(&mut self, v: usize, l: Load) {
        let seg = self.segs[v];
        if seg.len == seg.cap {
            self.relocate(v, seg_cap_for(seg.len + 1));
        }
        let seg = self.segs[v];
        let s = seg.start + seg.len;
        self.ids[s] = l.id;
        self.weights[s] = l.weight;
        self.set_bit(s, l.mobile);
        self.segs[v].len += 1;
        self.totals[v] += l.weight;
    }

    /// Move node v's segment to the arena frontier with `new_cap` slots,
    /// compacting the whole arena first when the abandoned space has
    /// reached the live capacity (amortized O(1) per relocated slot).
    fn relocate(&mut self, v: usize, new_cap: usize) {
        debug_assert!(new_cap >= self.segs[v].len);
        if self.live > 0 && self.frontier - self.live >= self.live {
            self.compact();
        }
        let seg = self.segs[v];
        let dst = self.frontier;
        self.grow_columns(dst + new_cap);
        self.ids.copy_within(seg.start..seg.start + seg.len, dst);
        self.weights.copy_within(seg.start..seg.start + seg.len, dst);
        for k in 0..seg.len {
            let b = self.bit(seg.start + k);
            self.set_bit(dst + k, b);
        }
        self.segs[v] = Seg {
            start: dst,
            len: seg.len,
            cap: new_cap,
        };
        self.frontier = dst + new_cap;
        debug_assert!(new_cap >= seg.cap);
        self.live += new_cap - seg.cap;
    }

    /// Slide every segment down over the abandoned ranges, in arena
    /// order.  Destinations never pass sources (segments are disjoint
    /// and processed in ascending start order), so the forward copies
    /// are safe.
    fn compact(&mut self) {
        let mut order: Vec<usize> = (0..self.segs.len()).collect();
        order.sort_unstable_by_key(|&v| self.segs[v].start);
        let mut cursor = 0usize;
        for &v in &order {
            let seg = self.segs[v];
            debug_assert!(cursor <= seg.start);
            if seg.start != cursor {
                self.ids.copy_within(seg.start..seg.start + seg.len, cursor);
                self.weights
                    .copy_within(seg.start..seg.start + seg.len, cursor);
                for k in 0..seg.len {
                    let b = self.bit(seg.start + k);
                    self.set_bit(cursor + k, b);
                }
                self.segs[v].start = cursor;
            }
            cursor += seg.cap;
        }
        self.frontier = cursor;
        debug_assert_eq!(self.frontier, self.live);
    }
}

/// What [`LoadState::gather_edge`] learned about an edge: the two pinned
/// base sums and whether each endpoint is already stored
/// pinned-prefix-first (see `balancer::apply_is_noop`).
#[derive(Clone, Copy, Debug)]
pub struct EdgeGather {
    /// Pinned weight sums of the two endpoints, folded in node order.
    pub base: [f64; 2],
    /// Whether each endpoint's slots hold every pinned load before any
    /// mobile one (true from the first write-back on).
    pub partitioned: [bool; 2],
}

/// Read-only view of one node's load sequence inside the arena.
///
/// Iteration yields [`Load`] values (not references) assembled from the
/// three columns, so all pre-arena call sites — `iter().any(..)`,
/// `iter().filter(|l| ..)`, `for l in state.node(v)` — keep working.
#[derive(Clone, Copy)]
pub struct NodeView<'a> {
    ids: &'a [u64],
    weights: &'a [f64],
    bits: &'a [u64],
    start: usize,
    len: usize,
}

impl<'a> NodeView<'a> {
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// The i-th load of the node (panics when out of range).
    pub fn get(&self, i: usize) -> Load {
        assert!(i < self.len, "load index {i} out of range for node of {}", self.len);
        let s = self.start + i;
        Load {
            id: self.ids[s],
            weight: self.weights[s],
            mobile: (self.bits[s >> 6] >> (s & 63)) & 1 == 1,
        }
    }

    pub fn iter(&self) -> NodeIter<'a> {
        NodeIter {
            view: *self,
            pos: 0,
        }
    }

    pub fn to_vec(&self) -> Vec<Load> {
        self.iter().collect()
    }
}

impl<'a> IntoIterator for NodeView<'a> {
    type Item = Load;
    type IntoIter = NodeIter<'a>;

    fn into_iter(self) -> NodeIter<'a> {
        self.iter()
    }
}

impl<'a> IntoIterator for &NodeView<'a> {
    type Item = Load;
    type IntoIter = NodeIter<'a>;

    fn into_iter(self) -> NodeIter<'a> {
        self.iter()
    }
}

/// Iterator over a [`NodeView`], yielding [`Load`] values.
pub struct NodeIter<'a> {
    view: NodeView<'a>,
    pos: usize,
}

impl Iterator for NodeIter<'_> {
    type Item = Load;

    fn next(&mut self) -> Option<Load> {
        if self.pos >= self.view.len {
            return None;
        }
        let l = self.view.get(self.pos);
        self.pos += 1;
        Some(l)
    }

    fn size_hint(&self) -> (usize, Option<usize>) {
        let left = self.view.len - self.pos;
        (left, Some(left))
    }
}

impl ExactSizeIterator for NodeIter<'_> {}

/// Concurrently-usable per-edge access to a matching's endpoint
/// segments, as handed out by [`LoadState::split_pairs`].
///
/// The matching validation guarantees every vertex appears in at most
/// one edge, so two threads working on *different* edges touch disjoint
/// `ids`/`weights`/`segs`/`totals` slots.  The mobility **bitset** is
/// the exception: segment boundaries are not word-aligned, so
/// neighboring segments can share a `u64` word — which is why every bit
/// access on this path is a `Relaxed` atomic (`fetch_or`/`fetch_and`
/// commute for disjoint bits, and each bit has exactly one writer, so
/// the result is deterministic).  Mixing atomic and plain accesses on
/// the same word would be UB; the `&mut LoadState` borrow held by this
/// struct keeps the plain-access methods unreachable while any view is
/// live.
pub struct EdgeViews<'a> {
    ids: *mut u64,
    weights: *mut f64,
    bits: *mut u64,
    segs: *mut Seg,
    totals: *mut f64,
    pairs: &'a [(u32, u32)],
    _state: PhantomData<&'a mut LoadState>,
}

// SAFETY: the raw pointers target a LoadState exclusively borrowed for
// 'a, and the per-edge methods only touch the two segments of their
// edge — vertex-disjoint across edges by the split_pairs validation —
// with all bitset words accessed atomically.
unsafe impl Send for EdgeViews<'_> {}
unsafe impl Sync for EdgeViews<'_> {}

impl EdgeViews<'_> {
    /// Number of edges in the matching.
    pub fn len(&self) -> usize {
        self.pairs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.pairs.is_empty()
    }

    /// The endpoints of edge `e`.
    pub fn pair(&self, e: usize) -> (u32, u32) {
        self.pairs[e]
    }

    /// Arena-view counterpart of [`LoadState::gather_edge`].
    ///
    /// # Safety
    ///
    /// Edge `e` must not be gathered or applied concurrently by another
    /// thread (partition the matching's edge indices across workers —
    /// different edges are always safe concurrently).
    pub unsafe fn gather(&self, e: usize, pool: &mut Vec<(Load, u8)>) -> EdgeGather {
        let (u, v) = self.pairs[e];
        pool.clear();
        let mut base = [0.0f64; 2];
        let mut partitioned = [true; 2];
        for (side, x) in [u as usize, v as usize].into_iter().enumerate() {
            let seg = *self.segs.add(x);
            let mut seen_mobile = false;
            for k in seg.start..seg.start + seg.len {
                if self.bit_atomic(k) {
                    seen_mobile = true;
                    pool.push((
                        Load {
                            id: *self.ids.add(k),
                            weight: *self.weights.add(k),
                            mobile: true,
                        },
                        side as u8,
                    ));
                } else {
                    if seen_mobile {
                        partitioned[side] = false;
                    }
                    base[side] += *self.weights.add(k);
                }
            }
        }
        EdgeGather { base, partitioned }
    }

    /// Arena-view counterpart of [`LoadState::apply_edge`], *without*
    /// relocation: returns `false` — mutating nothing — when either
    /// endpoint's new length would exceed its segment capacity, in
    /// which case the caller must defer the write-back to the owner of
    /// the `&mut LoadState` (who can relocate).
    ///
    /// # Safety
    ///
    /// Same contract as [`gather`](Self::gather): edge `e` must not be
    /// processed concurrently by another thread.
    pub unsafe fn try_apply(&self, e: usize, pool: &[(Load, u8)], dest: &[u8]) -> bool {
        debug_assert_eq!(pool.len(), dest.len());
        let (u, v) = self.pairs[e];
        let (u, v) = (u as usize, v as usize);
        let (su, sv) = (*self.segs.add(u), *self.segs.add(v));
        let mut inc = [0usize; 2];
        for &d in dest {
            inc[d as usize] += 1;
        }
        // Check both sides before mutating either: a half-applied edge
        // could not be handed back for deferred application.
        if self.count_pinned(su) + inc[0] > su.cap || self.count_pinned(sv) + inc[1] > sv.cap {
            return false;
        }
        self.apply_side_raw(u, 0, pool, dest);
        self.apply_side_raw(v, 1, pool, dest);
        true
    }

    unsafe fn count_pinned(&self, seg: Seg) -> usize {
        let mut pinned = 0usize;
        for k in seg.start..seg.start + seg.len {
            if !self.bit_atomic(k) {
                pinned += 1;
            }
        }
        pinned
    }

    unsafe fn apply_side_raw(&self, x: usize, tag: u8, pool: &[(Load, u8)], dest: &[u8]) {
        let seg = *self.segs.add(x);
        let mut w = 0usize;
        for k in 0..seg.len {
            let s = seg.start + k;
            if !self.bit_atomic(s) {
                let d = seg.start + w;
                if d != s {
                    *self.ids.add(d) = *self.ids.add(s);
                    *self.weights.add(d) = *self.weights.add(s);
                    self.set_bit_atomic(d, false);
                }
                w += 1;
            }
        }
        for (i, &(l, _)) in pool.iter().enumerate() {
            if dest[i] == tag {
                let s = seg.start + w;
                *self.ids.add(s) = l.id;
                *self.weights.add(s) = l.weight;
                self.set_bit_atomic(s, true);
                w += 1;
            }
        }
        (*self.segs.add(x)).len = w;
        let mut t = 0.0f64;
        for k in seg.start..seg.start + w {
            t += *self.weights.add(k);
        }
        *self.totals.add(x) = t;
    }

    #[inline]
    unsafe fn bit_word(&self, i: usize) -> &AtomicU64 {
        // SAFETY (of the cast): AtomicU64 has the same layout as u64,
        // and *every* hot-path access to the bitset words goes through
        // this atomic view while EdgeViews is live.
        &*(self.bits.add(i >> 6) as *const AtomicU64)
    }

    #[inline]
    unsafe fn bit_atomic(&self, i: usize) -> bool {
        (self.bit_word(i).load(Ordering::Relaxed) >> (i & 63)) & 1 == 1
    }

    #[inline]
    unsafe fn set_bit_atomic(&self, i: usize, v: bool) {
        let mask = 1u64 << (i & 63);
        if v {
            self.bit_word(i).fetch_or(mask, Ordering::Relaxed);
        } else {
            self.bit_word(i).fetch_and(!mask, Ordering::Relaxed);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(per_node: usize, mobility: Mobility, seed: u64) -> LoadState {
        let mut rng = Pcg64::new(seed);
        LoadState::init_uniform_counts(
            8,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        )
    }

    #[test]
    fn init_counts_and_ids() {
        let s = mk(10, Mobility::Full, 1);
        assert_eq!(s.n(), 8);
        assert_eq!(s.total_loads(), 80);
        let ids = s.all_ids();
        assert_eq!(ids, (0..80).collect::<Vec<u64>>());
    }

    #[test]
    fn full_mobility_all_mobile() {
        let s = mk(10, Mobility::Full, 2);
        assert!((0..s.n()).all(|v| s.node(v).iter().all(|l| l.mobile)));
    }

    #[test]
    fn partial_mobility_pins_some_not_all() {
        let s = mk(10, Mobility::Partial, 3);
        for v in 0..8 {
            let pinned = s.node(v).iter().filter(|l| !l.mobile).count();
            assert!(
                (1..10).contains(&pinned),
                "node {v}: {pinned} pinned of 10"
            );
        }
    }

    #[test]
    fn single_load_nodes_not_pinned() {
        let mut rng = Pcg64::new(4);
        let mut s = LoadState::empty(2);
        s.push(0, Load::new(0, 1.0));
        s.pin_random(&mut rng);
        assert!(s.node(0).get(0).mobile);
    }

    #[test]
    fn weights_and_discrepancy() {
        let mut s = LoadState::empty(3);
        s.push(0, Load::new(0, 5.0));
        s.push(0, Load::new(1, 3.0));
        s.push(2, Load::new(2, 1.0));
        assert_eq!(s.node_weight(0), 8.0);
        assert_eq!(s.node_weight(1), 0.0);
        assert_eq!(s.load_vector(), vec![8.0, 0.0, 1.0]);
        assert_eq!(s.discrepancy(), 8.0);
        assert_eq!(s.total_weight(), 9.0);
        assert_eq!(s.max_load_weight(), 5.0);
    }

    #[test]
    fn take_mobile_leaves_pinned() {
        let mut s = LoadState::empty(1);
        s.push(0, Load::new(0, 1.0));
        s.push(0, Load::pinned(1, 2.0));
        s.push(0, Load::new(2, 3.0));
        let taken = s.take_mobile(0);
        assert_eq!(taken.len(), 2);
        assert_eq!(s.node(0).len(), 1);
        assert_eq!(s.node(0).get(0).id, 1);
        assert_eq!(s.pinned_weight(0), 2.0);
        assert_eq!(s.node_weight(0), 2.0);
        s.give(0, taken);
        assert_eq!(s.node(0).len(), 3);
        assert_eq!(s.node_weight(0), 6.0);
    }

    #[test]
    fn take_node_empties_and_preserves_order() {
        let mut s = LoadState::empty(2);
        s.push(1, Load::new(0, 1.0));
        s.push(1, Load::pinned(1, 2.0));
        s.push(1, Load::new(2, 3.0));
        let taken = s.take_node(1);
        assert_eq!(
            taken.iter().map(|l| l.id).collect::<Vec<_>>(),
            vec![0, 1, 2]
        );
        assert!(!taken[1].mobile);
        assert_eq!(s.node(1).len(), 0);
        assert_eq!(s.node_weight(1), 0.0);
        assert_eq!(s.total_loads(), 0);
    }

    #[test]
    fn arena_grows_and_compacts_transparently() {
        // Push far past every relocation threshold on interleaved nodes
        // so segments relocate repeatedly and compaction triggers; the
        // logical content must never notice.
        let n = 16;
        let mut s = LoadState::empty(n);
        let mut id = 0u64;
        for round in 0..200 {
            for v in 0..n {
                s.push(v, Load::new(id, (round * n + v) as f64 * 0.5));
                id += 1;
            }
        }
        assert_eq!(s.total_loads(), 200 * n);
        for v in 0..n {
            let node = s.node(v);
            assert_eq!(node.len(), 200);
            // in push order: ids v, v+n, v+2n, ...
            for (i, l) in node.iter().enumerate() {
                assert_eq!(l.id, (v + i * n) as u64);
            }
            let fresh: f64 = node.iter().map(|l| l.weight).sum();
            assert_eq!(fresh, s.node_weight(v), "cached total diverged on {v}");
        }
        assert_eq!(s.all_ids(), (0..200 * n as u64).collect::<Vec<u64>>());
    }

    #[test]
    fn logical_equality_survives_different_layouts() {
        // Same content, different arena history => equal states.
        let mut a = LoadState::empty(2);
        let mut b = LoadState::empty(2);
        for i in 0..20u64 {
            a.push((i % 2) as usize, Load::new(i, i as f64));
        }
        // b takes a detour: big on node 0 first, then rebuilt
        for i in 0..64u64 {
            b.push(0, Load::new(100 + i, 1.0));
        }
        let _ = b.take_node(0);
        let _ = b.take_node(1);
        for i in 0..20u64 {
            b.push((i % 2) as usize, Load::new(i, i as f64));
        }
        assert_eq!(a, b);
        let mut c = a.clone();
        assert_eq!(a, c);
        let moved = c.take_mobile(0);
        c.give(1, moved);
        assert_ne!(a, c);
    }

    #[test]
    fn gather_apply_roundtrip_matches_take_give() {
        // apply_edge(gather_edge(..)) with dest == original hosts must
        // reproduce exactly what take_mobile + give produced.
        let mut a = mk(6, Mobility::Partial, 17);
        let mut b = a.clone();
        let mut pool = Vec::new();
        let g = a.gather_edge(2, 5, &mut pool);
        let base_check = [b.pinned_weight(2), b.pinned_weight(5)];
        assert_eq!(g.base, base_check);
        let dest: Vec<u8> = pool.iter().map(|&(_, h)| h).collect();
        a.apply_edge(2, 5, &pool, &dest);
        let m2 = b.take_mobile(2);
        let m5 = b.take_mobile(5);
        b.give(2, m2);
        b.give(5, m5);
        assert_eq!(a, b);
        // after a write-back both endpoints are pinned-prefix partitioned
        let g2 = a.gather_edge(2, 5, &mut pool);
        assert_eq!(g2.partitioned, [true, true]);
    }

    #[test]
    fn split_pairs_views_gather_and_apply() {
        let mut s = mk(5, Mobility::Full, 9);
        let total_before = s.total_loads();
        let sequential = {
            let mut t = s.clone();
            let mut pool = Vec::new();
            let _ = t.gather_edge(0, 3, &mut pool);
            // route everything to node 3
            let dest = vec![1u8; pool.len()];
            t.apply_edge(0, 3, &pool, &dest);
            t
        };
        {
            let mut seen = Vec::new();
            let pairs = [(0u32, 3u32), (1, 2)];
            let views = s.split_pairs(&pairs, &mut seen);
            assert_eq!(views.len(), 2);
            assert_eq!(views.pair(0), (0, 3));
            let mut pool = Vec::new();
            // SAFETY: single-threaded; each edge processed once.
            let g = unsafe { views.gather(0, &mut pool) };
            assert_eq!(g.base, [0.0, 0.0]);
            let dest = vec![1u8; pool.len()];
            if !unsafe { views.try_apply(0, &pool, &dest) } {
                // capacity overflow: fall back to the owning state
                drop(views);
                s.apply_edge(0, 3, &pool, &dest);
            }
        }
        assert_eq!(s.node(0).len(), 0);
        assert_eq!(s.node(3).len(), 10);
        assert_eq!(s.total_loads(), total_before);
        assert_eq!(s, sequential);
    }

    #[test]
    fn try_apply_refuses_capacity_overflow_without_mutating() {
        let mut s = LoadState::empty(4);
        // node 1 sized so receiving node 0's loads overflows its cap
        for i in 0..4u64 {
            s.push(0, Load::new(i, 1.0));
        }
        s.push(1, Load::new(10, 1.0));
        let before = s.clone();
        let cap1 = seg_cap_for(1).max(4);
        let mut seen = Vec::new();
        let mut pool = Vec::new();
        let pairs = [(0u32, 1u32)];
        let views = s.split_pairs(&pairs, &mut seen);
        let _ = unsafe { views.gather(0, &mut pool) };
        // everything to node 1: 5 loads > its cap of `cap1`
        assert!(pool.len() > cap1);
        let dest = vec![1u8; pool.len()];
        assert!(!unsafe { views.try_apply(0, &pool, &dest) });
        drop(views);
        assert_eq!(s, before, "failed try_apply must not mutate");
        // the owning state can: it relocates
        s.apply_edge(0, 1, &pool, &dest);
        assert_eq!(s.node(1).len(), 5);
        assert_eq!(s.node(0).len(), 0);
    }

    #[test]
    #[should_panic(expected = "not a matching")]
    fn split_pairs_rejects_repeated_vertex() {
        let mut s = mk(2, Mobility::Full, 10);
        let mut seen = Vec::new();
        let _ = s.split_pairs(&[(0, 1), (1, 2)], &mut seen);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn split_pairs_rejects_self_loop() {
        let mut s = mk(2, Mobility::Full, 11);
        let mut seen = Vec::new();
        let _ = s.split_pairs(&[(3, 3)], &mut seen);
    }

    #[test]
    fn threaded_weight_extremes_bit_identical_to_scalar() {
        // Exercise the actually-chunked path through the test-only
        // threshold override; REDUCE_CHUNK_MIN-sized states would be
        // debug-build-slow for no extra coverage.
        let mut rng = Pcg64::new(42);
        let n = 1024;
        let mut s = LoadState::empty(n);
        for v in 0..n {
            for j in 0..1 + (v % 3) {
                s.push(v, Load::new((v * 4 + j) as u64, rng.uniform(0.0, 10.0)));
            }
        }
        let scalar = s.weight_extremes();
        for threads in [1, 2, 3, 4, 8, 64] {
            assert_eq!(
                s.weight_extremes_chunked(threads, 64),
                scalar,
                "diverged at {threads} threads"
            );
        }
        // the public API spawns nothing below REDUCE_CHUNK_MIN nodes
        // per worker but must agree regardless
        assert_eq!(s.weight_extremes_threaded(8), scalar);
        assert_eq!(s.discrepancy_threaded(4), s.discrepancy());
        // empty nodes participate with weight 0 in both paths
        let mut t = LoadState::empty(n);
        t.push(0, Load::new(0, 5.0));
        assert_eq!(t.weight_extremes_chunked(8, 64), t.weight_extremes());
        assert_eq!(t.weight_extremes(), (0.0, 5.0));
    }

    #[test]
    fn mobility_parse() {
        assert_eq!(Mobility::parse("full"), Some(Mobility::Full));
        assert_eq!(Mobility::parse("partial"), Some(Mobility::Partial));
        assert_eq!(Mobility::parse("x"), None);
    }
}
