//! Multi-tenant service throughput: a ladder of concurrent job counts
//! on one shared [`ShardPool`] (the engine behind `bcm-dlb serve`),
//! measuring aggregate rounds/s across tenants.
//!
//! Every job's trace is checked bit-identical against `bcm::Sequential`
//! before its time is reported, so this bench doubles as a
//! multi-tenancy determinism smoke test: tenants interleaved on the
//! same workers must not perturb each other.
//!
//! `cargo bench --bench service_throughput` runs the n=1024 scenarios;
//! `-- --smoke` (or `BCM_DLB_SMOKE=1` / `BCM_DLB_QUICK=1`) derates to
//! n=128, 1 sweep for CI.  Smoke runs enforce the
//! `[service_throughput.smoke] min_rounds_per_s` floor from
//! `bench_floor.toml`; `-- --no-floor` skips the gate, and hosts with
//! fewer cores than the recorded `pinned_cores` skip it automatically
//! with a notice.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, RunTrace, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::{JobEvent, JobSpec, ShardPool};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::table::{f, Table};
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

const ALGO: PairAlgorithm = PairAlgorithm::SortedGreedy(SortAlgo::Quick);

fn read_floor(path: &Path, section: &str, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut in_section = false;
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            in_section = name.trim() == section;
        } else if in_section {
            if let Some((k, v)) = line.split_once('=') {
                if k.trim() == key {
                    return v.trim().parse().ok();
                }
            }
        }
    }
    None
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

/// One tenant, seeded exactly like `bcm-dlb run`'s first repetition.
fn make_tenant(n: usize, sweeps: usize, seed: u64) -> (JobSpec, RunTrace) {
    let mut rng = Pcg64::new(seed);
    let g = Topology::Torus2d.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        10,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let mut seq_state = state.clone();
    let seq_trace = Sequential.run(
        &mut seq_state,
        &schedule,
        ALGO,
        StopRule::sweeps(sweeps),
        seed,
    );
    (
        JobSpec {
            state,
            schedule,
            algo: ALGO,
            sweeps,
            seed,
            batch: 0,
            checkpoint_every: 0,
            churn: None,
        },
        seq_trace,
    )
}

/// Run `jobs` tenants concurrently; returns (secs, total rounds) or an
/// error string on divergence/failure.
fn run_fleet(jobs: usize, n: usize, sweeps: usize) -> Result<(f64, usize), String> {
    let mut pool = ShardPool::spawn(0);
    let mut refs: BTreeMap<u32, RunTrace> = BTreeMap::new();
    let start = std::time::Instant::now();
    for j in 0..jobs {
        let (spec, seq_trace) = make_tenant(n, sweeps, 1000 + j as u64);
        let id = pool.open_job(spec).map_err(|e| e.to_string())?;
        refs.insert(id, seq_trace);
    }
    let mut total_rounds = 0usize;
    let mut open = refs.len();
    while open > 0 {
        let events = pool.step(Duration::from_millis(20)).map_err(|e| e.to_string())?;
        for ev in events {
            match ev {
                JobEvent::Finished { job, trace, .. } => {
                    open -= 1;
                    total_rounds += trace.rounds.len();
                    if &trace != refs.get(&job).expect("known job") {
                        return Err(format!("job {job} diverged from Sequential"));
                    }
                }
                JobEvent::Failed { job, error } => {
                    return Err(format!("job {job} failed: {error}"));
                }
                _ => {}
            }
        }
    }
    let secs = start.elapsed().as_secs_f64();
    pool.shutdown().map_err(|e| e.to_string())?;
    Ok((secs, total_rounds))
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || env_flag("BCM_DLB_SMOKE")
        || env_flag("BCM_DLB_QUICK");
    let (n, sweeps) = if smoke { (128, 1) } else { (1024, 2) };
    let job_ladder = [1usize, 2, 4];
    eprintln!(
        "service_throughput: torus2d n={n}, sweeps={sweeps}, job ladder {job_ladder:?}{}",
        if smoke { " (smoke)" } else { "" }
    );

    let mut t = Table::new(
        "service throughput (one shared shard pool, every tenant verified vs Sequential)",
        &["concurrent jobs", "total rounds", "secs", "rounds/s"],
    );
    let mut best_rps: f64 = 0.0;
    let mut failed = false;
    for jobs in job_ladder {
        match run_fleet(jobs, n, sweeps) {
            Ok((secs, rounds)) => {
                let rps = rounds as f64 / secs.max(1e-12);
                best_rps = best_rps.max(rps);
                t.row(vec![
                    jobs.to_string(),
                    rounds.to_string(),
                    f(secs, 3),
                    f(rps, 0),
                ]);
            }
            Err(e) => {
                eprintln!("service_throughput: {jobs} jobs failed: {e}");
                failed = true;
            }
        }
    }
    println!("{}", t.render());
    t.write_csv(Path::new("results/service_throughput.csv")).ok();

    if smoke && !args.iter().any(|a| a == "--no-floor") {
        let floor_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_floor.toml");
        // the floor was pinned on a `pinned_cores` container; a smaller
        // host cannot hold it — skip with a notice instead of failing
        let host_cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        let pinned = read_floor(&floor_path, "service_throughput.smoke", "pinned_cores");
        let undersized = match pinned {
            Some(p) => (host_cores as f64) < p,
            None => false,
        };
        if undersized {
            eprintln!(
                "service_throughput: perf floor SKIPPED — this host has {host_cores} \
                 core(s), fewer than the bench_floor.toml pinned_cores the floor was \
                 pinned on"
            );
        } else {
            match read_floor(&floor_path, "service_throughput.smoke", "min_rounds_per_s") {
                Some(floor) if best_rps < floor => {
                    eprintln!(
                        "REGRESSION: best service throughput {} rounds/s is below the \
                         bench_floor.toml floor of {} rounds/s",
                        f(best_rps, 0),
                        f(floor, 0)
                    );
                    failed = true;
                }
                Some(floor) => {
                    eprintln!(
                        "perf floor ok: {} rounds/s >= {} rounds/s floor",
                        f(best_rps, 0),
                        f(floor, 0)
                    );
                }
                None => {
                    eprintln!(
                        "REGRESSION GATE BROKEN: no parsable [service_throughput.smoke] \
                         min_rounds_per_s in {} (use --no-floor to bypass deliberately)",
                        floor_path.display()
                    );
                    failed = true;
                }
            }
        }
    }
    if failed {
        std::process::exit(1);
    }
}
