//! E5 — regenerates paper Fig. 5 (Appendix C): offline balls-into-bins
//! discrepancy vs number of bins n, for m = 1024 and m = 3027 balls.
//!
//! Shape expectations: Greedy rises quickly then saturates; SortedGreedy
//! rises much more slowly (consistent with Talwar & Wieder's dependence
//! on both the distribution and n).

use bcm_dlb::experiments::figures;
use std::path::Path;

fn main() {
    let quick = std::env::var("BCM_DLB_QUICK").map(|v| v == "1").unwrap_or(false);
    let reps = if quick { 100 } else { 1000 };
    let start = std::time::Instant::now();
    for t in figures::fig5(reps, 2013, Path::new("results")) {
        println!("{}", t.render());
    }
    eprintln!("fig5 completed in {:.1}s", start.elapsed().as_secs_f64());
}
