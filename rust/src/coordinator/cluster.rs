//! The sharded leader: spawns one worker per core (each owning a
//! contiguous node shard), drives the BCM schedule in batches of rounds,
//! folds per-shard metrics, and tears the cluster down into a final
//! `LoadState`.  All I/O goes through a pluggable
//! [`LeaderTransport`]: in-process channels for the thread-per-shard
//! spawns, or TCP sockets ([`Cluster::spawn_tcp`] /
//! [`Cluster::spawn_tcp_connect`]) when the workers are separate OS
//! processes.
//!
//! This is the deployment shape the paper assumes (§1) at shard
//! granularity: the leader is pure control plane (schedule + metrics) —
//! load payloads only ever travel between the shards a cut edge spans,
//! so per-round traffic is O(cross-shard edges + shards / B) where `B`
//! is the round batch: the leader dispatches `B` rounds per
//! [`Ctl::RunBatch`] and receives one coalesced [`Report::Batch`] per
//! shard, amortizing the leader round-trip that dominates wall-clock at
//! large `n`.  Within a batch workers pipeline freely (see
//! [`worker`](super::worker)), synchronized only by their cut edges.
//!
//! Determinism: rounds are keyed by a run seed (`run_seeded`) and every
//! edge draws from `Pcg64::for_edge(seed, round, edge)`, so the trace and
//! final state are **bit-identical** to `bcm::Sequential` (and
//! `bcm::Parallel`) for every (shard count, batch size) combination —
//! asserted by `tests/property_invariants.rs`.

use super::messages::{Ctl, Report};
use super::shard::{RoundPlan, ShardMap};
use super::transport::tcp::{InitPayload, LeaderListener, TcpLeader};
use super::transport::{local, LeaderTransport, TransportError};
use super::worker::{ShardWorker, WorkerAlgo};
use crate::anyhow;
use crate::balancer::PairAlgorithm;
use crate::bcm::{RoundStats, RunTrace, Schedule};
use crate::load::{Load, LoadState};
use crate::util::error::{Error, Result};
use crate::util::rng::Pcg64;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// How long the leader waits on worker reports, per dispatched round,
/// before declaring the cluster wedged (a worker panic no longer blocks
/// forever).  Scaled by the batch size — a `RunBatch` only reports after
/// all of its rounds — and kept above the workers' equally-scaled peer
/// timeout so a genuine fault is blamed on the right shard and round.
const ROUND_TIMEOUT: Duration = Duration::from_secs(60);
const SHUTDOWN_TIMEOUT: Duration = Duration::from_secs(10);

/// `ROUND_TIMEOUT` scaled to a batch of `rounds` rounds.
fn batch_timeout(rounds: usize) -> Duration {
    ROUND_TIMEOUT.saturating_mul(u32::try_from(rounds).unwrap_or(u32::MAX))
}

/// Resolve the rounds-per-control-message knob: `0` = auto, which picks
/// `max(1, n / 16384)` — batching only pays once leader round-trips
/// dominate the per-round work, which empirically needs n >= 65536 for
/// B >= 4 (the open ROADMAP scale); smaller networks keep lock-step
/// B = 1.  Any explicit value is used as-is (clamped to >= 1).
pub fn resolve_batch_rounds(batch: usize, n: usize) -> usize {
    if batch == 0 {
        (n / 16384).max(1)
    } else {
        batch
    }
}

/// Carve `state` into per-shard node lists (each worker owns its slice
/// exclusively; the leader keeps only the empty husk).
fn carve(state: &mut LoadState, map: &ShardMap) -> Vec<Vec<Vec<Load>>> {
    (0..map.shards())
        .map(|s| {
            map.range(s)
                .map(|v| std::mem::take(state.node_mut(v)))
                .collect()
        })
        .collect()
}

/// Build the per-worker `Init` payloads of a TCP spawn.
fn tcp_inits(state: &mut LoadState, map: &ShardMap, algo: PairAlgorithm) -> Vec<InitPayload> {
    carve(state, map)
        .into_iter()
        .enumerate()
        .map(|(s, nodes)| InitPayload {
            lo: map.range(s).start,
            algo: algo.name(),
            nodes,
        })
        .collect()
}

/// Leader-side message accounting, used to assert the sharding
/// communication contract: leader traffic is O(shards / batch) per round
/// and worker-to-worker traffic is O(cross-shard edges).
#[derive(Clone, Copy, Debug, Default)]
pub struct MessageStats {
    /// Control messages the leader sent (one per shard per batch/poll).
    pub ctl_sent: usize,
    /// Reports the leader received (one per shard per batch/poll).
    pub reports_received: usize,
    /// Worker-to-worker messages (Offer + Settle: two per cross edge).
    pub peer_msgs: usize,
    /// Cross-shard edges encountered across all rounds run.
    pub cross_edges: usize,
    /// Rounds executed.
    pub rounds: usize,
    /// Batches dispatched (each a `Ctl::RunBatch` per shard).
    pub batches: usize,
}

/// The sharded cluster handle: owns the leader side of the transport
/// (and, on the local backend, the worker threads) and exposes the
/// seeded run API.
pub struct Cluster {
    map: ShardMap,
    transport: Box<dyn LeaderTransport>,
    /// Worker thread handles (empty on the TCP backend, where workers
    /// are separate processes).
    handles: Vec<JoinHandle<()>>,
    stats: MessageStats,
    /// Rounds dispatched per leader control message (0 = auto); resolved
    /// through [`resolve_batch_rounds`] at run time.
    batch_rounds: usize,
    /// Shards that reported a fatal error and exited (they will send no
    /// `Final` on shutdown).
    dead: Vec<bool>,
    /// First worker failure seen, re-surfaced by `shutdown`.
    failure: Option<String>,
}

impl Cluster {
    /// Spawn with one worker per available core.
    pub fn spawn(state: LoadState, algo: WorkerAlgo) -> Cluster {
        Self::spawn_sharded(state, algo, 0)
    }

    /// Spawn with an explicit shard count (`0` = one worker per core);
    /// the count is clamped to the node count.
    pub fn spawn_sharded(state: LoadState, algo: WorkerAlgo, shards: usize) -> Cluster {
        Self::spawn_with_algorithm(state, algo.pair(), shards)
    }

    /// Spawn with any local [`PairAlgorithm`] — the entry point that
    /// reproduces an engine run with the same algorithm bit-exactly.
    /// The state is carved into contiguous per-shard slices, each owned
    /// exclusively by its worker.
    pub fn spawn_with_algorithm(
        state: LoadState,
        algo: PairAlgorithm,
        shards: usize,
    ) -> Cluster {
        Self::spawn_inner(state, algo, shards, None)
    }

    /// Fault-injection spawn for tests: worker `fault.0` panics at the
    /// start of global round `fault.1`, exercising the mid-batch
    /// fail-stop contract.
    #[doc(hidden)]
    pub fn spawn_with_fault(
        state: LoadState,
        algo: WorkerAlgo,
        shards: usize,
        fault: (usize, usize),
    ) -> Cluster {
        Self::spawn_inner(state, algo.pair(), shards, Some(fault))
    }

    fn spawn_inner(
        mut state: LoadState,
        algo: PairAlgorithm,
        shards: usize,
        fault: Option<(usize, usize)>,
    ) -> Cluster {
        let map = ShardMap::new(state.n(), shards);
        let k = map.shards();
        let shard_nodes = carve(&mut state, &map);
        let (leader, workers) = local::pair(k);
        let mut handles = Vec::with_capacity(k);
        for (s, (transport, nodes)) in workers.into_iter().zip(shard_nodes).enumerate() {
            let worker = ShardWorker {
                shard: s,
                lo: map.range(s).start,
                nodes,
                algo,
                transport: Box::new(transport),
                fail_at_round: match fault {
                    Some((fs, fr)) if fs == s => Some(fr),
                    _ => None,
                },
            };
            handles.push(std::thread::spawn(move || {
                // a worker's failure already reached the leader as a
                // Report::Error; the return value only matters for
                // worker *processes* (exit codes)
                let _ = worker.run();
            }));
        }
        let dead = vec![false; k];
        Cluster {
            map,
            transport: Box::new(leader),
            handles,
            stats: MessageStats::default(),
            batch_rounds: 0,
            dead,
            failure: None,
        }
    }

    /// Spawn a cluster whose workers are separate OS processes speaking
    /// TCP: accept `shards` worker connections on `listener` (each
    /// started with `bcm-dlb cluster-worker --connect <addr>`), ship
    /// every worker its shard of `state`, and return the leader handle.
    /// The run API and the bit-identity contract are exactly those of
    /// the in-process spawns.
    pub fn spawn_tcp(
        mut state: LoadState,
        algo: PairAlgorithm,
        shards: usize,
        listener: LeaderListener,
    ) -> Result<Cluster> {
        if shards == 0 {
            return Err(anyhow!(
                "the tcp transport needs an explicit worker count (--shards >= 1): \
                 workers are external processes, not cores"
            ));
        }
        let map = ShardMap::new(state.n(), shards);
        if map.shards() != shards {
            // never leave extra worker processes dangling in the accept
            // queue: surface the clamp instead
            return Err(anyhow!(
                "{} shards requested for a {}-node network (at most one shard per node)",
                shards,
                state.n()
            ));
        }
        let inits = tcp_inits(&mut state, &map, algo);
        let transport = TcpLeader::accept(listener, inits)?;
        Ok(Self::from_transport(map, Box::new(transport)))
    }

    /// Spawn a TCP cluster by dialing one listening worker per entry of
    /// `peers` (each started with `bcm-dlb cluster-worker --listen
    /// <addr>`); worker `i` becomes shard `i`.
    pub fn spawn_tcp_connect(
        mut state: LoadState,
        algo: PairAlgorithm,
        peers: &[String],
    ) -> Result<Cluster> {
        if peers.is_empty() {
            return Err(anyhow!("the tcp transport needs at least one worker address"));
        }
        let map = ShardMap::new(state.n(), peers.len());
        if map.shards() != peers.len() {
            return Err(anyhow!(
                "{} worker addresses for a {}-node network (at most one shard per node)",
                peers.len(),
                state.n()
            ));
        }
        let inits = tcp_inits(&mut state, &map, algo);
        let transport = TcpLeader::connect(peers, inits)?;
        Ok(Self::from_transport(map, Box::new(transport)))
    }

    fn from_transport(map: ShardMap, transport: Box<dyn LeaderTransport>) -> Cluster {
        let dead = vec![false; map.shards()];
        Cluster {
            map,
            transport,
            handles: Vec::new(),
            stats: MessageStats::default(),
            batch_rounds: 0,
            dead,
            failure: None,
        }
    }

    /// Record a worker's fatal report: the shard sends no `Final` on
    /// shutdown, and the failure is re-surfaced there.
    fn worker_error(&mut self, shard: usize, message: String) -> Error {
        self.dead[shard] = true;
        let msg = format!("cluster worker {shard}: {message}");
        if self.failure.is_none() {
            self.failure = Some(msg.clone());
        }
        Error::msg(msg)
    }

    /// Any round/poll error leaves leader and workers desynchronized
    /// (e.g. a timed-out report could be attributed to a later round), so
    /// the cluster fails stop: further rounds are refused until shutdown.
    fn check_failed(&self) -> Result<()> {
        match &self.failure {
            Some(msg) => Err(anyhow!("cluster has failed, shutdown required: {msg}")),
            None => Ok(()),
        }
    }

    /// Record any error escaping a round/poll so [`check_failed`]
    /// poisons subsequent calls.
    fn poison_on_err<T>(&mut self, result: Result<T>) -> Result<T> {
        if let Err(e) = &result {
            if self.failure.is_none() {
                self.failure = Some(e.to_string());
            }
        }
        result
    }

    /// Number of nodes the cluster balances.
    pub fn n(&self) -> usize {
        self.map.n()
    }

    /// Resolved worker count.
    pub fn shards(&self) -> usize {
        self.map.shards()
    }

    /// Set the number of rounds dispatched per leader control message
    /// (`0` = auto, see [`resolve_batch_rounds`]).  Purely a performance
    /// knob: the determinism contract holds at every (shards, batch)
    /// combination because no RNG state crosses messages.
    pub fn set_batch_rounds(&mut self, batch: usize) {
        self.batch_rounds = batch;
    }

    /// The resolved rounds-per-control-message this cluster dispatches.
    pub fn batch_rounds(&self) -> usize {
        resolve_batch_rounds(self.batch_rounds, self.n())
    }

    /// Leader-side message accounting since spawn.
    pub fn message_stats(&self) -> MessageStats {
        self.stats
    }

    /// Drive `sweeps` full sweeps of the schedule.  The run seed is drawn
    /// from `rng`; use [`run_seeded`](Self::run_seeded) to reproduce an
    /// engine run bit-exactly.
    pub fn run(
        &mut self,
        schedule: &Schedule,
        sweeps: usize,
        rng: &mut Pcg64,
    ) -> Result<RunTrace> {
        self.run_seeded(schedule, sweeps, rng.next_u64())
    }

    /// Drive `sweeps` sweeps with counter-based per-edge randomness: the
    /// resulting trace and final state are bit-identical to
    /// `bcm::Sequential::run(.., StopRule::sweeps(sweeps), seed)` for any
    /// shard count and any batch size
    /// ([`set_batch_rounds`](Self::set_batch_rounds)).
    pub fn run_seeded(
        &mut self,
        schedule: &Schedule,
        sweeps: usize,
        seed: u64,
    ) -> Result<RunTrace> {
        assert_eq!(schedule.n(), self.n(), "state/schedule size mismatch");
        let d = schedule.period();
        // one classification per color, shared across sweeps and batches
        // (zero-copy per dispatch: workers receive Arcs)
        let plans: Arc<Vec<Arc<RoundPlan>>> = Arc::new(
            (0..d)
                .map(|c| Arc::new(RoundPlan::build(schedule.matching(c), &self.map)))
                .collect(),
        );
        let total = sweeps * d;
        let batch = self.batch_rounds();
        let mut trace = RunTrace {
            initial_discrepancy: self.poll_discrepancy()?,
            rounds: Vec::with_capacity(total),
        };
        let mut start = 0usize;
        while start < total {
            let b = batch.min(total - start);
            let colors = schedule.lookahead_colors(start, b);
            let stats = self.batch_with_plans(start, &colors, seed, &plans)?;
            trace.rounds.extend(stats);
            start += b;
        }
        Ok(trace)
    }

    /// Execute one round (matching `round % d`); the round's seed is
    /// drawn from `rng`.
    pub fn run_single_round(
        &mut self,
        schedule: &Schedule,
        round: usize,
        rng: &mut Pcg64,
    ) -> Result<RoundStats> {
        self.run_round_seeded(schedule, round, rng.next_u64())
    }

    /// Execute one round of a run keyed by `seed` (the per-edge streams
    /// also depend on `round`, so repeating all rounds of a run through
    /// this entry point reproduces [`run_seeded`](Self::run_seeded)).
    pub fn run_round_seeded(
        &mut self,
        schedule: &Schedule,
        round: usize,
        seed: u64,
    ) -> Result<RoundStats> {
        assert_eq!(schedule.n(), self.n(), "state/schedule size mismatch");
        let plans: Arc<Vec<Arc<RoundPlan>>> = Arc::new(vec![Arc::new(RoundPlan::build(
            schedule.matching(round),
            &self.map,
        ))]);
        let colors = [schedule.color_of(round)];
        let mut stats = self.batch_with_plans(round, &colors, seed, &plans)?;
        debug_assert_eq!(stats.len(), 1);
        stats.pop().ok_or_else(|| anyhow!("empty batch result"))
    }

    /// Run one batch behind the fail-stop guard.  `colors[i]` is the
    /// schedule color of round `start_round + i` (recorded in the trace);
    /// the plan of round `r` is `plans[r % plans.len()]`, mirroring the
    /// worker's indexing.
    fn batch_with_plans(
        &mut self,
        start_round: usize,
        colors: &[usize],
        seed: u64,
        plans: &Arc<Vec<Arc<RoundPlan>>>,
    ) -> Result<Vec<RoundStats>> {
        self.check_failed()?;
        let result = self.batch_inner(start_round, colors, seed, plans);
        self.poison_on_err(result)
    }

    fn batch_inner(
        &mut self,
        start_round: usize,
        colors: &[usize],
        seed: u64,
        plans: &Arc<Vec<Arc<RoundPlan>>>,
    ) -> Result<Vec<RoundStats>> {
        let b = colors.len();
        let d = plans.len();
        let mut edges = Vec::with_capacity(b);
        for i in 0..b {
            let plan = &plans[(start_round + i) % d];
            edges.push(plan.edges);
            self.stats.cross_edges += plan.cross_edges;
        }
        self.stats.rounds += b;
        self.stats.batches += 1;
        // dispatch: one RunBatch per shard covers all b rounds
        for s in 0..self.map.shards() {
            let msg = Ctl::RunBatch {
                start_round,
                rounds: b,
                seed,
                plans: plans.clone(),
            };
            if let Err(e) = self.transport.send_ctl(s, msg) {
                let msg = format!("control link closed before batch at round {start_round}: {e}");
                return Err(self.worker_error(s, msg));
            }
            self.stats.ctl_sent += 1;
        }
        // collect: one coalesced report per shard, folded per round
        let mut movements = vec![0usize; b];
        let mut min = vec![f64::INFINITY; b];
        let mut max = vec![f64::NEG_INFINITY; b];
        let wait = batch_timeout(b);
        for _ in 0..self.map.shards() {
            match self.recv_report("batch reports", wait)? {
                Report::Batch { shard, rounds } => {
                    if rounds.len() != b {
                        return Err(anyhow!(
                            "shard {shard} reported {} rounds for a {b}-round batch \
                             starting at round {start_round}",
                            rounds.len()
                        ));
                    }
                    for (i, r) in rounds.iter().enumerate() {
                        if r.round != start_round + i {
                            return Err(anyhow!(
                                "shard {shard} report out of order: round {} at slot {i} \
                                 of the batch starting at round {start_round}",
                                r.round
                            ));
                        }
                        movements[i] += r.movements;
                        min[i] = min[i].min(r.min_weight);
                        max[i] = max[i].max(r.max_weight);
                        self.stats.peer_msgs += r.peer_msgs;
                    }
                }
                Report::Error {
                    shard,
                    round,
                    message,
                } => {
                    let msg = match round {
                        Some(r) => format!("failed at round {r}: {message}"),
                        None => message,
                    };
                    return Err(self.worker_error(shard, msg));
                }
                other => {
                    return Err(anyhow!(
                        "unexpected report during batch at round {start_round}: {other:?}"
                    ))
                }
            }
        }
        Ok((0..b)
            .map(|i| RoundStats {
                round: start_round + i,
                color: colors[i],
                discrepancy: max[i] - min[i],
                movements: movements[i],
                edges: edges[i],
            })
            .collect())
    }

    /// Poll every shard's node weights and fold the global discrepancy —
    /// the same min/max fold `LoadState::discrepancy` performs.
    pub fn poll_discrepancy(&mut self) -> Result<f64> {
        let w = self.poll_weights()?;
        let max = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = w.iter().cloned().fold(f64::INFINITY, f64::min);
        Ok(max - min)
    }

    /// The per-node weight vector, assembled from one report per shard.
    pub fn poll_weights(&mut self) -> Result<Vec<f64>> {
        self.check_failed()?;
        let result = self.poll_weights_inner();
        self.poison_on_err(result)
    }

    fn poll_weights_inner(&mut self) -> Result<Vec<f64>> {
        for s in 0..self.map.shards() {
            if let Err(e) = self.transport.send_ctl(s, Ctl::PollWeights) {
                let msg = format!("control link closed during weight poll: {e}");
                return Err(self.worker_error(s, msg));
            }
            self.stats.ctl_sent += 1;
        }
        let mut w = vec![0.0f64; self.n()];
        for _ in 0..self.map.shards() {
            match self.recv_report("weight reports", ROUND_TIMEOUT)? {
                Report::Weights { shard, weights } => {
                    let range = self.map.range(shard);
                    debug_assert_eq!(weights.len(), range.len());
                    w[range].copy_from_slice(&weights);
                }
                Report::Error {
                    shard,
                    round: _,
                    message,
                } => return Err(self.worker_error(shard, message)),
                other => return Err(anyhow!("unexpected report while polling weights: {other:?}")),
            }
        }
        Ok(w)
    }

    fn recv_report(&mut self, what: &str, wait: Duration) -> Result<Report> {
        match self.transport.recv_report(wait) {
            Ok(r) => {
                self.stats.reports_received += 1;
                Ok(r)
            }
            Err(TransportError::Timeout) => Err(anyhow!(
                "timed out after {}s waiting for {what} (a worker likely panicked)",
                wait.as_secs()
            )),
            Err(TransportError::Closed(why)) => Err(anyhow!(
                "all cluster workers terminated while waiting for {what}: {why}"
            )),
        }
    }

    /// Shut the cluster down, join every worker, and reassemble the final
    /// `LoadState`.  Worker panics and protocol violations surface as
    /// errors instead of being silently discarded.
    pub fn shutdown(self) -> Result<LoadState> {
        let Cluster {
            map,
            mut transport,
            handles,
            dead,
            failure,
            ..
        } = self;
        for s in 0..map.shards() {
            // a worker that already exited is surfaced below
            let _ = transport.send_ctl(s, Ctl::Shutdown);
        }
        let mut state = LoadState::empty(map.n());
        let mut first_err: Option<Error> = failure.map(Error::msg);
        // shards that already died reported their error and send no Final
        let mut expected = dead.iter().filter(|&&d| !d).count();
        let mut got = 0usize;
        let mut timed_out = false;
        while got < expected {
            match transport.recv_report(SHUTDOWN_TIMEOUT) {
                Ok(Report::Final { shard, nodes }) => {
                    let lo = map.range(shard).start;
                    for (i, loads) in nodes.into_iter().enumerate() {
                        for l in loads {
                            state.push(lo + i, l);
                        }
                    }
                    got += 1;
                }
                Ok(Report::Error {
                    shard,
                    round,
                    message,
                }) => {
                    // that worker exits without sending a Final
                    first_err.get_or_insert_with(|| match round {
                        Some(r) => {
                            anyhow!("cluster worker {shard}: failed at round {r}: {message}")
                        }
                        None => anyhow!("cluster worker {shard}: {message}"),
                    });
                    expected = expected.saturating_sub(1);
                }
                // stale Batch/Weights reports can remain queued when a
                // run was aborted mid-batch; drain them
                Ok(_) => {}
                Err(_) => {
                    timed_out = true;
                    first_err
                        .get_or_insert_with(|| anyhow!("timed out collecting final shard states"));
                    break;
                }
            }
        }
        if !timed_out {
            // every worker has returned (Final or Error), so the joins
            // are immediate; skip them only when a wedged worker could
            // block forever
            for h in handles {
                if let Err(p) = h.join() {
                    let msg = super::worker::panic_message(p.as_ref());
                    first_err.get_or_insert_with(|| anyhow!("cluster worker panicked: {msg}"));
                }
            }
        }
        match first_err {
            None => Ok(state),
            Some(e) => Err(e),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::{PairAlgorithm, SortAlgo};
    use crate::bcm::{Engine, Sequential, StopRule};
    use crate::graph::Graph;
    use crate::load::{Load, Mobility, WeightDistribution};

    fn init(
        n: usize,
        per_node: usize,
        mobility: Mobility,
        seed: u64,
    ) -> (LoadState, Schedule, Pcg64) {
        let mut rng = Pcg64::new(seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            n,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        );
        (state, schedule, rng)
    }

    #[test]
    fn cluster_balances_and_conserves() {
        let (state, schedule, mut rng) = init(8, 30, Mobility::Full, 1);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init_disc = state.discrepancy();
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        let trace = cluster.run(&schedule, 8, &mut rng).unwrap();
        let final_state = cluster.shutdown().unwrap();
        assert_eq!(final_state.all_ids(), ids);
        assert!((final_state.total_weight() - mass).abs() < 1e-6);
        assert!(
            trace.final_discrepancy() < init_disc / 10.0,
            "init {init_disc} final {}",
            trace.final_discrepancy()
        );
        // the trace's own view agrees with the final state
        assert!((final_state.discrepancy() - trace.final_discrepancy()).abs() < 1e-9);
    }

    #[test]
    fn cluster_greedy_runs() {
        let (state, schedule, mut rng) = init(6, 20, Mobility::Partial, 2);
        let lmax = state.max_load_weight();
        let mut cluster = Cluster::spawn_sharded(state, WorkerAlgo::Greedy, 3);
        let trace = cluster.run(&schedule, 4, &mut rng).unwrap();
        // greedy can overshoot by at most the single-load quantum
        assert!(trace.final_discrepancy() <= trace.initial_discrepancy + lmax + 1e-9);
        cluster.shutdown().unwrap();
    }

    #[test]
    fn cluster_bit_identical_to_sequential_engine() {
        // The tentpole contract: same seed => same RunTrace and same
        // final LoadState as the sequential reference, for shard counts
        // 1, 2 and one-per-core.
        let (state0, schedule, _) = init(8, 40, Mobility::Full, 3);
        let seed = 77;
        let sweeps = 6;
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(sweeps),
            seed,
        );
        let cores = crate::coordinator::shard::resolve_shards(0);
        for shards in [1, 2, cores] {
            let mut cluster =
                Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
            let trace = cluster.run_seeded(&schedule, sweeps, seed).unwrap();
            let fin = cluster.shutdown().unwrap();
            assert_eq!(trace, seq_trace, "trace diverged at {shards} shards");
            assert_eq!(fin, seq_state, "state diverged at {shards} shards");
        }
    }

    #[test]
    fn batched_runs_bit_identical_at_every_batch_size() {
        // The batching extension of the tentpole contract: the pipelined
        // batched execution must not be observable in the results, for
        // any (shards, batch) combination including one batch covering
        // the whole run.
        let (state0, schedule, _) = init(10, 25, Mobility::Full, 8);
        let seed = 31;
        let sweeps = 4;
        let total_rounds = sweeps * schedule.period();
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(sweeps),
            seed,
        );
        for shards in [2usize, 3] {
            for batch in [1usize, 3, total_rounds] {
                let mut cluster =
                    Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
                cluster.set_batch_rounds(batch);
                assert_eq!(cluster.batch_rounds(), batch);
                let trace = cluster.run_seeded(&schedule, sweeps, seed).unwrap();
                let fin = cluster.shutdown().unwrap();
                assert_eq!(
                    trace, seq_trace,
                    "trace diverged at {shards} shards, batch {batch}"
                );
                assert_eq!(
                    fin, seq_state,
                    "state diverged at {shards} shards, batch {batch}"
                );
            }
        }
    }

    #[test]
    fn cluster_bit_identical_with_pinned_and_partial_mobility() {
        let (mut state0, schedule, _) = init(12, 8, Mobility::Partial, 9);
        state0.push(3, Load::pinned(10_000, 75.0));
        state0.push(0, Load::pinned(10_001, 5.0));
        let seed = 1234;
        let mut seq_state = state0.clone();
        let seq_trace = Sequential.run(
            &mut seq_state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(4),
            seed,
        );
        for shards in [1usize, 2, 3, 5] {
            let mut cluster =
                Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, shards);
            let trace = cluster.run_seeded(&schedule, 4, seed).unwrap();
            let fin = cluster.shutdown().unwrap();
            assert_eq!(trace, seq_trace, "trace diverged at {shards} shards");
            assert_eq!(fin, seq_state, "state diverged at {shards} shards");
            // the heavy pinned load never left its host
            assert!(fin.node(3).iter().any(|l| l.id == 10_000 && !l.mobile));
        }
    }

    #[test]
    fn leader_messages_scale_with_cut_not_n() {
        // Contiguous shards on a ring: the cut is exactly `shards` edges,
        // so per-round traffic must be O(shards), not O(n) — and batching
        // must shrink the leader's share by the batch factor.
        let n = 64;
        let shards = 4;
        let sweeps = 3;
        let g = Graph::ring(n);
        let schedule = Schedule::from_graph(&g);
        let mk_state = || {
            let mut rng = Pcg64::new(5);
            LoadState::init_uniform_counts(
                n,
                4,
                &WeightDistribution::paper_section6(),
                Mobility::Full,
                &mut rng,
            )
        };
        let mut cluster = Cluster::spawn_sharded(mk_state(), WorkerAlgo::SortedGreedy, shards);
        cluster.set_batch_rounds(1);
        cluster.run_seeded(&schedule, sweeps, 9).unwrap();
        let stats = cluster.message_stats();
        cluster.shutdown().unwrap();
        let rounds = sweeps * schedule.period();
        assert_eq!(stats.rounds, rounds);
        assert_eq!(stats.batches, rounds);
        // each of the ring's k cut edges appears once per sweep
        assert_eq!(stats.cross_edges, shards * sweeps);
        // exactly one Offer + one Settle per cross-shard edge
        assert_eq!(stats.peer_msgs, 2 * stats.cross_edges);
        // leader traffic: k ctl + k reports per round, plus one weight
        // poll (k + k) for the initial discrepancy — O(shards), never O(n)
        let leader_msgs = stats.ctl_sent + stats.reports_received;
        assert_eq!(leader_msgs, 2 * shards * (rounds + 1));
        assert!(
            leader_msgs < n * rounds,
            "leader messaging is O(n) again: {leader_msgs} msgs for {rounds} rounds"
        );

        // Batched rerun on the same ring: the per-round leader component
        // must shrink to exactly 1/B of the unbatched count (the poll is
        // batch-independent), while peer traffic stays pinned to the cut.
        let batch = 3;
        assert_eq!(rounds % batch, 0, "test wants an integral batch count");
        let mut batched = Cluster::spawn_sharded(mk_state(), WorkerAlgo::SortedGreedy, shards);
        batched.set_batch_rounds(batch);
        batched.run_seeded(&schedule, sweeps, 9).unwrap();
        let bstats = batched.message_stats();
        batched.shutdown().unwrap();
        assert_eq!(bstats.rounds, rounds);
        assert_eq!(bstats.batches, rounds / batch);
        assert_eq!(bstats.cross_edges, stats.cross_edges);
        assert_eq!(bstats.peer_msgs, stats.peer_msgs);
        let batched_leader = bstats.ctl_sent + bstats.reports_received;
        let poll = 2 * shards; // one PollWeights + one Weights per shard
        assert_eq!(
            batched_leader - poll,
            (leader_msgs - poll) / batch,
            "batching did not amortize leader round-trips by {batch}x"
        );
    }

    #[test]
    fn worker_panic_mid_batch_names_the_failing_round() {
        // A worker that dies inside a batch must surface an error naming
        // the round it died in, and the cluster must fail stop.
        let (state, schedule, _) = init(8, 10, Mobility::Full, 11);
        let fail_round = 3;
        let mut cluster =
            Cluster::spawn_with_fault(state, WorkerAlgo::SortedGreedy, 1, (0, fail_round));
        cluster.set_batch_rounds(schedule.period() * 3); // whole run in one batch
        let sweeps = 3;
        assert!(sweeps * schedule.period() > fail_round, "fault round never reached");
        let err = cluster
            .run_seeded(&schedule, sweeps, 5)
            .expect_err("injected fault did not surface")
            .to_string();
        assert!(
            err.contains(&format!("round {fail_round}")),
            "error does not name the failing round: {err}"
        );
        assert!(err.contains("injected fault"), "panic payload lost: {err}");
        // fail-stop: the poisoned cluster refuses further rounds and
        // re-surfaces the failure on shutdown
        assert!(cluster.run_seeded(&schedule, 1, 5).is_err());
        assert!(cluster.shutdown().is_err());
    }

    #[test]
    fn pinned_loads_survive_distributed_run() {
        let mut rng = Pcg64::new(4);
        let g = Graph::ring(4);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::empty(4);
        state.push(1, crate::load::Load::pinned(0, 42.0));
        state.push(0, crate::load::Load::new(1, 1.0));
        state.push(2, crate::load::Load::new(2, 2.0));
        let mut cluster = Cluster::spawn_sharded(state, WorkerAlgo::SortedGreedy, 2);
        cluster.run(&schedule, 3, &mut rng).unwrap();
        let fin = cluster.shutdown().unwrap();
        assert!(fin.node(1).iter().any(|l| l.id == 0 && !l.mobile));
        assert_eq!(fin.total_loads(), 3);
    }

    #[test]
    fn single_round_api_reproduces_full_runs() {
        let (state0, schedule, _) = init(10, 12, Mobility::Full, 6);
        let seed = 42;
        let sweeps = 2;
        let mut a = Cluster::spawn_sharded(state0.clone(), WorkerAlgo::SortedGreedy, 2);
        let full = a.run_seeded(&schedule, sweeps, seed).unwrap();
        let fin_a = a.shutdown().unwrap();
        let mut b = Cluster::spawn_sharded(state0, WorkerAlgo::SortedGreedy, 2);
        let mut rounds = Vec::new();
        for round in 0..sweeps * schedule.period() {
            rounds.push(b.run_round_seeded(&schedule, round, seed).unwrap());
        }
        let fin_b = b.shutdown().unwrap();
        assert_eq!(full.rounds, rounds);
        assert_eq!(fin_a, fin_b);
    }

    #[test]
    fn batch_knob_resolution() {
        assert_eq!(resolve_batch_rounds(0, 64), 1); // auto, small n
        assert_eq!(resolve_batch_rounds(0, 16384), 1);
        assert_eq!(resolve_batch_rounds(0, 65536), 4); // auto kicks in
        assert_eq!(resolve_batch_rounds(0, 262144), 16);
        assert_eq!(resolve_batch_rounds(7, 64), 7); // explicit wins
        assert_eq!(resolve_batch_rounds(1, 1 << 20), 1);
    }
}
