//! Churn-determinism suite for `workload::service_traffic`.
//!
//! The dynamic workload's pledge is the same one the engines make for
//! static runs, extended to a changing ball set: the churn stream is a
//! pure function of `(config, seed, round, node)`, and a churning run
//! is bit-identical — trace *and* final state — across the sequential
//! engine, the parallel engine at any thread count, and the sharded
//! cluster at any shard count.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Parallel, Schedule, Sequential};
use bcm_dlb::coordinator::resolve_shards;
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::workload::{
    ops_for_round, run_dynamic_cluster, run_dynamic_engine, ChurnOp, TrafficConfig,
};

/// One deterministic scenario: graph, schedule and initial state all
/// derived from `seed` exactly like `bcm-dlb run` derives them.
fn scenario(seed: u64, n: usize, loads: usize) -> (Schedule, LoadState) {
    let mut rng = Pcg64::new(seed);
    let g = Topology::RandomConnected.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        loads,
        &WeightDistribution::paper_section6(),
        Mobility::Partial,
        &mut rng,
    );
    (schedule, state)
}

#[test]
fn churn_stream_is_bit_identical_for_a_seed() {
    let cfg = TrafficConfig::default();
    for round in [0usize, 1, 7, 31, 32, 100] {
        let a = ops_for_round(&cfg, 99, round, 24);
        let b = ops_for_round(&cfg, 99, round, 24);
        assert_eq!(a, b, "stream not reproducible at round {round}");
        // PartialEq on f64 admits -0.0 == 0.0; pin the bits too
        for (x, y) in a.iter().zip(&b) {
            if let (
                ChurnOp::Arrive { weight: wx, .. },
                ChurnOp::Arrive { weight: wy, .. },
            ) = (x, y)
            {
                assert_eq!(wx.to_bits(), wy.to_bits());
            }
        }
    }
    // a different seed must diverge somewhere in the same horizon
    let a: Vec<ChurnOp> = (0..16).flat_map(|r| ops_for_round(&cfg, 99, r, 24)).collect();
    let b: Vec<ChurnOp> = (0..16).flat_map(|r| ops_for_round(&cfg, 100, r, 24)).collect();
    assert_ne!(a, b, "seeds 99 and 100 produced identical streams");
}

#[test]
fn churn_stream_is_independent_of_who_asks() {
    // the generator is keyed on (seed, round, node) counters, never on
    // shared RNG state, so slicing the horizon differently (as shards
    // and engines do) can't change any op
    let cfg = TrafficConfig::default();
    let whole: Vec<Vec<ChurnOp>> = (0..12).map(|r| ops_for_round(&cfg, 7, r, 10)).collect();
    // re-query out of order
    for r in [11usize, 3, 0, 5, 11, 2] {
        assert_eq!(ops_for_round(&cfg, 7, r, 10), whole[r]);
    }
    // per-node slices reassemble to the whole round
    for (r, round_ops) in whole.iter().enumerate() {
        for node in 0..10u32 {
            let slice: Vec<&ChurnOp> =
                round_ops.iter().filter(|op| op.node() == node).collect();
            let again = ops_for_round(&cfg, 7, r, 10);
            let slice2: Vec<&ChurnOp> =
                again.iter().filter(|op| op.node() == node).collect();
            assert_eq!(slice, slice2);
        }
    }
}

#[test]
fn churning_run_is_bit_identical_across_all_executors() {
    let cores = resolve_shards(0);
    for (seed, n, algo) in [
        (2013u64, 16usize, PairAlgorithm::SortedGreedy(SortAlgo::Quick)),
        (7, 24, PairAlgorithm::Greedy),
    ] {
        let (schedule, state0) = scenario(seed, n, 12);
        let rounds = 3 * schedule.period();
        let cfg = TrafficConfig::default();

        let mut seq_state = state0.clone();
        let seq_trace = run_dynamic_engine(
            &Sequential,
            &mut seq_state,
            &schedule,
            algo,
            &cfg,
            rounds,
            seed,
        );
        assert_eq!(seq_trace.rounds.len(), rounds);

        for threads in [1usize, 2, cores] {
            let mut state = state0.clone();
            let trace = run_dynamic_engine(
                &Parallel::new(threads),
                &mut state,
                &schedule,
                algo,
                &cfg,
                rounds,
                seed,
            );
            assert_eq!(trace, seq_trace, "trace diverged: threads={threads}");
            assert_eq!(state, seq_state, "state diverged: threads={threads}");
        }

        for shards in [1usize, 2, cores] {
            let (trace, fin) = run_dynamic_cluster(
                state0.clone(),
                &schedule,
                algo,
                &cfg,
                rounds,
                seed,
                shards,
            )
            .unwrap();
            assert_eq!(trace, seq_trace, "cluster trace diverged: shards={shards}");
            assert_eq!(fin, seq_state, "cluster state diverged: shards={shards}");
        }
    }
}

#[test]
fn hotspot_heavy_churn_preserves_executor_identity() {
    // aggressive knobs: frequent hotspot bursts, triple arrival rate,
    // heavy tail — the regime that maximises arena insert/relocate
    // pressure and per-shard op slicing
    let cfg = TrafficConfig {
        arrival_rate: 3.0,
        pareto_alpha: 1.5,
        hotspot_every: 4,
        hotspot_rounds: 2,
        ..TrafficConfig::default()
    };
    let (schedule, state0) = scenario(41, 12, 6);
    let rounds = 4 * schedule.period();
    let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);

    let mut seq_state = state0.clone();
    let seq_trace = run_dynamic_engine(
        &Sequential,
        &mut seq_state,
        &schedule,
        algo,
        &cfg,
        rounds,
        41,
    );
    // the stream must actually have grown the ball set past the static
    // census for this regime to mean anything
    assert!(seq_state.total_loads() > state0.total_loads());

    let mut par_state = state0.clone();
    let par_trace = run_dynamic_engine(
        &Parallel::auto(),
        &mut par_state,
        &schedule,
        algo,
        &cfg,
        rounds,
        41,
    );
    assert_eq!(par_trace, seq_trace);
    assert_eq!(par_state, seq_state);

    let (ctrace, cfin) =
        run_dynamic_cluster(state0, &schedule, algo, &cfg, rounds, 41, 3).unwrap();
    assert_eq!(ctrace, seq_trace);
    assert_eq!(cfin, seq_state);
}

#[test]
fn drain_heavy_churn_survives_empty_nodes() {
    // departures outpace arrivals: nodes routinely empty out, and the
    // modular victim indexing must keep every executor in lock-step
    // rather than panicking or skewing on short lists
    let cfg = TrafficConfig {
        arrival_rate: 0.2,
        depart_rate: 3.0,
        ..TrafficConfig::default()
    };
    let (schedule, state0) = scenario(17, 8, 2);
    let rounds = 5 * schedule.period();
    let algo = PairAlgorithm::Greedy;

    let mut seq_state = state0.clone();
    let seq_trace = run_dynamic_engine(
        &Sequential,
        &mut seq_state,
        &schedule,
        algo,
        &cfg,
        rounds,
        17,
    );
    let mut par_state = state0.clone();
    let par_trace = run_dynamic_engine(
        &Parallel::new(2),
        &mut par_state,
        &schedule,
        algo,
        &cfg,
        rounds,
        17,
    );
    assert_eq!(par_trace, seq_trace);
    assert_eq!(par_state, seq_state);

    let (ctrace, cfin) =
        run_dynamic_cluster(state0, &schedule, algo, &cfg, rounds, 17, 2).unwrap();
    assert_eq!(ctrace, seq_trace);
    assert_eq!(cfin, seq_state);
}
