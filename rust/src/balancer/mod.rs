//! Local load-balancing algorithms (paper §4): the offline weighted
//! balls-into-bins solvers and the pairwise rebalance used in each BCM
//! matching.

pub mod offline;
pub mod pair;
pub mod refine;
pub mod sorting;

pub use offline::{greedy, lightest_bin, random_place, sorted_greedy, Placement};
pub use pair::{
    apply_is_noop, balance_pair, balance_pool, decide_pool, EdgeDecision, EdgeScratch,
    PairAlgorithm, PairOutcome,
};
pub use sorting::SortAlgo;
