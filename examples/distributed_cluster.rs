//! E10 — the sharded leader/worker coordinator serving BCM rounds.
//!
//! ```bash
//! cargo run --release --example distributed_cluster
//! ```
//!
//! Spawns one worker per core, each owning a contiguous shard of the 64
//! processors.  Intra-shard edges are solved locally; only the edges
//! crossing a shard boundary exchange Offer/Settle messages, and every
//! edge draws from the counter-based `Pcg64::for_edge` streams.  Reports
//! throughput and per-round latency percentiles, then verifies the run
//! is **bit-identical** to the sequential reference engine — first over
//! the in-process transport (lock-step, then batched/pipelined), and
//! finally over **loopback TCP** with real sockets, the length-prefixed
//! binary wire codec, and the worker event loop on the other end.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, RunTrace, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::transport::tcp::{self, LeaderListener};
use bcm_dlb::coordinator::{Cluster, WorkerAlgo};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::stats::percentile;
use std::time::Instant;

fn main() {
    let n = 64;
    let loads_per_node = 100;
    let sweeps = 10;
    let seed = 2013u64;
    let mut rng = Pcg64::new(1);

    let g = Topology::RandomConnected.build(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        n,
        loads_per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    let state0 = state.clone();
    let total_loads = state.total_loads();
    let init_disc = state.discrepancy();

    let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
    println!(
        "cluster: {} shard workers over {n} nodes, {total_loads} loads, d={} colors, \
         initial discrepancy {init_disc:.1}",
        cluster.shards(),
        schedule.period()
    );

    // Per-round latency measurement: drive rounds one by one through the
    // seeded API, so the whole run reproduces `run_seeded` (and the
    // sequential engine) bit-exactly.
    let mut latencies_ms = Vec::new();
    let mut total_edges = 0usize;
    let start = Instant::now();
    let initial_discrepancy = cluster.poll_discrepancy().expect("cluster wedged");
    let mut rounds = Vec::new();
    for round in 0..sweeps * schedule.period() {
        let t0 = Instant::now();
        total_edges += schedule.matching(round).len();
        let stats = cluster
            .run_round_seeded(&schedule, round, seed)
            .expect("cluster round failed");
        latencies_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        rounds.push(stats);
    }
    let wall = start.elapsed().as_secs_f64();
    let trace = RunTrace {
        initial_discrepancy,
        rounds,
    };
    let final_disc = cluster.poll_discrepancy().expect("cluster wedged");
    let msg_stats = cluster.message_stats();
    let state = cluster.shutdown().expect("cluster shutdown failed");

    let movements: usize = trace.rounds.iter().map(|r| r.movements).sum();
    println!("\nafter {} rounds ({wall:.2}s):", trace.rounds.len());
    println!(
        "  final discrepancy  {final_disc:.3}  ({}x reduction)",
        (init_disc / final_disc.max(1e-9)) as u64
    );
    println!(
        "  edges balanced     {total_edges}  ({:.0} edges/s)",
        total_edges as f64 / wall
    );
    println!("  loads moved        {movements}");
    println!(
        "  messages           {} leader ctl, {} reports, {} peer (for {} cross-shard edges)",
        msg_stats.ctl_sent, msg_stats.reports_received, msg_stats.peer_msgs, msg_stats.cross_edges
    );
    println!(
        "  round latency      p50 {:.2} ms   p99 {:.2} ms   max {:.2} ms",
        percentile(&latencies_ms, 50.0),
        percentile(&latencies_ms, 99.0),
        percentile(&latencies_ms, 100.0)
    );

    // consistency: the collected state matches the polled discrepancy
    assert_eq!(state.total_loads(), total_loads, "loads lost!");
    assert!((state.discrepancy() - final_disc).abs() < 1e-9);

    // determinism: the whole distributed run is bit-identical to the
    // sequential reference engine with the same seed
    let mut seq_state = state0.clone();
    let seq_trace = Sequential.run(
        &mut seq_state,
        &schedule,
        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        StopRule::sweeps(sweeps),
        seed,
    );
    assert_eq!(trace, seq_trace, "cluster trace diverged from Sequential");
    assert_eq!(state, seq_state, "cluster state diverged from Sequential");
    println!("\nconsistency checks passed (loads conserved, bit-identical to Sequential)");

    // The pipelined batched protocol: dispatch a whole sweep of rounds
    // per leader Ctl message.  Workers overlap cross-shard Offer/Settle
    // traffic with local work and run ahead of slower peers; the leader
    // round-trip is amortized across the batch — and the result is still
    // bit-identical to the sequential engine.
    let batch = schedule.period();
    let mut batched = Cluster::spawn(state0.clone(), WorkerAlgo::SortedGreedy);
    batched.set_batch_rounds(batch);
    let batched_trace = batched
        .run_seeded(&schedule, sweeps, seed)
        .expect("batched cluster run failed");
    let batched_msgs = batched.message_stats();
    let batched_state = batched.shutdown().expect("batched shutdown failed");
    assert_eq!(batched_trace, seq_trace, "batched trace diverged");
    assert_eq!(batched_state, seq_state, "batched state diverged");
    println!(
        "batched rerun ({batch} rounds per Ctl message): {} leader ctl msgs for {} rounds \
         (vs {} unbatched), still bit-identical to Sequential",
        batched_msgs.ctl_sent,
        batched_msgs.rounds,
        msg_stats.ctl_sent,
    );

    // The TCP transport: the same protocol over loopback sockets.  In a
    // real deployment the two workers would be `bcm-dlb cluster-worker
    // --connect <leader>` processes on other machines (see
    // tests/tcp_cluster.rs for the multi-process version); here they run
    // as threads driving the identical socket code path, so the example
    // stays a single self-contained binary.
    let tcp_shards = 2;
    let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader socket");
    let addr = listener
        .local_addr()
        .expect("leader socket address")
        .to_string();
    let worker_threads: Vec<_> = (0..tcp_shards)
        .map(|_| {
            let addr = addr.clone();
            std::thread::spawn(move || {
                tcp::serve_connect(&addr, 40).expect("tcp worker failed");
            })
        })
        .collect();
    let t0 = Instant::now();
    let mut tcp_cluster = Cluster::spawn_tcp(
        state0,
        PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        tcp_shards,
        listener,
    )
    .expect("tcp cluster spawn failed");
    tcp_cluster.set_batch_rounds(batch);
    let tcp_trace = tcp_cluster
        .run_seeded(&schedule, sweeps, seed)
        .expect("tcp cluster run failed");
    let tcp_msgs = tcp_cluster.message_stats();
    let tcp_state = tcp_cluster.shutdown().expect("tcp shutdown failed");
    for t in worker_threads {
        t.join().expect("tcp worker thread panicked");
    }
    assert_eq!(tcp_trace, seq_trace, "tcp trace diverged");
    assert_eq!(tcp_state, seq_state, "tcp state diverged");
    println!(
        "loopback-TCP rerun on {addr} ({tcp_shards} socket workers, {:.2}s): \
         {} leader ctl frames, {} peer frames — bit-identical to Sequential over the wire",
        t0.elapsed().as_secs_f64(),
        tcp_msgs.ctl_sent,
        tcp_msgs.peer_msgs,
    );
}
