//! Time series recorded while a BCM protocol runs.

/// Statistics of one BCM round (one matching = one color class applied).
#[derive(Clone, Debug, PartialEq)]
pub struct RoundStats {
    /// Index of the round (0-based, counts color classes applied).
    pub round: usize,
    /// Color class index within the schedule.
    pub color: usize,
    /// Global discrepancy after the round.
    pub discrepancy: f64,
    /// Loads that changed host in this round.
    pub movements: usize,
    /// Matched edges balanced in this round.
    pub edges: usize,
}

/// Full trace of a protocol run.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct RunTrace {
    pub initial_discrepancy: f64,
    pub rounds: Vec<RoundStats>,
}

impl RunTrace {
    pub fn final_discrepancy(&self) -> f64 {
        self.rounds
            .last()
            .map(|r| r.discrepancy)
            .unwrap_or(self.initial_discrepancy)
    }

    pub fn total_movements(&self) -> usize {
        self.rounds.iter().map(|r| r.movements).sum()
    }

    pub fn total_edges_balanced(&self) -> usize {
        self.rounds.iter().map(|r| r.edges).sum()
    }

    /// Average number of load movements per balanced edge (the paper's
    /// communication-cost metric alpha, §6.2).
    pub fn movements_per_edge(&self) -> f64 {
        let edges = self.total_edges_balanced();
        if edges == 0 {
            0.0
        } else {
            self.total_movements() as f64 / edges as f64
        }
    }

    /// Discrepancy reduction ratio disc = G_initial / G_final (paper §7).
    pub fn discrepancy_reduction(&self) -> f64 {
        let fin = self.final_discrepancy();
        if fin <= 0.0 {
            f64::INFINITY
        } else {
            self.initial_discrepancy / fin
        }
    }

    /// Figure of merit S = p * disc / alpha (paper Eq. 5); `p` cancels in
    /// the relative comparison, so we report S with p = 1 and alpha = the
    /// total number of movements.
    pub fn figure_of_merit(&self) -> f64 {
        let alpha = self.total_movements();
        if alpha == 0 {
            f64::INFINITY
        } else {
            self.discrepancy_reduction() / alpha as f64
        }
    }

    /// First round index whose discrepancy is <= `target`, if reached.
    pub fn rounds_to_reach(&self, target: f64) -> Option<usize> {
        self.rounds
            .iter()
            .position(|r| r.discrepancy <= target)
            .map(|i| i + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(rounds: &[(f64, usize, usize)]) -> RunTrace {
        RunTrace {
            initial_discrepancy: 100.0,
            rounds: rounds
                .iter()
                .enumerate()
                .map(|(i, &(d, m, e))| RoundStats {
                    round: i,
                    color: i % 3,
                    discrepancy: d,
                    movements: m,
                    edges: e,
                })
                .collect(),
        }
    }

    #[test]
    fn aggregates() {
        let t = mk(&[(50.0, 10, 4), (20.0, 6, 4), (10.0, 2, 4)]);
        assert_eq!(t.final_discrepancy(), 10.0);
        assert_eq!(t.total_movements(), 18);
        assert_eq!(t.total_edges_balanced(), 12);
        assert!((t.movements_per_edge() - 1.5).abs() < 1e-12);
        assert!((t.discrepancy_reduction() - 10.0).abs() < 1e-12);
        assert!((t.figure_of_merit() - 10.0 / 18.0).abs() < 1e-12);
    }

    #[test]
    fn rounds_to_reach() {
        let t = mk(&[(50.0, 1, 1), (20.0, 1, 1), (10.0, 1, 1)]);
        assert_eq!(t.rounds_to_reach(25.0), Some(2));
        assert_eq!(t.rounds_to_reach(5.0), None);
        assert_eq!(t.rounds_to_reach(60.0), Some(1));
    }

    #[test]
    fn empty_trace() {
        let t = RunTrace {
            initial_discrepancy: 7.0,
            rounds: vec![],
        };
        assert_eq!(t.final_discrepancy(), 7.0);
        assert_eq!(t.movements_per_edge(), 0.0);
        assert!(t.figure_of_merit().is_infinite());
    }

    #[test]
    fn perfect_balance_infinite_reduction() {
        let t = mk(&[(0.0, 5, 2)]);
        assert!(t.discrepancy_reduction().is_infinite());
    }
}
