//! E3 + E7 — regenerates paper Fig. 3 (relative figure of merit S_rel,
//! Eq. 6) and the §6.1/§7 headline scalars (discrepancy ratio, movement
//! ratio, S_rel averages) with the paper's numbers side by side.

use bcm_dlb::experiments::{figures, SweepParams};
use std::path::Path;

fn main() {
    let params = SweepParams::from_env();
    let start = std::time::Instant::now();
    for t in figures::fig3(&params, Path::new("results")) {
        println!("{}", t.render());
    }
    eprintln!("fig3 completed in {:.1}s", start.elapsed().as_secs_f64());
}
