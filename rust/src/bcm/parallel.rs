//! The deterministic multi-threaded BCM engine.
//!
//! Edges within a color class are vertex-disjoint (a matching), so the
//! class can be applied concurrently — the execution model the protocol
//! actually prescribes, which the sequential engine merely simulates.
//! `LoadState::split_pairs` validates the matching and hands out
//! [`EdgeViews`](crate::load::EdgeViews): raw per-edge access to the
//! arena segments, partitioned
//! over `std::thread::scope` workers and balanced in parallel.  Each
//! worker owns a reusable [`EdgeScratch`], so a steady-state round
//! allocates nothing (`tests/alloc_budget.rs`).
//!
//! An edge whose write-back would overflow a segment's capacity cannot
//! relocate from a worker (relocation moves the arena frontier, which
//! is shared); such edges are **deferred** — the worker stages the
//! decided pool and the main thread applies them after the join, in
//! ascending edge order, through the owning `&mut LoadState`.  The
//! deferred write-back is the same pure function of the decision as the
//! in-place one, so the result is identical to sequential application.
//!
//! Determinism: edge `e` of round `t` draws all of its randomness from
//! `Pcg64::for_edge(seed, t, e)` — a counter-based stream keyed on values,
//! not on call order.  Together with the disjointness of the per-edge
//! state mutations this makes the result **bit-identical** to
//! [`Sequential`](super::engine::Sequential) for every thread count
//! (asserted by `tests/property_invariants.rs`).

use super::engine::{balance_edge_with, drive_dynamic_with, drive_with, Engine, StopRule};
use super::schedule::Schedule;
use super::trace::RunTrace;
use crate::balancer::{apply_is_noop, decide_pool, EdgeScratch, PairAlgorithm};
use crate::load::{Load, LoadState};
use crate::util::rng::Pcg64;

/// The multi-threaded [`Engine`].
pub struct Parallel {
    threads: usize,
}

impl Parallel {
    /// `threads == 0` means auto (one worker per available core).
    pub fn new(threads: usize) -> Self {
        Self { threads }
    }

    /// One worker per available core.
    pub fn auto() -> Self {
        Self { threads: 0 }
    }

    /// The resolved worker count.
    pub fn thread_count(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1)
        } else {
            self.threads
        }
    }
}

impl Engine for Parallel {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn run(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        stop: StopRule,
        seed: u64,
    ) -> RunTrace {
        let threads = self.thread_count();
        // One context for the whole run: per-worker scratches and the
        // matching-validation buffer warm up once, then every round
        // reuses them allocation-free.  The same worker pool also fans
        // out the per-round discrepancy reduction — the O(n) term that
        // would otherwise cap speedup.
        let mut ctx = RoundCtx::new(threads);
        drive_with(state, schedule, stop, threads, |state, pairs, round| {
            parallel_round_ctx(state, pairs, round, algo, seed, threads, &mut ctx)
        })
    }

    fn run_dynamic(
        &self,
        state: &mut LoadState,
        schedule: &Schedule,
        algo: PairAlgorithm,
        rounds: usize,
        seed: u64,
        churn: &mut dyn FnMut(&mut LoadState, usize),
    ) -> RunTrace {
        let threads = self.thread_count();
        let mut ctx = RoundCtx::new(threads);
        drive_dynamic_with(state, schedule, rounds, threads, churn, |state, pairs, round| {
            parallel_round_ctx(state, pairs, round, algo, seed, threads, &mut ctx)
        })
    }
}

/// An edge whose in-place write-back was refused (segment overflow),
/// staged for application by the arena owner after the join.
struct Deferred {
    u: u32,
    v: u32,
    pool: Vec<(Load, u8)>,
    dest: Vec<u8>,
}

/// Reusable cross-round working memory of [`parallel_round_ctx`]: one
/// [`EdgeScratch`] + deferred-edge buffer + movement slot per worker,
/// plus the matching-validation buffer.  Created once per run; after
/// warm-up, rounds draw on it without allocating.
pub struct RoundCtx {
    scratches: Vec<EdgeScratch>,
    deferred: Vec<Vec<Deferred>>,
    moved: Vec<usize>,
    seen: Vec<bool>,
}

impl RoundCtx {
    pub fn new(threads: usize) -> Self {
        let mut ctx = RoundCtx {
            scratches: Vec::new(),
            deferred: Vec::new(),
            moved: Vec::new(),
            seen: Vec::new(),
        };
        ctx.ensure(threads.max(1));
        ctx
    }

    fn ensure(&mut self, workers: usize) {
        while self.scratches.len() < workers {
            self.scratches.push(EdgeScratch::new());
            self.deferred.push(Vec::new());
            self.moved.push(0);
        }
    }
}

/// Apply one matching with up to `threads` workers; returns the movement
/// count.  Bit-identical to the per-edge sequential application for any
/// `threads >= 1`.
///
/// Convenience wrapper that pays a fresh [`RoundCtx`] per call; round
/// loops should hold a context and call [`parallel_round_ctx`].
pub fn parallel_round(
    state: &mut LoadState,
    pairs: &[(u32, u32)],
    round: usize,
    algo: PairAlgorithm,
    seed: u64,
    threads: usize,
) -> usize {
    let mut ctx = RoundCtx::new(threads);
    parallel_round_ctx(state, pairs, round, algo, seed, threads, &mut ctx)
}

/// [`parallel_round`] drawing on a caller-owned [`RoundCtx`] — the
/// steady-state zero-allocation round loop.
#[allow(clippy::too_many_arguments)]
pub fn parallel_round_ctx(
    state: &mut LoadState,
    pairs: &[(u32, u32)],
    round: usize,
    algo: PairAlgorithm,
    seed: u64,
    threads: usize,
    ctx: &mut RoundCtx,
) -> usize {
    let threads = threads.max(1).min(pairs.len());
    if threads <= 1 {
        // One worker (or <= 1 edge): skip thread setup, same arithmetic.
        ctx.ensure(1);
        let scratch = &mut ctx.scratches[0];
        let mut movements = 0usize;
        for (e, &(u, v)) in pairs.iter().enumerate() {
            let mut rng = Pcg64::for_edge(seed, round, e);
            movements += balance_edge_with(state, u as usize, v as usize, algo, &mut rng, scratch);
        }
        return movements;
    }
    let chunk = pairs.len().div_ceil(threads);
    let workers = pairs.len().div_ceil(chunk);
    ctx.ensure(workers);
    for d in ctx.deferred.iter_mut() {
        d.clear();
    }
    let views = state.split_pairs(pairs, &mut ctx.seen);
    std::thread::scope(|scope| {
        let views = &views;
        let mut rest_s = &mut ctx.scratches[..];
        let mut rest_d = &mut ctx.deferred[..];
        let mut rest_m = &mut ctx.moved[..];
        for wi in 0..workers {
            let (scratch, rs) = rest_s.split_first_mut().expect("scratch per worker");
            rest_s = rs;
            let (defer, rd) = rest_d.split_first_mut().expect("deferred buf per worker");
            rest_d = rd;
            let (moved_slot, rm) = rest_m.split_first_mut().expect("movement slot per worker");
            rest_m = rm;
            let lo = wi * chunk;
            let hi = (lo + chunk).min(pairs.len());
            // No handle vector: the scope joins every worker on exit and
            // the results land in the pre-split per-worker slots, which
            // keeps the spawn loop itself allocation-free.
            scope.spawn(move || {
                let mut movements = 0usize;
                for e in lo..hi {
                    let (u, v) = views.pair(e);
                    let mut rng = Pcg64::for_edge(seed, round, e);
                    // SAFETY: workers partition the edge indices, so no
                    // edge is gathered or applied concurrently; edges of
                    // one matching are vertex-disjoint (validated by
                    // split_pairs).
                    let gather = unsafe { views.gather(e, &mut scratch.pool) };
                    let decision = decide_pool(
                        &mut scratch.pool,
                        &mut scratch.dest,
                        gather.base,
                        algo,
                        &mut rng,
                    );
                    movements += decision.movements;
                    if apply_is_noop(algo, decision.movements, gather.partitioned) {
                        continue;
                    }
                    // SAFETY: as above.
                    if !unsafe { views.try_apply(e, &scratch.pool, &scratch.dest) } {
                        defer.push(Deferred {
                            u,
                            v,
                            pool: scratch.pool.clone(),
                            dest: scratch.dest.clone(),
                        });
                    }
                }
                *moved_slot = movements;
            });
        }
    });
    drop(views);
    // Deferred write-backs (segment overflow) are applied by the arena
    // owner in ascending edge order — worker chunks are contiguous, so
    // worker order *is* edge order — which reproduces the sequential
    // engine's relocation sequence exactly.
    for defer in ctx.deferred.iter_mut().take(workers) {
        for d in defer.drain(..) {
            state.apply_edge(d.u as usize, d.v as usize, &d.pool, &d.dest);
        }
    }
    ctx.moved[..workers].iter().sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::balancer::SortAlgo;
    use crate::graph::Graph;
    use crate::load::{Mobility, WeightDistribution};

    fn setup(n: usize, per_node: usize, mobility: Mobility, seed: u64) -> (LoadState, Schedule) {
        let mut rng = Pcg64::new(seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            n,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        );
        (state, schedule)
    }

    #[test]
    fn matches_sequential_for_every_thread_count() {
        let (state0, schedule) = setup(24, 25, Mobility::Partial, 1);
        let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
        let stop = StopRule::sweeps(5);
        let mut seq = state0.clone();
        let seq_trace = super::super::engine::Sequential.run(&mut seq, &schedule, algo, stop, 7);
        for threads in [1, 2, 3, 4, 7] {
            let mut par = state0.clone();
            let trace = Parallel::new(threads).run(&mut par, &schedule, algo, stop, 7);
            assert_eq!(trace, seq_trace, "trace diverged at {threads} threads");
            assert_eq!(par, seq, "state diverged at {threads} threads");
        }
    }

    #[test]
    fn auto_thread_count_resolves() {
        let p = Parallel::auto();
        assert!(p.thread_count() >= 1);
        assert_eq!(Parallel::new(3).thread_count(), 3);
        assert_eq!(p.name(), "parallel");
    }

    #[test]
    fn converges_and_conserves() {
        let (mut state, schedule) = setup(32, 30, Mobility::Full, 2);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init = state.discrepancy();
        let trace = Parallel::new(4).run(
            &mut state,
            &schedule,
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
            StopRule::sweeps(10),
            3,
        );
        assert!(trace.final_discrepancy() < init / 20.0);
        assert_eq!(state.all_ids(), ids);
        assert!((state.total_weight() - mass).abs() < 1e-6);
    }

    #[test]
    fn empty_matching_round_is_a_noop() {
        let (mut state, _) = setup(8, 10, Mobility::Full, 3);
        let before = state.clone();
        let moves = parallel_round(&mut state, &[], 0, PairAlgorithm::Greedy, 1, 4);
        assert_eq!(moves, 0);
        assert_eq!(state, before);
    }

    #[test]
    fn round_ctx_is_reusable_across_rounds_and_thread_counts() {
        // The same context must serve rounds at different worker counts
        // (it grows on demand) without perturbing results.
        let (state0, schedule) = setup(16, 12, Mobility::Full, 9);
        let algo = PairAlgorithm::Greedy;
        let mut a = state0.clone();
        let mut b = state0.clone();
        let mut ctx = RoundCtx::new(1);
        for round in 0..6 {
            let pairs = schedule.matching(round);
            let ma = parallel_round_ctx(&mut a, pairs, round, algo, 5, 1 + round % 4, &mut ctx);
            let mb = parallel_round(&mut b, pairs, round, algo, 5, 2);
            assert_eq!(ma, mb, "movement count diverged at round {round}");
        }
        assert_eq!(a, b);
    }

    #[test]
    fn threaded_metrics_reduction_keeps_traces_identical_at_scale() {
        // n large enough that `discrepancy_threaded` takes the chunked
        // path inside the parallel engine while the sequential reference
        // still folds scalar — the traces must stay bit-identical.  With
        // loads drawn from the paper distribution the node sizes churn,
        // so this also exercises segment relocation and the deferred
        // write-back path at scale.
        let n = 2 * crate::load::state::REDUCE_CHUNK_MIN;
        let mut rng = Pcg64::new(5);
        let g = Graph::ring(n);
        let schedule = Schedule::from_graph(&g);
        let state0 = LoadState::init_uniform_counts(
            n,
            2,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let algo = PairAlgorithm::Greedy;
        let stop = StopRule::sweeps(1);
        let mut seq = state0.clone();
        let seq_trace = super::super::engine::Sequential.run(&mut seq, &schedule, algo, stop, 11);
        let mut par = state0.clone();
        let par_trace = Parallel::new(4).run(&mut par, &schedule, algo, stop, 11);
        assert_eq!(par_trace, seq_trace);
        assert_eq!(par, seq);
    }

    #[test]
    fn more_threads_than_edges_is_fine() {
        let (state0, schedule) = setup(6, 10, Mobility::Full, 4);
        let algo = PairAlgorithm::Greedy;
        let stop = StopRule::sweeps(2);
        let mut a = state0.clone();
        let ta = Parallel::new(64).run(&mut a, &schedule, algo, stop, 5);
        let mut b = state0.clone();
        let tb = super::super::engine::Sequential.run(&mut b, &schedule, algo, stop, 5);
        assert_eq!(ta, tb);
        assert_eq!(a, b);
    }
}
