//! The BCM matching schedule: a periodic sequence of matchings derived
//! from an edge coloring, applied round-robin (paper §2.1, §5).

use crate::graph::{EdgeColoring, Graph};

/// A fixed, periodic sequence of d matchings covering every edge.
#[derive(Clone, Debug)]
pub struct Schedule {
    matchings: Vec<Vec<(u32, u32)>>,
    n: usize,
}

impl Schedule {
    /// Build the schedule from a graph via greedy edge coloring.
    pub fn from_graph(g: &Graph) -> Self {
        let coloring = EdgeColoring::greedy(g);
        debug_assert!(coloring.validate(g).is_ok());
        Self {
            matchings: coloring.classes().to_vec(),
            n: g.n(),
        }
    }

    pub fn from_classes(n: usize, classes: Vec<Vec<(u32, u32)>>) -> Self {
        Self {
            matchings: classes,
            n,
        }
    }

    /// d — the period (number of matchings per sweep).
    pub fn period(&self) -> usize {
        self.matchings.len()
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Color class (matching index) applied in round `t`.
    pub fn color_of(&self, t: usize) -> usize {
        t % self.matchings.len()
    }

    /// Matching applied in round `t` (round-robin over the colors).
    pub fn matching(&self, t: usize) -> &[(u32, u32)] {
        &self.matchings[self.color_of(t)]
    }

    /// Look-ahead window: the colors of the `b` rounds starting at
    /// `start`.  Because the schedule is a fixed periodic matching
    /// sequence, future rounds' plans are known in advance — this is
    /// what lets the sharded coordinator dispatch a whole batch of
    /// rounds in one control message and lets workers prefetch the next
    /// round's plan while the current round's messages are in flight.
    pub fn lookahead_colors(&self, start: usize, b: usize) -> Vec<usize> {
        (start..start + b).map(|t| self.color_of(t)).collect()
    }

    pub fn matchings(&self) -> &[Vec<(u32, u32)>] {
        &self.matchings
    }

    /// Largest matching size (the batch dimension the runtime must fit).
    pub fn max_matching_size(&self) -> usize {
        self.matchings.iter().map(|m| m.len()).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn ring_schedule() {
        let g = Graph::ring(8);
        let s = Schedule::from_graph(&g);
        assert_eq!(s.period(), 2);
        assert_eq!(s.n(), 8);
        let total: usize = s.matchings().iter().map(|m| m.len()).sum();
        assert_eq!(total, 8);
        assert_eq!(s.max_matching_size(), 4);
    }

    #[test]
    fn round_robin_wraps() {
        let g = Graph::ring(6);
        let s = Schedule::from_graph(&g);
        assert_eq!(s.matching(0), s.matching(s.period()));
        assert_eq!(s.matching(1), s.matching(s.period() + 1));
        assert_eq!(s.color_of(0), s.color_of(s.period()));
        assert_eq!(s.color_of(s.period() + 1), 1 % s.period());
    }

    #[test]
    fn lookahead_colors_cover_the_window_round_robin() {
        let g = Graph::ring(8);
        let s = Schedule::from_graph(&g); // period 2
        assert_eq!(s.lookahead_colors(0, 5), vec![0, 1, 0, 1, 0]);
        assert_eq!(s.lookahead_colors(3, 2), vec![1, 0]);
        assert!(s.lookahead_colors(4, 0).is_empty());
        // the window agrees with matching() round by round
        for (i, &c) in s.lookahead_colors(7, 6).iter().enumerate() {
            assert_eq!(s.matching(7 + i), s.matchings()[c].as_slice());
        }
    }

    #[test]
    fn covers_all_edges_random_graph() {
        let mut rng = Pcg64::new(2);
        let g = Graph::random_connected(24, &mut rng);
        let s = Schedule::from_graph(&g);
        let mut covered: Vec<(u32, u32)> = s.matchings().iter().flatten().cloned().collect();
        covered.sort_unstable();
        let mut expected = g.edges().to_vec();
        expected.sort_unstable();
        assert_eq!(covered, expected);
    }
}
