//! Codec property tests: round-trip fuzz over randomly generated
//! `Ctl`/`ShardMsg`/`Report` values (hand-rolled generators driven by
//! the crate's own deterministic RNG, proptest-style) plus rejection
//! tests for truncated, corrupted, and mis-versioned frames.

use bcm_dlb::coordinator::messages::{Ctl, Report, RoundReport, ShardMsg};
use bcm_dlb::coordinator::shard::{RoundPlan, ShardMap};
use bcm_dlb::coordinator::transport::codec::{
    crc32, decode_frame, encode_frame, CodecError, Init, WireMsg, HEADER_LEN,
};
use bcm_dlb::load::Load;
use bcm_dlb::util::rng::Pcg64;
use std::sync::Arc;

// ------------------------------------------------------------ generators

/// A weight palette mixing ordinary values with exact-representation
/// edge cases; bit-exact round-tripping over the wire is part of the
/// determinism contract.
fn gen_weight(rng: &mut Pcg64) -> f64 {
    match rng.below(8) {
        0 => 0.0,
        1 => -0.0,
        2 => 1e300,
        3 => 1e-300,
        4 => f64::MIN_POSITIVE,
        5 => -rng.uniform(0.0, 100.0),
        _ => rng.uniform(0.0, 1000.0),
    }
}

fn gen_load(rng: &mut Pcg64) -> Load {
    Load {
        id: rng.next_u64(),
        weight: gen_weight(rng),
        mobile: rng.coin(),
    }
}

fn gen_loads(rng: &mut Pcg64) -> Vec<Load> {
    (0..rng.below(6)).map(|_| gen_load(rng)).collect()
}

fn gen_string(rng: &mut Pcg64) -> String {
    let palette = ["", "worker panicked: injected fault", "127.0.0.1:7411", "κόσμος"];
    palette[rng.below(palette.len())].to_string()
}

/// A random matching over `n` nodes classified against a random shard
/// map — the payload of a `RunBatch` plan table.
fn gen_plan(rng: &mut Pcg64) -> RoundPlan {
    let n = 2 + rng.below(30);
    let shards = 1 + rng.below(4);
    let map = ShardMap::new(n, shards);
    let mut nodes: Vec<u32> = (0..n as u32).collect();
    rng.shuffle(&mut nodes);
    let edges = rng.below(n / 2 + 1);
    let pairs: Vec<(u32, u32)> = (0..edges)
        .map(|e| (nodes[2 * e], nodes[2 * e + 1]))
        .collect();
    RoundPlan::build(&pairs, &map)
}

/// A job id palette covering the classic single-job id 0, small service
/// ids, and the full u32 range.
fn gen_job(rng: &mut Pcg64) -> u32 {
    match rng.below(4) {
        0 => 0,
        1 => 1 + rng.below(8) as u32,
        2 => u32::MAX,
        _ => rng.next_u64() as u32,
    }
}

fn gen_ctl(rng: &mut Pcg64, variant: usize) -> Ctl {
    match variant % 7 {
        0 => {
            let d = 1 + rng.below(4);
            let plans: Vec<Arc<RoundPlan>> = (0..d).map(|_| Arc::new(gen_plan(rng))).collect();
            Ctl::RunBatch {
                job: gen_job(rng),
                start_round: rng.below(1 << 20),
                rounds: 1 + rng.below(64),
                seed: rng.next_u64(),
                plans: Arc::new(plans),
                checkpoint: rng.coin(),
            }
        }
        1 => Ctl::PollWeights { job: gen_job(rng) },
        2 => Ctl::OpenJob {
            job: gen_job(rng),
            lo: rng.below(1 << 16),
            algo: ["greedy", "sorted:quick", "random"][rng.below(3)].to_string(),
            nodes: (0..rng.below(10)).map(|_| gen_loads(rng)).collect(),
        },
        3 => Ctl::CloseJob { job: gen_job(rng) },
        4 => Ctl::AbortJob { job: gen_job(rng) },
        5 => Ctl::Remesh {
            shard: rng.below(16),
            // "" = demesh (reassignment); non-empty = rejoin re-dial
            addr: gen_string(rng),
        },
        _ => Ctl::Shutdown,
    }
}

fn gen_peer(rng: &mut Pcg64, variant: usize) -> ShardMsg {
    match variant % 2 {
        0 => ShardMsg::Offer {
            job: gen_job(rng),
            round: rng.below(1 << 16),
            edge: rng.below(1 << 16),
            loads: gen_loads(rng),
            pinned: gen_weight(rng),
        },
        _ => ShardMsg::Settle {
            job: gen_job(rng),
            round: rng.below(1 << 16),
            edge: rng.below(1 << 16),
            loads: gen_loads(rng),
        },
    }
}

fn gen_report(rng: &mut Pcg64, variant: usize) -> Report {
    match variant % 5 {
        4 => Report::Checkpoint {
            job: gen_job(rng),
            shard: rng.below(16),
            round: rng.below(1 << 16),
            nodes: (0..rng.below(10)).map(|_| gen_loads(rng)).collect(),
        },
        0 => Report::Batch {
            job: gen_job(rng),
            shard: rng.below(16),
            rounds: (0..rng.below(8))
                .map(|i| RoundReport {
                    round: i,
                    movements: rng.below(1000),
                    min_weight: gen_weight(rng),
                    max_weight: gen_weight(rng),
                    peer_msgs: rng.below(64),
                })
                .collect(),
        },
        1 => Report::Weights {
            job: gen_job(rng),
            shard: rng.below(16),
            weights: (0..rng.below(20)).map(|_| gen_weight(rng)).collect(),
        },
        2 => Report::Final {
            job: gen_job(rng),
            shard: rng.below(16),
            nodes: (0..rng.below(10)).map(|_| gen_loads(rng)).collect(),
        },
        _ => Report::Error {
            // None = worker-fatal, Some = job-fatal; both shapes must
            // survive the wire
            job: if rng.coin() { Some(gen_job(rng)) } else { None },
            shard: rng.below(16),
            round: if rng.coin() { Some(rng.below(1 << 16)) } else { None },
            message: gen_string(rng),
        },
    }
}

fn gen_wire(rng: &mut Pcg64, variant: usize) -> WireMsg {
    // cycle deterministically through the four families so every
    // variant of every enum is fuzzed
    match variant % 4 {
        0 => WireMsg::Ctl(gen_ctl(rng, variant / 4)),
        1 => WireMsg::Peer(gen_peer(rng, variant / 4)),
        2 => WireMsg::Report(gen_report(rng, variant / 4)),
        _ => match (variant / 4) % 3 {
            0 => WireMsg::Hello {
                peer_addr: gen_string(rng),
                // None = fresh worker, Some = reclaiming a dead shard
                rejoin: rng.coin().then(|| rng.next_u64()),
            },
            1 => WireMsg::PeerHello {
                shard: rng.below(16),
            },
            _ => WireMsg::Init(Init {
                shard: rng.below(8),
                shards: 1 + rng.below(8),
                lo: rng.below(1 << 16),
                algo: "sorted:quick".to_string(),
                nodes: (0..rng.below(12)).map(|_| gen_loads(rng)).collect(),
                peers: (0..rng.below(5)).map(|_| gen_string(rng)).collect(),
                rejoin: rng.coin(),
                resume_round: rng.below(1 << 16),
                token: rng.next_u64(),
            }),
        },
    }
}

// ---------------------------------------------------------------- tests

#[test]
fn prop_every_message_roundtrips_bit_exactly() {
    let mut rng = Pcg64::new(0xC0DEC);
    for variant in 0..400 {
        let msg = gen_wire(&mut rng, variant);
        let frame = encode_frame(&msg);
        let (back, used) = decode_frame(&frame)
            .unwrap_or_else(|e| panic!("decode failed ({e:?}) for {msg:?}"));
        assert_eq!(used, frame.len(), "partial consume for {msg:?}");
        assert_eq!(back, msg, "round-trip changed the message");
    }
}

#[test]
fn prop_truncated_frames_are_rejected_never_panic() {
    let mut rng = Pcg64::new(0x7A11);
    for variant in 0..40 {
        let msg = gen_wire(&mut rng, variant);
        let frame = encode_frame(&msg);
        // every strict prefix must fail cleanly with Truncated
        let cuts: Vec<usize> = if frame.len() <= 64 {
            (0..frame.len()).collect()
        } else {
            vec![0, 1, HEADER_LEN - 1, HEADER_LEN, frame.len() / 2, frame.len() - 1]
        };
        for cut in cuts {
            assert_eq!(
                decode_frame(&frame[..cut]).unwrap_err(),
                CodecError::Truncated,
                "cut {cut} of {} for {msg:?}",
                frame.len()
            );
        }
    }
}

#[test]
fn prop_payload_corruption_is_detected() {
    let mut rng = Pcg64::new(0xBADC);
    for variant in 0..60 {
        let msg = gen_wire(&mut rng, variant);
        let frame = encode_frame(&msg);
        if frame.len() == HEADER_LEN {
            continue; // no payload bytes to corrupt
        }
        let at = HEADER_LEN + rng.below(frame.len() - HEADER_LEN);
        let mut bad = frame.clone();
        bad[at] ^= 1 << rng.below(8);
        if bad[at] == frame[at] {
            continue;
        }
        assert_eq!(
            decode_frame(&bad).unwrap_err(),
            CodecError::BadChecksum,
            "flip at {at} for {msg:?}"
        );
    }
}

#[test]
fn prop_version_skew_and_bad_kind_are_rejected() {
    let mut rng = Pcg64::new(0x5EED);
    for variant in 0..24 {
        let msg = gen_wire(&mut rng, variant);
        let frame = encode_frame(&msg);

        let mut skew = frame.clone();
        skew[4] = skew[4].wrapping_add(1); // version low byte
        match decode_frame(&skew).unwrap_err() {
            CodecError::BadVersion(_) => {}
            other => panic!("version skew surfaced as {other:?}"),
        }

        let mut unkind = frame.clone();
        unkind[6] = 0xEE; // kind byte; checksum covers only the payload
        assert_eq!(decode_frame(&unkind).unwrap_err(), CodecError::BadKind(0xEE));

        let mut magic = frame;
        magic[1] ^= 0xFF;
        assert_eq!(decode_frame(&magic).unwrap_err(), CodecError::BadMagic);
    }
}

#[test]
fn corrupt_length_cannot_cause_huge_allocation() {
    let frame = encode_frame(&WireMsg::Ctl(Ctl::PollWeights { job: 0 }));
    let mut bad = frame;
    // claim a ~4 GiB payload; the decoder must refuse before allocating
    bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
    match decode_frame(&bad).unwrap_err() {
        CodecError::Malformed(_) | CodecError::Truncated => {}
        other => panic!("oversized length surfaced as {other:?}"),
    }
}

#[test]
fn checkpoint_declared_slice_size_is_cross_checked() {
    // A Checkpoint frame carries a declared total-load count ahead of
    // its node slices; a peer that lies about it (truncation bug,
    // hostile sender) must be rejected, not trusted.  Tamper with the
    // declared u64 of an honestly encoded frame and re-seal the
    // checksum so only the cross-check can catch it.
    let msg = WireMsg::Report(Report::Checkpoint {
        job: 7,
        shard: 2,
        round: 41,
        nodes: vec![
            vec![Load::new(1, 2.0), Load::new(2, 0.5)],
            vec![Load::new(3, 1.25)],
        ],
    });
    let frame = encode_frame(&msg);
    assert_eq!(decode_frame(&frame).unwrap().0, msg);
    // payload layout: job u32, shard u64, round u64, declared u64
    let at = HEADER_LEN + 4 + 8 + 8;
    let mut reseal = |declared: u64| {
        let mut bad = frame.clone();
        bad[at..at + 8].copy_from_slice(&declared.to_le_bytes());
        let crc = crc32(&bad[HEADER_LEN..]);
        bad[12..16].copy_from_slice(&crc.to_le_bytes());
        decode_frame(&bad).unwrap_err()
    };
    // understates and overstates both fail the cross-check
    for lie in [0u64, 2, 4] {
        assert_eq!(
            reseal(lie),
            CodecError::Malformed("checkpoint declared slice size disagrees with payload"),
            "declared {lie} for 3 carried loads"
        );
    }
    // an absurd declared size is refused before any allocation
    assert_eq!(
        reseal(u64::MAX / 16),
        CodecError::Malformed("length prefix overruns frame")
    );
}

#[test]
fn checksum_is_stable_across_runs() {
    // the CRC is part of the wire contract: a different implementation
    // on the other end must compute the same value
    let frame = encode_frame(&WireMsg::Hello {
        peer_addr: "192.168.1.9:6000".into(),
        rejoin: None,
    });
    let payload = &frame[HEADER_LEN..];
    let stored = u32::from_le_bytes([frame[12], frame[13], frame[14], frame[15]]);
    assert_eq!(crc32(payload), stored);
    assert_eq!(crc32(b"123456789"), 0xCBF4_3926); // IEEE check value
}
