//! A shard worker = one core owning a contiguous slice of processors —
//! for each job it participates in.
//!
//! Owns its nodes' load lists exclusively; all interaction goes through
//! its [`WorkerTransport`] (in-process channels or TCP sockets — the
//! round loop cannot tell).  Intra-shard edges are solved locally through the same
//! [`decide_pool`] primitive the engines use, on a reusable
//! [`EdgeScratch`] owned by the worker (no per-edge allocation); for a
//! cross-shard edge the
//! owner of `u` is the edge master — the slave ships `v`'s mobile loads
//! ([`ShardMsg::Offer`]), the master solves the two-bin problem and ships
//! `v`'s share back ([`ShardMsg::Settle`]).  Every edge draws its
//! randomness from `Pcg64::for_edge(seed, round, edge)`, so a sharded run
//! is bit-identical to `bcm::Sequential` for any shard count.
//!
//! # Jobs
//!
//! Since the multi-tenant service, one worker serves any number of
//! **jobs** — independent `(LoadState slice, algorithm, seed)` tenants
//! multiplexed over the same transport.  Jobs are installed by
//! [`Ctl::OpenJob`] (or at spawn time for the classic single-job paths,
//! which use job `0`), retired by [`Ctl::CloseJob`], and fail
//! *independently*: a panic or dead peer inside one job's batch sends a
//! job-scoped [`Report::Error`] and retires that job, while every other
//! job keeps its state and its bit-identical trace.  Determinism per job
//! is untouched by the interleaving because each job's RNG streams are
//! keyed by its own `(seed, round, edge)` and its loads never mix with
//! another job's.
//!
//! # The batched round state machine
//!
//! A [`Ctl::RunBatch`] carries `B` rounds of one job, with every round's
//! [`ShardPlan`] already on hand (the plans are known in advance because
//! the BCM schedule is a fixed periodic matching sequence, so the leader
//! ships the whole per-color plan table with the batch).  The worker
//! drives each round through three states:
//!
//! 1. **post-offers** — ship this round's slave offers; transport sends
//!    never block indefinitely, so no inter-shard ordering can deadlock.
//! 2. **solve-local** — balance the intra-shard edges while the offers
//!    (and the settles coming back) are in flight.
//! 3. **collect-settles** — serve master edges as offers arrive and
//!    absorb the settles for slave edges.  Arrival order is irrelevant:
//!    each edge's randomness is keyed on `(seed, round, edge)`.
//!
//! Within a batch no state touches the leader, so shards proceed at
//! their own pace, synchronized only by the cut edges they share: a fast
//! shard's round `r+1` traffic reaching a peer still collecting round
//! `r` is stashed by `(job, round)` tag and served when the peer gets
//! there — as is traffic for a *different* job, including one whose
//! `OpenJob` this worker has not processed yet (control and peer links
//! have no cross-channel ordering).  Rounds still execute in order *per
//! shard per job* (round `r+1` offers draw on loads settled in round
//! `r`), which is exactly the data dependency that keeps the pipeline
//! bit-identical to the lock-step execution.

use super::messages::{Ctl, Report, RoundReport, ShardMsg};
use super::shard::{RoundPlan, ShardPlan};
use super::transport::{TransportError, WorkerTransport};
use crate::balancer::{apply_is_noop, decide_pool, EdgeScratch, PairAlgorithm, SortAlgo};
use crate::load::Load;
use crate::util::rng::Pcg64;
use std::collections::{BTreeMap, BTreeSet};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::Arc;
use std::time::Duration;

/// Bounded mid-round wait for peer messages: a dead peer surfaces as a
/// reported error instead of wedging the worker (and with it every later
/// `Cluster::shutdown`) forever.  Scaled by the batch size before use —
/// pipelining allows up to B-1 rounds of inter-shard skew, so a fast
/// shard may legitimately wait while a slow peer works through earlier
/// rounds — and kept shorter than the leader's equally-scaled batch
/// timeout so the error report arrives before the leader gives up.
const PEER_TIMEOUT: Duration = Duration::from_secs(30);

/// `PEER_TIMEOUT` scaled to a batch of `rounds` rounds.
fn peer_timeout(rounds: usize) -> Duration {
    PEER_TIMEOUT.saturating_mul(u32::try_from(rounds).unwrap_or(u32::MAX))
}

/// Algorithm a worker runs on its matched edges.
#[derive(Clone, Copy, Debug)]
pub enum WorkerAlgo {
    /// Paper Alg. 4.2 applied to the pooled loads.
    Greedy,
    /// Paper Alg. 4.1 (LPT): sort descending, then greedy.
    SortedGreedy,
}

impl WorkerAlgo {
    /// The equivalent local [`PairAlgorithm`] (what the engines run).
    pub fn pair(self) -> PairAlgorithm {
        match self {
            WorkerAlgo::Greedy => PairAlgorithm::Greedy,
            WorkerAlgo::SortedGreedy => PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        }
    }
}

/// One job's state on one worker: a contiguous slice of that job's
/// nodes plus the algorithm it runs.
struct JobState {
    /// First node id owned; `nodes[i]` holds node `lo + i`.
    lo: usize,
    /// Per-node load lists, owned exclusively by this worker.
    nodes: Vec<Vec<Load>>,
    /// Local balancing algorithm run on every matched edge.
    algo: PairAlgorithm,
}

/// One coordinator worker multiplexing any number of jobs over a single
/// [`WorkerTransport`].
///
/// All communication — the leader's control/report plane and the peer
/// data plane — goes through the worker's transport, so the same round
/// loop runs unchanged whether the worker is a thread of the leader
/// process (the [`local`](super::transport::local) backend) or a
/// separate OS process speaking TCP
/// ([`tcp`](super::transport::tcp)).
pub struct ShardWorker {
    shard: usize,
    transport: Box<dyn WorkerTransport>,
    /// Open jobs by id.
    jobs: BTreeMap<u32, JobState>,
    /// Ids that were opened and since closed or failed; their late peer
    /// traffic is dropped silently.
    retired: BTreeSet<u32>,
    /// Peer messages that arrived ahead of this worker's pipeline
    /// position, keyed `(job, round, edge)`.  An Offer and a Settle can
    /// never collide: for a given key this shard is either the master
    /// (receives the Offer) or the slave (receives the Settle).
    stash: BTreeMap<(u32, usize, usize), ShardMsg>,
    /// Fault injection for tests: panic at the start of this job's
    /// global round, exercising the mid-batch failure contract.  Always
    /// `None` in production spawns, and **one-shot** — the fault is
    /// consumed when it fires, so a recovery replay of the same round
    /// does not re-trigger it.
    fault: Option<(u32, usize)>,
    /// Test override for the peer-collect wait (production uses
    /// `peer_timeout(batch)`).
    peer_wait: Option<Duration>,
    /// Fault injection for recovery tests: hard-exit the whole *process*
    /// at the start of this global round (any job) — to the leader it is
    /// indistinguishable from `kill -9`.  Only reachable through the
    /// hidden `cluster-worker --fault-exit` flag.
    fault_exit: Option<usize>,
    /// First job failure (tagged with its job), kept so a worker
    /// *process* exits nonzero after an abnormal lifecycle even though
    /// it served other jobs to completion.  Cleared by a later
    /// [`Ctl::AbortJob`] for the same job: an aborted epoch was
    /// recovered by the leader, so the lifecycle ends clean.
    first_failure: Option<(u32, String)>,
    /// Reusable edge working memory, shared by every job's local and
    /// master edges (one edge is solved at a time); warms up to the
    /// largest pool seen and then serves rounds allocation-free.
    scratch: EdgeScratch,
}

/// One color's resolved work for a shard: the plan slice plus the
/// edge-indexed lookup tables the collect state needs.  The plans
/// arrive prefetched for the whole batch (the leader ships the
/// per-color table ahead of time); the index maps are built once per
/// batch per color — O(colors x cut) memory, the same order as the plan
/// table itself — and shared by every round of that color.
struct ColorTask<'a> {
    /// This shard's slice of the color's matching.
    plan: &'a ShardPlan,
    /// edge -> (u, slave shard) for the edges this shard masters.
    masters: BTreeMap<usize, (u32, usize)>,
    /// edge -> v for the edges this shard slaves.
    slaves: BTreeMap<usize, u32>,
}

impl<'a> ColorTask<'a> {
    fn new(plan: &'a ShardPlan) -> Self {
        ColorTask {
            plan,
            masters: plan
                .master
                .iter()
                .map(|&(e, u, _v, slave)| (e, (u, slave)))
                .collect(),
            slaves: plan.slave.iter().map(|&(e, v, _)| (e, v)).collect(),
        }
    }
}

impl ShardWorker {
    /// A worker with no jobs installed; the shard index comes from the
    /// transport.
    pub fn new(transport: Box<dyn WorkerTransport>) -> ShardWorker {
        ShardWorker {
            shard: transport.shard(),
            transport,
            jobs: BTreeMap::new(),
            retired: BTreeSet::new(),
            stash: BTreeMap::new(),
            fault: None,
            peer_wait: None,
            fault_exit: None,
            first_failure: None,
            scratch: EdgeScratch::new(),
        }
    }

    /// Install a job before (or instead of) its `Ctl::OpenJob` — the
    /// classic single-job spawn paths install job `0` this way.
    pub fn install_job(&mut self, job: u32, lo: usize, nodes: Vec<Vec<Load>>, algo: PairAlgorithm) {
        self.jobs.insert(job, JobState { lo, nodes, algo });
    }

    /// Test hook: panic at the start of `round` of `job`.
    #[doc(hidden)]
    pub fn set_fault(&mut self, job: u32, round: usize) {
        self.fault = Some((job, round));
    }

    /// Test hook: cap the peer-collect wait so dead-peer paths resolve
    /// in test time rather than `PEER_TIMEOUT`.
    #[doc(hidden)]
    pub fn set_peer_wait(&mut self, wait: Duration) {
        self.peer_wait = Some(wait);
    }

    /// Test hook behind `cluster-worker --fault-exit`: kill the whole
    /// process at the start of global round `round`, simulating
    /// `kill -9` for the recovery smoke tests.
    #[doc(hidden)]
    pub fn set_fault_exit(&mut self, round: usize) {
        self.fault_exit = Some(round);
    }

    /// Retire a job: drop its state and purge its stashed traffic.
    fn retire(&mut self, job: u32) {
        self.jobs.remove(&job);
        self.retired.insert(job);
        self.stash
            .retain(|&(j, _, _), _| j != job);
    }

    fn job_failed(&mut self, job: u32, round: Option<usize>, message: String) {
        let rendered = match round {
            Some(r) => format!("failed at round {r}: {message}"),
            None => message.clone(),
        };
        if self.first_failure.is_none() {
            self.first_failure = Some((job, rendered));
        }
        self.retire(job);
        let _ = self.transport.send_report(Report::Error {
            job: Some(job),
            shard: self.shard,
            round,
            message,
        });
    }

    /// Event loop; returns when [`Ctl::Shutdown`] arrives or the leader
    /// goes away.  Job-scoped failures retire the job and keep the
    /// worker serving its other tenants.
    ///
    /// `Ok(())` means a clean [`Ctl::Shutdown`] lifecycle with no job
    /// failures; every other exit returns the (first) failure as `Err`,
    /// so a worker *process* can translate abnormal termination into a
    /// nonzero exit code (thread spawns ignore the value — the leader
    /// already learned of the failure through the report channel).
    pub fn run(mut self) -> Result<(), String> {
        loop {
            let msg = match self.transport.recv_ctl() {
                Ok(m) => m,
                Err(e) => return Err(format!("control link lost: {e}")),
            };
            match msg {
                Ctl::OpenJob {
                    job,
                    lo,
                    algo,
                    nodes,
                } => {
                    if self.jobs.contains_key(&job) || self.retired.contains(&job) {
                        self.job_failed(job, None, format!("job {job} already opened"));
                        continue;
                    }
                    match PairAlgorithm::parse(&algo) {
                        Some(a) => self.install_job(job, lo, nodes, a),
                        None => {
                            self.job_failed(job, None, format!("unknown algorithm '{algo}'"));
                        }
                    }
                }
                Ctl::CloseJob { job } => {
                    if let Some(mut js) = self.jobs.remove(&job) {
                        self.retired.insert(job);
                        self.stash.retain(|&(j, _, _), _| j != job);
                        let sent = self.transport.send_report(Report::Final {
                            job,
                            shard: self.shard,
                            nodes: std::mem::take(&mut js.nodes),
                        });
                        if let Err(e) = sent {
                            return Err(format!("report link lost: {e}"));
                        }
                    }
                    // late CloseJob for a failed job: nothing to say
                }
                Ctl::RunBatch {
                    job,
                    start_round,
                    rounds,
                    seed,
                    plans,
                    checkpoint,
                } => {
                    let Some(mut js) = self.jobs.remove(&job) else {
                        if !self.retired.contains(&job) {
                            self.job_failed(job, None, format!("batch for unknown job {job}"));
                        }
                        continue;
                    };
                    match self.run_batch(job, &mut js, start_round, rounds, seed, &plans) {
                        Ok(reports) => {
                            // the snapshot is taken after the batch's last
                            // round, before any later batch can touch the
                            // slice; FIFO reports keep it ordered right
                            // behind its Batch
                            let snapshot = checkpoint.then(|| js.nodes.clone());
                            self.jobs.insert(job, js);
                            let sent = self.transport.send_report(Report::Batch {
                                job,
                                shard: self.shard,
                                rounds: reports,
                            });
                            if let Err(e) = sent {
                                return Err(format!("report link lost: {e}"));
                            }
                            if let Some(nodes) = snapshot {
                                let sent = self.transport.send_report(Report::Checkpoint {
                                    job,
                                    shard: self.shard,
                                    round: start_round + rounds - 1,
                                    nodes,
                                });
                                if let Err(e) = sent {
                                    return Err(format!("report link lost: {e}"));
                                }
                            }
                        }
                        Err((round, message)) => {
                            self.job_failed(job, Some(round), message);
                        }
                    }
                }
                Ctl::PollWeights { job } => {
                    let Some(js) = self.jobs.get(&job) else {
                        if !self.retired.contains(&job) {
                            let why = format!("weight poll for unknown job {job}");
                            self.job_failed(job, None, why);
                        }
                        continue;
                    };
                    let weights = js
                        .nodes
                        .iter()
                        .map(|node| node.iter().map(|l| l.weight).sum())
                        .collect();
                    let sent = self.transport.send_report(Report::Weights {
                        job,
                        shard: self.shard,
                        weights,
                    });
                    if let Err(e) = sent {
                        return Err(format!("report link lost: {e}"));
                    }
                }
                Ctl::ApplyChurn { job, ops } => {
                    // reply-free by design: FIFO ordering on the control
                    // link guarantees the next RunBatch sees the
                    // post-churn lists
                    let Some(js) = self.jobs.get_mut(&job) else {
                        if !self.retired.contains(&job) {
                            let why = format!("churn for unknown job {job}");
                            self.job_failed(job, None, why);
                        }
                        continue;
                    };
                    crate::workload::service_traffic::apply_ops_nodes(
                        &mut js.nodes,
                        js.lo,
                        &ops,
                    );
                }
                Ctl::AbortJob { job } => {
                    // unconditional, reply-free retire: the leader is
                    // recovering this epoch and will reopen it under a
                    // fresh id — a failure recorded against it no
                    // longer makes the lifecycle abnormal
                    self.retire(job);
                    if matches!(self.first_failure, Some((j, _)) if j == job) {
                        self.first_failure = None;
                    }
                }
                Ctl::Remesh { shard, addr } => {
                    // a dead peer rejoined: replace the broken link with
                    // a fresh dial of its new listener.  Failing here is
                    // worker-fatal — a half-meshed worker cannot serve
                    // the resumed epoch, and exiting lets the leader
                    // recover around this worker too.
                    if let Err(e) = self.transport.remesh_peer(shard, &addr) {
                        return Err(format!("remesh to shard {shard} at {addr} failed: {e}"));
                    }
                }
                Ctl::Shutdown => {
                    let jobs = std::mem::take(&mut self.jobs);
                    for (job, mut js) in jobs {
                        let _ = self.transport.send_report(Report::Final {
                            job,
                            shard: self.shard,
                            nodes: std::mem::take(&mut js.nodes),
                        });
                    }
                    return match self.first_failure.take() {
                        Some((_, why)) => Err(why),
                        None => Ok(()),
                    };
                }
            }
        }
    }

    /// Execute one batch of rounds of one job; on failure, names the
    /// round that died.  Panics inside a round (including injected
    /// faults) are caught and converted into the same `(round, message)`
    /// error shape so the leader's fail-stop contract survives
    /// mid-batch.
    fn run_batch(
        &mut self,
        job: u32,
        js: &mut JobState,
        start_round: usize,
        rounds: usize,
        seed: u64,
        plans: &[Arc<RoundPlan>],
    ) -> Result<Vec<RoundReport>, (usize, String)> {
        let d = plans.len();
        let wait = self.peer_wait.unwrap_or_else(|| peer_timeout(rounds));
        // At most one lookup-table build per color per batch, shared by
        // every round of that color; filled lazily so a lock-step B=1
        // batch builds exactly the one color it runs.
        let shard = self.shard;
        let mut tasks: Vec<Option<ColorTask<'_>>> = (0..d).map(|_| None).collect();
        let mut reports = Vec::with_capacity(rounds);
        for round in start_round..start_round + rounds {
            let c = round % d;
            let task = tasks[c]
                .get_or_insert_with(|| ColorTask::new(&plans[c].per_shard[shard]));
            let caught = catch_unwind(AssertUnwindSafe(|| {
                self.run_round(job, js, seed, round, task, wait)
            }));
            match caught {
                Ok(Ok((movements, peer_msgs))) => {
                    let (min_weight, max_weight) = extremes(js);
                    reports.push(RoundReport {
                        round,
                        movements,
                        min_weight,
                        max_weight,
                        peer_msgs,
                    });
                }
                Ok(Err(message)) => return Err((round, message)),
                Err(payload) => {
                    return Err((round, format!("worker panicked: {}", panic_message(&payload))))
                }
            }
        }
        Ok(reports)
    }

    /// Drive one round through the post-offers / solve-local /
    /// collect-settles state machine; returns the movement count of the
    /// edges this shard mastered and the number of peer messages sent.
    fn run_round(
        &mut self,
        job: u32,
        js: &mut JobState,
        seed: u64,
        round: usize,
        task: &ColorTask<'_>,
        wait: Duration,
    ) -> Result<(usize, usize), String> {
        if self.fault_exit == Some(round) {
            // simulate `kill -9`: no report, no socket shutdown — the
            // leader and the peers just see the connections drop
            eprintln!("cluster-worker: injected process exit at round {round}");
            std::process::exit(3);
        }
        if self.fault == Some((job, round)) {
            // consume the fault first: a recovery replay of this round
            // must not die again
            self.fault = None;
            panic!("injected fault at round {round}");
        }
        let mut peer_msgs = 0usize;
        // State 1 — post offers.  Transport sends never block
        // indefinitely (unbounded queues; buffered nonblocking socket
        // writes), so no ordering between shards can deadlock.
        for &(edge, v, master) in &task.plan.slave {
            let (mobile, pinned) = drain_mobile(&mut js.nodes[v as usize - js.lo]);
            peer_msgs += 1;
            let offer = ShardMsg::Offer {
                job,
                round,
                edge,
                loads: mobile,
                pinned,
            };
            if let Err(e) = self.transport.send_peer(master, offer) {
                return Err(format!(
                    "peer shard {master} unreachable (offer, edge {edge}): {e}"
                ));
            }
        }
        // State 2 — solve intra-shard edges while the cross-shard
        // traffic is in flight; no messaging.
        let mut movements = 0usize;
        for &(edge, u, v) in &task.plan.local {
            let mut rng = Pcg64::for_edge(seed, round, edge);
            movements += balance_local(js, &mut self.scratch, &mut rng, u, v);
        }
        // State 3 — collect: serve master edges as offers arrive and
        // absorb the settles for slave edges, starting with anything a
        // faster peer already stashed for this round.  Messages for
        // later rounds, or for other jobs (even ones this worker has
        // not opened yet), are stashed in turn; traffic for retired
        // jobs is dropped.
        let mut pending_masters = task.masters.len();
        let mut pending_slaves = task.slaves.len();
        while pending_masters > 0 || pending_slaves > 0 {
            let msg = match take_stashed(&mut self.stash, job, round) {
                Some(m) => m,
                None => match self.transport.recv_peer(wait) {
                    Ok(m) => m,
                    Err(TransportError::Timeout) => {
                        return Err(format!(
                            "timed out waiting for peer messages \
                             ({pending_masters} offers, {pending_slaves} settles outstanding)"
                        ))
                    }
                    Err(TransportError::Closed(why)) => {
                        return Err(format!("peer channels closed mid-round: {why}"))
                    }
                },
            };
            let (msg_job, msg_round, msg_edge) = match &msg {
                ShardMsg::Offer {
                    job, round, edge, ..
                }
                | ShardMsg::Settle {
                    job, round, edge, ..
                } => (*job, *round, *edge),
            };
            if msg_job != job {
                if !self.retired.contains(&msg_job) {
                    // another tenant's traffic (possibly for a job whose
                    // OpenJob is still queued on the control link)
                    self.stash.insert((msg_job, msg_round, msg_edge), msg);
                }
                continue;
            }
            if msg_round != round {
                if msg_round < round {
                    return Err(format!(
                        "stale peer message for completed round {msg_round} (edge {msg_edge}) \
                         while collecting round {round}"
                    ));
                }
                // a peer is running ahead in the pipeline; hold its
                // message until this shard reaches that round
                self.stash.insert((msg_job, msg_round, msg_edge), msg);
                continue;
            }
            match msg {
                ShardMsg::Offer {
                    edge,
                    loads,
                    pinned,
                    ..
                } => {
                    let &(u, slave) = task
                        .masters
                        .get(&edge)
                        .ok_or_else(|| format!("offer for unmastered edge {edge}"))?;
                    let mut rng = Pcg64::for_edge(seed, round, edge);
                    movements += self.balance_master(
                        js,
                        &mut rng,
                        job,
                        round,
                        edge,
                        u,
                        (loads, pinned),
                        slave,
                    )?;
                    peer_msgs += 1; // the settle just sent
                    pending_masters -= 1;
                }
                ShardMsg::Settle { edge, loads, .. } => {
                    let &v = task
                        .slaves
                        .get(&edge)
                        .ok_or_else(|| format!("settle for unslaved edge {edge}"))?;
                    // pinned loads stayed put in state 1; the settled
                    // mobile loads are appended, exactly like the engines.
                    js.nodes[v as usize - js.lo].extend(loads);
                    pending_slaves -= 1;
                }
            }
        }
        Ok((movements, peer_msgs))
    }

    /// Rebalance a cross-shard edge from the slave's offer; returns the
    /// movement count after sending the settle.
    #[allow(clippy::too_many_arguments)]
    fn balance_master(
        &mut self,
        js: &mut JobState,
        rng: &mut Pcg64,
        job: u32,
        round: usize,
        edge: usize,
        u: u32,
        offer: (Vec<Load>, f64),
        slave: usize,
    ) -> Result<usize, String> {
        let (their_loads, their_pinned) = offer;
        let u_node = &mut js.nodes[u as usize - js.lo];
        let scratch = &mut self.scratch;
        scratch.pool.clear();
        let (u_pinned, u_part) = gather_from(u_node, 0, &mut scratch.pool);
        scratch.pool.extend(their_loads.iter().map(|&l| (l, 1)));
        let decision = decide_pool(
            &mut scratch.pool,
            &mut scratch.dest,
            [u_pinned, their_pinned],
            js.algo,
            rng,
        );
        // The slave's side is trivially partitioned — its offer carries
        // mobile loads only.  When nothing moved (and no sort permuted
        // the pool), `u` is untouched and the offer bounces straight
        // back in arrival order: the settle reuses the offer's own Vec.
        let loads = if apply_is_noop(js.algo, decision.movements, [u_part, true]) {
            their_loads
        } else {
            retain_pinned(u_node);
            let mut back = Vec::with_capacity(their_loads.len());
            for (&(l, _), &d) in scratch.pool.iter().zip(scratch.dest.iter()) {
                if d == 0 {
                    u_node.push(l);
                } else {
                    back.push(l);
                }
            }
            back
        };
        let settle = ShardMsg::Settle {
            job,
            round,
            edge,
            loads,
        };
        self.transport
            .send_peer(slave, settle)
            .map_err(|e| format!("peer shard {slave} unreachable (settle, edge {edge}): {e}"))?;
        Ok(decision.movements)
    }
}

/// Rebalance an intra-shard edge in place, on the worker's reusable
/// scratch.  Pool order (u then v), pinned handling and RNG consumption
/// mirror `balance_pair` exactly; the write-back (pinned compacted in
/// order, then the routed pool entries in pool order) reproduces the
/// historical `drain + extend` layout bit for bit.
fn balance_local(
    js: &mut JobState,
    scratch: &mut EdgeScratch,
    rng: &mut Pcg64,
    u: u32,
    v: u32,
) -> usize {
    let (ui, vi) = (u as usize - js.lo, v as usize - js.lo);
    let (u_node, v_node) = two_mut(&mut js.nodes, ui, vi);
    scratch.pool.clear();
    let (u_pinned, u_part) = gather_from(u_node, 0, &mut scratch.pool);
    let (v_pinned, v_part) = gather_from(v_node, 1, &mut scratch.pool);
    let decision = decide_pool(
        &mut scratch.pool,
        &mut scratch.dest,
        [u_pinned, v_pinned],
        js.algo,
        rng,
    );
    if !apply_is_noop(js.algo, decision.movements, [u_part, v_part]) {
        retain_pinned(u_node);
        retain_pinned(v_node);
        for (&(l, _), &d) in scratch.pool.iter().zip(scratch.dest.iter()) {
            if d == 0 {
                u_node.push(l);
            } else {
                v_node.push(l);
            }
        }
    }
    decision.movements
}

/// Append `node`'s mobile loads to `pool` tagged `tag`.  Returns the
/// pinned weight sum — folded in node order, exactly the fold
/// `drain_mobile` (and the engines' `gather_edge`) performs — and
/// whether the node is already partitioned pinned-prefix-first, the
/// precondition for skipping a no-move write-back.
fn gather_from(node: &[Load], tag: u8, pool: &mut Vec<(Load, u8)>) -> (f64, bool) {
    let mut pinned = 0.0f64;
    let mut saw_mobile = false;
    let mut partitioned = true;
    for &l in node {
        if l.mobile {
            saw_mobile = true;
            pool.push((l, tag));
        } else {
            if saw_mobile {
                partitioned = false;
            }
            pinned += l.weight;
        }
    }
    (pinned, partitioned)
}

/// Drop a node's mobile loads in place, keeping the pinned ones in
/// order — the write-back prefix every balanced node starts with.
fn retain_pinned(node: &mut Vec<Load>) {
    node.retain(|l| !l.mobile);
}

/// `(min, max)` node weight over the shard's nodes; the leader folds
/// the shards' extremes into the global discrepancy (f64 min/max are
/// exactly associative, so the fold order cannot change the result).
fn extremes(js: &JobState) -> (f64, f64) {
    let mut min = f64::INFINITY;
    let mut max = f64::NEG_INFINITY;
    for node in &js.nodes {
        let w: f64 = node.iter().map(|l| l.weight).sum();
        min = min.min(w);
        max = max.max(w);
    }
    (min, max)
}

/// Pop the earliest stashed message belonging to `(job, round)`, if any.
fn take_stashed(
    stash: &mut BTreeMap<(u32, usize, usize), ShardMsg>,
    job: u32,
    round: usize,
) -> Option<ShardMsg> {
    let key = *stash
        .range((job, round, 0)..(job, round + 1, 0))
        .next()?
        .0;
    stash.remove(&key)
}

/// Render a caught panic payload (str or String) for an error report —
/// shared by the mid-batch catch here and the leader's thread joins.
pub(crate) fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    payload
        .downcast_ref::<&'static str>()
        .map(|s| (*s).to_string())
        .or_else(|| payload.downcast_ref::<String>().cloned())
        .unwrap_or_else(|| "unknown panic payload".to_string())
}

/// Remove and return a node's mobile loads (in order) plus its pinned
/// weight sum, leaving the pinned loads in place — the same partition
/// (and the same f64 summation order) `balance_pair` performs on the
/// full load list.
fn drain_mobile(node: &mut Vec<Load>) -> (Vec<Load>, f64) {
    let mut mobile = Vec::with_capacity(node.len());
    let mut pinned_w = 0.0f64;
    let mut w = 0usize;
    // single pass, single allocation: pinned loads compact forward in
    // place while the mobiles stream out
    for r in 0..node.len() {
        let l = node[r];
        if l.mobile {
            mobile.push(l);
        } else {
            pinned_w += l.weight;
            node[w] = l;
            w += 1;
        }
    }
    node.truncate(w);
    (mobile, pinned_w)
}

/// Disjoint `&mut` views of two distinct entries of `nodes`.
fn two_mut(nodes: &mut [Vec<Load>], a: usize, b: usize) -> (&mut Vec<Load>, &mut Vec<Load>) {
    debug_assert_ne!(a, b, "matching contains a self-loop");
    if a < b {
        let (lo, hi) = nodes.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = nodes.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn drain_mobile_partitions_in_order() {
        let mut node = vec![
            Load::new(0, 1.0),
            Load::pinned(1, 2.0),
            Load::new(2, 3.0),
            Load::pinned(3, 4.0),
        ];
        let (mobile, pinned_w) = drain_mobile(&mut node);
        assert_eq!(mobile.iter().map(|l| l.id).collect::<Vec<_>>(), vec![0, 2]);
        assert_eq!(node.iter().map(|l| l.id).collect::<Vec<_>>(), vec![1, 3]);
        assert_eq!(pinned_w, 6.0);
    }

    #[test]
    fn two_mut_returns_disjoint_views_either_order() {
        let mut nodes = vec![vec![Load::new(0, 1.0)], vec![], vec![Load::new(1, 2.0)]];
        {
            let (a, b) = two_mut(&mut nodes, 2, 0);
            assert_eq!(a[0].id, 1);
            assert_eq!(b[0].id, 0);
            let l = b.pop().unwrap();
            a.push(l);
        }
        assert!(nodes[0].is_empty());
        assert_eq!(nodes[2].len(), 2);
    }

    #[test]
    fn worker_algo_maps_to_pair_algorithms() {
        assert_eq!(WorkerAlgo::Greedy.pair(), PairAlgorithm::Greedy);
        assert_eq!(
            WorkerAlgo::SortedGreedy.pair(),
            PairAlgorithm::SortedGreedy(SortAlgo::Quick)
        );
    }

    #[test]
    fn stash_is_drained_in_job_and_round_order() {
        let mut stash: BTreeMap<(u32, usize, usize), ShardMsg> = BTreeMap::new();
        stash.insert(
            (0, 3, 1),
            ShardMsg::Settle {
                job: 0,
                round: 3,
                edge: 1,
                loads: vec![],
            },
        );
        stash.insert(
            (0, 2, 5),
            ShardMsg::Offer {
                job: 0,
                round: 2,
                edge: 5,
                loads: vec![],
                pinned: 0.0,
            },
        );
        // same (round, edge) under a different job must not collide
        stash.insert(
            (1, 2, 5),
            ShardMsg::Settle {
                job: 1,
                round: 2,
                edge: 5,
                loads: vec![],
            },
        );
        assert!(take_stashed(&mut stash, 0, 1).is_none());
        let m = take_stashed(&mut stash, 0, 2).expect("round-2 message stashed");
        assert!(matches!(m, ShardMsg::Offer { job: 0, round: 2, edge: 5, .. }));
        assert!(take_stashed(&mut stash, 0, 2).is_none());
        let m = take_stashed(&mut stash, 0, 3).expect("round-3 message stashed");
        assert!(matches!(m, ShardMsg::Settle { job: 0, round: 3, edge: 1, .. }));
        let m = take_stashed(&mut stash, 1, 2).expect("job-1 message stashed");
        assert!(matches!(m, ShardMsg::Settle { job: 1, round: 2, edge: 5, .. }));
        assert!(stash.is_empty());
    }

    #[test]
    fn panic_message_renders_both_payload_shapes() {
        let s: Box<dyn std::any::Any + Send> = Box::new("static str");
        assert_eq!(panic_message(s.as_ref()), "static str");
        let owned: Box<dyn std::any::Any + Send> = Box::new(String::from("owned"));
        assert_eq!(panic_message(owned.as_ref()), "owned");
        let other: Box<dyn std::any::Any + Send> = Box::new(42usize);
        assert_eq!(panic_message(other.as_ref()), "unknown panic payload");
    }
}
