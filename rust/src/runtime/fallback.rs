//! Pure-Rust implementation of the device kernels' exact semantics.
//!
//! Used when `artifacts/` is absent or a problem exceeds every shape
//! bucket, and as the ground truth in the device-vs-fallback integration
//! tests.  Matches the Pallas kernels: descending sort (stable on ties),
//! then greedy placement with ties to bin 0.

use super::executor::{DeviceAlgo, EdgeProblem, EdgeSolution};

/// Solve one two-bin problem exactly like the device path does.
pub fn solve(p: &EdgeProblem, algo: DeviceAlgo) -> EdgeSolution {
    let m = p.weights.len();
    let mut sums = p.base;
    let mut assign = vec![0u8; m];
    match algo {
        DeviceAlgo::Greedy => {
            for (i, &w) in p.weights.iter().enumerate() {
                let k = usize::from(sums[1] < sums[0]);
                assign[i] = k as u8;
                sums[k] += w;
            }
        }
        DeviceAlgo::SortedGreedy => {
            // Sort (weight, index) pairs directly — contiguous accesses
            // beat the indirect index sort by ~2x (§Perf experiment E).
            // Stable descending, matching np.argsort(-w, kind="stable").
            let mut keyed: Vec<(f64, u32)> = p
                .weights
                .iter()
                .enumerate()
                .map(|(i, &w)| (w, i as u32))
                .collect();
            keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap().then(a.1.cmp(&b.1)));
            for &(w, i) in &keyed {
                let k = usize::from(sums[1] < sums[0]);
                assign[i as usize] = k as u8;
                sums[k] += w;
            }
        }
    }
    let movements = assign
        .iter()
        .zip(&p.hosts)
        .filter(|(a, h)| **a != **h)
        .count();
    EdgeSolution {
        assign,
        sums,
        movements,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sorted_greedy_two_balls() {
        let p = EdgeProblem {
            weights: vec![1.0, 5.0],
            hosts: vec![0, 0],
            base: [0.0, 0.0],
        };
        let s = solve(&p, DeviceAlgo::SortedGreedy);
        // 5 placed first into bin 0 (tie), 1 into bin 1
        assert_eq!(s.assign, vec![1, 0]);
        assert_eq!(s.sums, [5.0, 1.0]);
        assert_eq!(s.movements, 1);
    }

    #[test]
    fn greedy_keeps_arrival_order() {
        let p = EdgeProblem {
            weights: vec![1.0, 5.0],
            hosts: vec![0, 1],
            base: [0.0, 0.0],
        };
        let s = solve(&p, DeviceAlgo::Greedy);
        // 1 -> bin 0 (tie), 5 -> bin 1
        assert_eq!(s.assign, vec![0, 1]);
        assert_eq!(s.movements, 0);
    }

    #[test]
    fn base_offsets() {
        let p = EdgeProblem {
            weights: vec![1.0],
            hosts: vec![0],
            base: [10.0, 0.0],
        };
        let s = solve(&p, DeviceAlgo::SortedGreedy);
        assert_eq!(s.assign, vec![1]);
        assert_eq!(s.sums, [10.0, 1.0]);
    }

    #[test]
    fn stable_tie_ordering() {
        let p = EdgeProblem {
            weights: vec![2.0, 2.0, 2.0, 2.0],
            hosts: vec![0; 4],
            base: [0.0, 0.0],
        };
        let s = solve(&p, DeviceAlgo::SortedGreedy);
        // ties keep index order: 0->bin0, 1->bin1, 2->bin0, 3->bin1
        assert_eq!(s.assign, vec![0, 1, 0, 1]);
        assert_eq!(s.sums, [4.0, 4.0]);
    }
}
