//! Paper experiment drivers (E1–E8), the engine-scaling study (E11),
//! and the dynamic service-traffic study (E14): shared by the CLI and
//! the benches.

pub mod common;
pub mod dynamic;
pub mod figures;
pub mod scaling;
pub mod validate;

pub use common::{find, run_cell, run_sweep, CellStats, SweepParams, Variant};
pub use dynamic::{run_dynamic_experiment, DynamicCell, DynamicReport, E14_CSV};
pub use scaling::{
    large_scenarios, run_scaling, scaling_table, ScalingReport, ScalingScenario,
    ThreadMeasurement,
};
