//! Minimal JSON parser / emitter (serde is not vendored in this image).
//!
//! Supports the full JSON grammar minus exotic number forms; used for the
//! AOT `artifacts/manifest.json`, experiment configs, result files, and
//! the `bcm-dlb serve` job-spec protocol.
//!
//! Because `serve` parses attacker-adjacent input straight off a socket,
//! the parser enforces the same hostile-input posture as the wire codec's
//! length guards: nesting deeper than [`MAX_DEPTH`] and string/number
//! tokens longer than [`MAX_TOKEN`] bytes are rejected with typed errors
//! ([`JsonErrorKind`]) instead of recursing or allocating unboundedly.
//! For the streaming side, [`LineEmitter`] writes one value per line
//! through a reusable buffer, so emitting a long report stream never
//! buffers more than the single value in flight.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value.  Objects use `BTreeMap` for deterministic serialization.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            src: src.as_bytes(),
            pos: 0,
            depth: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.src.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as u64),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["k"]` access; returns `Json::Null` when missing.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

impl From<f64> for Json {
    fn from(x: f64) -> Self {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Self {
        Json::Num(x as f64)
    }
}
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Self {
        Json::Arr(v)
    }
}

/// Maximum nesting depth the parser accepts.  Deep enough for any real
/// config or result file; shallow enough that a `[[[[...` bomb off a
/// socket cannot blow the stack (the parser is recursive-descent).
pub const MAX_DEPTH: usize = 64;

/// Maximum byte length of a single string or number token.  Mirrors the
/// wire codec's hostile-length rejection: a forged multi-gigabyte token
/// fails fast instead of driving allocation.
pub const MAX_TOKEN: usize = 1 << 20;

/// What class of failure a [`JsonError`] is — callers that serve
/// untrusted input (the `serve` job-spec reader) distinguish malformed
/// text from resource-limit rejections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JsonErrorKind {
    /// The text is not well-formed JSON.
    Syntax,
    /// Well-formed so far, but nested deeper than [`MAX_DEPTH`].
    TooDeep,
    /// A string or number token exceeds [`MAX_TOKEN`] bytes.
    TokenTooLong,
}

#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
    pub kind: JsonErrorKind,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    src: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        self.err_kind(JsonErrorKind::Syntax, msg)
    }

    fn err_kind(&self, kind: JsonErrorKind, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
            kind,
        }
    }

    /// Guard a recursion step ([`MAX_DEPTH`]); callers pair it with
    /// `self.depth -= 1` on the way out.
    fn enter(&mut self) -> Result<(), JsonError> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err_kind(
                JsonErrorKind::TooDeep,
                &format!("nesting deeper than {MAX_DEPTH} levels"),
            ));
        }
        Ok(())
    }

    fn peek(&self) -> Option<u8> {
        self.src.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.src[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.object_inner();
        self.depth -= 1;
        v
    }

    fn object_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.enter()?;
        let v = self.array_inner();
        self.depth -= 1;
        v
    }

    fn array_inner(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(out)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            if s.len() > MAX_TOKEN {
                return Err(self.err_kind(
                    JsonErrorKind::TokenTooLong,
                    &format!("string longer than {MAX_TOKEN} bytes"),
                ));
            }
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{0008}'),
                    Some(b'f') => s.push('\u{000C}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex"))?;
                        }
                        s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x80 => s.push(c as char),
                Some(c) => {
                    // Re-decode the UTF-8 sequence starting at c.
                    let start = self.pos - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let end = (start + len).min(self.src.len());
                    let chunk = std::str::from_utf8(&self.src[start..end])
                        .map_err(|_| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if self.pos - start > MAX_TOKEN {
            return Err(self.err_kind(
                JsonErrorKind::TokenTooLong,
                &format!("number longer than {MAX_TOKEN} bytes"),
            ));
        }
        let text = std::str::from_utf8(&self.src[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn escape_into(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        write_json(self, &mut s);
        f.write_str(&s)
    }
}

/// Streaming JSON-lines emitter: one value per `emit`, rendered through
/// a single reusable buffer and flushed to the sink immediately.  The
/// high-water memory is the largest *single* value emitted, never the
/// whole stream — this is what `bcm-dlb serve` uses to stream per-round
/// reports without buffering a run's worth of output.
pub struct LineEmitter<W: std::io::Write> {
    sink: W,
    buf: String,
}

impl<W: std::io::Write> LineEmitter<W> {
    /// Wrap a sink.
    pub fn new(sink: W) -> LineEmitter<W> {
        LineEmitter {
            sink,
            buf: String::new(),
        }
    }

    /// Render `v` and write it to the sink as one `\n`-terminated line.
    pub fn emit(&mut self, v: &Json) -> std::io::Result<()> {
        self.buf.clear();
        write_json(v, &mut self.buf);
        self.buf.push('\n');
        self.sink.write_all(self.buf.as_bytes())
    }

    /// Borrow the underlying sink.
    pub fn get_mut(&mut self) -> &mut W {
        &mut self.sink
    }

    /// Unwrap back into the sink.
    pub fn into_inner(self) -> W {
        self.sink
    }
}

/// Render `v` into `out` (compact form, deterministic key order).
pub fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(true) => out.push_str("true"),
        Json::Bool(false) => out.push_str("false"),
        Json::Num(x) => {
            if x.fract() == 0.0 && x.abs() < 1e15 {
                out.push_str(&format!("{}", *x as i64));
            } else {
                out.push_str(&format!("{x}"));
            }
        }
        Json::Str(s) => escape_into(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, v) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(v, out);
            }
            out.push(']');
        }
        Json::Obj(o) => {
            out.push('{');
            for (i, (k, v)) in o.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                escape_into(k, out);
                out.push(':');
                write_json(v, out);
            }
            out.push('}');
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").as_arr().unwrap().len(), 3);
        assert_eq!(v.get("a").as_arr().unwrap()[2].get("b"), &Json::Null);
        assert_eq!(v.get("c").as_str().unwrap(), "x\ny");
    }

    #[test]
    fn parse_unicode_escape() {
        let v = Json::parse(r#""é""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é");
    }

    #[test]
    fn parse_utf8_passthrough() {
        let v = Json::parse("\"héllo → wörld\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo → wörld");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arts":[{"file":"a.hlo.txt","shape":[8,64]}],"v":1.5}"#;
        let v = Json::parse(src).unwrap();
        let emitted = v.to_string();
        assert_eq!(Json::parse(&emitted).unwrap(), v);
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(8.0).to_string(), "8");
        assert_eq!(Json::Num(8.5).to_string(), "8.5");
    }

    #[test]
    fn accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": true, "a": []}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("s").as_str(), Some("x"));
        assert_eq!(v.get("b").as_bool(), Some(true));
        assert_eq!(v.get("a").as_arr().map(|a| a.len()), Some(0));
        assert_eq!(v.get("missing"), &Json::Null);
        assert_eq!(v.get("s").as_f64(), None);
    }

    #[test]
    fn escape_roundtrip() {
        let s = "quote\" slash\\ nl\n tab\t ctl\u{0001}";
        let v = Json::Str(s.to_string());
        assert_eq!(Json::parse(&v.to_string()).unwrap().as_str().unwrap(), s);
    }

    #[test]
    fn depth_limit_rejects_nesting_bombs() {
        // a bare "[[[[..." prefix must fail fast, not recurse to a
        // stack overflow
        let bomb = "[".repeat(MAX_DEPTH * 4);
        assert_eq!(Json::parse(&bomb).unwrap_err().kind, JsonErrorKind::TooDeep);
        // exactly at the limit still parses; one past it does not
        let at = format!("{}{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(Json::parse(&at).is_ok());
        let over = format!("{}1{}", "[".repeat(MAX_DEPTH + 1), "]".repeat(MAX_DEPTH + 1));
        assert_eq!(Json::parse(&over).unwrap_err().kind, JsonErrorKind::TooDeep);
        // mixed nesting counts both kinds of container
        let mixed = "{\"a\":".repeat(MAX_DEPTH + 1) + "1" + &"}".repeat(MAX_DEPTH + 1);
        assert_eq!(
            Json::parse(&mixed).unwrap_err().kind,
            JsonErrorKind::TooDeep
        );
    }

    #[test]
    fn token_limit_rejects_oversized_strings_and_numbers() {
        let s = format!("\"{}\"", "a".repeat(MAX_TOKEN + 2));
        assert_eq!(
            Json::parse(&s).unwrap_err().kind,
            JsonErrorKind::TokenTooLong
        );
        let n = "1".repeat(MAX_TOKEN + 2);
        assert_eq!(
            Json::parse(&n).unwrap_err().kind,
            JsonErrorKind::TokenTooLong
        );
        // ordinary errors stay Syntax
        assert_eq!(Json::parse("{").unwrap_err().kind, JsonErrorKind::Syntax);
    }

    #[test]
    fn fuzz_truncated_and_mutated_specs_never_panic() {
        use crate::util::rng::Pcg64;
        // a realistic serve job spec (ASCII, so every byte index is a
        // char boundary)
        let spec = r#"{"n":64,"graph":"ring","algo":"sorted:quick","sweeps":4,"seed":7,"batch":2,"serve":{"listen":"127.0.0.1:0","max_jobs":2},"verify":true}"#;
        assert!(Json::parse(spec).is_ok());
        // every truncation must error cleanly, never panic or hang
        for cut in 0..spec.len() {
            assert!(Json::parse(&spec[..cut]).is_err() || cut == 0);
        }
        let mut rng = Pcg64::new(0x5e2_ce11);
        // random byte mutations of the spec
        for _ in 0..500 {
            let mut bytes = spec.as_bytes().to_vec();
            let flips = 1 + (rng.next_u64() % 4) as usize;
            for _ in 0..flips {
                let i = (rng.next_u64() % bytes.len() as u64) as usize;
                bytes[i] = (rng.next_u64() & 0xff) as u8;
            }
            if let Ok(s) = std::str::from_utf8(&bytes) {
                let _ = Json::parse(s); // outcome is free; crashing is not
            }
        }
        // pure garbage lines of the kind a confused client might send
        for _ in 0..200 {
            let len = (rng.next_u64() % 80) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0x7f) as u8).collect();
            if let Ok(s) = std::str::from_utf8(&bytes) {
                let _ = Json::parse(s);
            }
        }
    }

    #[test]
    fn line_emitter_streams_one_value_per_line() {
        let mut em = LineEmitter::new(Vec::new());
        em.emit(&Json::obj(vec![("round", Json::from(0usize))]))
            .unwrap();
        em.emit(&Json::obj(vec![("round", Json::from(1usize))]))
            .unwrap();
        let out = String::from_utf8(em.into_inner()).unwrap();
        let lines: Vec<&str> = out.lines().collect();
        assert_eq!(lines.len(), 2);
        assert_eq!(
            Json::parse(lines[1]).unwrap().get("round").as_usize(),
            Some(1)
        );
        assert!(out.ends_with('\n'));
    }
}
