//! The leader: spawns one worker thread per processor, drives the BCM
//! schedule round by round, aggregates metrics, and tears the cluster
//! down into a final `LoadState`.
//!
//! This is the deployment shape the paper assumes (§1): local one-to-one
//! communication only; the leader is pure control plane (schedule +
//! metrics) — load payloads only ever travel between matched workers.

use super::messages::{Ctl, Peer, Report};
use super::worker::{Worker, WorkerAlgo};
use crate::bcm::{RoundStats, RunTrace, Schedule};
use crate::load::LoadState;
use crate::util::rng::Pcg64;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;

pub struct Cluster {
    n: usize,
    ctl_tx: Vec<Sender<Ctl>>,
    report_rx: Receiver<Report>,
    handles: Vec<JoinHandle<()>>,
}

impl Cluster {
    /// Spawn `n` workers seeded with `state`'s loads.
    pub fn spawn(state: LoadState, algo: WorkerAlgo) -> Cluster {
        let n = state.n();
        let (report_tx, report_rx) = channel::<Report>();
        let mut ctl_tx = Vec::with_capacity(n);
        let mut ctl_rx = Vec::with_capacity(n);
        let mut peer_tx: Vec<Sender<Peer>> = Vec::with_capacity(n);
        let mut peer_rx = Vec::with_capacity(n);
        for _ in 0..n {
            let (ct, cr) = channel::<Ctl>();
            ctl_tx.push(ct);
            ctl_rx.push(Some(cr));
            let (pt, pr) = channel::<Peer>();
            peer_tx.push(pt);
            peer_rx.push(Some(pr));
        }
        let mut handles = Vec::with_capacity(n);
        for (v, loads) in (0..n).zip((0..n).map(|v| state.node(v).to_vec())) {
            let worker = Worker {
                id: v as u32,
                loads,
                algo,
                ctl_rx: ctl_rx[v].take().unwrap(),
                peer_rx: peer_rx[v].take().unwrap(),
                peer_tx: peer_tx.clone(),
                report_tx: report_tx.clone(),
            };
            handles.push(std::thread::spawn(move || worker.run()));
        }
        Cluster {
            n,
            ctl_tx,
            report_rx,
            handles,
        }
    }

    pub fn n(&self) -> usize {
        self.n
    }

    /// Drive `sweeps` full sweeps of the schedule.  Records per-round
    /// stats (discrepancy is polled from the workers after each round).
    pub fn run(&mut self, schedule: &Schedule, sweeps: usize, rng: &mut Pcg64) -> RunTrace {
        assert_eq!(schedule.n(), self.n);
        let mut trace = RunTrace {
            initial_discrepancy: self.poll_discrepancy(),
            rounds: Vec::new(),
        };
        let d = schedule.period();
        for round in 0..sweeps * d {
            let stats = self.run_single_round(schedule, round, rng);
            trace.rounds.push(stats);
        }
        trace
    }

    /// Execute one round (matching `round % d` of the schedule) and poll
    /// the resulting global discrepancy.
    pub fn run_single_round(
        &mut self,
        schedule: &Schedule,
        round: usize,
        rng: &mut Pcg64,
    ) -> RoundStats {
        let pairs = schedule.matching(round).to_vec();
        let movements = self.run_round(&pairs, rng);
        RoundStats {
            round,
            color: round % schedule.period(),
            discrepancy: self.poll_discrepancy(),
            movements,
            edges: pairs.len(),
        }
    }

    /// Execute one matching; returns total movements.
    fn run_round(&mut self, pairs: &[(u32, u32)], rng: &mut Pcg64) -> usize {
        let mut matched = vec![false; self.n];
        for &(u, v) in pairs {
            let flip = rng.coin();
            matched[u as usize] = true;
            matched[v as usize] = true;
            // lower id is the edge master
            self.ctl_tx[u as usize]
                .send(Ctl::Balance {
                    peer: v,
                    master: true,
                    flip,
                })
                .expect("worker died");
            self.ctl_tx[v as usize]
                .send(Ctl::Balance {
                    peer: u,
                    master: false,
                    flip,
                })
                .expect("worker died");
        }
        for (v, m) in matched.iter().enumerate() {
            if !m {
                self.ctl_tx[v].send(Ctl::Idle).expect("worker died");
            }
        }
        // Collect n RoundAcks + one EdgeDone per pair.
        let mut acks = 0usize;
        let mut movements = 0usize;
        let mut edges_done = 0usize;
        while acks < self.n || edges_done < pairs.len() {
            match self.report_rx.recv().expect("cluster wedged") {
                Report::RoundAck { .. } => acks += 1,
                Report::EdgeDone {
                    movements: m_edge, ..
                } => {
                    movements += m_edge;
                    edges_done += 1;
                }
                _ => {}
            }
        }
        movements
    }

    /// Poll every worker's weight and compute the global discrepancy.
    pub fn poll_discrepancy(&mut self) -> f64 {
        let w = self.poll_weights();
        let max = w.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
        let min = w.iter().cloned().fold(f64::INFINITY, f64::min);
        max - min
    }

    pub fn poll_weights(&mut self) -> Vec<f64> {
        for tx in &self.ctl_tx {
            tx.send(Ctl::Report).expect("worker died");
        }
        let mut w = vec![0.0; self.n];
        let mut got = 0;
        while got < self.n {
            if let Report::Weight { node, weight } = self.report_rx.recv().expect("wedged") {
                w[node as usize] = weight;
                got += 1;
            }
        }
        w
    }

    /// Shut the cluster down and collect the final load state.
    pub fn shutdown(self) -> LoadState {
        for tx in &self.ctl_tx {
            let _ = tx.send(Ctl::Shutdown);
        }
        let mut state = LoadState::empty(self.n);
        let mut got = 0;
        while got < self.n {
            if let Ok(Report::Final { node, loads }) = self.report_rx.recv() {
                for l in loads {
                    state.push(node as usize, l);
                }
                got += 1;
            }
        }
        for h in self.handles {
            let _ = h.join();
        }
        state
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Graph;
    use crate::load::{Mobility, WeightDistribution};

    fn init(
        n: usize,
        per_node: usize,
        mobility: Mobility,
        seed: u64,
    ) -> (LoadState, Schedule, Pcg64) {
        let mut rng = Pcg64::new(seed);
        let g = Graph::random_connected(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            n,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        );
        (state, schedule, rng)
    }

    #[test]
    fn cluster_balances_and_conserves() {
        let (state, schedule, mut rng) = init(8, 30, Mobility::Full, 1);
        let ids = state.all_ids();
        let mass = state.total_weight();
        let init_disc = state.discrepancy();
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        let trace = cluster.run(&schedule, 8, &mut rng);
        let final_state = cluster.shutdown();
        assert_eq!(final_state.all_ids(), ids);
        assert!((final_state.total_weight() - mass).abs() < 1e-6);
        assert!(
            trace.final_discrepancy() < init_disc / 10.0,
            "init {init_disc} final {}",
            trace.final_discrepancy()
        );
        // the trace's own view agrees with the final state
        assert!((final_state.discrepancy() - trace.final_discrepancy()).abs() < 1e-9);
    }

    #[test]
    fn cluster_greedy_runs() {
        let (state, schedule, mut rng) = init(6, 20, Mobility::Partial, 2);
        let mut cluster = Cluster::spawn(state, WorkerAlgo::Greedy);
        let trace = cluster.run(&schedule, 4, &mut rng);
        assert!(trace.final_discrepancy() <= trace.initial_discrepancy);
        cluster.shutdown();
    }

    #[test]
    fn cluster_matches_sequential_engine_statistically() {
        let (state, schedule, mut rng) = init(8, 40, Mobility::Full, 3);
        let mut seq_state = state.clone();
        let mut seq_rng = Pcg64::new(77);
        let t_seq = crate::bcm::run(
            &mut seq_state,
            &schedule,
            crate::balancer::PairAlgorithm::SortedGreedy(crate::balancer::SortAlgo::Quick),
            crate::bcm::StopRule::sweeps(6),
            &mut seq_rng,
        );
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        let t_par = cluster.run(&schedule, 6, &mut rng);
        cluster.shutdown();
        // Both runs should converge to a tiny discrepancy.
        assert!(t_seq.final_discrepancy() < t_seq.initial_discrepancy / 10.0);
        assert!(t_par.final_discrepancy() < t_par.initial_discrepancy / 10.0);
    }

    #[test]
    fn pinned_loads_survive_distributed_run() {
        let mut rng = Pcg64::new(4);
        let g = Graph::ring(4);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::empty(4);
        state.push(1, crate::load::Load::pinned(0, 42.0));
        state.push(0, crate::load::Load::new(1, 1.0));
        state.push(2, crate::load::Load::new(2, 2.0));
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        cluster.run(&schedule, 3, &mut rng);
        let fin = cluster.shutdown();
        assert!(fin.node(1).iter().any(|l| l.id == 0 && !l.mobile));
        assert_eq!(fin.total_loads(), 3);
    }
}
