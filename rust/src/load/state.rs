//! Per-network load state: which loads live on which processor.

use super::distribution::WeightDistribution;
use super::item::Load;
use crate::util::rng::Pcg64;

/// Load mobility model (paper §6.1).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Mobility {
    /// All loads are free to move.
    Full,
    /// On each node with m loads, r ~ U{1, .., m-1} of them are pinned
    /// uniformly at random ("we uniformly at random set r ∈ [1, …, l−1]
    /// of them to be immobile").
    Partial,
}

impl Mobility {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "full" => Some(Mobility::Full),
            "partial" => Some(Mobility::Partial),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Mobility::Full => "full",
            Mobility::Partial => "partial",
        }
    }
}

/// The assignment of loads to the n processors.
#[derive(Clone, Debug, PartialEq)]
pub struct LoadState {
    nodes: Vec<Vec<Load>>,
    next_id: u64,
}

/// Disjoint mutable views of a matching's endpoint load lists (one
/// `(u, v)` entry per edge), as handed out by [`LoadState::split_pairs`].
pub type PairSlots<'a> = Vec<(&'a mut Vec<Load>, &'a mut Vec<Load>)>;

/// Minimum nodes per worker before the chunked weight reduction spawns
/// threads; below this the scalar fold (tens of microseconds) is cheaper
/// than a scoped spawn/join barrier, so threading would regress the
/// round loop it is meant to speed up.
pub const REDUCE_CHUNK_MIN: usize = 8192;

impl LoadState {
    pub fn empty(n: usize) -> Self {
        Self {
            nodes: vec![Vec::new(); n],
            next_id: 0,
        }
    }

    /// The paper's §6 initialization: `per_node` loads on every node, each
    /// weight drawn i.i.d. from `dist`, then the mobility model applied.
    pub fn init_uniform_counts(
        n: usize,
        per_node: usize,
        dist: &WeightDistribution,
        mobility: Mobility,
        rng: &mut Pcg64,
    ) -> Self {
        let mut state = Self::empty(n);
        for v in 0..n {
            for _ in 0..per_node {
                let id = state.next_id;
                state.next_id += 1;
                state.nodes[v].push(Load::new(id, dist.sample(rng)));
            }
        }
        if mobility == Mobility::Partial {
            state.pin_random(rng);
        }
        state
    }

    /// Pin r ∈ U{1..m−1} random loads on every node with m ≥ 2 loads.
    pub fn pin_random(&mut self, rng: &mut Pcg64) {
        for node in &mut self.nodes {
            let m = node.len();
            if m < 2 {
                continue;
            }
            let r = rng.range_inclusive(1, m - 1);
            for idx in rng.sample_indices(m, r) {
                node[idx].mobile = false;
            }
        }
    }

    pub fn n(&self) -> usize {
        self.nodes.len()
    }

    pub fn node(&self, v: usize) -> &[Load] {
        &self.nodes[v]
    }

    pub fn node_mut(&mut self, v: usize) -> &mut Vec<Load> {
        &mut self.nodes[v]
    }

    pub fn push(&mut self, v: usize, load: Load) {
        self.next_id = self.next_id.max(load.id + 1);
        self.nodes[v].push(load);
    }

    /// Total weight on node v.
    pub fn node_weight(&self, v: usize) -> f64 {
        self.nodes[v].iter().map(|l| l.weight).sum()
    }

    /// Weight of the pinned loads on node v.
    pub fn pinned_weight(&self, v: usize) -> f64 {
        self.nodes[v]
            .iter()
            .filter(|l| !l.mobile)
            .map(|l| l.weight)
            .sum()
    }

    /// The load vector x^(t) (paper §2).
    pub fn load_vector(&self) -> Vec<f64> {
        (0..self.n()).map(|v| self.node_weight(v)).collect()
    }

    pub fn total_weight(&self) -> f64 {
        self.load_vector().iter().sum()
    }

    pub fn total_loads(&self) -> usize {
        self.nodes.iter().map(|n| n.len()).sum()
    }

    /// Discrepancy: weight difference between heaviest and lightest node.
    pub fn discrepancy(&self) -> f64 {
        let (min, max) = self.weight_extremes();
        max - min
    }

    /// `(min, max)` node weight, folded in node order — the scalar
    /// reduction behind [`discrepancy`](Self::discrepancy).
    pub fn weight_extremes(&self) -> (f64, f64) {
        let mut min = f64::INFINITY;
        let mut max = f64::NEG_INFINITY;
        for node in &self.nodes {
            let w: f64 = node.iter().map(|l| l.weight).sum();
            min = min.min(w);
            max = max.max(w);
        }
        (min, max)
    }

    /// [`weight_extremes`](Self::weight_extremes) fanned out over up to
    /// `threads` scoped workers, each folding a contiguous chunk of nodes.
    ///
    /// Bit-identical to the scalar fold for every thread count: each
    /// node's weight is summed by the same per-node loop, and f64 min/max
    /// are exactly associative and commutative (no rounding), so chunking
    /// cannot change the result.  Small states (under
    /// [`REDUCE_CHUNK_MIN`] nodes per worker) take the scalar path — the
    /// thread fan-out would cost more than the fold.
    pub fn weight_extremes_threaded(&self, threads: usize) -> (f64, f64) {
        let workers = threads
            .max(1)
            .min((self.nodes.len() / REDUCE_CHUNK_MIN).max(1));
        if workers <= 1 {
            return self.weight_extremes();
        }
        let chunk = self.nodes.len().div_ceil(workers);
        std::thread::scope(|scope| {
            let handles: Vec<_> = self
                .nodes
                .chunks(chunk)
                .map(|part| {
                    scope.spawn(move || {
                        let mut min = f64::INFINITY;
                        let mut max = f64::NEG_INFINITY;
                        for node in part {
                            let w: f64 = node.iter().map(|l| l.weight).sum();
                            min = min.min(w);
                            max = max.max(w);
                        }
                        (min, max)
                    })
                })
                .collect();
            handles.into_iter().fold(
                (f64::INFINITY, f64::NEG_INFINITY),
                |(amin, amax), h| {
                    let (min, max) = h.join().expect("reduction worker panicked");
                    (amin.min(min), amax.max(max))
                },
            )
        })
    }

    /// [`discrepancy`](Self::discrepancy) over the chunked reduction.
    pub fn discrepancy_threaded(&self, threads: usize) -> f64 {
        let (min, max) = self.weight_extremes_threaded(threads);
        max - min
    }

    /// Largest single load in the network (l_max, Appendix A req. 4).
    pub fn max_load_weight(&self) -> f64 {
        self.nodes
            .iter()
            .flatten()
            .map(|l| l.weight)
            .fold(0.0, f64::max)
    }

    /// Remove and return the mobile loads of node v (pinned loads stay).
    pub fn take_mobile(&mut self, v: usize) -> Vec<Load> {
        let (mobile, pinned): (Vec<Load>, Vec<Load>) =
            self.nodes[v].drain(..).partition(|l| l.mobile);
        self.nodes[v] = pinned;
        mobile
    }

    /// Append loads to node v.
    pub fn give(&mut self, v: usize, loads: impl IntoIterator<Item = Load>) {
        self.nodes[v].extend(loads);
    }

    /// Split the state into per-edge mutable views of the endpoint load
    /// lists of `pairs`.
    ///
    /// Edges within one BCM color class are vertex-disjoint by
    /// construction, so every returned view aliases nothing: the views can
    /// be balanced concurrently (the foundation of `bcm::parallel`).
    /// Panics if `pairs` is not a matching (a vertex repeats, a self-loop,
    /// or an index out of range) — the disjointness check is what makes
    /// the pointer fan-out below sound.
    pub fn split_pairs(&mut self, pairs: &[(u32, u32)]) -> PairSlots<'_> {
        let n = self.nodes.len();
        let mut seen = vec![false; n];
        for &(u, v) in pairs {
            let (u, v) = (u as usize, v as usize);
            assert!(u < n && v < n, "split_pairs: edge ({u},{v}) out of range for n={n}");
            assert!(u != v, "split_pairs: self-loop ({u},{v})");
            assert!(
                !seen[u] && !seen[v],
                "split_pairs: vertex reused by ({u},{v}) — pairs are not a matching"
            );
            seen[u] = true;
            seen[v] = true;
        }
        let base = self.nodes.as_mut_ptr();
        pairs
            .iter()
            .map(|&(u, v)| {
                // SAFETY: every index is in bounds (checked above) and no
                // index appears twice across the whole matching (checked
                // above), so each element is mutably borrowed at most once.
                unsafe { (&mut *base.add(u as usize), &mut *base.add(v as usize)) }
            })
            .collect()
    }

    /// Sorted ids across the whole network (conservation checks).
    pub fn all_ids(&self) -> Vec<u64> {
        let mut ids: Vec<u64> = self.nodes.iter().flatten().map(|l| l.id).collect();
        ids.sort_unstable();
        ids
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(per_node: usize, mobility: Mobility, seed: u64) -> LoadState {
        let mut rng = Pcg64::new(seed);
        LoadState::init_uniform_counts(
            8,
            per_node,
            &WeightDistribution::paper_section6(),
            mobility,
            &mut rng,
        )
    }

    #[test]
    fn init_counts_and_ids() {
        let s = mk(10, Mobility::Full, 1);
        assert_eq!(s.n(), 8);
        assert_eq!(s.total_loads(), 80);
        let ids = s.all_ids();
        assert_eq!(ids, (0..80).collect::<Vec<u64>>());
    }

    #[test]
    fn full_mobility_all_mobile() {
        let s = mk(10, Mobility::Full, 2);
        assert!(s.nodes.iter().flatten().all(|l| l.mobile));
    }

    #[test]
    fn partial_mobility_pins_some_not_all() {
        let s = mk(10, Mobility::Partial, 3);
        for v in 0..8 {
            let pinned = s.node(v).iter().filter(|l| !l.mobile).count();
            assert!(
                (1..10).contains(&pinned),
                "node {v}: {pinned} pinned of 10"
            );
        }
    }

    #[test]
    fn single_load_nodes_not_pinned() {
        let mut rng = Pcg64::new(4);
        let mut s = LoadState::empty(2);
        s.push(0, Load::new(0, 1.0));
        s.pin_random(&mut rng);
        assert!(s.node(0)[0].mobile);
    }

    #[test]
    fn weights_and_discrepancy() {
        let mut s = LoadState::empty(3);
        s.push(0, Load::new(0, 5.0));
        s.push(0, Load::new(1, 3.0));
        s.push(2, Load::new(2, 1.0));
        assert_eq!(s.node_weight(0), 8.0);
        assert_eq!(s.node_weight(1), 0.0);
        assert_eq!(s.load_vector(), vec![8.0, 0.0, 1.0]);
        assert_eq!(s.discrepancy(), 8.0);
        assert_eq!(s.total_weight(), 9.0);
        assert_eq!(s.max_load_weight(), 5.0);
    }

    #[test]
    fn take_mobile_leaves_pinned() {
        let mut s = LoadState::empty(1);
        s.push(0, Load::new(0, 1.0));
        s.push(0, Load::pinned(1, 2.0));
        s.push(0, Load::new(2, 3.0));
        let taken = s.take_mobile(0);
        assert_eq!(taken.len(), 2);
        assert_eq!(s.node(0).len(), 1);
        assert_eq!(s.node(0)[0].id, 1);
        assert_eq!(s.pinned_weight(0), 2.0);
        s.give(0, taken);
        assert_eq!(s.node(0).len(), 3);
    }

    #[test]
    fn split_pairs_disjoint_views() {
        let mut s = mk(5, Mobility::Full, 9);
        let total_before = s.total_loads();
        {
            let mut slots = s.split_pairs(&[(0, 3), (1, 2)]);
            assert_eq!(slots.len(), 2);
            // move one load across the first edge through the views
            let l = slots[0].0.pop().unwrap();
            slots[0].1.push(l);
        }
        assert_eq!(s.node(0).len(), 4);
        assert_eq!(s.node(3).len(), 6);
        assert_eq!(s.total_loads(), total_before);
    }

    #[test]
    #[should_panic(expected = "not a matching")]
    fn split_pairs_rejects_repeated_vertex() {
        let mut s = mk(2, Mobility::Full, 10);
        let _ = s.split_pairs(&[(0, 1), (1, 2)]);
    }

    #[test]
    #[should_panic(expected = "self-loop")]
    fn split_pairs_rejects_self_loop() {
        let mut s = mk(2, Mobility::Full, 11);
        let _ = s.split_pairs(&[(3, 3)]);
    }

    #[test]
    fn threaded_weight_extremes_bit_identical_to_scalar() {
        // Large enough that the chunked path actually engages
        // (REDUCE_CHUNK_MIN nodes per worker).
        let mut rng = Pcg64::new(42);
        let n = 4 * super::REDUCE_CHUNK_MIN;
        let mut s = LoadState::empty(n);
        for v in 0..n {
            for j in 0..1 + (v % 3) {
                s.push(v, Load::new((v * 4 + j) as u64, rng.uniform(0.0, 10.0)));
            }
        }
        let scalar = s.weight_extremes();
        for threads in [1, 2, 3, 4, 8, 64] {
            assert_eq!(
                s.weight_extremes_threaded(threads),
                scalar,
                "diverged at {threads} threads"
            );
        }
        assert_eq!(s.discrepancy_threaded(4), s.discrepancy());
        // empty nodes participate with weight 0 in both paths
        let mut t = LoadState::empty(n);
        t.push(0, Load::new(0, 5.0));
        assert_eq!(t.weight_extremes_threaded(8), t.weight_extremes());
        assert_eq!(t.weight_extremes(), (0.0, 5.0));
    }

    #[test]
    fn mobility_parse() {
        assert_eq!(Mobility::parse("full"), Some(Mobility::Full));
        assert_eq!(Mobility::parse("partial"), Some(Mobility::Partial));
        assert_eq!(Mobility::parse("x"), None);
    }
}
