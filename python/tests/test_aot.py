"""AOT path: HLO text artifacts are parseable, executable, and correct.

Loads a lowered artifact back through xla_client, executes it on the CPU
backend, and checks the numbers against the oracles — the same contract the
Rust runtime relies on.
"""

import json
import os
import subprocess
import sys
import tempfile

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from jax._src.lib import xla_client as xc

from compile import aot, model
from compile.kernels import ref


def test_catalog_names_unique():
    names = [item["name"] for item in aot.build_catalog()]
    assert len(names) == len(set(names))


def test_catalog_covers_all_entries():
    entries = {item["entry"] for item in aot.build_catalog()}
    assert entries == {
        "balance_two_bin",
        "greedy_two_bin",
        "offline_nbin",
        "continuous_round",
    }


def test_hlo_text_roundtrip_small():
    """Lower one small bucket and reparse the text as an HloModule.

    The actual *execution* of the reparsed text happens on the Rust side
    (xla_extension 0.5.1 via the `xla` crate) and is covered by
    rust/tests/integration_runtime.rs; here we verify the text is valid
    HLO and the entry computation has the manifest's arity/shapes.
    """
    b, m = 8, 64
    lowered = jax.jit(model.balance_two_bin).lower(
        jax.ShapeDtypeStruct((b, m), jnp.float32),
        jax.ShapeDtypeStruct((b, 2), jnp.float32),
    )
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text

    module = xc._xla.hlo_module_from_text(text)
    reparsed = module.to_string()
    assert "f32[8,64]" in reparsed  # weights param survives the roundtrip
    assert "f32[8,2]" in reparsed  # base param
    assert "s32[8,64]" in reparsed  # perm output


def test_manifest_written(tmp_path):
    rc = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(tmp_path),
         "--only", "greedy_two_bin_b8_m64"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        capture_output=True,
        text=True,
    )
    assert rc.returncode == 0, rc.stderr
    man = json.loads((tmp_path / "manifest.json").read_text())
    assert man["format"] == "hlo-text"
    names = {a["name"] for a in man["artifacts"]}
    assert "greedy_two_bin_b8_m64" in names
    # the --only filter wrote just that artifact file
    assert (tmp_path / "greedy_two_bin_b8_m64.hlo.txt").exists()
    by_name = {a["name"]: a for a in man["artifacts"]}
    art = by_name["greedy_two_bin_b8_m64"]
    assert art["inputs"][0]["shape"] == [8, 64]
    assert art["outputs"][-1]["shape"] == [8, 2]


def test_repo_artifacts_if_present():
    """If make artifacts has run, every manifest entry's file exists."""
    art_dir = os.path.join(
        os.path.dirname(os.path.dirname(os.path.dirname(os.path.abspath(__file__)))),
        "artifacts",
    )
    mpath = os.path.join(art_dir, "manifest.json")
    if not os.path.exists(mpath):
        pytest.skip("artifacts/ not built")
    man = json.loads(open(mpath).read())
    for a in man["artifacts"]:
        path = os.path.join(art_dir, a["file"])
        assert os.path.exists(path), f"missing artifact {a['file']}"
        head = open(path).read(200)
        assert "HloModule" in head
