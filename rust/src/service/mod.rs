//! The multi-tenant balancer service behind `bcm-dlb serve`.
//!
//! One process, one thread, two event sources: a line-mode
//! [`Poller`] carrying client connections, and a [`ShardPool`] running
//! every accepted job on one shared set of shard workers.  The server
//! alternates short turns over both — accept/parse job specs, schedule
//! them onto the pool as slots free up, and stream each job's per-round
//! reports back to its client as JSON lines the moment the pool
//! surfaces them (via [`LineEmitter`], so no run's report stream is
//! ever buffered whole).
//!
//! # Protocol (JSON lines over TCP)
//!
//! A client sends **one** line: either a job spec (the
//! [`ExperimentConfig`] schema; unknown keys are ignored, plus
//! `"verify": true` to have the service check the finished run against
//! `bcm::Sequential`) or `{"cmd": "shutdown"}` to ask the service to
//! finish its queue and exit.  The server answers with a stream of
//! event lines, ending the connection after a terminal event:
//!
//! | line                                                        | meaning |
//! |-------------------------------------------------------------|---------|
//! | `{"event":"accepted"}`                                      | spec parsed; job queued |
//! | `{"event":"start","job":J,"initial_discrepancy":D}`         | scheduled on the pool |
//! | `{"event":"round","job":J,"round":R,"color":C,...}`         | one per round, streamed per batch |
//! | `{"event":"recover","job":J,"round":R}`                      | worker lost; job replays from round `R` (`checkpoint_every > 0` specs only) |
//! | `{"event":"stats","jobs_active":J,"rounds_per_s":R}`        | service-side throughput snapshot, just before `done` (`"stats": true` specs only) |
//! | `{"event":"done","job":J,"rounds":R,...,"verified":B}`      | terminal: run complete |
//! | `{"event":"error","message":M}`                             | terminal: job or spec failed |
//! | `{"event":"shutdown"}`                                      | terminal: drain acknowledged |
//!
//! Each job is seeded exactly like `bcm-dlb run` seeds its first
//! repetition, so a served run's round stream is **bit-identical** to
//! `Sequential` with the same spec — concurrency with other tenants
//! cannot perturb it (per-job RNG streams and load slices; see
//! `coordinator`).  Job failures are per-connection: one tenant's
//! panic or dead peer errors that connection only.

use crate::anyhow;
use crate::balancer::PairAlgorithm;
use crate::bcm::{Engine, RoundStats, Schedule, Sequential, StopRule};
use crate::config::ExperimentConfig;
use crate::coordinator::cluster::{JobEvent, JobSpec, ShardPool};
use crate::coordinator::transport::poll::{Event, Poller};
use crate::coordinator::transport::tcp::{connect_with_retry, DEFAULT_CONNECT_RETRIES};
use crate::load::LoadState;
use crate::util::error::Result;
use crate::util::json::{Json, LineEmitter};
use crate::util::rng::Pcg64;
use crate::workload::service_traffic::{run_dynamic_engine, TrafficConfig};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpListener};
use std::time::Duration;

/// How the server splits one loop iteration between its two event
/// sources; small enough that neither side waits noticeably on the
/// other.
const CLIENT_POLL: Duration = Duration::from_millis(5);
const POOL_POLL: Duration = Duration::from_millis(20);

/// `bcm-dlb serve` knobs.
pub struct ServeOptions {
    /// Bind address (config key `serve.listen`).
    pub listen: String,
    /// Concurrent job slots (config key `serve.max_jobs`); further
    /// submissions queue.
    pub max_jobs: usize,
    /// Pool worker count (`0` = one per core).
    pub shards: usize,
    /// Connection cap (active + queued); extras are refused at accept.
    pub max_conns: usize,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            listen: "127.0.0.1:7412".to_string(),
            max_jobs: 4,
            shards: 0,
            max_conns: 64,
        }
    }
}

/// Everything needed to re-run a job against `bcm::Sequential` after
/// the pool finishes it (`"verify": true` specs only).
struct VerifySrc {
    state: LoadState,
    schedule: Schedule,
    algo: PairAlgorithm,
    sweeps: usize,
    seed: u64,
    /// Set for churning specs: the reference re-run applies the same
    /// generated churn stream (`run_dynamic` instead of `run`).
    churn: Option<TrafficConfig>,
}

/// A parsed spec waiting for a job slot.
struct QueuedJob {
    spec: JobSpec,
    verify: Option<VerifySrc>,
    /// `"stats": true` in the spec (`bcm-dlb submit --stats`): stream a
    /// service-side throughput snapshot before the terminal `done`.
    stats: bool,
}

/// Per-connection lifecycle.
enum ConnState {
    /// Waiting for the client's single spec line.
    AwaitingSpec,
    /// Spec parsed; waiting for a job slot.
    Queued(Box<QueuedJob>),
    /// Running as this pool job.
    Running(u32),
}

struct ClientConn {
    state: ConnState,
    /// Terminal event sent; the connection is removed once its output
    /// buffer drains.
    done: bool,
}

/// The serve event loop: one poller for clients, one shard pool for
/// jobs, one thread for everything.
pub struct Server {
    poller: Poller,
    pool: ShardPool,
    addr: SocketAddr,
    max_jobs: usize,
    max_conns: usize,
    conns: BTreeMap<usize, ClientConn>,
    /// Tokens of `Queued` connections, in arrival order.
    pending: VecDeque<usize>,
    /// Pool job id -> client token (`None` once the client vanished
    /// mid-run; the job still completes, its events are discarded).
    by_job: BTreeMap<u32, Option<usize>>,
    /// Verification sources for running `--verify` jobs.
    verify: BTreeMap<u32, VerifySrc>,
    /// Start instants of running `--stats` jobs, for the `rounds_per_s`
    /// figure of their terminal stats event.
    stats: BTreeMap<u32, std::time::Instant>,
    emitter: LineEmitter<Vec<u8>>,
    shutting_down: bool,
}

impl Server {
    /// Bind the listen socket and spawn the shard pool.  The server
    /// does not serve until [`run`](Self::run).
    pub fn bind(opts: ServeOptions) -> Result<Server> {
        let listener = TcpListener::bind(&opts.listen)
            .map_err(|e| anyhow!("serve: cannot bind {}: {e}", opts.listen))?;
        let addr = listener.local_addr()?;
        let mut poller = Poller::new();
        poller.add_listener(listener)?;
        Ok(Server {
            poller,
            pool: ShardPool::spawn(opts.shards),
            addr,
            max_jobs: opts.max_jobs.max(1),
            max_conns: opts.max_conns.max(1),
            conns: BTreeMap::new(),
            pending: VecDeque::new(),
            by_job: BTreeMap::new(),
            verify: BTreeMap::new(),
            stats: BTreeMap::new(),
            emitter: LineEmitter::new(Vec::new()),
            shutting_down: false,
        })
    }

    /// The bound address (resolves `:0` ephemeral ports for tests).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Serve until a client sends `{"cmd":"shutdown"}` and every
    /// accepted job has drained.  `Err` means the pool itself failed.
    pub fn run(&mut self) -> Result<()> {
        let mut events = VecDeque::new();
        loop {
            // 1. client side: accepts, spec lines, hangups
            self.poller.poll(CLIENT_POLL, &mut events);
            while let Some(ev) = events.pop_front() {
                self.handle_client_event(ev);
            }
            // 2. move queued specs onto free job slots
            self.schedule_pending();
            // 3. pool side: job progress -> client streams
            let job_events = match self.pool.step(POOL_POLL) {
                Ok(evs) => evs,
                Err(e) => {
                    // the pool is gone; tell every client before dying
                    let toks: Vec<usize> = self.conns.keys().copied().collect();
                    let msg = e.to_string();
                    for tok in toks {
                        self.fail_conn(tok, &msg);
                    }
                    self.flush_remaining();
                    return Err(e);
                }
            };
            for ev in job_events {
                self.handle_job_event(ev);
            }
            // 4. reap connections whose terminal output has drained
            self.reap_done();
            // 5. drain-and-exit
            if self.shutting_down && self.by_job.is_empty() && self.pending.is_empty() {
                self.flush_remaining();
                return self.pool.shutdown();
            }
        }
    }

    fn handle_client_event(&mut self, ev: Event) {
        match ev {
            Event::Accepted { stream, .. } => {
                if self.conns.len() >= self.max_conns {
                    drop(stream); // refuse: at capacity
                    return;
                }
                if let Ok(tok) = self.poller.add_line_conn(stream) {
                    self.conns.insert(
                        tok,
                        ClientConn {
                            state: ConnState::AwaitingSpec,
                            done: false,
                        },
                    );
                }
            }
            Event::Line { token, line } => self.handle_line(token, &line),
            Event::Frame { .. } => unreachable!("client connections are line mode"),
            Event::Closed { token, .. } => {
                if let Some(conn) = self.conns.remove(&token) {
                    match conn.state {
                        ConnState::Queued(_) => self.pending.retain(|&t| t != token),
                        ConnState::Running(job) => {
                            // the job runs to completion; drop its stream
                            if let Some(slot) = self.by_job.get_mut(&job) {
                                *slot = None;
                            }
                        }
                        ConnState::AwaitingSpec => {}
                    }
                }
                self.poller.remove(token);
            }
        }
    }

    fn handle_line(&mut self, token: usize, line: &str) {
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        if conn.done || !matches!(conn.state, ConnState::AwaitingSpec) {
            self.fail_conn(token, "protocol: one spec line per connection");
            return;
        }
        let parsed = match Json::parse(line) {
            Ok(v) => v,
            Err(e) => {
                self.fail_conn(token, &format!("bad job spec: {e}"));
                return;
            }
        };
        if parsed.get("cmd").as_str() == Some("shutdown") {
            self.shutting_down = true;
            self.send_event(token, &Json::obj(vec![("event", "shutdown".into())]));
            self.finish_conn(token);
            return;
        }
        if self.shutting_down {
            self.fail_conn(token, "service is shutting down");
            return;
        }
        match build_job(line, &parsed) {
            Ok(queued) => {
                if let Some(conn) = self.conns.get_mut(&token) {
                    conn.state = ConnState::Queued(Box::new(queued));
                    self.pending.push_back(token);
                    self.send_event(token, &Json::obj(vec![("event", "accepted".into())]));
                }
            }
            Err(e) => self.fail_conn(token, &format!("bad job spec: {e}")),
        }
    }

    fn schedule_pending(&mut self) {
        while self.by_job.len() < self.max_jobs {
            let Some(token) = self.pending.pop_front() else {
                return;
            };
            let Some(conn) = self.conns.get_mut(&token) else {
                continue; // client hung up while queued
            };
            let ConnState::Queued(queued) =
                std::mem::replace(&mut conn.state, ConnState::AwaitingSpec)
            else {
                continue;
            };
            let QueuedJob { spec, verify, stats } = *queued;
            match self.pool.open_job(spec) {
                Ok(job) => {
                    if let Some(conn) = self.conns.get_mut(&token) {
                        conn.state = ConnState::Running(job);
                    }
                    self.by_job.insert(job, Some(token));
                    if let Some(v) = verify {
                        self.verify.insert(job, v);
                    }
                    if stats {
                        self.stats.insert(job, std::time::Instant::now());
                    }
                }
                Err(e) => {
                    let msg = e.to_string();
                    self.fail_conn(token, &msg);
                }
            }
        }
    }

    fn handle_job_event(&mut self, ev: JobEvent) {
        match ev {
            JobEvent::Started {
                job,
                initial_discrepancy,
            } => {
                if let Some(&Some(token)) = self.by_job.get(&job) {
                    self.send_event(
                        token,
                        &Json::obj(vec![
                            ("event", "start".into()),
                            ("job", (job as usize).into()),
                            ("initial_discrepancy", initial_discrepancy.into()),
                        ]),
                    );
                }
            }
            JobEvent::Rounds { job, stats } => {
                if let Some(&Some(token)) = self.by_job.get(&job) {
                    for s in &stats {
                        self.send_event(token, &round_json(job, s));
                    }
                }
            }
            JobEvent::Recovering { job, round } => {
                if let Some(&Some(token)) = self.by_job.get(&job) {
                    self.send_event(
                        token,
                        &Json::obj(vec![
                            ("event", "recover".into()),
                            ("job", (job as usize).into()),
                            ("round", round.into()),
                        ]),
                    );
                }
            }
            JobEvent::Finished { job, trace, state } => {
                let token = self.by_job.remove(&job).flatten();
                // --stats snapshot first, so the terminal `done` stays
                // the last line: jobs still sharing the pool right now,
                // and this job's end-to-end round throughput.
                if let Some(started) = self.stats.remove(&job) {
                    let secs = started.elapsed().as_secs_f64();
                    let rps = if secs > 0.0 {
                        trace.rounds.len() as f64 / secs
                    } else {
                        0.0
                    };
                    if let Some(token) = token {
                        self.send_event(
                            token,
                            &Json::obj(vec![
                                ("event", "stats".into()),
                                ("jobs_active", self.by_job.len().into()),
                                ("rounds_per_s", rps.into()),
                            ]),
                        );
                    }
                }
                let verified = match self.verify.remove(&job) {
                    None => false,
                    Some(src) => {
                        let mut seq_state = src.state;
                        let seq_trace = match &src.churn {
                            None => Sequential.run(
                                &mut seq_state,
                                &src.schedule,
                                src.algo,
                                StopRule::sweeps(src.sweeps),
                                src.seed,
                            ),
                            Some(cfg) => run_dynamic_engine(
                                &Sequential,
                                &mut seq_state,
                                &src.schedule,
                                src.algo,
                                cfg,
                                src.sweeps * src.schedule.period(),
                                src.seed,
                            ),
                        };
                        if seq_trace != trace || seq_state != state {
                            if let Some(token) = token {
                                self.fail_conn(
                                    token,
                                    "served run diverged from the sequential reference",
                                );
                            }
                            return;
                        }
                        true
                    }
                };
                if let Some(token) = token {
                    self.send_event(
                        token,
                        &Json::obj(vec![
                            ("event", "done".into()),
                            ("job", (job as usize).into()),
                            ("rounds", trace.rounds.len().into()),
                            ("final_discrepancy", trace.final_discrepancy().into()),
                            ("movements", trace.total_movements().into()),
                            ("verified", verified.into()),
                        ]),
                    );
                    self.finish_conn(token);
                }
            }
            JobEvent::Failed { job, error } => {
                self.verify.remove(&job);
                self.stats.remove(&job);
                if let Some(Some(token)) = self.by_job.remove(&job) {
                    self.fail_conn(token, &error);
                }
            }
        }
    }

    /// Send a terminal error event and mark the connection done.
    fn fail_conn(&mut self, token: usize, message: &str) {
        self.send_event(
            token,
            &Json::obj(vec![
                ("event", "error".into()),
                ("message", message.into()),
            ]),
        );
        self.finish_conn(token);
    }

    /// Mark a connection terminal; it is removed once its buffered
    /// output drains ([`reap_done`](Self::reap_done)).
    fn finish_conn(&mut self, token: usize) {
        if let Some(conn) = self.conns.get_mut(&token) {
            conn.done = true;
        }
        // the client closes after the terminal line; don't surface its
        // EOF as an error
        self.poller.set_done(token);
    }

    fn reap_done(&mut self) {
        // reap a terminal connection once its output drained — or as
        // soon as its socket died (done suppresses the Closed event, so
        // this sweep is what frees such slots)
        let drained: Vec<usize> = self
            .conns
            .iter()
            .filter(|(&t, c)| {
                c.done && (self.poller.pending_tx(t) == 0 || self.poller.is_closed(t))
            })
            .map(|(&t, _)| t)
            .collect();
        for token in drained {
            self.conns.remove(&token);
            self.poller.remove(token);
        }
    }

    /// Final flush before exit: give lingering output buffers a bounded
    /// chance to drain.
    fn flush_remaining(&mut self) {
        let mut events = VecDeque::new();
        for _ in 0..200 {
            self.reap_done();
            let waiting = self
                .conns
                .iter()
                .any(|(&t, c)| c.done && self.poller.pending_tx(t) > 0 && !self.poller.is_closed(t));
            if !waiting {
                break;
            }
            self.poller.poll(Duration::from_millis(5), &mut events);
            events.clear();
        }
    }

    /// Render one JSON value as a line and queue it on the client's
    /// socket (built through the streaming [`LineEmitter`]; memory
    /// high-water is this single line).
    fn send_event(&mut self, token: usize, v: &Json) {
        self.emitter.get_mut().clear();
        self.emitter
            .emit(v)
            .expect("writing to a Vec cannot fail");
        let buf = std::mem::take(self.emitter.get_mut());
        // a vanished client is handled by its Closed event; sends to it
        // are best-effort
        let _ = self.poller.send_bytes(token, &buf);
        *self.emitter.get_mut() = buf;
    }
}

/// One round's streamed report line.
fn round_json(job: u32, s: &RoundStats) -> Json {
    Json::obj(vec![
        ("event", "round".into()),
        ("job", (job as usize).into()),
        ("round", s.round.into()),
        ("color", s.color.into()),
        ("discrepancy", s.discrepancy.into()),
        ("movements", s.movements.into()),
        ("edges", s.edges.into()),
    ])
}

/// Build the pool job (and its verification source) from a spec line.
/// Seeding mirrors `bcm-dlb run`'s first repetition exactly, so a
/// served job reproduces `run --verify` bit-for-bit.
fn build_job(line: &str, parsed: &Json) -> Result<QueuedJob> {
    let cfg = ExperimentConfig::from_json_str(line)?;
    let verify = parsed.get("verify").as_bool().unwrap_or(false);
    let mut rng = Pcg64::new(cfg.seed);
    let g = cfg.topology.build(cfg.n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let state = LoadState::init_uniform_counts(
        cfg.n,
        cfg.loads_per_node,
        &cfg.distribution,
        cfg.mobility,
        &mut rng,
    );
    let verify = verify.then(|| VerifySrc {
        state: state.clone(),
        schedule: schedule.clone(),
        algo: cfg.algorithm,
        sweeps: cfg.sweeps,
        seed: cfg.seed,
        churn: cfg.traffic(),
    });
    Ok(QueuedJob {
        spec: JobSpec {
            state,
            schedule,
            algo: cfg.algorithm,
            sweeps: cfg.sweeps,
            seed: cfg.seed,
            batch: cfg.batch_rounds,
            checkpoint_every: cfg.checkpoint_every,
            churn: cfg.traffic(),
        },
        verify,
        stats: parsed.get("stats").as_bool().unwrap_or(false),
    })
}

/// `bcm-dlb submit`: send one spec line to a serve instance, stream its
/// event lines to `out`, and report how the job ended.  `Ok(true)` is a
/// clean terminal event (`done` / `shutdown`), `Ok(false)` a served
/// `error`; transport problems are `Err`.
pub fn submit(addr: &str, line: &str, out: &mut dyn Write) -> Result<bool> {
    let mut stream = connect_with_retry(addr, DEFAULT_CONNECT_RETRIES)
        .map_err(|e| anyhow!("submit: cannot reach {addr}: {e}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(300)))?;
    stream.write_all(line.as_bytes())?;
    stream.write_all(b"\n")?;
    stream.flush()?;
    let reader = BufReader::new(stream);
    for got in reader.lines() {
        let got = got.map_err(|e| anyhow!("submit: stream lost: {e}"))?;
        writeln!(out, "{got}")?;
        let v = Json::parse(&got)
            .map_err(|e| anyhow!("submit: unparseable server line: {e}"))?;
        match v.get("event").as_str() {
            Some("done") | Some("shutdown") => return Ok(true),
            Some("error") => return Ok(false),
            _ => {}
        }
    }
    Err(anyhow!("submit: connection closed before a terminal event"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_job_reads_spec_and_verify_flag() {
        let line = r#"{"n":8,"loads_per_node":4,"sweeps":2,"seed":9,"verify":true}"#;
        let parsed = Json::parse(line).unwrap();
        let q = build_job(line, &parsed).unwrap();
        assert_eq!(q.spec.state.n(), 8);
        assert_eq!(q.spec.sweeps, 2);
        assert_eq!(q.spec.seed, 9);
        let v = q.verify.expect("verify source captured");
        assert_eq!(v.state, q.spec.state);
        assert_eq!(v.sweeps, 2);

        let line = r#"{"n":8}"#;
        let parsed = Json::parse(line).unwrap();
        let q = build_job(line, &parsed).unwrap();
        assert!(q.verify.is_none());
        assert!(!q.stats);

        let line = r#"{"n":8,"stats":true}"#;
        let parsed = Json::parse(line).unwrap();
        assert!(build_job(line, &parsed).unwrap().stats);

        let parsed = Json::parse("{}").unwrap();
        assert!(build_job(r#"{"n":1}"#, &parsed).is_err());
    }

    #[test]
    fn round_lines_carry_the_full_roundstats() {
        let s = RoundStats {
            round: 3,
            color: 1,
            discrepancy: 2.5,
            movements: 7,
            edges: 4,
        };
        let v = round_json(9, &s);
        assert_eq!(v.get("event").as_str(), Some("round"));
        assert_eq!(v.get("job").as_usize(), Some(9));
        assert_eq!(v.get("round").as_usize(), Some(3));
        assert_eq!(v.get("discrepancy").as_f64(), Some(2.5));
    }
}
