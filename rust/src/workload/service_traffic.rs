//! `service_traffic`: a seeded dynamic workload simulating a service
//! fleet under live traffic — the paper's premise of loads "that vary
//! over time in an unpredictable way" made concrete (and the regime
//! analyzed by Berenbrink et al., arXiv 2302.12201: loads arrive over
//! time and the interesting metric is the *sustained* discrepancy, not
//! the final one).
//!
//! Between balancing rounds the generator emits a [`ChurnOp`] stream:
//!
//! * **Arrivals** — per-node Poisson arrivals of new tasks whose costs
//!   are heavy-tailed (Pareto, the classic request-cost model), with a
//!   diurnal sinusoidal wave modulating the global rate and periodic
//!   **hotspot bursts** multiplying the rate on an index-contiguous
//!   node neighborhood (a viral shard, a tenant stampede).
//! * **Departures** — tasks complete and leave; only mobile loads
//!   depart (a pinned load models resident work that never finishes).
//! * **Cost drift** — a resident task's cost is rescaled by a
//!   multiplicative factor (cache warming, growing state).  Drift may
//!   touch pinned loads too: immobility forbids *migration*, not cost
//!   change.
//!
//! # Determinism contract
//!
//! The stream is a **pure function of `(config, seed, round, node)`**:
//! node `v`'s ops for round `t` are drawn from the counter-based
//! substream `Pcg64::keyed(&[seed, TRAFFIC_STREAM, t, v])`, never from
//! engine state, thread count, or shard count.  Every executor —
//! `bcm::Sequential`, `bcm::Parallel` at any thread count, the sharded
//! `Cluster`/`ShardPool` at any shard count — therefore applies the
//! bit-identical op sequence at the same round boundary, and because
//! the op *application* below is also deterministic (single IEEE
//! multiply for drift, order-preserving removal for departures), a
//! churning run keeps the repo's bit-identity contract: same trace,
//! same final `LoadState`, everywhere.  `tests/workload_churn.rs` pins
//! this.
//!
//! Departure/drift victims are addressed by a **modular index** (`k mod
//! mobile-count` / `k mod node-len`) rather than a load id: the
//! interpretation depends on the node's current contents, which is safe
//! precisely because all executors hold bit-identical state at every
//! round boundary — and it keeps an op O(1) words on the wire.

use crate::balancer::PairAlgorithm;
use crate::bcm::{Engine, RunTrace, Schedule};
use crate::coordinator::{Cluster, TierLayout, TierTraffic};
use crate::load::{Load, LoadState};
use crate::util::error::Result;
use crate::util::rng::Pcg64;
use std::sync::Arc;

/// Substream tag separating traffic draws from every other consumer of
/// the run seed (the per-edge balancing streams use `Pcg64::for_edge`).
const TRAFFIC_STREAM: u64 = 0x5345_5256_4943_45; // "SERVICE"

/// Substream tag for the per-burst hotspot placement draw.
const HOTSPOT_STREAM: u64 = 0x484f_5453_504f_54; // "HOTSPOT"

/// Arrival ids pack `(round, node, seq)` into disjoint bit ranges so
/// ids are unique across the whole run and never collide with the
/// dense small ids of an initial state: `((round+1) << ROUND_SHIFT)`
/// clears everything below 2^40.
const ID_ROUND_SHIFT: u32 = 40;
const ID_NODE_SHIFT: u32 = 16;

/// One churn event, applied to the load state between rounds.
///
/// Ops travel the cluster wire inside [`Ctl::ApplyChurn`]
/// (`coordinator::messages`), so the variants stay O(1) words each.
///
/// [`Ctl::ApplyChurn`]: crate::coordinator::messages::Ctl::ApplyChurn
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ChurnOp {
    /// A new mobile task of weight `weight` arrives on `node`.
    Arrive {
        /// Hosting node (global index).
        node: u32,
        /// Globally unique task id (see [`arrival_id`]).
        id: u64,
        /// Task cost, Pareto-distributed.
        weight: f64,
    },
    /// The `(k mod mobile-count)`-th mobile load of `node` departs
    /// (node order, counting mobiles only); a no-op when the node has
    /// no mobile load.  Pinned loads never depart.
    Depart {
        /// Hosting node (global index).
        node: u32,
        /// Raw victim selector, reduced modulo the mobile count.
        k: u64,
    },
    /// The `(k mod len)`-th load of `node` (mobile *or* pinned — drift
    /// is cost change, not migration) has its weight multiplied by
    /// `factor`; a no-op on an empty node.  A single IEEE
    /// multiplication, so the result is bitwise deterministic.
    Drift {
        /// Hosting node (global index).
        node: u32,
        /// Raw victim selector, reduced modulo the node's load count.
        k: u64,
        /// Multiplicative cost factor (around 1.0).
        factor: f64,
    },
}

impl ChurnOp {
    /// The global node index the op targets — what the cluster leader
    /// slices per-shard op batches by.
    pub fn node(&self) -> u32 {
        match *self {
            ChurnOp::Arrive { node, .. }
            | ChurnOp::Depart { node, .. }
            | ChurnOp::Drift { node, .. } => node,
        }
    }
}

/// Knobs of the service-traffic generator.  `Default` models a busy but
/// stable fleet; the CLI exposes `arrival_rate`, `pareto_alpha` and
/// `hotspot_every` (`--workload service-traffic`), the rest are fixed
/// scenario shape.
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficConfig {
    /// Mean arrivals per node per round at the diurnal baseline.
    pub arrival_rate: f64,
    /// Pareto tail index of request costs (smaller = heavier tail;
    /// must be > 1 for a finite mean).
    pub pareto_alpha: f64,
    /// Pareto scale (minimum request cost).
    pub pareto_scale: f64,
    /// Rounds per diurnal cycle (0 disables the wave).
    pub diurnal_period: usize,
    /// Relative amplitude of the diurnal wave in [0, 1): the rate
    /// swings between `(1 - a)` and `(1 + a)` times the baseline.
    pub diurnal_amplitude: f64,
    /// A hotspot burst starts every this many rounds (0 = no bursts).
    pub hotspot_every: usize,
    /// Rounds a burst lasts (clamped to `hotspot_every`).
    pub hotspot_rounds: usize,
    /// Nodes in the burst's index-contiguous neighborhood (wraps).
    pub hotspot_width: usize,
    /// Arrival-rate multiplier inside a burst neighborhood.
    pub hotspot_boost: f64,
    /// Mean departures per node per round (follows the diurnal wave).
    pub depart_rate: f64,
    /// Mean cost-drift events per node per round.
    pub drift_rate: f64,
    /// Drift magnitude: factors are uniform in `[1 - m, 1 + m]`.
    pub drift_mag: f64,
}

impl Default for TrafficConfig {
    fn default() -> Self {
        TrafficConfig {
            arrival_rate: 1.0,
            pareto_alpha: 2.5,
            pareto_scale: 1.0,
            diurnal_period: 64,
            diurnal_amplitude: 0.5,
            hotspot_every: 32,
            hotspot_rounds: 4,
            hotspot_width: 4,
            hotspot_boost: 8.0,
            depart_rate: 0.9,
            drift_rate: 0.25,
            drift_mag: 0.2,
        }
    }
}

impl TrafficConfig {
    /// Validate the knob ranges; the config layer surfaces the message
    /// to the user.
    pub fn validate(&self) -> std::result::Result<(), String> {
        if !(self.arrival_rate.is_finite() && self.arrival_rate >= 0.0) {
            return Err(format!("arrival_rate must be >= 0, got {}", self.arrival_rate));
        }
        if !(self.pareto_alpha.is_finite() && self.pareto_alpha > 1.0) {
            return Err(format!(
                "pareto_alpha must be > 1 (finite mean), got {}",
                self.pareto_alpha
            ));
        }
        if !(0.0..1.0).contains(&self.diurnal_amplitude) {
            return Err(format!(
                "diurnal_amplitude must be in [0, 1), got {}",
                self.diurnal_amplitude
            ));
        }
        Ok(())
    }
}

/// The globally unique id of the `seq`-th arrival on `node` in `round`:
/// disjoint bit ranges make collisions impossible (for `node < 2^24`
/// and `seq < 2^16`, both enforced) and the `round + 1` offset keeps
/// every arrival id above any plausible initial id.
pub fn arrival_id(round: usize, node: u32, seq: u32) -> u64 {
    debug_assert!(node < 1 << (ID_ROUND_SHIFT - ID_NODE_SHIFT));
    debug_assert!(seq < 1 << ID_NODE_SHIFT);
    ((round as u64 + 1) << ID_ROUND_SHIFT) | (u64::from(node) << ID_NODE_SHIFT) | u64::from(seq)
}

/// Knuth's product-of-uniforms Poisson sampler.  λ is clamped to 32 so
/// a mis-tuned hotspot boost cannot spin the loop (and `exp(-32)` is
/// still comfortably above f64 underflow).  Consumes a data-dependent
/// number of draws — safe, because each `(round, node)` has its own
/// keyed substream.
fn poisson(rng: &mut Pcg64, lambda: f64) -> u32 {
    let lambda = lambda.clamp(0.0, 32.0);
    if lambda <= 0.0 {
        return 0;
    }
    let floor = (-lambda).exp();
    let mut k = 0u32;
    let mut p = 1.0f64;
    loop {
        p *= rng.next_f64();
        if p <= floor {
            return k;
        }
        k += 1;
    }
}

/// The diurnal modulation factor of round `t`: `1 + a·sin(2πt/T)`.
fn diurnal(cfg: &TrafficConfig, round: usize) -> f64 {
    if cfg.diurnal_period == 0 || cfg.diurnal_amplitude == 0.0 {
        return 1.0;
    }
    let phase = 2.0 * std::f64::consts::PI * (round as f64) / (cfg.diurnal_period as f64);
    1.0 + cfg.diurnal_amplitude * phase.sin()
}

/// The hotspot neighborhood active in `round`, if any: `(start, width)`
/// of an index-contiguous (wrapping) node span.  The span's placement
/// is drawn from a per-burst keyed substream, so it is independent of
/// the per-node traffic draws.
fn hotspot_span(cfg: &TrafficConfig, seed: u64, round: usize, n: usize) -> Option<(usize, usize)> {
    if cfg.hotspot_every == 0 || cfg.hotspot_width == 0 || n == 0 {
        return None;
    }
    let burst = round / cfg.hotspot_every;
    let phase = round % cfg.hotspot_every;
    if phase >= cfg.hotspot_rounds.clamp(1, cfg.hotspot_every) {
        return None;
    }
    let mut rng = Pcg64::keyed(&[seed, HOTSPOT_STREAM, burst as u64]);
    let start = rng.below(n);
    Some((start, cfg.hotspot_width.min(n)))
}

/// Is node `v` inside the wrapping span `(start, width)` of an
/// `n`-node index space?
fn in_span(v: usize, start: usize, width: usize, n: usize) -> bool {
    (v + n - start) % n < width
}

/// Generate the churn ops applied **before** round `round` of a run
/// keyed by `seed`, over an `n`-node network.  Pure function of its
/// arguments — see the module docs for the determinism contract.  Ops
/// are emitted in node order, arrivals before departures before drift
/// per node; executors must apply them in stream order.
pub fn ops_for_round(
    cfg: &TrafficConfig,
    seed: u64,
    round: usize,
    n: usize,
) -> Vec<ChurnOp> {
    let mut ops = Vec::new();
    let wave = diurnal(cfg, round);
    let hot = hotspot_span(cfg, seed, round, n);
    for v in 0..n {
        let mut rng = Pcg64::keyed(&[seed, TRAFFIC_STREAM, round as u64, v as u64]);
        let boost = match hot {
            Some((start, width)) if in_span(v, start, width, n) => cfg.hotspot_boost,
            _ => 1.0,
        };
        let arrivals = poisson(&mut rng, cfg.arrival_rate * wave * boost);
        for seq in 0..arrivals {
            let weight = rng.pareto(cfg.pareto_scale, cfg.pareto_alpha);
            ops.push(ChurnOp::Arrive {
                node: v as u32,
                id: arrival_id(round, v as u32, seq),
                weight,
            });
        }
        let departures = poisson(&mut rng, cfg.depart_rate * wave);
        for _ in 0..departures {
            ops.push(ChurnOp::Depart {
                node: v as u32,
                k: rng.next_u64(),
            });
        }
        let drifts = poisson(&mut rng, cfg.drift_rate);
        for _ in 0..drifts {
            let factor = rng.uniform(1.0 - cfg.drift_mag, 1.0 + cfg.drift_mag);
            ops.push(ChurnOp::Drift {
                node: v as u32,
                k: rng.next_u64(),
                factor,
            });
        }
    }
    ops
}

/// Apply an op stream to an arena `LoadState`, in stream order.  This
/// is the engine-side executor; [`apply_ops_nodes`] is its bit-exact
/// twin on the workers' plain per-node load lists.
pub fn apply_ops(state: &mut LoadState, ops: &[ChurnOp]) {
    for &op in ops {
        match op {
            ChurnOp::Arrive { node, id, weight } => {
                state.push(node as usize, Load::new(id, weight));
            }
            ChurnOp::Depart { node, k } => {
                state.remove_mobile_mod(node as usize, k);
            }
            ChurnOp::Drift { node, k, factor } => {
                state.scale_load_mod(node as usize, k, factor);
            }
        }
    }
}

/// Apply an op stream to a worker's node slice (`nodes[i]` holds global
/// node `lo + i`), bit-identically to [`apply_ops`]: same victim
/// selection (node-order modular indexing), same order-preserving
/// removal, same single-multiply drift.  Ops for nodes outside the
/// slice are the leader's bug; `debug_assert`ed.
pub fn apply_ops_nodes(nodes: &mut [Vec<Load>], lo: usize, ops: &[ChurnOp]) {
    for &op in ops {
        let v = op.node() as usize;
        debug_assert!(v >= lo && v - lo < nodes.len(), "churn op outside shard slice");
        let node = &mut nodes[v - lo];
        match op {
            ChurnOp::Arrive { id, weight, .. } => node.push(Load::new(id, weight)),
            ChurnOp::Depart { k, .. } => {
                let mobiles = node.iter().filter(|l| l.mobile).count();
                if mobiles == 0 {
                    continue;
                }
                let target = (k % mobiles as u64) as usize;
                let at = node
                    .iter()
                    .enumerate()
                    .filter(|(_, l)| l.mobile)
                    .nth(target)
                    .map(|(i, _)| i)
                    .expect("target < mobile count");
                node.remove(at);
            }
            ChurnOp::Drift { k, factor, .. } => {
                if node.is_empty() {
                    continue;
                }
                let at = (k % node.len() as u64) as usize;
                node[at].weight *= factor;
            }
        }
    }
}

/// The id high-water mark of an op stream: one past the largest arrival
/// id (0 when the stream has none).  Engines bump `LoadState::next_id`
/// automatically on every push, including arrivals that later depart;
/// a cluster reassembles its final state from *surviving* loads only,
/// so the driver folds this mark over every round's ops and calls
/// [`LoadState::reserve_ids`] to restore the bit-identical `next_id`.
pub fn id_high_water(ops: &[ChurnOp]) -> u64 {
    ops.iter()
        .map(|op| match *op {
            ChurnOp::Arrive { id, .. } => id + 1,
            _ => 0,
        })
        .max()
        .unwrap_or(0)
}

/// Drive a churning run on an in-process engine: before each round the
/// generator's ops for that round are applied, then the round balances
/// as usual.  `trace.initial_discrepancy` reflects the pre-churn state.
/// Any [`Engine`] yields the bit-identical trace and final state.
pub fn run_dynamic_engine(
    engine: &dyn Engine,
    state: &mut LoadState,
    schedule: &Schedule,
    algo: PairAlgorithm,
    cfg: &TrafficConfig,
    rounds: usize,
    seed: u64,
) -> RunTrace {
    let n = state.n();
    let cfg = cfg.clone();
    let mut churn = move |state: &mut LoadState, round: usize| {
        let ops = ops_for_round(&cfg, seed, round, n);
        apply_ops(state, &ops);
    };
    engine.run_dynamic(state, schedule, algo, rounds, seed, &mut churn)
}

/// Drive a churning run on a sharded [`Cluster`]: per round, the
/// leader ships each shard its slice of the op stream
/// (`Ctl::ApplyChurn`, FIFO-ordered ahead of the round's `RunBatch`)
/// and executes the round; the final state's `next_id` is restored via
/// [`id_high_water`].  Bit-identical to [`run_dynamic_engine`] with
/// `bcm::Sequential` for every shard count — the property
/// `tests/workload_churn.rs` pins.
///
/// Churning cluster runs are dispatched round-by-round (churn is a
/// round-boundary mutation, so batching rounds under one control
/// message cannot apply) and without checkpoint recovery — a worker
/// failure fails the run.
pub fn run_dynamic_cluster(
    state: LoadState,
    schedule: &Schedule,
    algo: PairAlgorithm,
    cfg: &TrafficConfig,
    rounds: usize,
    seed: u64,
    shards: usize,
) -> Result<(RunTrace, LoadState)> {
    let n = state.n();
    let mut hw = state.next_id();
    let mut cluster = Cluster::spawn_with_algorithm(state, algo, shards);
    let mut trace = RunTrace {
        initial_discrepancy: cluster.poll_discrepancy()?,
        rounds: Vec::with_capacity(rounds),
    };
    for round in 0..rounds {
        let ops = ops_for_round(cfg, seed, round, n);
        hw = hw.max(id_high_water(&ops));
        cluster.apply_churn(&ops)?;
        trace.rounds.push(cluster.run_round_seeded(schedule, round, seed)?);
    }
    let mut fin = cluster.shutdown()?;
    fin.reserve_ids(hw);
    Ok((trace, fin))
}

/// [`run_dynamic_cluster`] on the two-tier in-process twin
/// ([`Cluster::spawn_tiered`]): the state is partitioned cut-aware
/// against `edges`, every peer send is classified against `layout`, and
/// the returned [`TierTraffic`] reports what the slow tier carried
/// while the churn stream ran.  Trace and final state stay
/// bit-identical to [`run_dynamic_engine`] with `bcm::Sequential` —
/// the tiered partition is just another contiguous `ShardMap`.
pub fn run_dynamic_cluster_tiered(
    state: LoadState,
    schedule: &Schedule,
    algo: PairAlgorithm,
    cfg: &TrafficConfig,
    rounds: usize,
    seed: u64,
    layout: TierLayout,
    edges: &[(u32, u32)],
) -> Result<(RunTrace, LoadState, Arc<TierTraffic>)> {
    let n = state.n();
    let mut hw = state.next_id();
    let (mut cluster, traffic) = Cluster::spawn_tiered(state, algo, layout, edges);
    let mut trace = RunTrace {
        initial_discrepancy: cluster.poll_discrepancy()?,
        rounds: Vec::with_capacity(rounds),
    };
    for round in 0..rounds {
        let ops = ops_for_round(cfg, seed, round, n);
        hw = hw.max(id_high_water(&ops));
        cluster.apply_churn(&ops)?;
        trace.rounds.push(cluster.run_round_seeded(schedule, round, seed)?);
    }
    let mut fin = cluster.shutdown()?;
    fin.reserve_ids(hw);
    Ok((trace, fin, traffic))
}

/// Sustained-discrepancy summary of a churning run (the E14 metrics):
/// under open arrivals the discrepancy never converges, so the figure
/// of merit is where it *settles* — mean, p99 and max over the trailing
/// window — plus what keeping it there cost in migration traffic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SustainedStats {
    /// Rounds actually covered by the window (≤ the requested window).
    pub window: usize,
    /// Mean discrepancy over the window.
    pub mean: f64,
    /// 99th-percentile discrepancy over the window (nearest-rank).
    pub p99: f64,
    /// Maximum discrepancy over the window.
    pub max: f64,
    /// Loads migrated across the **whole** run.
    pub movements: usize,
    /// Cumulative migration traffic across the whole run, counting each
    /// moved load at its wire size (17 payload bytes: id + weight +
    /// mobility, see the codec).
    pub migration_bytes: u64,
}

/// Bytes one load occupies in a wire frame's payload (`put_load`).
pub const LOAD_WIRE_BYTES: u64 = 17;

/// Fold a trace into its [`SustainedStats`] over the trailing `window`
/// rounds (clamped to the trace length; `window = 0` means the whole
/// trace).
pub fn sustained_stats(trace: &RunTrace, window: usize) -> SustainedStats {
    let len = trace.rounds.len();
    let w = if window == 0 { len } else { window.min(len) };
    let tail = &trace.rounds[len - w..];
    let mut discs: Vec<f64> = tail.iter().map(|r| r.discrepancy).collect();
    discs.sort_by(f64::total_cmp);
    let mean = if w == 0 {
        0.0
    } else {
        discs.iter().sum::<f64>() / w as f64
    };
    // nearest-rank p99: the smallest value with at least 99% of the
    // window at or below it
    let p99 = if w == 0 {
        0.0
    } else {
        let rank = ((w as f64) * 0.99).ceil() as usize;
        discs[rank.clamp(1, w) - 1]
    };
    let max = discs.last().copied().unwrap_or(0.0);
    let movements = trace.total_movements();
    SustainedStats {
        window: w,
        mean,
        p99,
        max,
        movements,
        migration_bytes: movements as u64 * LOAD_WIRE_BYTES,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bcm::RoundStats;

    fn cfg() -> TrafficConfig {
        TrafficConfig::default()
    }

    #[test]
    fn same_seed_same_stream_bitwise() {
        for round in [0usize, 1, 31, 32, 63, 100] {
            let a = ops_for_round(&cfg(), 42, round, 24);
            let b = ops_for_round(&cfg(), 42, round, 24);
            assert_eq!(a, b, "stream not reproducible at round {round}");
            // PartialEq on f64 can equate distinct bit patterns through
            // signed zeros; pin the exact bits too
            for (x, y) in a.iter().zip(b.iter()) {
                if let (
                    ChurnOp::Arrive { weight: wa, .. },
                    ChurnOp::Arrive { weight: wb, .. },
                ) = (x, y)
                {
                    assert_eq!(wa.to_bits(), wb.to_bits());
                }
            }
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let a: Vec<_> = (0..8).flat_map(|r| ops_for_round(&cfg(), 1, r, 24)).collect();
        let b: Vec<_> = (0..8).flat_map(|r| ops_for_round(&cfg(), 2, r, 24)).collect();
        assert!(!a.is_empty());
        assert_ne!(a, b, "different seeds produced the same stream");
    }

    #[test]
    fn arrival_ids_unique_across_rounds_and_nodes() {
        let mut seen = std::collections::BTreeSet::new();
        for round in 0..50 {
            for op in ops_for_round(&cfg(), 7, round, 16) {
                if let ChurnOp::Arrive { id, .. } = op {
                    assert!(seen.insert(id), "duplicate arrival id {id}");
                    assert!(id >= 1 << ID_ROUND_SHIFT, "arrival id {id} collides with small ids");
                }
            }
        }
        assert!(seen.len() > 100, "workload produced too few arrivals to test");
    }

    #[test]
    fn poisson_sampler_tracks_its_mean() {
        let mut rng = Pcg64::new(9);
        for lambda in [0.5f64, 2.0, 8.0] {
            let reps = 4000;
            let total: u64 = (0..reps).map(|_| u64::from(poisson(&mut rng, lambda))).sum();
            let mean = total as f64 / reps as f64;
            assert!(
                (mean - lambda).abs() < 0.2 * lambda + 0.1,
                "poisson({lambda}) sample mean {mean}"
            );
        }
        assert_eq!(poisson(&mut rng, 0.0), 0);
        assert_eq!(poisson(&mut rng, -3.0), 0);
    }

    #[test]
    fn hotspot_bursts_boost_a_contiguous_neighborhood() {
        let mut c = cfg();
        c.hotspot_every = 8;
        c.hotspot_rounds = 2;
        c.hotspot_width = 3;
        let n = 32;
        // burst rounds have a span; off-phase rounds do not
        assert!(hotspot_span(&c, 5, 0, n).is_some());
        assert!(hotspot_span(&c, 5, 1, n).is_some());
        assert!(hotspot_span(&c, 5, 2, n).is_none());
        let (start, width) = hotspot_span(&c, 5, 8, n).unwrap();
        assert_eq!(width, 3);
        assert!(start < n);
        // membership wraps
        assert!(in_span(start, start, width, n));
        assert!(in_span((start + width - 1) % n, start, width, n));
        assert!(!in_span((start + width) % n, start, width, n));
        // disabling bursts removes the span everywhere
        c.hotspot_every = 0;
        assert!(hotspot_span(&c, 5, 0, n).is_none());
    }

    #[test]
    fn arena_and_vec_executors_agree_bitwise() {
        // Seed a state with a pinned load so departures must skip it
        // and drift can hit it.
        let n = 8;
        let mut state = LoadState::empty(n);
        let mut model: Vec<Vec<Load>> = vec![Vec::new(); n];
        let mut id = 0u64;
        for v in 0..n {
            for j in 0..5 {
                let l = if j == 2 {
                    Load::pinned(id, 3.0 + v as f64)
                } else {
                    Load::new(id, 1.0 + j as f64)
                };
                state.push(v, l);
                model[v].push(l);
                id += 1;
            }
        }
        for round in 0..40 {
            let ops = ops_for_round(&cfg(), 11, round, n);
            apply_ops(&mut state, &ops);
            apply_ops_nodes(&mut model, 0, &ops);
            for v in 0..n {
                let arena: Vec<Load> = state.node(v).to_vec();
                assert_eq!(arena.len(), model[v].len(), "node {v} length at round {round}");
                for (a, m) in arena.iter().zip(model[v].iter()) {
                    assert_eq!(a.id, m.id, "node {v} id order at round {round}");
                    assert_eq!(
                        a.weight.to_bits(),
                        m.weight.to_bits(),
                        "node {v} weight bits at round {round}"
                    );
                    assert_eq!(a.mobile, m.mobile);
                }
            }
        }
        // pinned loads never departed
        for v in 0..n {
            assert!(model[v].iter().any(|l| !l.mobile), "node {v} lost its pinned load");
        }
    }

    #[test]
    fn high_water_restores_next_id_parity() {
        let ops = vec![
            ChurnOp::Arrive { node: 0, id: arrival_id(3, 0, 0), weight: 1.0 },
            ChurnOp::Depart { node: 1, k: 7 },
            ChurnOp::Arrive { node: 2, id: arrival_id(3, 2, 1), weight: 2.0 },
        ];
        assert_eq!(id_high_water(&ops), arrival_id(3, 2, 1) + 1);
        assert_eq!(id_high_water(&[]), 0);
        let mut s = LoadState::empty(4);
        s.reserve_ids(id_high_water(&ops));
        assert_eq!(s.next_id(), arrival_id(3, 2, 1) + 1);
    }

    #[test]
    fn sustained_stats_fold_the_trailing_window() {
        let rounds: Vec<RoundStats> = (0..10)
            .map(|i| RoundStats {
                round: i,
                color: 0,
                discrepancy: (10 - i) as f64, // 10, 9, ..., 1
                movements: 3,
                edges: 4,
            })
            .collect();
        let trace = RunTrace {
            initial_discrepancy: 12.0,
            rounds,
        };
        let s = sustained_stats(&trace, 4);
        assert_eq!(s.window, 4);
        assert_eq!(s.mean, (4.0 + 3.0 + 2.0 + 1.0) / 4.0);
        assert_eq!(s.p99, 4.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(s.movements, 30);
        assert_eq!(s.migration_bytes, 30 * LOAD_WIRE_BYTES);
        // window 0 = whole trace; oversized window clamps
        assert_eq!(sustained_stats(&trace, 0).window, 10);
        assert_eq!(sustained_stats(&trace, 64).window, 10);
        assert_eq!(sustained_stats(&trace, 0).max, 10.0);
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut c = cfg();
        assert!(c.validate().is_ok());
        c.arrival_rate = -1.0;
        assert!(c.validate().is_err());
        c = cfg();
        c.pareto_alpha = 1.0;
        assert!(c.validate().is_err());
        c = cfg();
        c.diurnal_amplitude = 1.0;
        assert!(c.validate().is_err());
    }
}
