//! Loopback-TCP multi-process cluster tests: the leader runs in this
//! test process, the shard workers are real `bcm-dlb cluster-worker`
//! OS processes on 127.0.0.1 — and the result must be bit-identical to
//! `bcm::Sequential`, at lock-step batching and with the pipeline on.

use bcm_dlb::balancer::{PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{Engine, Schedule, Sequential, StopRule};
use bcm_dlb::coordinator::transport::tcp::LeaderListener;
use bcm_dlb::coordinator::Cluster;
use bcm_dlb::graph::Graph;
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::util::rng::Pcg64;
use std::process::{Child, Command, Stdio};

const ALGO: PairAlgorithm = PairAlgorithm::SortedGreedy(SortAlgo::Quick);

fn init_scenario(n: usize, per_node: usize, seed: u64) -> (LoadState, Schedule) {
    let mut rng = Pcg64::new(seed);
    let g = Graph::random_connected(n, &mut rng);
    let schedule = Schedule::from_graph(&g);
    let mut state = LoadState::init_uniform_counts(
        n,
        per_node,
        &WeightDistribution::paper_section6(),
        Mobility::Full,
        &mut rng,
    );
    // a couple of pinned loads so partial mobility crosses the wire too
    state.push(0, Load::pinned(90_000, 17.5));
    state.push(n / 2, Load::pinned(90_001, 3.25));
    (state, schedule)
}

/// Spawn `k` worker processes dialing the leader at `addr`.
fn spawn_workers(addr: &str, k: usize) -> Vec<Child> {
    (0..k)
        .map(|_| {
            Command::new(env!("CARGO_BIN_EXE_bcm-dlb"))
                .args(["cluster-worker", "--connect", addr, "--retry", "40"])
                .stdout(Stdio::null())
                .stderr(Stdio::null())
                .spawn()
                .expect("spawning a cluster-worker process")
        })
        .collect()
}

#[test]
fn tcp_cluster_processes_bit_identical_to_sequential() {
    let (state0, schedule) = init_scenario(24, 10, 41);
    let sweeps = 4;
    let seed = 77u64;
    let mut seq_state = state0.clone();
    let seq_trace = Sequential.run(
        &mut seq_state,
        &schedule,
        ALGO,
        StopRule::sweeps(sweeps),
        seed,
    );
    // batch-rounds 1 (lock-step), 0 (auto), and 3 (pipelining inside
    // batches); each lifecycle gets fresh worker processes
    for batch in [1usize, 0, 3] {
        let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader");
        let addr = listener.local_addr().expect("local addr").to_string();
        let mut workers = spawn_workers(&addr, 2);
        let mut cluster =
            Cluster::spawn_tcp(state0.clone(), ALGO, 2, listener).expect("tcp spawn");
        assert_eq!(cluster.shards(), 2);
        cluster.set_batch_rounds(batch);
        let trace = cluster.run_seeded(&schedule, sweeps, seed).expect("tcp run");
        let fin = cluster.shutdown().expect("tcp shutdown");
        assert_eq!(trace, seq_trace, "TCP trace diverged at batch {batch}");
        assert_eq!(fin, seq_state, "TCP state diverged at batch {batch}");
        // pinned loads made the round trip without moving hosts
        assert!(fin.node(0).iter().any(|l| l.id == 90_000 && !l.mobile));
        for w in &mut workers {
            let status = w.wait().expect("waiting for worker");
            assert!(status.success(), "worker exited nonzero at batch {batch}");
        }
    }
}

#[test]
fn tcp_cluster_fail_stops_when_a_worker_process_dies() {
    let (state0, schedule) = init_scenario(16, 6, 5);
    let listener = LeaderListener::bind("127.0.0.1:0").expect("bind leader");
    let addr = listener.local_addr().expect("local addr").to_string();
    let mut workers = spawn_workers(&addr, 2);
    let mut cluster = Cluster::spawn_tcp(state0, ALGO, 2, listener).expect("tcp spawn");
    // kill one worker after the handshake; the next batch must surface
    // an error quickly (EOF-driven, not timeout-driven) and poison the
    // cluster
    workers[0].kill().expect("killing worker 0");
    workers[0].wait().expect("reaping worker 0");
    let err = cluster
        .run_seeded(&schedule, 2, 9)
        .expect_err("run against a dead worker succeeded")
        .to_string();
    assert!(
        err.contains("lost") || err.contains("disconnect") || err.contains("closed"),
        "error does not mention the lost connection: {err}"
    );
    // fail-stop: poisoned for further rounds, and shutdown re-surfaces
    assert!(cluster.run_seeded(&schedule, 1, 9).is_err());
    assert!(cluster.shutdown().is_err());
    // the surviving worker exits once the leader closes its sockets
    let _ = workers[1].wait();
}
