//! Hot-path microbenchmarks (the §Perf deliverable's measurement tool).
//!
//! Measures, on the end-to-end BCM round hot path:
//!   1. pure-Rust pairwise rebalance throughput (edges/s, balls/s)
//!   2. device-path (PJRT) batched round latency per bucket
//!   3. the sequential engine's full-round throughput
//!   4. the distributed cluster's round latency
//!   5. the per-stage split of one round — **edge solve** (gather +
//!      decide on the reusable scratch), **weight reduction** (the
//!      cached-totals min/max fold), **migration apply** (arena
//!      write-back) — so a regression names the stage that caused it.
//!
//! Results feed EXPERIMENTS.md §Perf.
//!
//! `-- --smoke` (or `BCM_DLB_SMOKE=1` / `BCM_DLB_QUICK=1`) derates to a
//! seconds-long run: section 1 plus the per-stage split at n = 256,
//! skipping the device and cluster sections (CI exercises those through
//! their own benches).  Smoke runs enforce the perf-regression floors
//! in `bench_floor.toml` (section `[hotpath_micro.smoke]`); `--no-floor`
//! bypasses the gate on hosts known to be slower than the floor assumes,
//! and hosts with fewer cores than the recorded `pinned_cores` skip it
//! automatically with a notice.

use bcm_dlb::balancer::{balance_pair, decide_pool, EdgeScratch, PairAlgorithm, SortAlgo};
use bcm_dlb::bcm::{balance_round, Schedule};
use bcm_dlb::coordinator::{Cluster, WorkerAlgo};
use bcm_dlb::graph::Topology;
use bcm_dlb::load::{Load, LoadState, Mobility, WeightDistribution};
use bcm_dlb::runtime::{solve_batch, DeviceAlgo, EdgeProblem, Runtime};
use bcm_dlb::util::rng::Pcg64;
use bcm_dlb::util::table::Table;
use std::path::Path;
use std::time::Instant;

fn bench<T>(iters: usize, mut body: impl FnMut() -> T) -> f64 {
    // one warmup
    std::hint::black_box(body());
    let start = Instant::now();
    for _ in 0..iters {
        std::hint::black_box(body());
    }
    start.elapsed().as_secs_f64() / iters as f64
}

/// Read `key` from `[section]` of the checked-in floor file (the same
/// toml-subset parser as `cluster_sharded`).
fn read_floor(path: &Path, section: &str, key: &str) -> Option<f64> {
    let text = std::fs::read_to_string(path).ok()?;
    let mut in_section = false;
    for line in text.lines() {
        let line = line.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if let Some(name) = line.strip_prefix('[').and_then(|l| l.strip_suffix(']')) {
            in_section = name.trim() == section;
        } else if in_section {
            if let Some((k, v)) = line.split_once('=') {
                if k.trim() == key {
                    return v.trim().parse().ok();
                }
            }
        }
    }
    None
}

fn env_flag(name: &str) -> bool {
    std::env::var(name).map(|v| v == "1").unwrap_or(false)
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let smoke = args.iter().any(|a| a == "--smoke")
        || env_flag("BCM_DLB_SMOKE")
        || env_flag("BCM_DLB_QUICK");
    let mut t = Table::new(
        "hot-path microbenchmarks",
        &["benchmark", "time/op", "throughput"],
    );

    // 1. pairwise rebalance (the innermost hot path)
    for (label, algo) in [
        ("balance_pair greedy, 2x50 balls", PairAlgorithm::Greedy),
        (
            "balance_pair sorted:quick, 2x50 balls",
            PairAlgorithm::SortedGreedy(SortAlgo::Quick),
        ),
        (
            "balance_pair sorted:std, 2x50 balls",
            PairAlgorithm::SortedGreedy(SortAlgo::Std),
        ),
    ] {
        let mut rng = Pcg64::new(1);
        let u: Vec<Load> = (0..50).map(|i| Load::new(i, rng.uniform(0.0, 100.0))).collect();
        let v: Vec<Load> = (0..50)
            .map(|i| Load::new(100 + i, rng.uniform(0.0, 100.0)))
            .collect();
        let s = bench(if smoke { 200 } else { 2000 }, || {
            balance_pair(&u, &v, algo, &mut rng)
        });
        t.row(vec![
            label.into(),
            format!("{:.2} us", s * 1e6),
            format!("{:.2} Mballs/s", 100.0 / s / 1e6),
        ]);
    }

    // 2. one full sequential-engine round on the paper's largest setting
    if !smoke {
        let mut rng = Pcg64::new(2);
        let g = Topology::RandomConnected.build(128, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            128,
            100,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let pairs = schedule.matching(0).to_vec();
        // reset the state every iteration so the measured work is stable
        // (a balanced state has different pool sizes than the initial one)
        let s = bench(200, || {
            let mut st = state.clone();
            balance_round(&mut st, &pairs, DeviceAlgo::SortedGreedy, None, &mut rng).unwrap()
        });
        t.row(vec![
            format!("engine round n=128 L/n=100 ({} edges), rust path", pairs.len()),
            format!("{:.1} us", s * 1e6),
            format!("{:.2} Medges/s", pairs.len() as f64 / s / 1e6),
        ]);
    }

    // 3. PJRT device path (if artifacts are built)
    let dir = bcm_dlb::runtime::default_artifacts_dir();
    if smoke {
        eprintln!("smoke mode — skipping PJRT and cluster sections");
    } else if dir.join("manifest.json").exists() {
        let mut rt = Runtime::new(&dir).expect("runtime");
        rt.warm_entry("balance_two_bin").expect("warm");
        for (b, m) in [(64usize, 100usize), (64, 200), (8, 500)] {
            let mut rng = Pcg64::new(3);
            let problems: Vec<EdgeProblem> = (0..b)
                .map(|_| EdgeProblem {
                    weights: (0..m).map(|_| rng.uniform(0.0, 100.0)).collect(),
                    hosts: (0..m).map(|_| rng.below(2) as u8).collect(),
                    base: [0.0, 0.0],
                })
                .collect();
            let s_dev = bench(20, || {
                solve_batch(Some(&mut rt), DeviceAlgo::SortedGreedy, &problems).unwrap()
            });
            let s_fb = bench(50, || {
                solve_batch(None, DeviceAlgo::SortedGreedy, &problems).unwrap()
            });
            t.row(vec![
                format!("device batch {b} edges x {m} balls (PJRT)"),
                format!("{:.2} ms", s_dev * 1e3),
                format!("{:.0} kball/s", b as f64 * m as f64 / s_dev / 1e3),
            ]);
            t.row(vec![
                format!("same batch, rust fallback"),
                format!("{:.3} ms", s_fb * 1e3),
                format!(
                    "{:.0} kball/s (device/fallback = {:.0}x)",
                    b as f64 * m as f64 / s_fb / 1e3,
                    s_dev / s_fb
                ),
            ]);
        }
    } else {
        eprintln!("artifacts/ absent — skipping PJRT microbenches");
    }

    // 4. distributed cluster round latency (n=64)
    if !smoke {
        let mut rng = Pcg64::new(4);
        let g = Topology::RandomConnected.build(64, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let state = LoadState::init_uniform_counts(
            64,
            100,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let mut cluster = Cluster::spawn(state, WorkerAlgo::SortedGreedy);
        let mut round = 0usize;
        let s = bench(50, || {
            let st = cluster
                .run_single_round(&schedule, round, &mut rng)
                .expect("cluster round failed");
            round += 1;
            st
        });
        cluster.shutdown().expect("cluster shutdown failed");
        t.row(vec![
            "cluster round n=64 L/n=100 (sharded, one worker/core)".into(),
            format!("{:.2} ms", s * 1e3),
            format!("{:.0} rounds/s", 1.0 / s),
        ]);
    }

    // 5. per-stage split of the round hot path (DESIGN.md §9)
    //
    // Solve and apply are timed separately: the decisions for the whole
    // matching are computed once, then replayed — apply_edge is
    // idempotent for a fixed (pool, dest), so the write-back can be
    // re-timed on a steady arena without re-deciding.
    let (solve_eps, reduce_nps, apply_eps) = {
        let mut rng = Pcg64::new(6);
        let n = if smoke { 256 } else { 4096 };
        let g = Topology::RandomConnected.build(n, &mut rng);
        let schedule = Schedule::from_graph(&g);
        let mut state = LoadState::init_uniform_counts(
            n,
            50,
            &WeightDistribution::paper_section6(),
            Mobility::Full,
            &mut rng,
        );
        let pairs = schedule.matching(0).to_vec();
        let algo = PairAlgorithm::SortedGreedy(SortAlgo::Quick);
        let seed = 99u64;
        let iters = if smoke { 40 } else { 200 };

        // stage: edge solve — gather + decide on the reusable scratch,
        // no write-back (the state is untouched, so pools are stable)
        let mut scratch = EdgeScratch::new();
        let s_solve = bench(iters, || {
            let mut movements = 0usize;
            for (e, &(u, v)) in pairs.iter().enumerate() {
                let mut r = Pcg64::for_edge(seed, 0, e);
                let gth = state.gather_edge(u as usize, v as usize, &mut scratch.pool);
                movements +=
                    decide_pool(&mut scratch.pool, &mut scratch.dest, gth.base, algo, &mut r)
                        .movements;
            }
            movements
        });
        t.row(vec![
            format!("stage: edge solve n={n} L/n=50 ({} edges)", pairs.len()),
            format!("{:.1} us/round", s_solve * 1e6),
            format!("{:.0} kedges/s", pairs.len() as f64 / s_solve / 1e3),
        ]);

        // stage: weight reduction — the per-round O(n) discrepancy fold
        // over the cached totals column
        let s_reduce = bench(if smoke { 2000 } else { 5000 }, || state.weight_extremes());
        t.row(vec![
            format!("stage: weight reduction n={n} (cached totals)"),
            format!("{:.2} us/fold", s_reduce * 1e6),
            format!("{:.0} Mnodes/s", n as f64 / s_reduce / 1e6),
        ]);

        // stage: migration apply — replay precomputed decisions into the
        // arena (first replay settles segment caps; bench() warms up)
        let plans: Vec<_> = pairs
            .iter()
            .enumerate()
            .map(|(e, &(u, v))| {
                let mut r = Pcg64::for_edge(seed, 0, e);
                let mut pool = Vec::new();
                let mut dest = Vec::new();
                let gth = state.gather_edge(u as usize, v as usize, &mut pool);
                decide_pool(&mut pool, &mut dest, gth.base, algo, &mut r);
                (pool, dest)
            })
            .collect();
        let s_apply = bench(iters, || {
            for (e, &(u, v)) in pairs.iter().enumerate() {
                let (pool, dest) = &plans[e];
                state.apply_edge(u as usize, v as usize, pool, dest);
            }
        });
        t.row(vec![
            format!("stage: migration apply n={n} (arena write-back)"),
            format!("{:.1} us/round", s_apply * 1e6),
            format!("{:.0} kedges/s", pairs.len() as f64 / s_apply / 1e3),
        ]);
        (
            pairs.len() as f64 / s_solve,
            n as f64 / s_reduce,
            pairs.len() as f64 / s_apply,
        )
    };

    println!("{}", t.render());
    t.write_csv(Path::new("results/hotpath_micro.csv")).ok();

    if smoke && !args.iter().any(|a| a == "--no-floor") {
        let floor_path = Path::new(env!("CARGO_MANIFEST_DIR")).join("bench_floor.toml");
        // floors were pinned on a `pinned_cores` container; a smaller
        // host cannot hold them — skip with a notice instead of failing
        let host_cores = std::thread::available_parallelism()
            .map(|c| c.get())
            .unwrap_or(1);
        if let Some(p) = read_floor(&floor_path, "hotpath_micro.smoke", "pinned_cores") {
            if (host_cores as f64) < p {
                eprintln!(
                    "hotpath_micro: floors SKIPPED — this host has {host_cores} core(s), \
                     fewer than the bench_floor.toml pinned_cores the floors were pinned on"
                );
                return;
            }
        }
        let mut failed = false;
        for (key, measured, unit) in [
            ("min_solve_edges_per_s", solve_eps, "edge solves/s"),
            ("min_reduce_nodes_per_s", reduce_nps, "reduced nodes/s"),
            ("min_apply_edges_per_s", apply_eps, "edge applies/s"),
        ] {
            match read_floor(&floor_path, "hotpath_micro.smoke", key) {
                Some(floor) if measured < floor => {
                    eprintln!(
                        "hotpath_micro: FLOOR FAILED — {measured:.0} {unit} is below \
                         the bench_floor.toml floor of {floor:.0}"
                    );
                    failed = true;
                }
                Some(floor) => {
                    eprintln!("hotpath_micro: floor ok — {measured:.0} {unit} >= {floor:.0}");
                }
                None => {
                    eprintln!(
                        "hotpath_micro: no {key} in {} (use --no-floor to bypass deliberately)",
                        floor_path.display()
                    );
                    failed = true;
                }
            }
        }
        if failed {
            std::process::exit(1);
        }
    }
}
