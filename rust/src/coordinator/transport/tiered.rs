//! The two-tier hierarchical transport: in-process shard workers under
//! a TCP super-shard mesh.
//!
//! # Why a second tier
//!
//! The flat [`tcp`](super::tcp) mesh pays one socket frame for *every*
//! cross-shard edge, even when both shards live in the same process —
//! wire traffic scales with the **global** cut.  Here one
//! `bcm-dlb cluster-worker` process per host runs
//! `shards_per_host` shard workers as threads wired by `std::sync::mpsc`
//! channels (the [`local`](super::local) discipline), and a single
//! per-process **egress pump** multiplexes all of the host's cross-host
//! `Offer`/`Settle` traffic onto one TCP connection per peer host.
//! Cross-host traffic then scales with the **inter-host** cut, which
//! [`ShardMap::partition_tiered`] minimizes — the tiered-bandwidth
//! regime of the divisible-load scheduling literature.
//!
//! # Topology
//!
//! * leader <-> host: one duplex connection per host process.  Control
//!   and report frames ride it wrapped in a [`WireMsg::Mux`] envelope
//!   tagging the global shard index ([the inner `Ctl`/`ShardMsg` already
//!   carries `(job, round)`], so every super-shard frame is
//!   `(shard, job, round)`-addressed).
//! * host <-> host: one duplex connection per unordered host pair (host
//!   `h` dials every host `< h`, accepts every host `> h` — the same
//!   bootstrap as the flat shard mesh, one tier up).  Cross-host
//!   `Offer`/`Settle` frames travel Mux-wrapped with their *destination*
//!   shard.
//! * intra-host: same-host cross-shard edges never touch the codec —
//!   workers hand `ShardMsg`s to their siblings over mpsc channels
//!   directly, bypassing the pump entirely.
//!
//! # Determinism
//!
//! The envelope is pure routing: no payload is reordered, rewritten, or
//! re-randomized, every `f64` still crosses the wire as its exact bit
//! pattern, and per-link FIFO holds on every leg (mpsc channels and TCP
//! streams are both ordered, and the pump forwards in arrival order).
//! A tiered run is therefore **bit-identical** to `bcm::Sequential` for
//! every (hosts x shards-per-host x batch) combination — the tiered
//! partition is just another contiguous [`ShardMap`], and the
//! determinism contract never depended on which transport carries a
//! message (asserted by `tests/tiered_cluster.rs`).
//!
//! # Failure mapping
//!
//! A lost host connection surfaces on the leader as one synthesized
//! `Report::Error { job: None, shard }` **per shard of that host** —
//! a whole-host death is indistinguishable from that many simultaneous
//! worker deaths, which is exactly the multi-casualty input the
//! recovery drain in `Cluster::recover` already classifies.  Recovery
//! then reassigns the lost shards onto the surviving hosts (tiered
//! clusters do not rejoin a replacement host mid-run; the reassign arm
//! of the recovery contract covers them).
//!
//! [`ShardMap`]: crate::coordinator::shard::ShardMap
//! [`ShardMap::partition_tiered`]: crate::coordinator::shard::ShardMap::partition_tiered

use super::codec::{encode_frame, write_frame, HostInit, WireMsg};
use super::local::LocalWorker;
use super::poll::{Event, Poller};
use super::tcp::{
    accept_with_deadline, connect_with_retry, fresh_token, read_frame_timed, LeaderListener,
    DEFAULT_CONNECT_RETRIES, HANDSHAKE_TIMEOUT,
};
use super::{LeaderTransport, TransportError, WorkerTransport};
use crate::anyhow;
use crate::balancer::PairAlgorithm;
use crate::coordinator::messages::{Ctl, Report, ShardMsg};
use crate::coordinator::shard::{RoundPlan, ShardPlan, TierLayout};
use crate::coordinator::worker::ShardWorker;
use crate::load::Load;
use crate::util::affinity;
use crate::util::error::{Context, Result};
use std::collections::VecDeque;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How long the pump sleeps in a poll pass when the previous pass moved
/// nothing — short enough that a cross-host Offer/Settle round trip
/// costs at most a few wakeups, long enough that an idle host does not
/// spin.
const PUMP_IDLE_WAIT: Duration = Duration::from_millis(1);

/// How long the pump keeps retrying buffered socket writes after its
/// last worker exited before abandoning them.
const PUMP_FLUSH_TIMEOUT: Duration = Duration::from_secs(5);

// ------------------------------------------------------ traffic census

/// Shared counters of the slow tier, kept by the counting tiered-local
/// transport ([`CountingTieredWorker`]) so benches and tests can assert
/// the tentpole claim — cross-host traffic scales with the *inter-host*
/// cut — without real sockets (`benches/cluster_sharded.rs` E15).
#[derive(Debug, Default)]
pub struct TierTraffic {
    /// Bytes the inter-host `ShardMsg`s would occupy on the wire (the
    /// exact encoded `Mux` frame length, header included).
    pub inter_host_bytes: AtomicU64,
    /// Inter-host `ShardMsg`s sent.
    pub inter_host_msgs: AtomicU64,
    /// Same-host cross-shard `ShardMsg`s sent (these never touch the
    /// codec in a real deployment).
    pub intra_host_msgs: AtomicU64,
}

impl TierTraffic {
    /// Snapshot `(inter_host_bytes, inter_host_msgs, intra_host_msgs)`.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.inter_host_bytes.load(Ordering::Relaxed),
            self.inter_host_msgs.load(Ordering::Relaxed),
            self.intra_host_msgs.load(Ordering::Relaxed),
        )
    }
}

/// A [`LocalWorker`] that classifies every peer send against a
/// [`TierLayout`] and records what the slow tier *would* carry: the
/// in-process twin of the real two-tier deployment, with identical
/// routing decisions and bit-identical results.
pub struct CountingTieredWorker {
    inner: LocalWorker,
    layout: TierLayout,
    traffic: Arc<TierTraffic>,
}

impl CountingTieredWorker {
    /// Wrap `inner`, charging inter-host sends to `traffic`.
    pub fn new(
        inner: LocalWorker,
        layout: TierLayout,
        traffic: Arc<TierTraffic>,
    ) -> CountingTieredWorker {
        CountingTieredWorker {
            inner,
            layout,
            traffic,
        }
    }
}

impl WorkerTransport for CountingTieredWorker {
    fn shard(&self) -> usize {
        self.inner.shard()
    }

    fn shards(&self) -> usize {
        WorkerTransport::shards(&self.inner)
    }

    fn recv_ctl(&mut self) -> Result<Ctl, TransportError> {
        self.inner.recv_ctl()
    }

    fn send_report(&mut self, msg: Report) -> Result<(), TransportError> {
        self.inner.send_report(msg)
    }

    fn send_peer(&mut self, peer: usize, msg: ShardMsg) -> Result<(), TransportError> {
        let msg = if self.layout.is_inter_host(self.inner.shard(), peer) {
            // measure the exact frame the egress pump would emit: a Mux
            // envelope addressed to the destination shard (ShardMsg is
            // deliberately not Clone, so wrap, measure, and unwrap)
            let wm = WireMsg::Mux {
                shard: peer,
                inner: Box::new(WireMsg::Peer(msg)),
            };
            let len = encode_frame(&wm).len() as u64;
            self.traffic.inter_host_bytes.fetch_add(len, Ordering::Relaxed);
            self.traffic.inter_host_msgs.fetch_add(1, Ordering::Relaxed);
            let WireMsg::Mux { inner, .. } = wm else {
                unreachable!("just built");
            };
            let WireMsg::Peer(msg) = *inner else {
                unreachable!("just built");
            };
            msg
        } else {
            self.traffic.intra_host_msgs.fetch_add(1, Ordering::Relaxed);
            msg
        };
        self.inner.send_peer(peer, msg)
    }

    fn recv_peer(&mut self, wait: Duration) -> Result<ShardMsg, TransportError> {
        self.inner.recv_peer(wait)
    }
}

// ---------------------------------------------------------------- leader

/// Initial state shipped to one host in its [`HostInit`] frame: per
/// local shard, the shard's first node id and its carved load slice.
pub struct HostSeed {
    /// In global-shard order within the host's block.
    pub shards: Vec<(usize, Vec<Vec<Load>>)>,
}

/// The leader's two-tier endpoint: one connected socket per *host*,
/// each carrying the Mux-wrapped control/report traffic of all of that
/// host's shards.
pub struct TieredLeader {
    layout: TierLayout,
    poller: Poller,
    /// Poller token per host.
    tokens: Vec<usize>,
    /// Shard sent its terminal report (possibly synthesized from a lost
    /// host connection); ignore anything further.
    done: Vec<bool>,
    queue: VecDeque<Report>,
    events: VecDeque<Event>,
}

impl TieredLeader {
    /// Accept `layout.hosts` host processes on `listener`, then complete
    /// the handshake: collect `Hello`s (each carrying the host's mesh
    /// listener address), assign host indices in connection order, and
    /// ship every host its [`HostInit`].
    pub fn accept(
        listener: LeaderListener,
        layout: TierLayout,
        algo: &str,
        seeds: Vec<HostSeed>,
    ) -> Result<TieredLeader> {
        assert_eq!(seeds.len(), layout.hosts, "one seed per host");
        let listener = listener.into_inner();
        let mut conns = Vec::with_capacity(layout.hosts);
        for h in 0..layout.hosts {
            let stream = accept_with_deadline(
                &listener,
                HANDSHAKE_TIMEOUT,
                &format!("cluster host {} of {}", h + 1, layout.hosts),
            )?;
            conns.push(stream);
        }
        Self::handshake(conns, layout, algo, seeds)
    }

    /// Dial one listening host process per address (each started with
    /// `bcm-dlb cluster-worker --listen`), then complete the handshake.
    /// Host `i` of `addrs` becomes host index `i`.
    pub fn connect(
        addrs: &[String],
        layout: TierLayout,
        algo: &str,
        seeds: Vec<HostSeed>,
    ) -> Result<TieredLeader> {
        assert_eq!(addrs.len(), layout.hosts, "one address per host");
        assert_eq!(seeds.len(), layout.hosts, "one seed per host");
        let mut conns = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let stream = connect_with_retry(addr, DEFAULT_CONNECT_RETRIES)
                .with_context(|| format!("dialing cluster host {addr}"))?;
            conns.push(stream);
        }
        Self::handshake(conns, layout, algo, seeds)
    }

    fn handshake(
        mut conns: Vec<TcpStream>,
        layout: TierLayout,
        algo: &str,
        seeds: Vec<HostSeed>,
    ) -> Result<TieredLeader> {
        let mut host_peers = Vec::with_capacity(conns.len());
        for (h, stream) in conns.iter_mut().enumerate() {
            match read_frame_timed(stream, &format!("Hello from host {h}"))? {
                WireMsg::Hello { peer_addr, rejoin: _ } => host_peers.push(peer_addr),
                other => {
                    return Err(anyhow!("host {h} handshake: expected Hello, got {other:?}"))
                }
            }
        }
        for (h, (stream, seed)) in conns.iter_mut().zip(seeds).enumerate() {
            let msg = WireMsg::HostInit(HostInit {
                host: h,
                hosts: layout.hosts,
                shards_per_host: layout.shards_per_host,
                algo: algo.to_string(),
                shards: seed.shards,
                host_peers: host_peers.clone(),
                token: fresh_token(h),
            });
            write_frame(stream, &msg).with_context(|| format!("sending HostInit to host {h}"))?;
        }
        let mut poller = Poller::new();
        let mut tokens = Vec::with_capacity(conns.len());
        for stream in conns {
            tokens.push(
                poller
                    .add_frame_conn(stream)
                    .context("registering a host socket")?,
            );
        }
        Ok(TieredLeader {
            done: vec![false; layout.shards()],
            layout,
            poller,
            tokens,
            queue: VecDeque::new(),
            events: VecDeque::new(),
        })
    }

    fn host_of_token(&self, token: usize) -> Option<usize> {
        self.tokens.iter().position(|&t| t == token)
    }

    /// Declare every not-yet-terminal shard of `host` dead, queueing one
    /// synthesized error per casualty — the whole-host analogue of the
    /// flat leader's connection-loss synthesis, shaped so the recovery
    /// drain classifies each shard individually.
    fn host_lost(&mut self, host: usize, reason: &str) {
        for s in self.layout.host_range(host) {
            if self.done[s] {
                continue;
            }
            self.done[s] = true;
            self.queue.push_back(Report::Error {
                job: None,
                shard: s,
                round: None,
                message: format!("host connection lost: {reason}"),
            });
        }
        self.poller.set_done(self.tokens[host]);
    }

    fn absorb(&mut self, ev: Event) {
        match ev {
            Event::Frame { token, msg } => {
                let Some(host) = self.host_of_token(token) else {
                    return;
                };
                match msg {
                    WireMsg::Mux { shard, inner } => {
                        if shard >= self.done.len() || self.layout.host_of(shard) != host {
                            self.host_lost(host, &format!("report for foreign shard {shard}"));
                            return;
                        }
                        if self.done[shard] {
                            return;
                        }
                        match *inner {
                            WireMsg::Report(report) => {
                                let terminal = match &report {
                                    Report::Final { .. } => true,
                                    Report::Error { job, .. } => job.is_none(),
                                    _ => false,
                                };
                                if terminal {
                                    self.done[shard] = true;
                                    if self.layout.host_range(host).all(|s| self.done[s]) {
                                        self.poller.set_done(token);
                                    }
                                }
                                self.queue.push_back(report);
                            }
                            other => self.host_lost(
                                host,
                                &format!("protocol violation: unexpected frame {other:?}"),
                            ),
                        }
                    }
                    other => self.host_lost(
                        host,
                        &format!("protocol violation: unwrapped frame {other:?}"),
                    ),
                }
            }
            Event::Closed { token, reason } => {
                if let Some(host) = self.host_of_token(token) {
                    self.host_lost(host, &reason);
                }
            }
            _ => {}
        }
    }
}

impl LeaderTransport for TieredLeader {
    fn shards(&self) -> usize {
        self.layout.shards()
    }

    fn send_ctl(&mut self, shard: usize, msg: Ctl) -> Result<(), TransportError> {
        // same egress economy as the flat TCP leader: a worker only
        // reads its own slice of each plan, so blank the other shards'
        // entries before serializing
        let msg = match msg {
            Ctl::RunBatch {
                job,
                start_round,
                rounds,
                seed,
                plans,
                checkpoint,
            } => {
                let sliced: Vec<Arc<RoundPlan>> = plans
                    .iter()
                    .map(|p| {
                        let mut per_shard = vec![ShardPlan::default(); p.per_shard.len()];
                        per_shard[shard] = p.per_shard[shard].clone();
                        Arc::new(RoundPlan {
                            per_shard,
                            cross_edges: p.cross_edges,
                            edges: p.edges,
                        })
                    })
                    .collect();
                Ctl::RunBatch {
                    job,
                    start_round,
                    rounds,
                    seed,
                    plans: Arc::new(sliced),
                    checkpoint,
                }
            }
            other => other,
        };
        let host = self.layout.host_of(shard);
        let token = self.tokens[host];
        if self.poller.is_closed(token) {
            return Err(TransportError::Closed(format!(
                "host {host} connection closed (shard {shard} unreachable)"
            )));
        }
        self.poller
            .send(
                token,
                &WireMsg::Mux {
                    shard,
                    inner: Box::new(WireMsg::Ctl(msg)),
                },
            )
            .map_err(|e| TransportError::Closed(format!("host {host} connection closed: {e}")))
    }

    fn recv_report(&mut self, wait: Duration) -> Result<Report, TransportError> {
        let deadline = Instant::now() + wait;
        loop {
            if let Some(r) = self.queue.pop_front() {
                return Ok(r);
            }
            if self.done.iter().all(|&d| d) {
                return Err(TransportError::Closed(
                    "all cluster host connections closed".to_string(),
                ));
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(TransportError::Timeout);
            }
            self.poller.poll(deadline - now, &mut self.events);
            while let Some(ev) = self.events.pop_front() {
                self.absorb(ev);
            }
        }
    }

    // await_rejoin: the trait default (`Ok(None)`) is deliberate — a
    // tiered cluster recovers a lost host by reassigning its shards
    // onto the survivors, never by re-admitting a replacement host.
}

// ------------------------------------------------------- host process

/// Leader-bound traffic a local shard worker hands to the pump.
enum Up {
    Report { shard: usize, msg: Report },
    Remote { to: usize, msg: ShardMsg },
}

/// Control-plane traffic the pump hands to a local shard worker.
enum Down {
    Ctl(Box<Ctl>),
    Gone(String),
}

/// Data-plane traffic entering a local shard worker: a sibling's direct
/// send, a remote shard's Mux'd frame, or a host-link loss marker.
enum PeerIn {
    Msg(ShardMsg),
    Gone { host: usize, reason: String },
}

/// A shard worker's endpoint inside a two-tier host process: mpsc to
/// the pump for everything that leaves the host, mpsc straight to the
/// sibling for everything that does not.
struct TieredWorkerTransport {
    shard: usize,
    layout: TierLayout,
    down_rx: Receiver<Down>,
    up_tx: Sender<Up>,
    peer_rx: Receiver<PeerIn>,
    /// Direct channels to the host's workers, by local index (the
    /// worker's own entry included, by symmetry with `local::pair`).
    sibling_tx: Vec<Sender<PeerIn>>,
    /// Peer events pulled off `peer_rx` by a remesh purge, replayed
    /// ahead of the channel.
    replay: VecDeque<PeerIn>,
}

impl TieredWorkerTransport {
    fn peer_event(&mut self, got: PeerIn) -> Result<ShardMsg, TransportError> {
        match got {
            PeerIn::Msg(m) => Ok(m),
            PeerIn::Gone { host, reason } => Err(TransportError::Closed(format!(
                "host {host} disconnected: {reason}"
            ))),
        }
    }
}

impl WorkerTransport for TieredWorkerTransport {
    fn shard(&self) -> usize {
        self.shard
    }

    fn shards(&self) -> usize {
        self.layout.shards()
    }

    fn recv_ctl(&mut self) -> Result<Ctl, TransportError> {
        match self.down_rx.recv() {
            Ok(Down::Ctl(c)) => Ok(*c),
            Ok(Down::Gone(reason)) => Err(TransportError::Closed(reason)),
            Err(_) => Err(TransportError::Closed(
                "host pump terminated".to_string(),
            )),
        }
    }

    fn send_report(&mut self, msg: Report) -> Result<(), TransportError> {
        self.up_tx
            .send(Up::Report {
                shard: self.shard,
                msg,
            })
            .map_err(|_| TransportError::Closed("host pump terminated".to_string()))
    }

    fn send_peer(&mut self, peer: usize, msg: ShardMsg) -> Result<(), TransportError> {
        if self.layout.is_inter_host(self.shard, peer) {
            self.up_tx
                .send(Up::Remote { to: peer, msg })
                .map_err(|_| TransportError::Closed("host pump terminated".to_string()))
        } else {
            let local = peer - self.layout.host_range(self.layout.host_of(peer)).start;
            self.sibling_tx[local]
                .send(PeerIn::Msg(msg))
                .map_err(|_| TransportError::Closed(format!("sibling shard {peer} exited")))
        }
    }

    fn recv_peer(&mut self, wait: Duration) -> Result<ShardMsg, TransportError> {
        if let Some(got) = self.replay.pop_front() {
            return self.peer_event(got);
        }
        match self.peer_rx.recv_timeout(wait) {
            Ok(got) => self.peer_event(got),
            Err(RecvTimeoutError::Timeout) => Err(TransportError::Timeout),
            Err(RecvTimeoutError::Disconnected) => Err(TransportError::Closed(
                "host pump terminated".to_string(),
            )),
        }
    }

    fn remesh_peer(&mut self, shard: usize, _addr: &str) -> Result<(), TransportError> {
        // a reassigned-away shard's host link may have queued loss
        // markers; purge them so an idle survivor does not trip over a
        // stale `Gone` in its next epoch (tiered recovery is
        // reassign-only, so the address is always empty)
        let lost = self.layout.host_of(shard);
        self.replay
            .retain(|e| !matches!(e, PeerIn::Gone { host, .. } if *host == lost));
        while let Ok(got) = self.peer_rx.try_recv() {
            if matches!(&got, PeerIn::Gone { host, .. } if *host == lost) {
                continue;
            }
            self.replay.push_back(got);
        }
        Ok(())
    }
}

/// Serve one two-tier host process: build the host mesh, spawn the
/// in-process shard workers (each pinned to its own core when `pin`),
/// and pump frames between the sockets and the workers until the
/// cluster shuts down.  Entered from `tcp::serve` when the leader's
/// init frame turns out to be a [`HostInit`].
pub(crate) fn serve_host(
    leader: TcpStream,
    mesh_listener: TcpListener,
    hi: HostInit,
    fault_exit: Option<usize>,
    pin: bool,
) -> Result<()> {
    let HostInit {
        host,
        hosts,
        shards_per_host,
        algo,
        shards,
        host_peers,
        token: _,
    } = hi;
    if hosts == 0
        || shards_per_host == 0
        || host >= hosts
        || host_peers.len() != hosts
        || shards.len() != shards_per_host
    {
        return Err(anyhow!(
            "handshake: inconsistent HostInit (host {host} of {hosts}, \
             {shards_per_host} shards per host, {} slices, {} peers)",
            shards.len(),
            host_peers.len()
        ));
    }
    let layout = TierLayout::new(hosts, shards_per_host);
    let algo = PairAlgorithm::parse(&algo)
        .with_context(|| format!("leader sent unknown algorithm '{algo}'"))?;
    // host mesh: dial every lower host, accept every higher one, so
    // each unordered host pair shares exactly one socket (`PeerHello`
    // carries the host index on this tier)
    let mut mesh: Vec<Option<TcpStream>> = (0..hosts).map(|_| None).collect();
    for (h, addr) in host_peers.iter().enumerate().take(host) {
        let mut stream = connect_with_retry(addr, DEFAULT_CONNECT_RETRIES)
            .with_context(|| format!("dialing peer host {h} at {addr}"))?;
        write_frame(&mut stream, &WireMsg::PeerHello { shard: host })
            .with_context(|| format!("greeting peer host {h}"))?;
        mesh[h] = Some(stream);
    }
    for _ in host + 1..hosts {
        let mut stream =
            accept_with_deadline(&mesh_listener, HANDSHAKE_TIMEOUT, "a host-mesh connection")?;
        match read_frame_timed(&mut stream, "PeerHello")? {
            WireMsg::PeerHello { shard: h } if h < hosts && h > host && mesh[h].is_none() => {
                mesh[h] = Some(stream);
            }
            WireMsg::PeerHello { shard: h } => {
                return Err(anyhow!("host mesh: unexpected PeerHello from host {h}"))
            }
            other => return Err(anyhow!("host mesh: expected PeerHello, got {other:?}")),
        }
    }
    // channel fabric: per worker one control lane (pump -> worker), one
    // peer lane (pump or sibling -> worker); one shared up lane
    // (workers -> pump)
    let (up_tx, up_rx) = channel::<Up>();
    let mut down_tx = Vec::with_capacity(shards_per_host);
    let mut down_rx = Vec::with_capacity(shards_per_host);
    let mut peer_tx = Vec::with_capacity(shards_per_host);
    let mut peer_rx = Vec::with_capacity(shards_per_host);
    for _ in 0..shards_per_host {
        let (dt, dr) = channel::<Down>();
        down_tx.push(dt);
        down_rx.push(dr);
        let (pt, pr) = channel::<PeerIn>();
        peer_tx.push(pt);
        peer_rx.push(pr);
    }
    let base = layout.host_range(host).start;
    eprintln!(
        "cluster-worker: host {host}/{hosts} serving shards {base}..{} \
         ({shards_per_host} in-process)",
        base + shards_per_host
    );
    let mut handles = Vec::with_capacity(shards_per_host);
    for (i, ((lo, nodes), (dr, pr))) in shards
        .into_iter()
        .zip(down_rx.into_iter().zip(peer_rx))
        .enumerate()
    {
        let transport = TieredWorkerTransport {
            shard: base + i,
            layout,
            down_rx: dr,
            up_tx: up_tx.clone(),
            peer_rx: pr,
            sibling_tx: peer_tx.clone(),
            replay: VecDeque::new(),
        };
        let mut worker = ShardWorker::new(Box::new(transport));
        worker.install_job(0, lo, nodes, algo);
        if let Some(round) = fault_exit {
            worker.set_fault_exit(round);
        }
        handles.push(
            std::thread::Builder::new()
                .name(format!("shard-{}", base + i))
                .spawn(move || {
                    if pin && !affinity::pin_current_thread(i) {
                        eprintln!(
                            "cluster-worker: could not pin shard {} to cpu {i}, running unpinned",
                            base + i
                        );
                    }
                    worker.run()
                })
                .context("spawning a shard worker thread")?,
        );
    }
    // the pump must observe worker exits as channel disconnects, so it
    // keeps no spare sender
    drop(up_tx);
    pump(
        leader, mesh, layout, host, up_rx, &down_tx, &peer_tx,
    )?;
    drop(down_tx);
    drop(peer_tx);
    let mut first_err = None;
    for (i, h) in handles.into_iter().enumerate() {
        match h.join() {
            Ok(Ok(())) => {}
            Ok(Err(e)) => {
                first_err.get_or_insert(format!("shard {} terminated abnormally: {e}", base + i));
            }
            Err(p) => {
                let msg = crate::coordinator::worker::panic_message(p.as_ref());
                first_err.get_or_insert(format!("shard {} panicked: {msg}", base + i));
            }
        }
    }
    match first_err {
        None => Ok(()),
        Some(msg) => Err(anyhow!("cluster-worker host {host}: {msg}")),
    }
}

/// The host's egress/ingress pump: one poller over the leader link and
/// the host mesh, one drain of the workers' shared up-channel per pass.
/// Returns once every worker has exited (their senders disconnect) and
/// all buffered socket writes are flushed or abandoned.
fn pump(
    leader: TcpStream,
    mesh: Vec<Option<TcpStream>>,
    layout: TierLayout,
    host: usize,
    up_rx: Receiver<Up>,
    down_tx: &[Sender<Down>],
    peer_tx: &[Sender<PeerIn>],
) -> Result<()> {
    let base = layout.host_range(host).start;
    let mut poller = Poller::new();
    let leader_tok = poller
        .add_frame_conn(leader)
        .context("registering the leader socket")?;
    let mut host_toks: Vec<Option<usize>> = vec![None; mesh.len()];
    for (h, slot) in mesh.into_iter().enumerate() {
        if let Some(stream) = slot {
            host_toks[h] = Some(
                poller
                    .add_frame_conn(stream)
                    .context("registering a host-mesh socket")?,
            );
        }
    }
    let mut events: VecDeque<Event> = VecDeque::new();
    let mut workers_done = false;
    while !workers_done {
        // outbound: everything the workers queued since the last pass
        let mut moved = false;
        loop {
            match up_rx.try_recv() {
                Ok(Up::Report { shard, msg }) => {
                    moved = true;
                    let _ = poller.send(
                        leader_tok,
                        &WireMsg::Mux {
                            shard,
                            inner: Box::new(WireMsg::Report(msg)),
                        },
                    );
                }
                Ok(Up::Remote { to, msg }) => {
                    moved = true;
                    debug_assert_ne!(layout.host_of(to), host, "remote send to own host");
                    // a send toward a dead host is dropped: the loss
                    // marker already en route to the worker ends its
                    // round with the proper error
                    if let Some(tok) = host_toks[layout.host_of(to)] {
                        let _ = poller.send(
                            tok,
                            &WireMsg::Mux {
                                shard: to,
                                inner: Box::new(WireMsg::Peer(msg)),
                            },
                        );
                    }
                }
                Err(TryRecvError::Empty) => break,
                Err(TryRecvError::Disconnected) => {
                    workers_done = true;
                    break;
                }
            }
        }
        if workers_done {
            break;
        }
        // inbound: drain the sockets (zero wait when the outbound pass
        // moved traffic, so a ready reply never waits out the idle nap)
        let wait = if moved { Duration::ZERO } else { PUMP_IDLE_WAIT };
        poller.poll(wait, &mut events);
        while let Some(ev) = events.pop_front() {
            route_event(
                ev, leader_tok, &host_toks, layout, base, down_tx, peer_tx,
            );
        }
    }
    // the workers' last reports (their `Final`s) may still sit in the
    // poller's write buffers: retry until flushed or plainly undeliverable
    let deadline = Instant::now() + PUMP_FLUSH_TIMEOUT;
    while Instant::now() < deadline {
        let pending = poller.pending_tx(leader_tok)
            + host_toks
                .iter()
                .flatten()
                .map(|&t| poller.pending_tx(t))
                .sum::<usize>();
        if pending == 0 || poller.is_closed(leader_tok) {
            break;
        }
        poller.poll(Duration::from_millis(5), &mut events);
        events.clear();
    }
    Ok(())
}

/// Route one poller event into the worker channels.  Send failures are
/// ignored: a worker that already exited has no further use for them.
fn route_event(
    ev: Event,
    leader_tok: usize,
    host_toks: &[Option<usize>],
    layout: TierLayout,
    base: usize,
    down_tx: &[Sender<Down>],
    peer_tx: &[Sender<PeerIn>],
) {
    let host_of_token =
        |token: usize| host_toks.iter().position(|&t| t == Some(token));
    match ev {
        Event::Frame { token, msg } if token == leader_tok => match msg {
            WireMsg::Mux { shard, inner } => {
                let Some(local) = shard.checked_sub(base).filter(|&l| l < down_tx.len())
                else {
                    return;
                };
                match *inner {
                    WireMsg::Ctl(ctl) => {
                        let _ = down_tx[local].send(Down::Ctl(Box::new(ctl)));
                    }
                    other => {
                        let reason =
                            format!("protocol violation: unexpected frame from leader {other:?}");
                        for tx in down_tx {
                            let _ = tx.send(Down::Gone(reason.clone()));
                        }
                    }
                }
            }
            other => {
                let reason = format!("protocol violation: unwrapped frame from leader {other:?}");
                for tx in down_tx {
                    let _ = tx.send(Down::Gone(reason.clone()));
                }
            }
        },
        Event::Frame { token, msg } => {
            let Some(h) = host_of_token(token) else {
                return;
            };
            match msg {
                WireMsg::Mux { shard, inner } => {
                    let Some(local) = shard.checked_sub(base).filter(|&l| l < peer_tx.len())
                    else {
                        return;
                    };
                    match *inner {
                        WireMsg::Peer(m) => {
                            let _ = peer_tx[local].send(PeerIn::Msg(m));
                        }
                        other => {
                            let reason =
                                format!("protocol violation: unexpected frame {other:?}");
                            for tx in peer_tx {
                                let _ = tx.send(PeerIn::Gone {
                                    host: h,
                                    reason: reason.clone(),
                                });
                            }
                        }
                    }
                }
                other => {
                    let reason = format!("protocol violation: unwrapped frame {other:?}");
                    for tx in peer_tx {
                        let _ = tx.send(PeerIn::Gone {
                            host: h,
                            reason: reason.clone(),
                        });
                    }
                }
            }
        }
        Event::Closed { token, reason } => {
            if token == leader_tok {
                let reason = format!("leader connection lost: {reason}");
                for tx in down_tx {
                    let _ = tx.send(Down::Gone(reason.clone()));
                }
            } else if let Some(h) = host_of_token(token) {
                for tx in peer_tx {
                    let _ = tx.send(PeerIn::Gone {
                        host: h,
                        reason: reason.clone(),
                    });
                }
            }
        }
        _ => {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::transport::local;

    #[test]
    fn counting_wrapper_charges_only_the_slow_tier() {
        // layout 2x2: shards {0,1} on host 0, {2,3} on host 1
        let layout = TierLayout::new(2, 2);
        let traffic = Arc::new(TierTraffic::default());
        let (_leader, mut workers) = local::pair(4);
        let w3 = workers.pop().unwrap();
        let w2 = workers.pop().unwrap();
        let mut w0 = CountingTieredWorker::new(
            workers.remove(0),
            layout,
            traffic.clone(),
        );
        let settle = |edge| ShardMsg::Settle {
            job: 0,
            round: 0,
            edge,
            loads: vec![],
        };
        // same host: no wire bytes
        w0.send_peer(1, settle(0)).unwrap();
        assert_eq!(traffic.snapshot(), (0, 0, 1));
        // cross host: exactly one Mux frame's bytes
        w0.send_peer(2, settle(1)).unwrap();
        let (bytes, inter, intra) = traffic.snapshot();
        assert_eq!((inter, intra), (1, 1));
        let expect = encode_frame(&WireMsg::Mux {
            shard: 2,
            inner: Box::new(WireMsg::Peer(settle(1))),
        })
        .len() as u64;
        assert_eq!(bytes, expect);
        // the payload itself still arrives untouched
        let mut w2 = w2;
        match w2.recv_peer(Duration::from_secs(1)).unwrap() {
            ShardMsg::Settle { edge: 1, .. } => {}
            other => panic!("wrong message routed: {other:?}"),
        }
        drop(w3);
    }

    #[test]
    fn tiered_worker_transport_purges_stale_host_loss_on_remesh() {
        let layout = TierLayout::new(2, 1);
        let (up_tx, _up_rx) = channel::<Up>();
        let (_down_tx, down_rx) = channel::<Down>();
        let (ptx, prx) = channel::<PeerIn>();
        let mut t = TieredWorkerTransport {
            shard: 0,
            layout,
            down_rx,
            up_tx,
            peer_rx: prx,
            sibling_tx: vec![ptx.clone()],
            replay: VecDeque::new(),
        };
        // host 1 died while this worker idled between epochs...
        ptx.send(PeerIn::Gone {
            host: 1,
            reason: "reset".into(),
        })
        .unwrap();
        // ...and a live message is queued behind the stale marker
        ptx.send(PeerIn::Msg(ShardMsg::Settle {
            job: 0,
            round: 7,
            edge: 3,
            loads: vec![],
        }))
        .unwrap();
        // the demesh order for shard 1 (host 1) purges the marker only
        t.remesh_peer(1, "").unwrap();
        match t.recv_peer(Duration::from_millis(50)).unwrap() {
            ShardMsg::Settle { round: 7, .. } => {}
            other => panic!("expected the queued settle, got {other:?}"),
        }
    }
}
